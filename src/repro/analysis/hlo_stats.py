"""Optimized-HLO static analyzer: FLOPs / HBM bytes / collective bytes with
correct while-loop trip-count multiplication.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a while
body ONCE, so scanned-layer models (all of ours — stages are lax.scan'd)
under-count flops/bytes by ~n_layers. This module parses the post-SPMD
optimized HLO text into its computation graph and evaluates

    total(entry),  where  while -> trip_count x (body + cond)
                          fusion/call/to_apply -> callee (flops only;
                          bytes count at the call site: operands + result,
                          matching HloCostAnalysis fusion semantics)

FLOPs counted for dot ops (2 * prod(result_dims) * contraction), the only
material compute in these models; elementwise flops are ignored (sub-1%).
Collective operand bytes are derived from result shape and replica-group
size per kind (operands are printed without types in this dialect).
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")

# Ops whose bytes we count (TPU-fusion-adjusted semantics): matmuls,
# fusions, slices/cache-updates (aliased: only the moved window counts),
# collectives and opaque calls. Everything elementwise / layout-only is
# treated as fused away (XLA:TPU does; XLA:CPU leaves them unfused, which
# would inflate the memory term ~40x — see DESIGN.md §7).
_BYTE_OPS = {"dot", "fusion", "custom-call", "reduce", "reduce-window",
             "convolution", "scatter", "gather", "sort", "cholesky",
             "triangular-solve"}
_WINDOW_OPS = {"dynamic-update-slice": 1, "dynamic-slice": -1,
               "slice": -1, "pad": -1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1),
             [int(d) for d in m.group(2).split(",")] if m.group(2) else [])
            for m in _SHAPE_RE.finditer(type_str)]


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    attrs: str
    raw: str = ""


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symbols: Dict[str, List[Tuple[str, List[int]]]]


def _split_args(arg_str: str) -> Tuple[List[str], str]:
    """Split the call-paren contents into operand names + trailing attrs."""
    depth = 0
    end = None
    for i, ch in enumerate(arg_str):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                end = i
                break
            depth -= 1
    if end is None:
        end = len(arg_str)
    inner, attrs = arg_str[:end], arg_str[end + 1:]
    names = re.findall(r"%([\w.\-]+)", inner)
    return names, attrs


def parse_module(hlo_text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{?\s*$", line)
            if line.endswith("{") and ("(" in line):
                m2 = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                if m2:
                    cur = _Computation(m2.group(2), [], {})
                    if m2.group(1):
                        entry = m2.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        operands, attrs = _split_args(rest)
        op = _Op(name, kind, _parse_shapes(type_str), operands, attrs, rest)
        cur.ops.append(op)
        cur.symbols[name] = op.result_shapes
    if cur is not None:
        comps[cur.name] = cur
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _group_size(attrs: str, default: int = 1) -> int:
    # replica_groups=[2,4]<=[8]  -> groups of 4;   {{0,1},{2,3}} -> 2
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    n_collectives: int = 0

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.coll_bytes += mult * other.coll_bytes
        self.n_collectives += int(mult * other.n_collectives)
        for k in COLLECTIVES:
            self.coll_by_kind[k] += mult * other.coll_by_kind[k]


def _called(attrs: str, key: str) -> List[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    if m:
        return [m.group(1)]
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if m:
        return re.findall(r"%?([\w.\-]+)", m.group(1))
    return []


def trip_count(cond: _Computation) -> int:
    """Loop bound from the condition's compare-with-constant. The compare is
    often fusion-wrapped (kLoop '%wrapped_compare'), so accept a constant
    operand of the root compare OR of a root fusion; fall back to the max
    positive constant in the (tiny) condition computation."""
    consts = {}
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"\s*(-?\d+)\s*\)", op.raw)
            if m:
                consts[op.name] = int(m.group(1))
    best = 0
    for op in cond.ops:
        if op.kind in ("compare", "fusion"):
            for o in op.operands:
                if o in consts and consts[o] > best:
                    best = consts[o]
    if best == 0 and consts:
        best = max(v for v in consts.values())
    return max(best, 1)


def analyze(hlo_text: str) -> HloStats:
    comps, entry = parse_module(hlo_text)
    memo: Dict[str, HloStats] = {}
    # computations reached via fusion calls: bytes are call-site-only
    fused: set = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                for callee in _called(op.attrs, "calls"):
                    fused.add(callee)

    def flops_only(cname: str, seen=()) -> float:
        """dot flops inside fused computations (rare on CPU, cheap to cover)."""
        c = comps.get(cname)
        if c is None or cname in seen:
            return 0.0
        total = 0.0
        for op in c.ops:
            if op.kind == "dot":
                total += _dot_flops(c, op)
            for key in ("calls", "to_apply", "body"):
                for callee in _called(op.attrs, key):
                    total += flops_only(callee, (*seen, cname))
        return total

    def _dot_flops(c: _Computation, op: _Op) -> float:
        res_elems = 0
        for dt, dims in op.result_shapes:
            n = 1
            for d in dims:
                n *= d
            res_elems += n
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        contraction = 1
        if m and op.operands:
            lhs = c.symbols.get(op.operands[0])
            if lhs:
                dims = lhs[0][1]
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        contraction *= dims[int(idx)]
        return 2.0 * res_elems * contraction

    def visit(cname: str, stack=()) -> HloStats:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return HloStats()
        c = comps[cname]
        st = HloStats()
        for op in c.ops:
            if op.kind == "dot":
                st.flops += _dot_flops(c, op)
            if op.kind in COLLECTIVES or any(
                    op.kind == k + s for k in COLLECTIVES
                    for s in ("-start", "-done")):
                base = next((k for k in COLLECTIVES if op.kind.startswith(k)),
                            None)
                if base and not op.kind.endswith("-done"):
                    rb = _shape_bytes(op.result_shapes)
                    g = _group_size(op.attrs)
                    if base == "all-gather":
                        b = rb / max(g, 1)
                    elif base == "reduce-scatter":
                        b = rb * g
                    else:
                        b = rb
                    st.coll_bytes += b
                    st.coll_by_kind[base] += b
                    st.n_collectives += 1
            # HBM bytes (TPU-fusion-adjusted, see _BYTE_OPS note)
            if op.kind in _BYTE_OPS:
                b = _shape_bytes(op.result_shapes)
                for o in op.operands:
                    sh = c.symbols.get(o)
                    if sh:
                        b += _shape_bytes(sh)
                st.hbm_bytes += b
            elif op.kind in _WINDOW_OPS:
                # aliased window move: read+write of the moved window only
                if op.kind == "dynamic-update-slice" and len(op.operands) > 1:
                    upd = c.symbols.get(op.operands[1])
                    st.hbm_bytes += 2 * _shape_bytes(upd) if upd else 0
                else:
                    st.hbm_bytes += 2 * _shape_bytes(op.result_shapes)
            elif op.kind in COLLECTIVES or any(
                    op.kind == k + sfx for k in COLLECTIVES
                    for sfx in ("-start",)):
                st.hbm_bytes += 2 * _shape_bytes(op.result_shapes)
            # control flow
            if op.kind == "while":
                bodies = _called(op.attrs, "body")
                conds = _called(op.attrs, "condition")
                trips = trip_count(comps[conds[0]]) if conds and \
                    conds[0] in comps else 1
                for bname in bodies:
                    st.add(visit(bname, (*stack, cname)), mult=trips)
                for cn in conds:
                    st.add(visit(cn, (*stack, cname)), mult=trips)
            elif op.kind == "fusion":
                for callee in _called(op.attrs, "calls"):
                    st.flops += flops_only(callee)
            elif op.kind in ("call", "async-start"):
                for callee in _called(op.attrs, "to_apply"):
                    st.add(visit(callee, (*stack, cname)))
            elif op.kind == "conditional":
                branches = _called(op.attrs, "branch_computations")
                if branches:
                    sub = [visit(b, (*stack, cname)) for b in branches]
                    worst = max(sub, key=lambda s: s.flops + s.hbm_bytes)
                    st.add(worst)
        memo[cname] = st
        return st

    return visit(entry)
