"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md §7):
  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_operand_bytes / (chips * ICI_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
i.e. already per-partition after SPMD; we multiply back to global where
noted). Collective bytes are parsed from the post-SPMD optimized HLO text —
the sum of operand sizes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Collective operand bytes per kind (while-trip aware, via hlo_stats)."""
    from repro.analysis import hlo_stats
    st = hlo_stats.analyze(hlo_text)
    out = {k: int(v) for k, v in st.coll_by_kind.items()}
    out["total"] = int(st.coll_bytes)
    out["count"] = st.n_collectives
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(cost_analysis: Dict[str, float], hlo_text: str,
                   chips: int, model_flops: Optional[float] = None,
                   ) -> Roofline:
    """Terms from the static HLO analysis (hlo_stats — while-trip aware;
    XLA's own cost_analysis counts loop bodies once and is kept only as a
    recorded diagnostic). model_flops is the GLOBAL 6ND-style count;
    useful_ratio = model_flops / (flops * chips)."""
    from repro.analysis import hlo_stats
    st = hlo_stats.analyze(hlo_text)
    flops = st.flops
    hbm = st.hbm_bytes
    coll = st.coll_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops / (flops * chips)
              if model_flops and flops else None)
    return Roofline(flops, hbm, coll, chips, compute_s, memory_s,
                    collective_s, dominant, model_flops, useful)


# ------------------------------------------------------- MODEL_FLOPS (6ND)
def model_flops(cfg, shape_kind: str, batch: int, seq: int,
                params_total: int, params_active: int) -> float:
    """6*N*D for train, 2*N*D per generated token for decode/prefill-style
    forward (D = tokens processed)."""
    n = params_active
    tokens = batch * (1 if shape_kind == "decode" else seq)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens


def count_params(struct_tree) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(struct_tree)))


def active_params(cfg, total: int) -> int:
    """MoE: discount inactive experts (top_k of n_experts active)."""
    if not cfg.n_experts:
        return total
    import numpy as np
    # expert params per layer (gate+up+down)
    moe_layers = sum(s.unit.count("moe") + s.unit.count("mla_moe")
                     for s in cfg.stages for _ in range(1)) or 0
    moe_layers = sum((s.unit.count("moe") + s.unit.count("mla_moe"))
                     * s.repeats for s in cfg.stages)
    per_expert = 3 * cfg.d_model * cfg.expert_d_ff
    inactive = moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - int(inactive)
