"""GPipe-style pipeline parallelism over a mesh axis (optional alternative
to pure DP on the 'pod' axis; exercised by tests + a dry-run variant).

Stage parameters are stacked on a leading axis sharded over ``axis``; each
device executes its own stage and microbatch activations hop stage→stage
with ``jax.lax.ppermute``. The schedule is the classic GPipe loop: with S
stages and M microbatches, the pipe runs S+M-1 ticks; device s computes on
ticks s .. s+M-1 (bubble fraction (S-1)/(S+M-1)).

This wrapper is forward-only-composable (wrap it in jax.grad for training:
XLA differentiates through ppermute). For production schedules (1F1B,
interleaved), the tick loop is the extension point.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh,
                   axis: str = "pod", microbatches: int = None):
    """Run ``stage_fn(params_s, x) -> x`` through S pipeline stages.

    stage_params: pytree stacked on a leading S axis (sharded over ``axis``).
    x: (B, ...) global batch; split into ``microbatches`` (default = S).
    Returns the pipeline output with the same sharding as x.
    """
    S = mesh.shape[axis]
    M = microbatches or S
    B = x.shape[0]
    assert B % M == 0, (B, M)
    mb = x.reshape(M, B // M, *x.shape[1:])

    def per_device(params_stacked, mb_local):
        # params_stacked: (1, ...) local stage slice; mb_local: full microbatches
        params_local = jax.tree.map(lambda a: a[0], params_stacked)
        s_idx = jax.lax.axis_index(axis)
        n_ticks = S + M - 1

        def tick(carry, t):
            buf, outs = carry            # buf: (B/M, ...) current activation
            # stage 0 ingests microbatch t (when valid), others use buf
            feed = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(s_idx == 0, mb_local[feed], buf)
            active = (t >= s_idx) & (t - s_idx < M)
            y = stage_fn(params_local, x_in)
            y = jnp.where(active, y, buf)
            # pass activations down the pipe: s -> s+1
            y_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(S - 1)])
            # last stage emits microbatch (t - (S-1))
            out_slot = jnp.clip(t - (S - 1), 0, M - 1)
            emit = (s_idx == S - 1) & (t >= S - 1)
            outs = jnp.where(
                emit,
                outs.at[out_slot].set(y),
                outs)
            return (y_next, outs), None

        buf0 = jnp.zeros_like(mb_local[0])
        outs0 = jnp.zeros_like(mb_local)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # broadcast final outputs from the last stage to all (psum of masked)
        outs = jax.lax.psum(
            jnp.where(s_idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    out = ctx.shard_map(
        per_device, mesh=mesh,
        in_specs=(spec_params, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, mb)
    return out.reshape(B, *x.shape[1:])
