"""Trace-time distributed context.

Model code stays mesh-agnostic; launch code activates a mesh here (inside
`jax.set_mesh`) and the few distribution-aware ops consult it:
  * ops.decode_attention -> seq-sharded flash-decoding (LSE psum combine)
  * transformer residual-stream SP constraints (Megatron sequence parallel)
"""

from __future__ import annotations

import contextlib
import inspect
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_MESH = None


def mesh_context(mesh):
    """``jax.set_mesh`` on new jax; on older versions the Mesh object itself
    is the (legacy global-mesh) context manager with the same effect."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` on new jax, the experimental module on older jax.
    The replication-check kwarg is picked from the target's signature
    (``check_rep`` was renamed ``check_vma`` independently of the function's
    promotion out of jax.experimental)."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    kw = {}
    if check_vma is not None:
        params = inspect.signature(sm).parameters
        kw = {"check_vma" if "check_vma" in params else "check_rep":
              check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


@contextlib.contextmanager
def activate(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh_context(mesh):
            yield mesh
    finally:
        _MESH = prev


def mesh():
    return _MESH


def dp_axes() -> Optional[Tuple[str, ...]]:
    if _MESH is None:
        return None
    return tuple(a for a in _MESH.axis_names if a in ("pod", "data"))


def model_axis_size() -> int:
    if _MESH is None or "model" not in _MESH.axis_names:
        return 1
    return _MESH.shape["model"]


def constrain(x, spec: P):
    """with_sharding_constraint iff a mesh is active."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_sp(x):
    """Sequence-parallel residual constraint: (B, S, d) -> shard S over
    'model' (and B over DP). No-op off-mesh or when S doesn't divide."""
    if _MESH is None:
        return x
    tp = model_axis_size()
    dp = dp_axes()
    if x.ndim != 3 or tp <= 1 or x.shape[1] % tp != 0:
        return x
    dps = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, P(dps, "model", None))
