"""Trace-time distributed context.

Model code stays mesh-agnostic; launch code activates a mesh here (inside
`jax.set_mesh`) and the few distribution-aware ops consult it:
  * ops.decode_attention -> seq-sharded flash-decoding (LSE psum combine)
  * transformer residual-stream SP constraints (Megatron sequence parallel)
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# Version-drift shims live in repro.compat (the consolidated home);
# re-exported here because every distribution-aware call site already
# imports them as ctx.mesh_context / ctx.shard_map.
from repro.compat import mesh_context, shard_map  # noqa: F401

_MESH = None


@contextlib.contextmanager
def activate(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh_context(mesh):
            yield mesh
    finally:
        _MESH = prev


def mesh():
    return _MESH


def dp_axes() -> Optional[Tuple[str, ...]]:
    if _MESH is None:
        return None
    return tuple(a for a in _MESH.axis_names if a in ("pod", "data"))


def model_axis_size() -> int:
    if _MESH is None or "model" not in _MESH.axis_names:
        return 1
    return _MESH.shape["model"]


def constrain(x, spec: P):
    """with_sharding_constraint iff a mesh is active."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_sp(x):
    """Sequence-parallel residual constraint: (B, S, d) -> shard S over
    'model' (and B over DP). No-op off-mesh or when S doesn't divide."""
    if _MESH is None:
        return x
    tp = model_axis_size()
    dp = dp_axes()
    if x.ndim != 3 or tp <= 1 or x.shape[1] % tp != 0:
        return x
    dps = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, P(dps, "model", None))
