"""Sharding rules: parameter PartitionSpec trees + batch/cache specs.

Megatron-style TP on the 'model' axis, DP over ('pod','data'):
  * embed / lm_head           vocab-sharded
  * wq, mlp up/gate           column-parallel (output dim)
  * wo, mlp down              row-parallel (input dim)
  * wk/wv                     head-sharded when kv_heads % tp == 0, else
                              replicated (GQA-standard)
  * MoE experts_*             expert-parallel (leading E axis)
  * mamba in_z/in_x/conv_x/out_proj  head-channel-sharded; in_bc/in_dt tiny,
                              replicated; per-head vectors (A_log, D, dt_bias)
                              sharded over heads
Leaf specs are matched by parameter name; stacked (scanned) parameters get
leading None axes padded automatically by rank.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _rules(cfg: ModelConfig, tp: int) -> Dict[str, P]:
    kv_shardable = cfg.n_kv_heads > 0 and (
        cfg.n_kv_heads % tp == 0 or cfg.n_kv_heads == cfg.n_heads)
    kv = P(None, "model") if kv_shardable else P(None, None)
    kv_b = P("model") if kv_shardable else P(None)
    h_shardable = cfg.mamba_heads % tp == 0 if cfg.ssm_state else False
    hvec = P("model") if h_shardable else P(None)
    return {
        # embedding / head
        "embed": P("model", None),
        "lm_head": P(None, "model"),
        "final_norm": P(None),
        # attention
        "wq": P(None, "model"), "bq": P("model"),
        "wk": kv, "bk": kv_b, "wv": kv, "bv": kv_b,
        "wo": P("model", None),
        "q_norm": P(None), "k_norm": P(None),
        # MLA
        "w_dkv": P(None, None), "kv_norm": P(None),
        "w_uk": P(None, "model"), "w_uv": P(None, "model"),
        # MLP
        "gate": P(None, "model"), "up": P(None, "model"),
        "down": P("model", None),
        # MoE
        "router": P(None, None),
        "experts_gate": P("model", None, None),
        "experts_up": P("model", None, None),
        "experts_down": P("model", None, None),
        # norms
        "ln1": P(None), "ln2": P(None), "lnc": P(None),
        "post_ln1": P(None), "post_ln2": P(None),
        # mamba2
        "in_z": P(None, "model"), "in_x": P(None, "model"),
        "in_bc": P(None, None),
        "in_dt": P(None, "model") if h_shardable else P(None, None),
        "conv_x_w": P(None, "model"), "conv_x_b": P("model"),
        "conv_bc_w": P(None, None), "conv_bc_b": P(None),
        "A_log": hvec, "D": hvec, "dt_bias": hvec,
        "gate_norm": P("model"),
        "out_proj": P("model", None),
    }


def param_specs(params_tree, cfg: ModelConfig, tp: int):
    """PartitionSpec pytree matching ``params_tree`` (arrays or
    ShapeDtypeStructs)."""
    rules = _rules(cfg, tp)

    def spec_for(path, leaf) -> P:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        base = rules.get(name, P())
        pad = leaf.ndim - len(base)
        assert pad >= 0, (name, leaf.ndim, base)
        return P(*([None] * pad), *base)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def _dp(mesh, batch: Optional[int] = None):
    """DP spec component; degrades to replication when the global batch
    doesn't divide the DP axes (e.g. long_500k's batch=1)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    if batch is not None and batch % total != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_specs(cfg: ModelConfig, mesh, kind: str = "train",
                batch: Optional[int] = None):
    """Input shardings. Batch over ('pod','data'); seq/model unsharded for
    token inputs (TP shards activations internally)."""
    dp = _dp(mesh, batch)
    spec = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.cross_context:
        spec["context"] = P(dp, None, None)
    if cfg.encoder_stages is not None:
        spec["frames"] = P(dp, None, None)
    return spec


def cache_specs(cfg: ModelConfig, mesh, batch: Optional[int] = None):
    """KV caches: batch over DP axes, sequence over 'model' (SP decode);
    mamba states: batch over DP, heads/channels over 'model'."""
    dp = _dp(mesh, batch)
    kv = P(None, dp, "model", None, None)       # (rep, B, S, hkv, hd)
    mla = P(None, dp, "model", None)            # (rep, B, S, r+rope)
    h_shardable = cfg.ssm_state and cfg.mamba_heads % mesh.shape["model"] == 0
    conv = P(None, dp, None, "model")           # (rep, B, W-1, C)
    ssm = P(None, dp, "model" if h_shardable else None, None, None)
    specs = []
    for s in cfg.stages:
        unit = []
        for kind in s.unit:
            if kind in ("attn", "attn_local", "moe", "decoder", "shared_attn"):
                unit.append((kv, kv))
            elif kind in ("mla_dense", "mla_moe"):
                unit.append(mla)
            elif kind == "mamba":
                unit.append((conv, P(None, dp, None, None), ssm))
            else:
                unit.append(None)
        specs.append(tuple(unit))
    return tuple(specs)


def to_named(tree, mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def constrain(x, *spec):
    """with_sharding_constraint that no-ops when no mesh is active."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def zero1_specs(spec_tree, struct_tree, dp_axis: str = "data",
                dp_size: int = 16):
    """ZeRO-1: optimizer-state specs = param TP specs + the first unsharded
    divisible dim additionally sharded over the data axis. Keeps fp32
    master/m/v within HBM for the 90B-class archs (DESIGN §6)."""

    def f(spec, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (e, d) in enumerate(zip(entries, leaf.shape)):
            if e is None and d % dp_size == 0 and d >= dp_size:
                entries[i] = dp_axis
                break
        return P(*entries)

    return jax.tree.map(f, spec_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, P))
