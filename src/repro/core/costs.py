"""Cloud storage cost model — parameters and cost algebra from the paper.

All monetary quantities are in **cents**. Sizes are in **GB**. Times in seconds.
Defaults reproduce Table I / Table XII (Azure ADLS Gen2) of
*Towards Optimizing Storage Costs on the Cloud* (2023).

The model is deliberately provider-agnostic: a :class:`CostTable` is just a set
of per-tier vectors, so AWS/GCP tables can be dropped in (paper §III footnote 2).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Tier indices follow the paper's convention: 0 = lowest latency (Premium),
# L-1 = archival (highest latency).
PREMIUM, HOT, COOL, ARCHIVE = 0, 1, 2, 3
TIER_NAMES = ("premium", "hot", "cool", "archive")


@dataclasses.dataclass(frozen=True)
class CostTable:
    """Per-tier cost/latency parameters (vectors of length L).

    Attributes
    ----------
    storage_cents_gb_month : C^s_l — storage cost, cents per GB per month.
    read_cents_gb          : C^r_l — read (egress+ops) cost, cents per GB.
    write_cents_gb         : C^w_l — write cost, cents per GB (= Delta_{-1,l}).
    ttfb_seconds           : B_l   — read latency (time to first byte), seconds.
    capacity_gb            : S_l   — reserved capacity (np.inf = unbounded).
    early_delete_months    : minimum residency before a free move-out.
    compute_cents_sec      : C^c   — compute cost, cents per second (scalar).
    """

    storage_cents_gb_month: np.ndarray
    read_cents_gb: np.ndarray
    write_cents_gb: np.ndarray
    ttfb_seconds: np.ndarray
    capacity_gb: np.ndarray
    early_delete_months: np.ndarray
    compute_cents_sec: float = 0.001
    names: Sequence[str] = TIER_NAMES

    @property
    def num_tiers(self) -> int:
        return int(self.storage_cents_gb_month.shape[0])

    def tier_change_cents_gb(self) -> np.ndarray:
        """Delta_{u,v} per GB: read from u + write to v. Shape (L+1, L).

        Row index L(P)=-1 (new data) is stored last: Delta[-1, v] = write-only.
        Diagonal (u == v) is zero — staying put is free.
        """
        L = self.num_tiers
        delta = self.read_cents_gb[:, None] + self.write_cents_gb[None, :]
        delta = delta * (1.0 - np.eye(L))
        new_row = self.write_cents_gb[None, :]  # ingestion: write cost only
        return np.concatenate([delta, new_row], axis=0)

    def with_capacity(self, capacity_gb: Sequence[float]) -> "CostTable":
        return dataclasses.replace(self, capacity_gb=np.asarray(capacity_gb, np.float64))


def azure_table() -> CostTable:
    """Azure ADLS Gen2 parameters (paper Tables I & XII).

    Read cost in Table XII is already normalized to cents/GB. Write costs are
    not printed in the paper; we derive them from Azure's published write-ops
    pricing at the same 4 MB-per-op granularity (documented assumption,
    DESIGN.md §8).
    """
    return CostTable(
        storage_cents_gb_month=np.array([15.0, 2.08, 1.52, 0.099]),
        read_cents_gb=np.array([0.004659, 0.01331, 0.0333, 16.64]),
        write_cents_gb=np.array([0.00923, 0.0333, 0.0666, 0.0666]),
        ttfb_seconds=np.array([0.0053, 0.0614, 0.0614, 3600.0]),
        capacity_gb=np.array([np.inf, np.inf, np.inf, np.inf]),
        early_delete_months=np.array([0.0, 0.0, 1.0, 6.0]),
        compute_cents_sec=0.001,
    )


def tpch_capacity_table(total_gb: float) -> CostTable:
    """Capacity-constrained variant used for TPC-H experiments (Table XII):
    Premium/Hot/Cool capacities in ratio 0.163 : 0.326 : 0.4891, Archive inf."""
    t = azure_table()
    frac = np.array([0.163, 0.326, 0.4891, np.inf])
    return t.with_capacity(frac * total_gb if np.isfinite(total_gb) else frac)


@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective hyper-parameters (paper §IV-A): alpha weights storage,
    beta weights access (read + decompression-compute), gamma weights
    tier-change cost."""

    alpha: float = 1.0
    beta: float = 1.0
    gamma: float = 1.0


def cost_tensor(
    spans_gb: np.ndarray,          # (N,)  Sp(P_n)
    accesses: np.ndarray,          # (N,)  rho(P_n) — projected # of reads
    current_tier: np.ndarray,      # (N,)  L(P_n) in {-1, 0..L-1}
    ratios: np.ndarray,            # (N,K) R_n^k   — compression ratios (>=1)
    decomp_sec: np.ndarray,        # (N,K) D_n^k   — decompression seconds (whole partition)
    table: CostTable,
    weights: Weights = Weights(),
    months: float = 1.0,
    pushdown_fraction: float = 0.0,
) -> np.ndarray:
    """Full OPTASSIGN objective tensor, shape (N, L, K).

    cost[n,l,k] = (alpha*C^s_l*months + gamma*Delta_{L(n),l}) * Sp_n / R_nk
                + beta * (1-f) * rho_n * (C^c * D_nk + C^r_l * Sp_n / R_nk)

    ``pushdown_fraction`` is the paper's `f`: queries answerable directly on
    compressed data contribute neither read nor decompression cost.
    """
    N = spans_gb.shape[0]
    L = table.num_tiers
    delta = table.tier_change_cents_gb()          # (L+1, L)
    move = delta[current_tier.astype(int)]        # (N, L) — cents/GB
    stored_gb = spans_gb[:, None] / ratios        # (N, K)
    eff_rho = (1.0 - pushdown_fraction) * accesses

    hold = (weights.alpha * table.storage_cents_gb_month[None, :] * months
            + weights.gamma * move)               # (N, L)
    storage_cost = hold[:, :, None] * stored_gb[:, None, :]          # (N,L,K)
    read_cost = (table.read_cents_gb[None, :, None]
                 * stored_gb[:, None, :])                             # (N,L,K)
    decomp_cost = (table.compute_cents_sec * decomp_sec)[:, None, :]  # (N,1,K)->(N,L,K)
    access_cost = weights.beta * eff_rho[:, None, None] * (decomp_cost + read_cost)
    return storage_cost + access_cost


def early_delete_penalty_gb(
    table: CostTable,
    current_tier: np.ndarray,      # (N,) in {-1, 0..L-1}; -1 = new data
    months_held: "float | np.ndarray" = 0.0,
) -> np.ndarray:
    """Per-GB charge if a partition leaves ``current_tier`` now, shape (N,).

    The prorated remainder of the tier's minimum-stay storage charge —
    mirrors ``TieredStore.change_tier`` / ``delete`` semantics. Zero for new
    data (tier -1) and for tiers without a minimum stay.
    """
    cur = np.asarray(current_tier, int)
    held = np.broadcast_to(np.asarray(months_held, np.float64), cur.shape)
    safe = np.maximum(cur, 0)
    due = np.maximum(0.0, table.early_delete_months[safe] - held)
    return np.where(cur >= 0, due * table.storage_cents_gb_month[safe], 0.0)


def latency_feasible(
    decomp_sec: np.ndarray,       # (N,K)
    latency_threshold: np.ndarray,  # (N,)
    table: CostTable,
) -> np.ndarray:
    """Latency constraint mask, shape (N, L, K): D_nk + B_l <= T_n."""
    total = decomp_sec[:, None, :] + table.ttfb_seconds[None, :, None]
    return total <= latency_threshold[:, None, None]
