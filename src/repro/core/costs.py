"""Cloud storage cost model — parameters and cost algebra from the paper.

All monetary quantities are in **cents**. Sizes are in **GB**. Times in seconds.
Defaults reproduce Table I / Table XII (Azure ADLS Gen2) of
*Towards Optimizing Storage Costs on the Cloud* (2023).

The model is deliberately provider-agnostic: a :class:`CostTable` is just a set
of per-tier vectors, so AWS/GCP tables can be dropped in (paper §III footnote 2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# Tier indices follow the paper's convention: 0 = lowest latency (Premium),
# L-1 = archival (highest latency).
PREMIUM, HOT, COOL, ARCHIVE = 0, 1, 2, 3
TIER_NAMES = ("premium", "hot", "cool", "archive")


@dataclasses.dataclass(frozen=True)
class CostTable:
    """Per-tier cost/latency parameters (vectors of length L).

    Attributes
    ----------
    storage_cents_gb_month : C^s_l — storage cost, cents per GB per month.
    read_cents_gb          : C^r_l — read (egress+ops) cost, cents per GB.
    write_cents_gb         : C^w_l — write cost, cents per GB (= Delta_{-1,l}).
    ttfb_seconds           : B_l   — read latency (time to first byte), seconds.
    capacity_gb            : S_l   — reserved capacity (np.inf = unbounded).
    early_delete_months    : minimum residency before a free move-out.
    compute_cents_sec      : C^c   — compute cost, cents per second (scalar).
    """

    storage_cents_gb_month: np.ndarray
    read_cents_gb: np.ndarray
    write_cents_gb: np.ndarray
    ttfb_seconds: np.ndarray
    capacity_gb: np.ndarray
    early_delete_months: np.ndarray
    compute_cents_sec: float = 0.001
    names: Sequence[str] = TIER_NAMES

    @property
    def num_tiers(self) -> int:
        return int(self.storage_cents_gb_month.shape[0])

    @property
    def retrieval_latency_ms(self) -> np.ndarray:
        """Per-tier retrieval latency in milliseconds, shape (L,).

        The same ``ttfb_seconds`` model viewed in SLA units: milliseconds
        for online tiers, hours-scale values for archive restore (e.g.
        Azure archive rehydration = 3.6e6 ms). This is the latency the
        soft-SLA penalty (:func:`sla_penalty_tensor`) prices, while
        ``latency_feasible`` keeps using seconds for the hard cutoff.
        """
        return self.ttfb_seconds * 1e3

    def tier_change_cents_gb(self) -> np.ndarray:
        """Delta_{u,v} per GB: read from u + write to v. Shape (L+1, L).

        Row index L(P)=-1 (new data) is stored last: Delta[-1, v] = write-only.
        Diagonal (u == v) is zero — staying put is free.
        """
        L = self.num_tiers
        delta = self.read_cents_gb[:, None] + self.write_cents_gb[None, :]
        delta = delta * (1.0 - np.eye(L))
        new_row = self.write_cents_gb[None, :]  # ingestion: write cost only
        return np.concatenate([delta, new_row], axis=0)

    def with_capacity(self, capacity_gb: Sequence[float]) -> "CostTable":
        return dataclasses.replace(self, capacity_gb=np.asarray(capacity_gb, np.float64))


def azure_table() -> CostTable:
    """Azure ADLS Gen2 parameters (paper Tables I & XII).

    Read cost in Table XII is already normalized to cents/GB. Write costs are
    not printed in the paper; we derive them from Azure's published write-ops
    pricing at the same 4 MB-per-op granularity (documented assumption,
    DESIGN.md §8).
    """
    return CostTable(
        storage_cents_gb_month=np.array([15.0, 2.08, 1.52, 0.099]),
        read_cents_gb=np.array([0.004659, 0.01331, 0.0333, 16.64]),
        write_cents_gb=np.array([0.00923, 0.0333, 0.0666, 0.0666]),
        ttfb_seconds=np.array([0.0053, 0.0614, 0.0614, 3600.0]),
        capacity_gb=np.array([np.inf, np.inf, np.inf, np.inf]),
        early_delete_months=np.array([0.0, 0.0, 1.0, 6.0]),
        compute_cents_sec=0.001,
    )


def tpch_capacity_table(total_gb: float) -> CostTable:
    """Capacity-constrained variant used for TPC-H experiments (Table XII):
    Premium/Hot/Cool capacities in ratio 0.163 : 0.326 : 0.4891, Archive inf."""
    t = azure_table()
    frac = np.array([0.163, 0.326, 0.4891, np.inf])
    return t.with_capacity(frac * total_gb if np.isfinite(total_gb) else frac)


# ------------------------------------------------------------- multi-cloud
@dataclasses.dataclass(frozen=True)
class ProviderCostTable:
    """One provider's tier lattice plus its outbound data-transfer rate.

    ``egress_out_cents_gb`` is the provider's internet/cross-cloud egress
    price — what the *source* provider bills when bytes leave it for another
    cloud. ``capacity_gb`` caps the provider's total footprint across all of
    its tiers (np.inf = unbounded); it becomes a group constraint row in the
    capacitated solver.

    ``region`` models one provider deployed in several regions: build one
    ``ProviderCostTable`` per region with the SAME ``provider`` name and
    distinct regions. Moves between two regions of one provider then
    default to the *reduced* intra-provider rate
    ``region_egress_out_cents_gb`` (inter-region transfer is far cheaper
    than internet egress) instead of the full cross-cloud rate; moves
    within one region stay free. With ``region=None`` (the default)
    nothing changes — single-region tables are bit-identical to before.
    """

    provider: str
    table: CostTable
    egress_out_cents_gb: float = 0.0
    capacity_gb: float = np.inf
    region: Optional[str] = None
    region_egress_out_cents_gb: float = 0.0


@dataclasses.dataclass(frozen=True)
class MultiCloudCostTable(CostTable):
    """A flattened ``(provider, tier)`` placement space.

    Concatenates P providers' tier vectors into one ``CostTable`` with
    ``L = sum(L_p)`` flat tiers, so every consumer of the single-cloud model
    (cost tensor, solvers, billing, store) works unchanged. The one semantic
    extension is :meth:`tier_change_cents_gb`: moves whose source and
    destination flat tiers belong to different providers additionally pay the
    source provider's egress — the **off-diagonal blocks** of the Delta
    matrix. With one provider and zero egress this class is bit-for-bit
    equivalent to its underlying :class:`CostTable`.

    Build instances with :func:`multi_cloud_table`, not directly.
    """

    provider_names: Tuple[str, ...] = ()
    provider_of_tier: Optional[np.ndarray] = None    # (L,) int
    egress_cents_gb: Optional[np.ndarray] = None     # (P,P), zero diagonal
    provider_capacity_gb: Optional[np.ndarray] = None  # (P,)
    provider_regions: Optional[Tuple] = None         # (P,) region or None

    @property
    def num_providers(self) -> int:
        return len(self.provider_names)

    def provider_tiers(self, p: int) -> np.ndarray:
        """Flat tier indices belonging to provider ``p``."""
        return np.where(self.provider_of_tier == p)[0]

    def tier_change_cents_gb(self) -> np.ndarray:
        """Block-structured Delta: within-provider blocks are read+write as in
        the base class; cross-provider blocks add ``egress[p(u), p(v)]``.
        The ingestion row (new data, L(P) = -1) never pays egress."""
        delta = super().tier_change_cents_gb()        # (L+1, L)
        L = self.num_tiers
        p = self.provider_of_tier
        delta[:L] += self.egress_cents_gb[p[:, None], p[None, :]]
        return delta


def multi_cloud_table(providers: Sequence[ProviderCostTable],
                      egress_cents_gb: Optional[np.ndarray] = None,
                      ) -> MultiCloudCostTable:
    """Flatten provider tier lattices into one ``(provider, tier)`` space.

    ``egress_cents_gb`` overrides the (P,P) egress matrix; by default row i
    is ``providers[i].egress_out_cents_gb`` everywhere off the diagonal
    (cross-cloud transfer is billed by the source as internet egress),
    except between two entries that carry the SAME provider name and
    distinct (non-None) ``region`` fields — those intra-provider
    cross-region lanes price the source's reduced
    ``region_egress_out_cents_gb`` instead. The diagonal is always forced
    to zero — moving within one (provider, region) pays no egress.
    ``compute_cents_sec`` is taken from the first provider (the paper's
    C^c is a property of where decompression runs, not of storage).
    """
    if not providers:
        raise ValueError("need at least one provider")
    P = len(providers)
    if egress_cents_gb is None:
        out = np.array([p.egress_out_cents_gb for p in providers])
        egress = np.repeat(out[:, None], P, axis=1)
        for i, pi in enumerate(providers):
            for j, pj in enumerate(providers):
                if (i != j and pi.provider == pj.provider
                        and pi.region is not None and pj.region is not None):
                    egress[i, j] = (pi.region_egress_out_cents_gb
                                    if pi.region != pj.region else 0.0)
    else:
        egress = np.array(egress_cents_gb, np.float64, copy=True)
        if egress.shape != (P, P):
            raise ValueError(f"egress matrix must be ({P},{P}), "
                             f"got {egress.shape}")
    np.fill_diagonal(egress, 0.0)
    tabs = [p.table for p in providers]
    cat = lambda attr: np.concatenate([getattr(t, attr) for t in tabs])
    return MultiCloudCostTable(
        storage_cents_gb_month=cat("storage_cents_gb_month"),
        read_cents_gb=cat("read_cents_gb"),
        write_cents_gb=cat("write_cents_gb"),
        ttfb_seconds=cat("ttfb_seconds"),
        capacity_gb=cat("capacity_gb"),
        early_delete_months=cat("early_delete_months"),
        compute_cents_sec=tabs[0].compute_cents_sec,
        names=tuple((f"{p.provider}@{p.region}:{n}" if p.region is not None
                     else f"{p.provider}:{n}") for p in providers
                    for n in p.table.names),
        provider_names=tuple(p.provider for p in providers),
        provider_of_tier=np.concatenate(
            [np.full(t.num_tiers, i) for i, t in enumerate(tabs)]),
        egress_cents_gb=egress,
        provider_capacity_gb=np.array([p.capacity_gb for p in providers],
                                      np.float64),
        provider_regions=tuple(p.region for p in providers),
    )


def move_egress_cents_gb(table: CostTable,
                         from_tier: "int | np.ndarray",
                         to_tier: "int | np.ndarray") -> np.ndarray:
    """Per-GB cross-provider egress for a tier move (broadcasts).

    Zero for plain single-cloud tables, for new data (``from_tier == -1``),
    and for moves within one provider.
    """
    f = np.asarray(from_tier, int)
    t = np.asarray(to_tier, int)
    p = getattr(table, "provider_of_tier", None)
    if p is None:
        return np.zeros(np.broadcast(f, t).shape)
    safe_f, safe_t = np.maximum(f, 0), np.maximum(t, 0)
    eg = table.egress_cents_gb[p[safe_f], p[safe_t]]
    return np.where((f >= 0) & (t >= 0), eg, 0.0)


def aws_s3_provider(capacity_gb: float = np.inf) -> ProviderCostTable:
    """AWS S3, us-east-1 list prices (2024), normalized like the paper's
    Table XII: request charges amortized per GB at 4 MB-per-op granularity,
    retrieval fees folded into ``read_cents_gb``. Tiers: Standard /
    Standard-IA / Glacier Instant Retrieval / Glacier Deep Archive."""
    return ProviderCostTable(
        provider="aws",
        table=CostTable(
            storage_cents_gb_month=np.array([2.3, 1.25, 0.4, 0.099]),
            read_cents_gb=np.array([0.0103, 1.0, 3.0, 2.0]),
            write_cents_gb=np.array([0.0128, 0.0256, 0.0512, 0.128]),
            ttfb_seconds=np.array([0.02, 0.02, 0.05, 43200.0]),
            capacity_gb=np.array([np.inf] * 4),
            early_delete_months=np.array([0.0, 1.0, 3.0, 6.0]),
            names=("standard", "standard_ia", "glacier_ir", "deep_archive"),
        ),
        egress_out_cents_gb=9.0,
        capacity_gb=capacity_gb,
    )


def gcp_gcs_provider(capacity_gb: float = np.inf) -> ProviderCostTable:
    """GCP Cloud Storage, regional us-central1 list prices (2024), same
    normalization. All four GCS classes are online (millisecond TTFB) —
    archival is priced, not slow. Tiers: Standard / Nearline / Coldline /
    Archive."""
    return ProviderCostTable(
        provider="gcp",
        table=CostTable(
            storage_cents_gb_month=np.array([2.0, 1.0, 0.4, 0.12]),
            read_cents_gb=np.array([0.0102, 1.0256, 2.0512, 5.128]),
            write_cents_gb=np.array([0.0128, 0.0256, 0.0256, 0.128]),
            ttfb_seconds=np.array([0.02, 0.02, 0.02, 0.05]),
            capacity_gb=np.array([np.inf] * 4),
            early_delete_months=np.array([0.0, 1.0, 3.0, 12.0]),
            names=("standard", "nearline", "coldline", "archive"),
        ),
        egress_out_cents_gb=12.0,
        capacity_gb=capacity_gb,
    )


def azure_blob_provider(capacity_gb: float = np.inf) -> ProviderCostTable:
    """Azure Blob Storage, East US LRS flat-namespace list prices (2024),
    same normalization. Distinct from :func:`azure_table`, which reproduces
    the paper's ADLS Gen2 Tables I & XII. Tiers: Hot / Cool / Cold /
    Archive (Archive TTFB is the documented up-to-15 h rehydration)."""
    return ProviderCostTable(
        provider="azure",
        table=CostTable(
            storage_cents_gb_month=np.array([1.84, 1.0, 0.36, 0.099]),
            read_cents_gb=np.array([0.0111, 1.0256, 3.0768, 2.7184]),
            write_cents_gb=np.array([0.0163, 0.0325, 0.0585, 0.0666]),
            ttfb_seconds=np.array([0.02, 0.02, 0.02, 54000.0]),
            capacity_gb=np.array([np.inf] * 4),
            early_delete_months=np.array([0.0, 1.0, 3.0, 6.0]),
            names=("hot", "cool", "cold", "archive"),
        ),
        egress_out_cents_gb=8.7,
        capacity_gb=capacity_gb,
    )


def big3_table(aws_capacity_gb: float = np.inf,
               gcp_capacity_gb: float = np.inf,
               azure_capacity_gb: float = np.inf) -> MultiCloudCostTable:
    """AWS + GCP + Azure flattened into one 12-tier placement space."""
    return multi_cloud_table([aws_s3_provider(aws_capacity_gb),
                              gcp_gcs_provider(gcp_capacity_gb),
                              azure_blob_provider(azure_capacity_gb)])


@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective hyper-parameters (paper §IV-A): alpha weights storage,
    beta weights access (read + decompression-compute), gamma weights
    tier-change cost."""

    alpha: float = 1.0
    beta: float = 1.0
    gamma: float = 1.0


def cost_tensor(
    spans_gb: np.ndarray,          # (N,)  Sp(P_n)
    accesses: np.ndarray,          # (N,)  rho(P_n) — projected # of reads
    current_tier: np.ndarray,      # (N,)  L(P_n) in {-1, 0..L-1}
    ratios: np.ndarray,            # (N,K) R_n^k   — compression ratios (>=1)
    decomp_sec: np.ndarray,        # (N,K) D_n^k   — decompression seconds (whole partition)
    table: CostTable,
    weights: Weights = Weights(),
    months: float = 1.0,
    pushdown_fraction: float = 0.0,
) -> np.ndarray:
    """Full OPTASSIGN objective tensor, shape (N, L, K).

    cost[n,l,k] = (alpha*C^s_l*months + gamma*Delta_{L(n),l}) * Sp_n / R_nk
                + beta * (1-f) * rho_n * (C^c * D_nk + C^r_l * Sp_n / R_nk)

    ``pushdown_fraction`` is the paper's `f`: queries answerable directly on
    compressed data contribute neither read nor decompression cost.
    """
    N = spans_gb.shape[0]
    L = table.num_tiers
    delta = table.tier_change_cents_gb()          # (L+1, L)
    move = delta[current_tier.astype(int)]        # (N, L) — cents/GB
    stored_gb = spans_gb[:, None] / ratios        # (N, K)
    eff_rho = (1.0 - pushdown_fraction) * accesses

    hold = (weights.alpha * table.storage_cents_gb_month[None, :] * months
            + weights.gamma * move)               # (N, L)
    storage_cost = hold[:, :, None] * stored_gb[:, None, :]          # (N,L,K)
    read_cost = (table.read_cents_gb[None, :, None]
                 * stored_gb[:, None, :])                             # (N,L,K)
    decomp_cost = (table.compute_cents_sec * decomp_sec)[:, None, :]  # (N,1,K)->(N,L,K)
    access_cost = weights.beta * eff_rho[:, None, None] * (decomp_cost + read_cost)
    return storage_cost + access_cost


def early_delete_penalty_gb(
    table: CostTable,
    current_tier: np.ndarray,      # (N,) in {-1, 0..L-1}; -1 = new data
    months_held: "float | np.ndarray" = 0.0,
) -> np.ndarray:
    """Per-GB charge if a partition leaves ``current_tier`` now, shape (N,).

    The prorated remainder of the tier's minimum-stay storage charge —
    mirrors ``TieredStore.change_tier`` / ``delete`` semantics. Zero for new
    data (tier -1) and for tiers without a minimum stay.
    """
    cur = np.asarray(current_tier, int)
    held = np.broadcast_to(np.asarray(months_held, np.float64), cur.shape)
    safe = np.maximum(cur, 0)
    due = np.maximum(0.0, table.early_delete_months[safe] - held)
    return np.where(cur >= 0, due * table.storage_cents_gb_month[safe], 0.0)


def sla_penalty_tensor(
    accesses: np.ndarray,          # (N,)  rho — projected # of reads
    sla_ms: np.ndarray,            # (N,)  per-partition target (inf = none)
    decomp_sec: np.ndarray,        # (N,K) whole-partition decompression
    table: CostTable,
) -> np.ndarray:
    """Soft-SLA violation penalty tensor, shape (N, L, K).

    penalty[n,l,k] = rho_n * max(0, B_l*1e3 + D_nk*1e3 - sla_ms_n)

    Units are **rho-weighted excess milliseconds** — deliberately not
    cents. The solver objective adds ``sla_lambda * penalty`` (lambda
    converts excess-ms to objective units); billing reports the raw
    penalty of the chosen cells separately and never meters it as cents.
    Rows with ``sla_ms = inf`` contribute exactly 0.0.
    """
    lat_ms = (table.ttfb_seconds[None, :, None]
              + decomp_sec[:, None, :]) * 1e3              # (N,L,K)
    sla = np.asarray(sla_ms, np.float64)[:, None, None]
    # inf - inf would NaN; an infinite SLA means "no target" -> zero excess
    excess = np.where(np.isfinite(sla), np.maximum(lat_ms - sla, 0.0), 0.0)
    return np.asarray(accesses, np.float64)[:, None, None] * excess


def latency_feasible(
    decomp_sec: np.ndarray,       # (N,K)
    latency_threshold: np.ndarray,  # (N,)
    table: CostTable,
) -> np.ndarray:
    """Latency constraint mask, shape (N, L, K): D_nk + B_l <= T_n."""
    total = decomp_sec[:, None, :] + table.ttfb_seconds[None, :, None]
    return total <= latency_threshold[:, None, None]
