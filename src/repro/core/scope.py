"""SCOPe — compatibility facade over the staged PlacementEngine (paper §VII).

The pipeline itself now lives in :mod:`repro.core.engine` as four composable
stages exchanging typed payloads::

    PartitionStage -> CompressStage -> AssignStage -> BillingStage
    (G-PART)          (COMPREDICT)     (OPTASSIGN)     (array-math billing)

plus :meth:`~repro.core.engine.PlacementEngine.reoptimize` for online
re-optimization under access-pattern drift. This module keeps the legacy
surface:

 * ``run_pipeline`` — one-shot batch optimization returning the same
   :class:`PipelineReport` as the original monolith;
 * ``paper_variants`` — the P/T/C ablation grid of Tables IX–XI
   (Ares = C only, Hermes = T only, HCompress = latency-focused T+C,
   '+ G-PART' rows = same with P on), with weights selecting the
   'latency focused' / 'read+decomp focused' / 'total cost focused'
   SCOPe variants and ``capacity`` switching greedy (Thm 3) vs
   capacitated solving.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import datapart
from repro.core.costs import CostTable, Weights
from repro.core.engine import (MigrationPlan, PipelineReport, PlacementEngine,
                               PlacementPlan, PlacementProblem, ScopeConfig)
from repro.data.tables import Table

__all__ = [
    "MigrationPlan", "PipelineReport", "PlacementEngine", "PlacementPlan",
    "PlacementProblem", "ScopeConfig", "paper_variants", "run_pipeline",
]


def run_pipeline(
    parts: List[datapart.Partition],
    file_rows: Dict[str, Tuple[Table, np.ndarray]],
    table: CostTable,
    cfg: ScopeConfig,
) -> PipelineReport:
    """Legacy one-shot entry point: build + solve + bill via the engine."""
    return PlacementEngine(table, cfg).run(parts, file_rows).report


# ------------------------------------------------------- paper table variants
def paper_variants(capacity_gb: np.ndarray) -> Dict[str, ScopeConfig]:
    """The 11 rows of Tables IX–XI, keyed by the paper's row names."""
    no_cap = None
    no_archive = (0, 1, 2)
    return {
        "Default (store on premium)": ScopeConfig(
            use_partitioning=False, use_tiering=False, use_compression=False,
            fixed_tier=0, tier_whitelist=no_archive),
        "Compress & store on premium [Ares]": ScopeConfig(
            use_partitioning=False, use_tiering=False, use_compression=True,
            fixed_tier=0, tier_whitelist=no_archive),
        "Multi-Tiering [Hermes]": ScopeConfig(
            use_partitioning=False, use_tiering=True, use_compression=False,
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
        "Latency time focused [HCompress]": ScopeConfig(
            use_partitioning=False, use_tiering=True, use_compression=True,
            weights=Weights(alpha=0.0, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "Partition & store on premium": ScopeConfig(
            use_partitioning=True, use_tiering=False, use_compression=False,
            fixed_tier=0, tier_whitelist=no_archive),
        "Partitioning + Tiering [Hermes + G-PART]": ScopeConfig(
            use_partitioning=True, use_tiering=True, use_compression=False,
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
        "Partitioning + Compression [Ares + G-PART]": ScopeConfig(
            use_partitioning=True, use_tiering=False, use_compression=True,
            fixed_tier=0, tier_whitelist=no_archive),
        "SCOPe (Latency time focused)": ScopeConfig(
            weights=Weights(alpha=0.0, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "SCOPe (No capacity constraint)": ScopeConfig(
            capacity_gb=no_cap, tier_whitelist=no_archive),
        "SCOPe (Read+Decomp. cost focused)": ScopeConfig(
            weights=Weights(alpha=0.05, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "SCOPe (Total cost focused)": ScopeConfig(
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
    }
