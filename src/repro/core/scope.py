"""SCOPe — the unified pipeline (paper §VII).

G-PART (partitioning) -> COMPREDICT (compression prediction) -> OPTASSIGN
(tier + scheme assignment), with the paper's ablation flags:

 * P/T/C toggles reproduce the baseline adaptations of Tables IX–XI
   (Ares = C only, Hermes = T only, HCompress = latency-focused T+C,
   '+ G-PART' rows = same with P on);
 * weights select the 'latency focused' / 'read+decomp focused' /
   'total cost focused' SCOPe variants;
 * ``capacity`` switches greedy (Thm 3) vs capacitated solving.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import datapart
from repro.core.compredict import CompressionPredictor
from repro.core.costs import (CostTable, Weights, cost_tensor,
                              latency_feasible, TIER_NAMES)
from repro.core.optassign import (Assignment, capacitated_assign, greedy_assign)
from repro.data.tables import Table
from repro.storage.codecs import codec_by_name, measure


@dataclasses.dataclass
class ScopeConfig:
    use_partitioning: bool = True
    use_tiering: bool = True
    use_compression: bool = True
    weights: Weights = dataclasses.field(default_factory=Weights)
    months: float = 5.5                      # paper's evaluation window
    schemes: Sequence[str] = ("none", "zlib-1", "zstd-3", "zstd-19", "lzma-1")
    layout: str = "col"
    capacity_gb: Optional[np.ndarray] = None  # None = unbounded (greedy path)
    latency_sla_sec: float = np.inf
    tier_whitelist: Optional[Sequence[int]] = None  # e.g. (0,1,2) = no archive
    s_thresh_mult: float = 3.0               # G-PART span cap, x median family span
    rho_c: float = 4.0
    rho_c_abs: float = 10.0
    predictor: str = "truth"                 # 'truth' | 'model'
    fixed_tier: Optional[int] = None         # e.g. 0 -> 'store on premium'


@dataclasses.dataclass
class PipelineReport:
    storage_cents: float
    decomp_cents: float
    read_cents: float
    total_cents: float
    read_latency_ttfb: float          # access-weighted mean TTFB (s)
    decomp_latency_ms: float          # access-weighted mean decompression (ms)
    tiering_scheme: List[int]         # partitions per tier
    n_partitions: int
    assignment: Assignment
    spans_gb: np.ndarray
    rho: np.ndarray
    schemes: Sequence[str]


def _partition_tables(parts: Sequence[datapart.Partition],
                      file_rows: Dict[str, Tuple[Table, np.ndarray]]) -> List[Table]:
    """Materialize each partition as the concatenation of its files' rows."""
    out: List[Table] = []
    for p in parts:
        tabs: Dict[str, List[np.ndarray]] = {}
        base: Optional[Table] = None
        per_table: Dict[str, List[np.ndarray]] = {}
        for f in sorted(p.files):
            t, idx = file_rows[f]
            per_table.setdefault(t.name, []).append(idx)
            base = base or t
        # A query family touches exactly one table in our workload; guard anyway.
        name = max(per_table, key=lambda n: sum(len(i) for i in per_table[n]))
        t0 = [file_rows[f][0] for f in sorted(p.files)
              if file_rows[f][0].name == name][0]
        idx = np.sort(np.concatenate(per_table[name]))
        out.append(t0.select(idx))
    return out


def run_pipeline(
    parts: List[datapart.Partition],
    file_rows: Dict[str, Tuple[Table, np.ndarray]],
    table: CostTable,
    cfg: ScopeConfig,
) -> PipelineReport:
    # ---------------------------------------------------------- partitioning
    if cfg.use_partitioning:
        med = float(np.median([p.span for p in parts])) if parts else 0.0
        merged = datapart.g_part(parts, s_thresh=cfg.s_thresh_mult * med,
                                 rho_c=cfg.rho_c, rho_c_abs=cfg.rho_c_abs)
    else:
        # paper's non-partitioned baselines treat each DATASET (table) as
        # one partition: every access scans its whole table
        by_table: Dict[str, List[datapart.Partition]] = {}
        for p in parts:
            tname = sorted(p.files)[0].split("/")[0]
            by_table.setdefault(tname, []).append(p)
        merged = []
        for group in by_table.values():
            merged.extend(datapart.merge_all(group))
    tables = _partition_tables(merged, file_rows)
    raw_bytes = [t.serialize(cfg.layout) for t in tables]
    spans_gb = np.array([len(b) / 1e9 for b in raw_bytes])
    rho = np.array([p.rho for p in merged])
    N = len(merged)

    # ----------------------------------------------------------- compression
    schemes = list(cfg.schemes) if cfg.use_compression else ["none"]
    K = len(schemes)
    R = np.ones((N, K))
    D = np.zeros((N, K))
    if cfg.use_compression:
        if cfg.predictor == "truth":
            for i, b in enumerate(raw_bytes):
                for k, s in enumerate(schemes):
                    if s == "none":
                        continue
                    m = measure(codec_by_name(s), b)
                    R[i, k] = m.ratio
                    D[i, k] = m.decompress_sec_per_gb * (len(b) / 1e9)
        else:
            pred: CompressionPredictor = cfg.predictor  # fitted instance
            Rm, Dm = pred.predict_matrix(tables, schemes, cfg.layout)
            R = Rm
            D = Dm * spans_gb[:, None]   # sec/GB -> sec for this partition

    # ------------------------------------------------------------ assignment
    cur = np.full(N, -1)
    cost = cost_tensor(spans_gb, rho, cur, R, D, table, cfg.weights,
                       months=cfg.months)
    feas = latency_feasible(D, np.full(N, cfg.latency_sla_sec), table)
    if cfg.tier_whitelist is not None:
        allowed = np.zeros(table.num_tiers, bool)
        allowed[list(cfg.tier_whitelist)] = True
        feas &= allowed[None, :, None]
    if not cfg.use_tiering:
        fixed = cfg.fixed_tier if cfg.fixed_tier is not None else 0
        only = np.zeros(table.num_tiers, bool)
        only[fixed] = True
        feas &= only[None, :, None]
    if cfg.capacity_gb is None:
        assign = greedy_assign(cost, feas)
    else:
        stored = spans_gb[:, None, None] / R[:, None, :] * np.ones(
            (1, table.num_tiers, 1))
        assign = capacitated_assign(cost, feas, stored, cfg.capacity_gb)

    # --------------------------------------------------------------- billing
    storage = read = decomp = 0.0
    ttfb_acc = dlat_acc = rho_tot = 0.0
    scheme_counts = [0] * table.num_tiers
    for n in range(N):
        l, k = int(assign.tier[n]), int(assign.scheme[n])
        stored_gb = spans_gb[n] / R[n, k]
        storage += stored_gb * table.storage_cents_gb_month[l] * cfg.months
        read += rho[n] * stored_gb * table.read_cents_gb[l]
        decomp += rho[n] * D[n, k] * table.compute_cents_sec
        ttfb_acc += rho[n] * table.ttfb_seconds[l]
        dlat_acc += rho[n] * D[n, k]
        rho_tot += rho[n]
        scheme_counts[l] += 1
    return PipelineReport(
        storage_cents=storage, decomp_cents=decomp, read_cents=read,
        total_cents=storage + decomp + read,
        read_latency_ttfb=ttfb_acc / max(rho_tot, 1e-12),
        decomp_latency_ms=1e3 * dlat_acc / max(rho_tot, 1e-12),
        tiering_scheme=scheme_counts, n_partitions=N, assignment=assign,
        spans_gb=spans_gb, rho=rho, schemes=schemes)


# ------------------------------------------------------- paper table variants
def paper_variants(capacity_gb: np.ndarray) -> Dict[str, ScopeConfig]:
    """The 11 rows of Tables IX–XI, keyed by the paper's row names."""
    no_cap = None
    no_archive = (0, 1, 2)
    return {
        "Default (store on premium)": ScopeConfig(
            use_partitioning=False, use_tiering=False, use_compression=False,
            fixed_tier=0, tier_whitelist=no_archive),
        "Compress & store on premium [Ares]": ScopeConfig(
            use_partitioning=False, use_tiering=False, use_compression=True,
            fixed_tier=0, tier_whitelist=no_archive),
        "Multi-Tiering [Hermes]": ScopeConfig(
            use_partitioning=False, use_tiering=True, use_compression=False,
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
        "Latency time focused [HCompress]": ScopeConfig(
            use_partitioning=False, use_tiering=True, use_compression=True,
            weights=Weights(alpha=0.0, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "Partition & store on premium": ScopeConfig(
            use_partitioning=True, use_tiering=False, use_compression=False,
            fixed_tier=0, tier_whitelist=no_archive),
        "Partitioning + Tiering [Hermes + G-PART]": ScopeConfig(
            use_partitioning=True, use_tiering=True, use_compression=False,
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
        "Partitioning + Compression [Ares + G-PART]": ScopeConfig(
            use_partitioning=True, use_tiering=False, use_compression=True,
            fixed_tier=0, tier_whitelist=no_archive),
        "SCOPe (Latency time focused)": ScopeConfig(
            weights=Weights(alpha=0.0, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "SCOPe (No capacity constraint)": ScopeConfig(
            capacity_gb=no_cap, tier_whitelist=no_archive),
        "SCOPe (Read+Decomp. cost focused)": ScopeConfig(
            weights=Weights(alpha=0.05, beta=1.0), capacity_gb=capacity_gb,
            tier_whitelist=no_archive),
        "SCOPe (Total cost focused)": ScopeConfig(
            capacity_gb=capacity_gb, tier_whitelist=no_archive),
    }
