"""Resilient asynchronous execution plane for ``MigrationPlan``s.

Planning and execution are split: the optimizer
(:meth:`~repro.core.engine.PlacementEngine.reoptimize` + the daemon's
budget knapsack) *selects* moves; :class:`AsyncMigrator` *lands* them
against a :class:`~repro.storage.store.TieredStore` as a per-move task
queue with

* **bounded retries** with exponential backoff + seeded jitter on
  transient faults (429/503, in-flight corruption),
* **per-move checksum verification** — the decoded payload is hashed and
  checked against the store's metadata before any delete/commit, and the
  bytes handed back for the re-put are re-verified inside the store's
  atomic :meth:`~repro.storage.store.TieredStore.replace` commit,
* **atomic metadata commit** — a move is either fully billed-and-applied
  or fully rolled back; the source object is never left deleted without
  a committed destination,
* **budget gating over attempted spend** — with ``budget_cents`` set, a
  task (or retry) is only launched while the cycle's *attempted* cents
  (committed + wasted) still leave room for the move's planned charge,
  so retry storms cannot blow through a per-cycle migration cap.

Task lifecycle::

    pending -> in-flight -> committed                     (landed)
                         -> in-flight        (transient: backoff + retry)
                         -> rolled-back      (permanent error mid-move;
                                              partial work undone)
                         -> failed           (retries exhausted)
    pending -> skipped                       (budget gate: never launched)

With **zero injected faults and ``workers=1``** the task queue executes
the exact op sequence of the synchronous ``store.migrate`` /
``store.sync_plan`` paths — bit-identical store state and metered cents
(the parity pin in ``tests/test_migrator.py``). ``workers > 1`` overlaps
the backoff sleeps of independent tasks (store operations themselves are
serialized under an op lock so per-attempt cents stay attributable);
float accumulation order then depends on scheduling, so parity is
approximate.

Accounting is over the **deterministic** meter fields (storage, read,
write, penalty, egress). Decompression-compute cents are wall-clock
measured by the store and excluded, so retry/failed cents are exactly
reproducible for a fixed chaos seed.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import queue
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.storage.chaos import PermanentStoreError, TransientStoreError
from repro.storage.store import ChecksumError, TieredStore

__all__ = ["AsyncMigrator", "MoveState", "MoveTask", "MigratorReport"]


class MoveState(str, enum.Enum):
    PENDING = "pending"
    IN_FLIGHT = "in-flight"
    COMMITTED = "committed"
    ROLLED_BACK = "rolled-back"       # permanent error; partial work undone
    FAILED = "failed"                 # retries exhausted
    SKIPPED = "skipped"               # budget gate: never launched


#: terminal states whose plan rows did NOT land (fed back to the planner)
_UNAPPLIED = (MoveState.ROLLED_BACK, MoveState.FAILED, MoveState.SKIPPED)
#: terminal states that count as execution *failures* (skips are deferrals)
_FAILED = (MoveState.ROLLED_BACK, MoveState.FAILED)


@dataclasses.dataclass
class MoveTask:
    """One queued store operation derived from a plan row."""

    index: int                        # plan row; -1 for sync-path deletes
    key: str
    kind: str                         # 'tier' | 'reencode' | 'put' | 'delete'
    new_tier: int = -1
    codec: str = "none"
    payload: Optional[bytes] = None   # raw bytes for 'put'
    charge_cents: float = 0.0         # planned one-off charge (budget gate)
    state: MoveState = MoveState.PENDING
    attempts: int = 0
    spent_cents: float = 0.0          # deterministic cents metered, total
    committed_cents: float = 0.0      # cents of the successful attempt
    backoff_s: float = 0.0            # total backoff delay scheduled
    error: str = ""

    @property
    def retry_cents(self) -> float:
        """Cents burned by attempts that did not commit."""
        return self.spent_cents - self.committed_cents


@dataclasses.dataclass
class MigratorReport:
    """Outcome of one :meth:`AsyncMigrator.execute`/``execute_sync`` run.

    ``committed_cents + retry_cents + failed_cents == attempted_cents`` —
    the exact (deterministic-field) meter delta of the run. ``n_rows`` is
    the plan length, so the masks align with ``MigrationPlan`` arrays.
    """

    tasks: List[MoveTask]
    n_rows: int
    n_committed: int
    n_failed: int                     # rolled-back + retries-exhausted
    n_rolled_back: int
    n_skipped: int                    # budget-gated, never launched
    n_attempts: int
    committed_cents: float            # cents of successful attempts
    retry_cents: float                # wasted attempts of committed tasks
    failed_cents: float               # all cents of failed tasks
    backoff_s: float

    @property
    def attempted_cents(self) -> float:
        return self.committed_cents + self.retry_cents + self.failed_cents

    def _mask(self, states) -> np.ndarray:
        m = np.zeros(self.n_rows, bool)
        for t in self.tasks:
            if t.index >= 0 and t.state in states:
                m[t.index] = True
        return m

    def committed_mask(self) -> np.ndarray:
        return self._mask((MoveState.COMMITTED,))

    def failed_mask(self) -> np.ndarray:
        """Plan rows that terminally failed (rolled back or exhausted)."""
        return self._mask(_FAILED)

    def unapplied_mask(self) -> np.ndarray:
        """Plan rows that did not land (failed OR budget-skipped) — what
        the planner reverts via ``MigrationPlan.land`` and re-plans next
        cycle."""
        return self._mask(_UNAPPLIED)


def _meter_cents(meter) -> float:
    """Deterministic billed cents (excludes wall-clock-measured
    decompression compute, which would make retry accounting
    irreproducible)."""
    return (meter.storage_cents + meter.read_cents + meter.write_cents
            + meter.penalty_cents + meter.egress_cents)


class _Budget:
    """Shared attempted-spend ledger for one execution run."""

    def __init__(self, cap: float):
        self.cap = float(cap)
        self.spent = 0.0

    def admits(self, charge: float) -> bool:
        # an attempt can cost at most the move's planned charge, so gating
        # on it keeps cumulative attempted spend under the cap
        return charge <= self.cap - self.spent + 1e-9


class AsyncMigrator:
    """Executes selected ``MigrationPlan`` moves as a resilient task queue.

    ``store`` is a :class:`TieredStore` or a
    :class:`~repro.storage.chaos.ChaosStore` wrapping one. ``sleep_fn``
    performs the backoff delays (pass ``None`` to skip sleeping —
    delays are still computed and reported — the right setting for tests
    and simulation loops). ``seed`` drives the backoff jitter only; fault
    schedules live in the ChaosStore's own generator.
    """

    def __init__(self, store, *, max_attempts: int = 4,
                 base_delay_s: float = 0.05, backoff_mult: float = 2.0,
                 jitter: float = 0.5, seed: int = 0,
                 verify_checksums: bool = True, workers: int = 1,
                 sleep_fn: Optional[Callable[[float], None]] = time.sleep):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.store = store
        self.max_attempts = int(max_attempts)
        self.base_delay_s = float(base_delay_s)
        self.backoff_mult = float(backoff_mult)
        self.jitter = float(jitter)
        self.verify_checksums = verify_checksums
        self.workers = int(workers)
        self.sleep_fn = sleep_fn
        self._rng = np.random.default_rng(seed)
        self._oplock = threading.Lock()

    # ------------------------------------------------------------ task build
    @staticmethod
    def _move_charges(migration) -> np.ndarray:
        return np.asarray(migration.move_transfer_cents
                          + migration.move_egress_cents
                          + migration.move_penalty_cents, np.float64)

    def execute(self, migration, keys: Optional[list] = None, *,
                budget_cents: Optional[float] = None) -> MigratorReport:
        """Land a (possibly partial) ``MigrationPlan`` — the resilient
        counterpart of :meth:`TieredStore.migrate`.

        Tier-only moves become ``change_tier`` tasks; scheme changes
        become verified-re-encode tasks (get -> checksum -> atomic
        ``replace``). Only ``migration.moved`` rows are queued, so
        budget-deferred candidates are untouched, exactly like the
        synchronous path.
        """
        moved = np.asarray(migration.moved, bool)
        N = int(moved.shape[0])
        if keys is not None and len(keys) != N:
            raise ValueError(f"keys has {len(keys)} entries for a "
                             f"{N}-partition migration; nothing executed")
        schemes = migration.plan.problem.schemes
        charges = self._move_charges(migration)
        tasks: List[MoveTask] = []
        for n in range(N):
            if not moved[n]:
                continue
            key = keys[n] if keys is not None else TieredStore._plan_key(n)
            if migration.new_scheme[n] != migration.old_scheme[n]:
                kind = "reencode"
                codec = schemes[int(migration.new_scheme[n])]
            else:
                kind, codec = "tier", "none"
            tasks.append(MoveTask(
                index=n, key=key, kind=kind,
                new_tier=int(migration.new_tier[n]), codec=codec,
                charge_cents=float(charges[n])))
        return self._run(tasks, N, budget_cents)

    def execute_sync(self, migration, payloads: Optional[list] = None, *,
                     budget_cents: Optional[float] = None) -> MigratorReport:
        """Reconcile the store with a streaming plan — the resilient
        counterpart of :meth:`TieredStore.sync_plan`.

        New partitions become verified ``put`` tasks, codec changes
        verified re-encodes, tier changes ``change_tier`` tasks, and
        vanished ``gpart-*`` objects ``delete`` tasks (``index = -1``:
        not plan rows; a failed delete simply lingers and is retried on
        the next sync). Ingestion puts and garbage deletes are outside
        the migration budget, matching the daemon's knapsack accounting;
        only the *move* tasks are budget-gated.
        """
        plan = migration.plan
        parts = plan.problem.partitions
        if parts is None:
            raise ValueError("plan has no partitions; execute_sync needs "
                             "the partition file sets to key objects")
        if payloads is None:
            payloads = plan.problem.raw_bytes
        if payloads is not None and len(payloads) != len(parts):
            raise ValueError(f"payloads has {len(payloads)} entries for "
                             f"{len(parts)} partitions; nothing executed")
        schemes = plan.problem.schemes
        charges = self._move_charges(migration)
        keys = self.store.plan_keys(plan)
        desired = set(keys)
        tasks: List[MoveTask] = []
        for n, key in enumerate(keys):
            tier = int(plan.assignment.tier[n])
            codec = schemes[int(plan.assignment.scheme[n])]
            if not self.store.has(key):
                if payloads is None:
                    raise ValueError("new partitions need payloads (pass "
                                     "payloads= or build with raw_bytes)")
                tasks.append(MoveTask(index=n, key=key, kind="put",
                                      new_tier=tier, codec=codec,
                                      payload=payloads[n]))
            elif self.store.codec_of(key) != codec:
                tasks.append(MoveTask(index=n, key=key, kind="reencode",
                                      new_tier=tier, codec=codec,
                                      charge_cents=float(charges[n])))
            elif self.store.tier_of(key) != tier:
                tasks.append(MoveTask(index=n, key=key, kind="tier",
                                      new_tier=tier, codec=codec,
                                      charge_cents=float(charges[n])))
        for key in self.store.keys():
            if key.startswith("gpart-") and key not in desired:
                tasks.append(MoveTask(index=-1, key=key, kind="delete"))
        return self._run(tasks, len(parts), budget_cents)

    # --------------------------------------------------------- execution
    def _attempt(self, task: MoveTask) -> None:
        """One attempt of a task's op sequence against the store. Any
        partial billing before a raised fault is the attempt's (wasted)
        retry cents; mutations are atomic per store op, so an aborted
        attempt leaves the source object intact."""
        st = self.store
        if task.kind == "tier":
            st.change_tier(task.key, task.new_tier)
        elif task.kind == "reencode":
            raw = st.get(task.key)
            h = None
            if self.verify_checksums:
                h = hashlib.sha256(raw).hexdigest()
                want = st.checksum(task.key)
                if h != want:
                    raise ChecksumError(
                        f"get {task.key!r}: decoded payload hash "
                        f"{h[:12]} != stored {want[:12]}")
            st.replace(task.key, raw, task.new_tier, task.codec,
                       expect_checksum=h)
        elif task.kind == "put":
            h = (hashlib.sha256(task.payload).hexdigest()
                 if self.verify_checksums else None)
            st.put(task.key, task.payload, task.new_tier, task.codec,
                   expect_checksum=h)
        elif task.kind == "delete":
            st.delete(task.key)
        else:  # pragma: no cover - task construction is internal
            raise ValueError(f"unknown task kind {task.kind!r}")

    def _run_task(self, task: MoveTask, budget: Optional[_Budget]) -> None:
        while True:
            delay = None
            with self._oplock:
                if task.state is MoveState.PENDING and budget is not None \
                        and not budget.admits(task.charge_cents):
                    task.state = MoveState.SKIPPED
                    task.error = "budget exhausted before launch"
                    return
                task.state = MoveState.IN_FLIGHT
                task.attempts += 1
                before = _meter_cents(self.store.meter)
                try:
                    self._attempt(task)
                except (TransientStoreError, ChecksumError) as e:
                    spent = _meter_cents(self.store.meter) - before
                    task.spent_cents += spent
                    if budget is not None:
                        budget.spent += spent
                    task.error = str(e)
                    if task.attempts >= self.max_attempts:
                        task.state = MoveState.FAILED
                        return
                    if budget is not None \
                            and not budget.admits(task.charge_cents):
                        # no room for another full-cost attempt: stop here
                        task.state = MoveState.FAILED
                        task.error += " (budget exhausted mid-retry)"
                        return
                    u = float(self._rng.random())
                    delay = (self.base_delay_s
                             * self.backoff_mult ** (task.attempts - 1)
                             * (1.0 + self.jitter * u))
                    task.backoff_s += delay
                except PermanentStoreError as e:
                    spent = _meter_cents(self.store.meter) - before
                    task.spent_cents += spent
                    if budget is not None:
                        budget.spent += spent
                    task.error = str(e)
                    task.state = MoveState.ROLLED_BACK
                    return
                else:
                    spent = _meter_cents(self.store.meter) - before
                    task.spent_cents += spent
                    task.committed_cents = spent
                    if budget is not None:
                        budget.spent += spent
                    task.state = MoveState.COMMITTED
                    return
            if delay is not None and self.sleep_fn is not None:
                self.sleep_fn(delay)

    def _run(self, tasks: List[MoveTask], n_rows: int,
             budget_cents: Optional[float]) -> MigratorReport:
        budget = (_Budget(budget_cents)
                  if budget_cents is not None and np.isfinite(budget_cents)
                  else None)
        if self.workers == 1 or len(tasks) <= 1:
            for t in tasks:
                self._run_task(t, budget)
        else:
            q: "queue.SimpleQueue[MoveTask]" = queue.SimpleQueue()
            for t in tasks:
                q.put(t)

            def worker():
                while True:
                    try:
                        t = q.get_nowait()
                    except queue.Empty:
                        return
                    self._run_task(t, budget)

            threads = [threading.Thread(target=worker, daemon=True)
                       for _ in range(min(self.workers, len(tasks)))]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        committed = [t for t in tasks if t.state is MoveState.COMMITTED]
        failed = [t for t in tasks if t.state in _FAILED]
        return MigratorReport(
            tasks=tasks, n_rows=n_rows,
            n_committed=len(committed), n_failed=len(failed),
            n_rolled_back=sum(t.state is MoveState.ROLLED_BACK
                              for t in tasks),
            n_skipped=sum(t.state is MoveState.SKIPPED for t in tasks),
            n_attempts=sum(t.attempts for t in tasks),
            committed_cents=float(sum(t.committed_cents for t in committed)),
            retry_cents=float(sum(t.retry_cents for t in committed)),
            failed_cents=float(sum(t.spent_cents for t in failed)),
            backoff_s=float(sum(t.backoff_s for t in tasks)))
