"""Calibrated access forecasting — closing the paper's §IV-C loop.

The paper trains a RandomForest that maps per-dataset features (size, age,
recent monthly read/write aggregates) to the *OPTASSIGN-optimal tier* on
the future access window ("We used OPTASSIGN to assign the ground truth
label encoding (i.e. the optimal tier) for each dataset while training").
:class:`AccessForecaster` packages that model as a daemon-compatible
``forecast_fn``: instead of reacting to last month's observed rho, the
:class:`~repro.core.daemon.ReoptimizationDaemon` places partitions against
a *projected* rho, pre-warming them before a predicted spike lands.

Three layers keep the projection trustworthy enough to feed straight into
the ``budgeted_moves`` knapsack and min-stay deferral math:

1. **model** — the §IV-C forest, fitted out-of-time on
   :func:`~repro.data.workloads.feature_matrix` rows with
   :func:`~repro.core.access_predict.optimal_tiers` labels computed on the
   future window ``[t, t+horizon)``;
2. **reliability** — an :class:`~repro.core.ml.IsotonicCalibrator` fitted
   on a held-out *later* slice of training months, so the forest's vote
   fraction for the hot tier becomes an empirical probability. The
   projection is then the calibrated expectation
   ``(1-p)·trend + p·max(trend, hot-level)``, which is exactly the rho
   under which the cost optimizer makes the expected-cost-optimal call;
3. **sanity** — :func:`clamp_rho`: forecasts are forced finite and
   non-negative and capped at ``spike_mult`` times the larger of the
   partition's own historical peak and the fleet-wide hot level, so an
   uncalibrated tree can never trigger phantom migrations.

The module owns the *shared sanity layer* of every forecasting path:
:func:`clamp_rho` and :func:`linear_trend_forecast` live here and are
re-exported by ``core/daemon.py`` (its default building block).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ml
from repro.core.access_predict import optimal_tiers
from repro.core.costs import CostTable
from repro.data.workloads import Workload, feature_matrix


# ------------------------------------------------------------- sanity layer
def clamp_rho(rho, lo: float = 0.0, hi=None):
    """Sanity-clamp projected access rates before they reach the cost
    model: non-finite values collapse to ``lo``, everything is bounded
    below by ``lo`` (negative rho would flow into ``cost_tensor`` as
    negative access cost) and optionally above by ``hi`` (the bounded
    spike multiplier). Scalars in, float out; arrays in, array out."""
    r = np.asarray(rho, np.float64)
    r = np.where(np.isfinite(r), r, lo)
    r = np.maximum(r, lo)
    if hi is not None:
        r = np.minimum(r, np.asarray(hi, np.float64))
    return float(r) if r.ndim == 0 else r


def linear_trend_forecast(history: Sequence, horizon: float = 1.0,
                          clip_min: float = 0.0):
    """Least-squares linear trend over a rho history, extrapolated
    ``horizon`` cycles ahead (clamped non-negative).

    ``history`` is a sequence of per-cycle observations — scalars in
    streaming mode (one partition's rho per cycle), (N,) vectors in batch
    mode. The default daemon ``forecast_fn`` building block; swap in an
    :class:`AccessForecaster` for feature-driven projection.

    Every return path goes through :func:`clamp_rho`: a single-entry or
    all-constant history returns the last value clamped at ``clip_min``,
    and a steep negative trend clamps to ``clip_min`` instead of
    extrapolating below zero.
    """
    h = np.asarray(history, np.float64)
    T = h.shape[0]
    if T == 0:
        raise ValueError("cannot forecast from an empty history")
    if T < 2:
        return clamp_rho(h[-1], lo=clip_min)
    t = np.arange(T, dtype=np.float64)
    tm = t.mean()
    ctr = (t - tm).reshape((T,) + (1,) * (h.ndim - 1))
    slope = (ctr * (h - h.mean(0))).sum(0) / (ctr * ctr).sum()
    return clamp_rho(h[-1] + horizon * slope, lo=clip_min)


# ------------------------------------------------------------- fit report
@dataclasses.dataclass
class ForecastFitReport:
    """What one :meth:`AccessForecaster.fit` call trained and measured.

    ``label_windows`` records every ``[lo, hi)`` month window whose reads
    produced a training/calibration label — the out-of-time contract is
    ``hi <= fit_month`` for all of them (pinned by tests).
    """

    fit_month: int
    train_months: Tuple[int, ...]
    cal_months: Tuple[int, ...]
    label_windows: Tuple[Tuple[int, int], ...]
    n_rows: int
    accuracy: float          # hot-vs-rest accuracy on the calibration slice
    ece_raw: float           # calibration error of raw forest votes
    ece_cal: float           # ... after the isotonic reliability layer
    hot_rho: float           # fleet-wide hot-level rho (median hot future)
    calibrated: bool


class AccessForecaster:
    """Paper-§IV-C access forecaster packaged as a daemon ``forecast_fn``.

    Usage (batch mode)::

        fc = AccessForecaster(table, horizon=2, history=4)
        fc.fit(workload, fit_month=12)       # out-of-time: labels < month 12
        fc.bind(month0=11)                   # month of the first observation
        daemon = ReoptimizationDaemon(engine, plan=plan0,
                                      forecast_fn=fc.forecast_rho)

    ``forecast_rho(history)`` receives the daemon's rolling window of
    observed (N,) rho vectors and returns the projected (N,) rho for the
    coming cycle. When constructed with ``refit_every=k``, every k-th
    forecast cycle refits the forest out-of-time on everything observed so
    far (recorded in ``refits_``). Streaming mode uses
    :meth:`stream_forecast_fn` (per-partition scalar histories keyed by
    file-set identity, sizes via the daemon's context protocol); fleet
    mode passes one bound forecaster per tenant as a ``forecast_fn`` list.

    ``tiers`` must be sorted hottest-first (ascending tier index); the
    calibrated probability is for ``tiers[0]``, the hot class.
    """

    def __init__(self, table: CostTable, *, tiers: Sequence[int] = (1, 2),
                 horizon: int = 2, history: int = 4, n_trees: int = 24,
                 max_depth: int = 10, seed: int = 0,
                 spike_mult: float = 8.0, refit_every: int = 0,
                 cal_frac: float = 0.25, min_cal_rows: int = 20):
        tiers = tuple(int(t) for t in tiers)
        if len(tiers) < 2 or list(tiers) != sorted(set(tiers)):
            raise ValueError(f"tiers must be >= 2 distinct indices sorted "
                             f"hottest-first, got {tiers}")
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1 month, got {horizon}")
        if spike_mult < 1.0:
            raise ValueError(f"spike_mult < 1 would cap forecasts below "
                             f"the observed peak, got {spike_mult}")
        self.table = table
        self.tiers = tiers
        self.horizon = int(horizon)
        self.history = int(history)
        self.n_trees, self.max_depth, self.seed = n_trees, max_depth, seed
        self.spike_mult = float(spike_mult)
        self.refit_every = int(refit_every)
        self.cal_frac = float(cal_frac)
        self.min_cal_rows = int(min_cal_rows)

        self.model: Optional[ml.RandomForest] = None
        self.calibrator: Optional[ml.IsotonicCalibrator] = None
        self.fit_report: Optional[ForecastFitReport] = None
        self.hot_rho_ = 0.0          # fleet-wide hot level (rho / month)
        self.med_size_gb_ = 1.0      # imputation when size is unknown
        self.refits_: List[int] = []
        self._w: Optional[Workload] = None
        self._fit_month = -1
        self.month0 = 0
        self._calls = 0

    # -------------------------------------------------------------- fitting
    def fit(self, w: Workload, *, fit_month: Optional[int] = None,
            ) -> ForecastFitReport:
        """Fit forest + reliability layer on months strictly before
        ``fit_month`` (default: the whole trace).

        Rows are (feature_matrix at t, optimal tier on [t, t+horizon))
        pairs over every usable month t; the *latest* ``cal_frac`` of those
        months is held out (out-of-time) to fit the isotonic calibrator
        and measure reliability, the rest trains the forest.
        """
        fit_month = w.n_months if fit_month is None else int(fit_month)
        if fit_month > w.n_months:
            raise ValueError(f"fit_month {fit_month} beyond the trace "
                             f"({w.n_months} months)")
        months = list(range(1, fit_month - self.horizon + 1))
        if len(months) < 2:
            raise ValueError(
                f"need >= 2 usable train months (1 <= t <= fit_month - "
                f"horizon = {fit_month - self.horizon}) to fit out-of-time")
        n_cal = max(1, int(round(self.cal_frac * len(months))))
        n_cal = min(n_cal, len(months) - 1)
        train_months, cal_months = months[:-n_cal], months[-n_cal:]

        t2c = {t: i for i, t in enumerate(self.tiers)}

        def rows(ms):
            X, c, fut = [], [], []
            for t in ms:
                X.append(feature_matrix(w, t, self.history))
                lab = optimal_tiers(w, self.table, t, t + self.horizon,
                                    self.tiers)
                c.append(np.array([t2c[v] for v in lab]))
                fut.append(w.reads_in(t, t + self.horizon)
                           / float(self.horizon))
            return np.vstack(X), np.concatenate(c), np.concatenate(fut)

        X_tr, c_tr, fut_tr = rows(train_months)
        X_cal, c_cal, fut_cal = rows(cal_months)

        clf = ml.RandomForest(n_trees=self.n_trees, max_depth=self.max_depth,
                              task="clf", n_classes=len(self.tiers),
                              seed=self.seed)
        clf.fit(X_tr, c_tr)
        raw = clf.predict_proba(X_cal)[:, 0]
        y_hot = (c_cal == 0).astype(float)
        calibrated = bool(len(y_hot) >= self.min_cal_rows
                          and 0.0 < y_hot.mean() < 1.0)
        cal = ml.IsotonicCalibrator().fit(raw, y_hot) if calibrated else None
        p_cal = cal.predict(raw) if cal is not None else raw

        # fleet-wide hot level: an upper-quartile future monthly rho of rows
        # the oracle labeled hot — the magnitude a predicted-hot partition is
        # pre-warmed toward when its own history has never spiked. P75 rather
        # than the median: with a calibrated-but-modest p, a median anchor
        # leaves the expected-cost projection just under the hot break-even
        # for exactly the spike onsets pre-warming exists for.
        fut_all = np.concatenate([fut_tr, fut_cal])
        hot_all = np.concatenate([c_tr, c_cal]) == 0
        self.hot_rho_ = float(np.percentile(fut_all[hot_all], 75)
                              if hot_all.any() else np.median(fut_all))
        self.med_size_gb_ = float(np.median(
            [d.size_gb for d in w.datasets])) if w.datasets else 1.0

        self.model, self.calibrator = clf, cal
        self._w, self._fit_month = w, fit_month
        wins = tuple((t, t + self.horizon) for t in months)
        self.fit_report = ForecastFitReport(
            fit_month=fit_month,
            train_months=tuple(train_months), cal_months=tuple(cal_months),
            label_windows=wins, n_rows=len(c_tr) + len(c_cal),
            accuracy=float(((raw >= 0.5) == (y_hot >= 0.5)).mean()),
            ece_raw=ml.expected_calibration_error(raw, y_hot),
            ece_cal=ml.expected_calibration_error(p_cal, y_hot),
            hot_rho=self.hot_rho_, calibrated=calibrated)
        return self.fit_report

    def bind(self, w: Optional[Workload] = None,
             month0: Optional[int] = None) -> "AccessForecaster":
        """Anchor the forecaster's clock: ``month0`` is the workload month
        of the FIRST observation the daemon will feed it (so the t-th
        forecast call targets month ``month0 + t``). Resets the cycle
        counter; optionally rebinds the workload used for size/age/write
        features and refits."""
        if w is not None:
            self._w = w
        if month0 is not None:
            self.month0 = int(month0)
        self._calls = 0
        return self

    def maybe_refit(self, at_month: int) -> bool:
        """Refit out-of-time at ``at_month`` if the refit cadence says so:
        only label windows ending <= at_month are used, so the daemon
        never trains on months it has not yet observed."""
        if self.refit_every <= 0 or self._w is None:
            return False
        if at_month - self._fit_month < self.refit_every:
            return False
        fm = min(int(at_month), self._w.n_months)
        if fm == self._fit_month or fm - self.horizon < 2:
            return False
        self.fit(self._w, fit_month=fm)
        self.refits_.append(fm)
        return True

    # ----------------------------------------------------------- projection
    def predict_p_hot(self, X: np.ndarray) -> np.ndarray:
        """Calibrated P(hot tier is cost-optimal on the coming window)."""
        if self.model is None:
            return np.zeros(len(X))
        raw = self.model.predict_proba(np.asarray(X, float))[:, 0]
        return (self.calibrator.predict(raw)
                if self.calibrator is not None else raw)

    def _project(self, reads_win: np.ndarray, base: np.ndarray,
                 hist_max: np.ndarray, sizes: np.ndarray, ages: np.ndarray,
                 writes_win: np.ndarray) -> np.ndarray:
        """The calibrated-expectation projection with the sanity clamp.
        ``reads_win``/``writes_win`` are (history, N); the rest (N,)."""
        X = np.concatenate([np.log1p(sizes)[:, None], ages[:, None],
                            reads_win.T, writes_win.T], axis=1)
        p = self.predict_p_hot(X)
        # stash for serving-cache admission: forecast_admission(...,
        # p_hot=fc.last_p_hot_) gates the cache on the calibrated
        # probability behind the projection just returned
        self.last_p_hot_ = p
        hot_level = np.maximum(hist_max, self.hot_rho_)
        proj = (1.0 - p) * base + p * np.maximum(base, hot_level)
        cap = self.spike_mult * np.maximum(hist_max, self.hot_rho_)
        return clamp_rho(proj, 0.0, cap)

    def _pad_window(self, arr: np.ndarray) -> np.ndarray:
        """Last ``history`` rows of a (T, N) series, zero-padded on the
        left — months before the first observation carry no accesses."""
        T, N = arr.shape
        if T >= self.history:
            return arr[T - self.history:]
        return np.vstack([np.zeros((self.history - T, N)), arr])

    def forecast_rho(self, history: Sequence) -> np.ndarray:
        """Daemon-compatible ``forecast_fn``: the rolling window of
        observed rho (scalars, or (N,) vectors in batch mode) in, the
        projected rho for the coming cycle out.

        Stateful: each call advances the forecaster's month clock by one
        cycle (the daemon calls it exactly once per cycle; re-anchor with
        :meth:`bind` before reuse). The daemon's ``forecast_window`` should
        be >= ``history`` so the feature window is fully populated.
        """
        if len(history) == 0:
            raise ValueError("cannot forecast from an empty history")
        self._calls += 1
        at = self.month0 + self._calls
        self.maybe_refit(at)

        h = [np.atleast_1d(np.asarray(x, np.float64)) for x in history]
        scalar = all(x.ndim == 1 and x.shape[0] == 1 for x in h) \
            and np.ndim(history[-1]) == 0
        arr = np.stack(h)                        # (T, N)
        N = arr.shape[1]
        base = np.atleast_1d(np.asarray(
            linear_trend_forecast(arr), np.float64))
        hist_max = arr.max(axis=0)
        reads_win = self._pad_window(arr)

        w = self._w
        if w is not None and N == len(w.datasets):
            # bound batch mode: the workload IS the observation record for
            # months < at, so take the feature window and the historical
            # peak from it — the daemon's rolling window starts empty at
            # month0 and would zero-pad away the previous spike (no
            # leakage: strictly-past months only, same rows training used)
            at_w = min(at, w.n_months)
            lo = max(at_w - self.history, 0)
            reads_win = self._pad_window(
                np.stack([d.reads[lo:at_w] for d in w.datasets], axis=1))
            hist_max = np.maximum(
                hist_max,
                np.array([float(d.reads[:at_w].max()) if at_w else 0.0
                          for d in w.datasets]))
            sizes = np.array([d.size_gb for d in w.datasets])
            ages = np.array([float(d.age_at(at)) for d in w.datasets])
            wr = np.stack([d.writes[lo:at_w] for d in w.datasets], axis=1)
            writes_win = self._pad_window(wr)
        else:
            sizes = np.full(N, self.med_size_gb_)
            ages = np.full(N, float(len(h)))
            writes_win = np.zeros((self.history, N))

        out = self._project(reads_win, base, hist_max, sizes, ages,
                            writes_win)
        return float(out[0]) if scalar else out

    __call__ = forecast_rho

    def stream_forecast_fn(self) -> Callable:
        """A streaming-mode ``forecast_fn``: per-partition scalar
        histories, keyed by file-set identity. Opts into the daemon's
        context protocol (``stream_context = True``) so each call receives
        ``key=`` (the partition's file-set key — ages survive
        re-partitioning exactly like the daemon's own deferral ages) and
        ``span_gb=`` (the partition's stored size, the paper's strongest
        feature). Write aggregates are unobservable on the query stream
        and imputed as zero."""
        ages: Dict = {}

        def fn(history, key=None, span_gb=None):
            if len(history) == 0:
                raise ValueError("cannot forecast from an empty history")
            if key is not None:
                ages[key] = ages.get(key, 0) + 1
            age = float(ages.get(key, len(history)))
            arr = np.asarray(list(history), np.float64)[:, None]   # (T, 1)
            base = np.atleast_1d(np.asarray(
                linear_trend_forecast(arr), np.float64))
            out = self._project(
                self._pad_window(arr), base, arr.max(axis=0),
                np.array([float(span_gb) if span_gb else
                          self.med_size_gb_]),
                np.array([age]), np.zeros((self.history, 1)))
            return float(out[0])

        fn.stream_context = True
        return fn
