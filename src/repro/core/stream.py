"""Streaming G-PART — incremental access-log ingestion (paper §VI, online).

DATAPART's G-PART (Algorithm 1) partitions from a *static* access log, but
the paper's premise — temporal access predictions feeding the optimizer —
implies logs arrive continuously. :class:`StreamingPartitioner` maintains the
G-PART partition state across :meth:`~StreamingPartitioner.ingest` calls,
LSM-tree style: new query families are *folded* into the existing partitions
with the same fractional-overlap max-heap merge rule, and a family-level log
(the "memtable of evidence") is kept alongside so :meth:`compact` can run a
full re-merge when accumulated drift exceeds a threshold.

Overlap queries route through the array-native core shared with batch
:func:`repro.core.datapart.g_part`: files are interned once into int32
codes (:class:`~repro.core.datapart.FileInterner`, first-seen order — the
same assignment a batch rebuild of the concatenated log produces) and every
edge weight comes from one vectorized one-vs-many pass over the live set
(:class:`~repro.core.datapart._NodeStore`) instead of per-pair
``frozenset`` intersections.

Correctness contract (pinned down by ``tests/test_stream.py``):

* total rho is conserved exactly by folding (merges sum rho, repeated
  families accumulate into their owning partition);
* with no decay, no window, and compaction after every batch, the streaming
  state is **exactly** batch ``g_part`` on the concatenated log — compaction
  replays Algorithm 1 over the family log with identical heap tie-breaking,
  and the shared store makes the weights bit-identical, not just equal-order;
* between compactions the objective (``datapart.read_cost``) tracks the
  batch answer within a drift-bounded tolerance.

Rolling-window semantics: ``decay`` exponentially ages all accumulated rho
once per ingest; ``window=W`` additionally retires the contribution of
batches older than ``W`` ingests (delta-subtraction, view-maintenance
style). Both leave partition *structure* untouched until the next compact.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import (Deque, Dict, FrozenSet, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core.datapart import (FileInterner, FileSizes, Partition,
                                 _feasible_mask, _NodeStore, feasible_pair)

QueryFamilies = Sequence[Tuple[Tuple[str, ...], float]]


def occurrence_keys(parts: Sequence[Partition],
                    ) -> List[Tuple[FrozenSet[str], int]]:
    """Stable per-partition identity: ``(file set, occurrence index)``.

    Two live partitions can share a file set (a query family can coexist
    with a merge producing the same union when access-comparability blocks
    folding them), so bare file sets are not unique; duplicates get an
    occurrence index in plan order. This is THE disambiguation rule for
    anything keyed by partition identity across re-partitionings —
    ``TieredStore.plan_keys`` object keys and the re-optimization daemon's
    deferral/forecast bookkeeping both derive from it.
    """
    keys: List[Tuple[FrozenSet[str], int]] = []
    seen: Dict[FrozenSet[str], int] = {}
    for p in parts:
        c = seen.get(p.files, 0)
        seen[p.files] = c + 1
        keys.append((p.files, c))
    return keys


@dataclasses.dataclass
class StreamStats:
    """Counters for the ingest/compact lifecycle (benchmarks report these)."""

    n_batches: int = 0
    n_families_ingested: int = 0
    n_fold_merges: int = 0
    n_compactions: int = 0
    n_compact_merges: int = 0


class StreamingPartitioner:
    """Incremental G-PART over an unbounded stream of query families.

    Parameters mirror :func:`repro.core.datapart.g_part` (``s_thresh``,
    ``rho_c``, ``rho_c_abs``); ``decay``/``window`` define the rolling
    window, ``drift_threshold`` gates automatic compaction: ``compact()``
    re-merges once the rho mass ingested (or retired) since the last
    compaction exceeds that fraction of the total.
    """

    def __init__(self, sizes: Union[FileSizes, Dict[str, float]],
                 s_thresh: float, rho_c: float = 4.0,
                 rho_c_abs: float = 10.0, decay: float = 1.0,
                 window: Optional[int] = None,
                 drift_threshold: float = 0.5):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if window is not None and window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.sizes = sizes if isinstance(sizes, FileSizes) else FileSizes(sizes)
        self.s_thresh = float(s_thresh)
        self.rho_c = float(rho_c)
        self.rho_c_abs = float(rho_c_abs)
        self.decay = float(decay)
        self.window = window
        self.drift_threshold = float(drift_threshold)
        self.stats = StreamStats()
        # family log: insertion-ordered, so compaction replays the
        # concatenated stream exactly like datapart.make_partitions would
        self._families: Dict[FrozenSet[str], float] = {}
        self._live: Dict[int, Partition] = {}
        self._owner: Dict[FrozenSet[str], int] = {}     # family -> live id
        self._owned: Dict[int, List[FrozenSet[str]]] = {}  # live id -> families
        self._next_id = 0
        # the array-native mirror of _live: same node ids, int32 code rows,
        # spans/rho — all edge weights come from here, one vectorized
        # one-vs-many pass per query instead of per-pair frozenset math
        self._interner = FileInterner()
        self._store = _NodeStore(self._interner)
        self._codes: Dict[FrozenSet[str], np.ndarray] = {}  # family codes
        # merge products at/over the span cap: Algorithm 1 never pushes new
        # edges from them, and no later-arriving node may link to them either
        # (in batch, a family node only ever has edges to its coevals) — the
        # seal is what keeps incremental folds from growing giants unboundedly
        self._sealed: set = set()
        self._history: Deque[Dict[FrozenSet[str], float]] = collections.deque()
        self._rho_drift = 0.0            # rho ingested/retired since compact

    # ------------------------------------------------------------- inspection
    @property
    def partitions(self) -> List[Partition]:
        return list(self._live.values())

    @property
    def n_partitions(self) -> int:
        return len(self._live)

    @property
    def n_families(self) -> int:
        return len(self._families)

    def total_rho(self) -> float:
        return float(sum(p.rho for p in self._live.values()))

    def drift(self) -> float:
        """Fraction of the current rho mass that arrived (or was retired)
        since the last compaction — the compaction trigger metric."""
        return self._rho_drift / max(self.total_rho(), 1e-12)

    # --------------------------------------------------------------- ingest
    def ingest(self, query_files: QueryFamilies) -> List[Partition]:
        """Fold one access-log batch into the partition state.

        Families seen before route their rho straight to the partition that
        owns them (delta propagation); genuinely new families enter as fresh
        nodes and are greedily merged against the live set with the same
        heap rule as Algorithm 1. Returns the current partitions.
        """
        self.stats.n_batches += 1
        if self.decay != 1.0:
            self._apply_decay()
        if self.window is not None:
            self._retire_expired()

        batch: Dict[FrozenSet[str], float] = {}
        touched: List[int] = []
        new_ids: List[int] = []
        for files, rho in query_files:
            key = frozenset(files)
            if not key:
                continue
            self.stats.n_families_ingested += 1
            rho = float(rho)
            self._families[key] = self._families.get(key, 0.0) + rho
            batch[key] = batch.get(key, 0.0) + rho
            self._rho_drift += rho
            owner = self._owner.get(key)
            if owner is not None:
                p = self._live[owner]
                self._live[owner] = Partition(p.files, p.rho + rho, p.sizes)
                self._store.rho[owner] = p.rho + rho
                touched.append(owner)
            else:
                nid = self._next_id
                self._next_id += 1
                self._live[nid] = Partition(key, rho, self.sizes)
                self._store.add(nid, self._family_codes(key), rho)
                self._owner[key] = nid
                self._owned[nid] = [key]
                new_ids.append(nid)
        if self.window is not None:
            self._history.append(batch)
        if touched or new_ids:
            seeds = sorted(set(touched) | set(new_ids))
            self.stats.n_fold_merges += self._merge(self._seed_edges(seeds))
        return self.partitions

    def _family_codes(self, key: FrozenSet[str]) -> np.ndarray:
        codes = self._codes.get(key)
        if codes is None:
            codes = self._codes[key] = self._interner.codes_of(key, self.sizes)
        return codes

    def _apply_decay(self) -> None:
        d = self.decay
        for key in self._families:
            self._families[key] *= d
        for i, p in self._live.items():
            self._live[i] = Partition(p.files, p.rho * d, p.sizes)
            self._store.rho[i] = p.rho * d
        for hist in self._history:
            for key in hist:
                hist[key] *= d
        self._rho_drift *= d

    def _retire_expired(self) -> None:
        """Subtract the contribution of batches older than the window."""
        while len(self._history) >= self.window:
            expired = self._history.popleft()
            for key, rho in expired.items():
                held = self._families.get(key, 0.0)
                take = min(rho, held)          # guard fp drift on re-decayed rho
                if held - take <= 1e-12:
                    take = held
                    self._families.pop(key, None)
                else:
                    self._families[key] = held - take
                owner = self._owner.get(key)
                if owner is not None:
                    p = self._live[owner]
                    new_rho = max(p.rho - take, 0.0)
                    self._live[owner] = Partition(p.files, new_rho, p.sizes)
                    self._store.rho[owner] = new_rho
                self._rho_drift += take

    # ---------------------------------------------------------- merge machinery
    def _push_from(self, heap: List[Tuple[float, int, int]], i: int,
                   targets: List[int]) -> None:
        """Push every feasible positive-overlap edge (i, t) — one vectorized
        weight pass through the shared store."""
        if not targets:
            return
        w, rho_o = self._store.weights_against(i, targets)
        ok = (w > 0.0) & _feasible_mask(self._store.rho[i], rho_o,
                                        self.rho_c, self.rho_c_abs)
        for t in np.flatnonzero(ok):
            k = targets[t]
            heapq.heappush(heap, (-float(w[t]), min(i, k), max(i, k)))

    def _seed_edges(self, seeds: Sequence[int]) -> List[Tuple[float, int, int]]:
        """Heap edges from each seed node to every live partner (the bounded
        local neighbourhood a fold has to consider)."""
        heap: List[Tuple[float, int, int]] = []
        seed_set = set(seeds)
        for i in seeds:
            if i in self._sealed:
                continue
            # both-seed pairs pushed once (from the smaller id)
            targets = [j for j in self._live
                       if j != i and j not in self._sealed
                       and not (j in seed_set and j < i)]
            self._push_from(heap, i, targets)
        return heap

    def _all_edges(self) -> List[Tuple[float, int, int]]:
        """All-pairs edges — Algorithm 1's construction, one vectorized
        row per node instead of a Python pair loop."""
        heap: List[Tuple[float, int, int]] = []
        ids = list(self._live)
        for a_i in range(len(ids)):
            self._push_from(heap, ids[a_i], ids[a_i + 1:])
        return heap

    def _merge(self, heap: List[Tuple[float, int, int]]) -> int:
        """Lazy-deletion heap merge loop — operationally identical to
        ``datapart.g_part`` so compaction reproduces it bit-for-bit."""
        n_merges = 0
        dead: set = set()
        store = self._store
        while heap:
            _, i, j = heapq.heappop(heap)
            if i in dead or j in dead:
                continue
            a, b = self._live[i], self._live[j]
            if not feasible_pair(a, b, self.rho_c, self.rho_c_abs):
                continue
            merged = Partition(a.files | b.files, a.rho + b.rho, a.sizes)
            dead.update((i, j))
            del self._live[i], self._live[j]
            mid = self._next_id
            self._next_id += 1
            self._live[mid] = merged
            store.merge(i, j, mid)
            fams = self._owned.pop(i, []) + self._owned.pop(j, [])
            self._owned[mid] = fams
            for key in fams:
                self._owner[key] = mid
            n_merges += 1
            if store.span[mid] >= self.s_thresh:
                self._sealed.add(mid)
            else:
                self._push_from(heap, mid,
                                [k for k in self._live if k != mid])
        return n_merges

    # --------------------------------------------------------------- compact
    def compact(self, force: bool = False) -> bool:
        """Full re-merge from the family log when drift warrants it.

        Rebuilds one node per accumulated family (in first-seen order) and
        replays Algorithm 1's heap construction exactly, which is what makes
        the compacted state equal batch ``g_part`` on the concatenated
        (decayed / windowed) log. Returns True if a compaction ran.
        """
        if not force and self.drift() <= self.drift_threshold:
            return False
        self._live = {}
        self._owner = {}
        self._owned = {}
        self._sealed = set()
        self._store = _NodeStore(self._interner)
        for i, (key, rho) in enumerate(self._families.items()):
            self._live[i] = Partition(key, rho, self.sizes)
            self._store.add(i, self._family_codes(key), rho)
            self._owner[key] = i
            self._owned[i] = [key]
        self._next_id = len(self._families)
        self.stats.n_compact_merges += self._merge(self._all_edges())
        self.stats.n_compactions += 1
        self._rho_drift = 0.0
        return True
