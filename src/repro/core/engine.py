"""PlacementEngine — the SCOPe pipeline (paper §VII) as composable stages.

The monolithic ``run_pipeline`` is decomposed into four explicit stages that
exchange typed payloads::

    PartitionStage   (parts, file_rows)      -> PartitionedData
    CompressStage    PartitionedData         -> PlacementProblem
    AssignStage      PlacementProblem        -> Assignment
    BillingStage     (problem, assignment)   -> PipelineReport

``PlacementEngine`` wires them together and adds the scenario the monolith
could not express: **online re-optimization**. :meth:`PlacementEngine.reoptimize`
takes an existing :class:`PlacementPlan` plus drifted access rates and returns
a :class:`MigrationPlan` whose objective internalizes tier-change transfer
costs (``CostTable.tier_change_cents_gb``) and early-deletion penalties, and
which can be applied to a live :class:`~repro.storage.store.TieredStore` via
``apply_plan`` / ``migrate`` with full ``BillingMeter`` accounting.

:mod:`repro.core.scope` keeps the legacy ``run_pipeline`` API as a thin
wrapper over this engine.
"""

from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, FrozenSet, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import datapart
from repro.core.cache import (CacheConfig, cache_access_adjustment,
                              cache_cents, forecast_admission,
                              served_latency_terms, weighted_p99_ms)
from repro.core.stream import QueryFamilies, StreamingPartitioner
from repro.core.costs import (CostTable, Weights, cost_tensor,
                              early_delete_penalty_gb, latency_feasible,
                              move_egress_cents_gb, sla_penalty_tensor)
from repro.core.optassign import (Assignment, capacitated_assign,
                                  greedy_assign, lock_schemes)
from repro.data.tables import Table
from repro.storage.codecs import available_schemes, codec_by_name, measure


@dataclasses.dataclass
class ScopeConfig:
    use_partitioning: bool = True
    use_tiering: bool = True
    use_compression: bool = True
    weights: Weights = dataclasses.field(default_factory=Weights)
    months: float = 5.5                      # paper's evaluation window
    schemes: Sequence[str] = dataclasses.field(default_factory=available_schemes)
    layout: str = "col"
    capacity_gb: Optional[np.ndarray] = None  # None = unbounded (greedy path)
    latency_sla_sec: float = np.inf
    tier_whitelist: Optional[Sequence[int]] = None  # e.g. (0,1,2) = no archive
    provider_whitelist: Optional[Sequence[str]] = None  # multi-cloud tables:
    # restrict placement to these providers' flat tiers (None = all)
    s_thresh_mult: float = 3.0               # G-PART span cap, x median family span
    rho_c: float = 4.0
    rho_c_abs: float = 10.0
    # G-PART candidate-graph backend: 'numpy' (exact inverted-index join),
    # 'jnp' | 'pallas' | 'interpret' (device overlap-matrix kernel, the
    # latter sharded over an active mesh), or 'ref' (original pair loop)
    partition_backend: str = "numpy"
    partition_sample: Optional[float] = None  # MinHash-style code sampling
    # rate for the candidate graph (None = exact; see docs/engine.md)
    predictor: str = "truth"                 # 'truth' | fitted CompressionPredictor
    feature_backend: str = "numpy"           # 'numpy' | 'jnp' | 'pallas'
    fixed_tier: Optional[int] = None         # e.g. 0 -> 'store on premium'
    # ---- serving SLA (soft constraints; see docs/engine.md) -------------
    sla_lambda: float = 0.0                  # objective = cost + lambda*penalty
    sla_ms: float = np.inf                   # default per-partition SLA target
    # (per-partition overrides via PlacementProblem.sla_ms; inf = no target)
    cache: Optional[CacheConfig] = None      # optional serving cache tier
    replicas: int = 1                        # copies for hot partitions
    replica_rho_min: float = np.inf          # replicate when rho >= this


@dataclasses.dataclass
class PipelineReport:
    storage_cents: float
    decomp_cents: float
    read_cents: float
    total_cents: float
    read_latency_ttfb: float          # access-weighted mean TTFB (s)
    decomp_latency_ms: float          # access-weighted mean decompression (ms)
    tiering_scheme: List[int]         # partitions per tier
    n_partitions: int
    assignment: Assignment
    spans_gb: np.ndarray
    rho: np.ndarray
    schemes: Sequence[str]
    provider_scheme: Optional[List[int]] = None  # partitions per provider
    # (multi-cloud tables only; None for single-cloud)
    # ---- serving metrics (SLA/cache; zero when the features are off) ----
    sla_penalty: float = 0.0          # rho-weighted excess ms — NOT cents,
    # never metered by BillingMeter; lambda-weighted only inside the solver
    p99_latency_ms: float = 0.0       # access-weighted p99 serving latency
    cache_cents: float = 0.0          # cache storage + fill spend (real cents,
    # included in total_cents when a cache tier is configured)
    n_cached: int = 0                 # partitions admitted to the cache


@dataclasses.dataclass
class PartitionedData:
    """Output of :class:`PartitionStage`."""

    partitions: List[datapart.Partition]
    tables: List[Table]
    raw_bytes: List[bytes]
    spans_gb: np.ndarray              # (N,)
    rho: np.ndarray                   # (N,)


@dataclasses.dataclass
class PlacementProblem:
    """Everything :class:`AssignStage` needs — the typed stage boundary."""

    spans_gb: np.ndarray              # (N,)  raw partition sizes
    rho: np.ndarray                   # (N,)  projected access counts
    current_tier: np.ndarray          # (N,)  -1 = new data (ingestion)
    R: np.ndarray                     # (N,K) compression ratios (>= 1)
    D: np.ndarray                     # (N,K) decompression seconds, whole part
    schemes: Sequence[str]
    table: CostTable
    cfg: ScopeConfig
    partitions: Optional[List[datapart.Partition]] = None
    raw_bytes: Optional[List[bytes]] = None
    sla_ms: Optional[np.ndarray] = None  # (N,) per-partition SLA targets;
    # None -> broadcast cfg.sla_ms (inf = no target, zero penalty)

    @property
    def n(self) -> int:
        return int(self.spans_gb.shape[0])

    def effective_sla_ms(self) -> np.ndarray:
        """(N,) SLA targets: the per-partition override or the config
        default broadcast. ``inf`` rows contribute exactly zero penalty."""
        if self.sla_ms is not None:
            sla = np.asarray(self.sla_ms, np.float64)
            if sla.shape != (self.n,):
                raise ValueError(f"sla_ms must have shape ({self.n},), "
                                 f"got {sla.shape}")
            return sla
        return np.full(self.n, float(self.cfg.sla_ms))

    def stored_matrix(self) -> np.ndarray:
        """(N,L,K) GB occupied if cell (l,k) is chosen (tier-independent)."""
        L = self.table.num_tiers
        return np.repeat((self.spans_gb[:, None] / self.R)[:, None, :], L, 1)


@dataclasses.dataclass
class PlacementPlan:
    problem: PlacementProblem
    assignment: Assignment
    report: PipelineReport

    @property
    def stored_gb(self) -> np.ndarray:
        """(N,) GB actually occupied under the chosen schemes."""
        n = np.arange(self.problem.n)
        return self.problem.spans_gb / self.problem.R[n, self.assignment.scheme]


def drift_gate(rho: np.ndarray, rho_ref: np.ndarray, rho_rel_tol: float,
               rho_abs_tol: float = 0.0) -> np.ndarray:
    """Boolean drift mask shared by ``reoptimize``, the streaming engine,
    and the daemon's hysteresis.

    A partition counts as drifted only when ``|rho - rho_ref|`` exceeds
    **both** the relative band (``rho_rel_tol`` of the lock-base rate) and
    the absolute floor ``rho_abs_tol``. The floor is what keeps the scheme
    lock stable for cold data: with ``rho_ref == 0`` the relative band
    collapses to ~0, so without a floor a single epsilon access would
    unlock (and churn) every cold partition.
    """
    thr = np.maximum(rho_rel_tol * np.maximum(rho_ref, 1e-12), rho_abs_tol)
    return np.abs(rho - rho_ref) > thr


@dataclasses.dataclass
class MigrationPlan:
    """Incremental move set produced by :meth:`PlacementEngine.reoptimize`.

    The solver proposes a set of **candidate** moves; by default all of
    them are **selected** (``moved == candidate``). Under a migration
    budget, :meth:`select` keeps a subset and reverts the rest — the
    daemon defers them to a later cycle. Per-move cents arrays carry the
    one-off charge break-up so partial plans meter exactly.
    """

    plan: PlacementPlan               # re-optimized placement (new rho)
    moved: np.ndarray                 # (N,) bool — selected moves
    old_tier: np.ndarray
    new_tier: np.ndarray
    old_scheme: np.ndarray
    new_scheme: np.ndarray
    migration_cents: float            # read-out + egress + write-in transfer
    penalty_cents: float              # early-deletion charges
    egress_cents: float = 0.0         # cross-provider egress component of
    # migration_cents (already included there; broken out for visibility)
    candidate: Optional[np.ndarray] = None   # (N,) bool — proposed moves
    move_transfer_cents: Optional[np.ndarray] = None  # (N,) read+write, no egress
    move_egress_cents: Optional[np.ndarray] = None    # (N,)
    move_penalty_cents: Optional[np.ndarray] = None   # (N,)
    old_stored_gb: Optional[np.ndarray] = None        # (N,) bytes at old cell

    def __post_init__(self):
        if self.candidate is None:
            self.candidate = self.moved.copy()
        z = lambda: np.zeros(self.moved.shape[0])
        if self.move_transfer_cents is None:
            self.move_transfer_cents = z()
        if self.move_egress_cents is None:
            self.move_egress_cents = z()
        if self.move_penalty_cents is None:
            self.move_penalty_cents = z()
        if self.old_stored_gb is None:
            self.old_stored_gb = z()

    @property
    def n_moved(self) -> int:
        return int(self.moved.sum())

    @property
    def n_candidates(self) -> int:
        return int(self.candidate.sum())

    @property
    def deferred(self) -> np.ndarray:
        """(N,) bool — candidate moves not selected this cycle."""
        return self.candidate & ~self.moved

    @property
    def total_move_cents(self) -> float:
        return self.migration_cents + self.penalty_cents

    def steady_savings_cents(self, months: Optional[float] = None,
                             ) -> np.ndarray:
        """(N,) steady-state savings each candidate move yields over
        ``months`` (default: the plan's ``cfg.months`` horizon) — old cell
        minus new cell under the plan's access rates. The daemon's knapsack
        numerator.

        With a serving SLA configured (``cfg.sla_lambda > 0``) the savings
        additionally include the lambda-weighted latency-penalty relief of
        the move, so SLA-violation moves compete in the same
        savings-per-cent knapsack as pure cost moves. The relief is an
        *objective* quantity (lambda * excess-ms), not cents — what gets
        **spent** on a move (``move_transfer/egress/penalty_cents``) stays
        pure cents either way. With a cache tier, admitted partitions'
        backing traffic is their miss traffic only.
        """
        p = self.plan.problem
        t = p.table
        cfg = p.cfg
        m = cfg.months if months is None else float(months)
        n = np.arange(p.n)
        old_l = np.maximum(self.old_tier, 0)
        old_k = np.maximum(self.old_scheme, 0)
        new_l, new_k = self.new_tier.astype(int), self.new_scheme.astype(int)

        rho_eff = p.rho
        if cfg.cache is not None:
            cached = forecast_admission(p.rho, p.spans_gb, cfg.cache)
            rho_eff = np.where(cached, cfg.cache.miss_rate * p.rho, p.rho)

        def cell(stored, l, k):
            return (stored * t.storage_cents_gb_month[l] * m
                    + rho_eff * (stored * t.read_cents_gb[l]
                                 + p.D[n, k] * t.compute_cents_sec))

        new_stored = p.spans_gb / p.R[n, new_k]
        sav = cell(self.old_stored_gb, old_l, old_k) \
            - cell(new_stored, new_l, new_k)
        if cfg.sla_lambda > 0:
            sla = p.effective_sla_ms()
            if bool(np.isfinite(sla).any()):
                def excess(l, k):
                    lat = (t.ttfb_seconds[l] + p.D[n, k]) * 1e3
                    return np.where(np.isfinite(sla),
                                    np.maximum(lat - sla, 0.0), 0.0)
                sav = sav + cfg.sla_lambda * rho_eff * (
                    excess(old_l, old_k) - excess(new_l, new_k))
        return np.where(self.candidate, sav, 0.0)

    def select(self, keep: np.ndarray) -> "MigrationPlan":
        """Partial plan executing only ``candidate & keep``.

        Deferred partitions revert to their old tier and scheme in the
        returned plan's assignment (so ``TieredStore.migrate``/``sync_plan``
        leave them untouched and the steady-state report prices the state
        actually reached); aggregate cents re-sum the selected moves only.
        When every candidate is kept, returns ``self`` unchanged — the
        unbudgeted path stays bit-identical.
        """
        sel = self.candidate & np.asarray(keep, bool)
        if bool((sel == self.candidate).all()):
            return self
        defer = self.candidate & ~sel
        tier = np.where(defer, self.old_tier, self.new_tier).astype(int)
        scheme = np.where(defer, self.old_scheme, self.new_scheme).astype(int)
        problem = self.plan.problem
        # the migration objective (one-off terms included) is not
        # reconstructible here, so the partial assignment carries no cost
        assignment = dataclasses.replace(self.plan.assignment, tier=tier,
                                         scheme=scheme, cost=float("nan"))
        report = BillingStage(problem.table, problem.cfg)(problem, assignment)
        egress = float(np.where(sel, self.move_egress_cents, 0.0).sum())
        transfer = float(np.where(sel, self.move_transfer_cents, 0.0).sum())
        penalty = float(np.where(sel, self.move_penalty_cents, 0.0).sum())
        return MigrationPlan(
            plan=PlacementPlan(problem, assignment, report), moved=sel,
            old_tier=self.old_tier, new_tier=tier,
            old_scheme=self.old_scheme, new_scheme=scheme,
            migration_cents=egress + transfer, penalty_cents=penalty,
            egress_cents=egress, candidate=self.candidate.copy(),
            move_transfer_cents=self.move_transfer_cents,
            move_egress_cents=self.move_egress_cents,
            move_penalty_cents=self.move_penalty_cents,
            old_stored_gb=self.old_stored_gb)

    def land(self, unapplied: np.ndarray) -> "MigrationPlan":
        """Fold execution outcomes back into the plan.

        ``unapplied`` marks selected moves that did **not** land (the
        executor's failed/budget-skipped rows). Those moves revert to
        deferred-candidate status — old tier/scheme in the assignment, so
        the steady-state report prices the state actually reached and the
        next cycle re-plans them. When every selected move landed, returns
        ``self`` unchanged (the zero-fault parity pin).
        """
        unapplied = np.asarray(unapplied, bool)
        if not bool((unapplied & self.moved).any()):
            return self
        return self.select(self.moved & ~unapplied)


@dataclasses.dataclass
class ReplicaPlan:
    """K-replica placement for read locality (hot partitions only).

    Extra copies of a partition are placed on *distinct providers* (or
    distinct tiers, for single-cloud tables) so reads can be served by the
    closest/fastest copy; each of a partition's ``copies`` serves ``1 /
    copies`` of its reads. Produced by
    :meth:`PlacementEngine.plan_replicas`.
    """

    copies: np.ndarray                # (N,) total copies actually placed
    replica_tier: np.ndarray          # (N, R-1) int; -1 = no copy
    replica_scheme: np.ndarray        # (N, R-1) int; -1 = no copy
    replica_cents: float              # storage + ingestion write + the read
    # share the replicas serve — real cents
    read_rebate_cents: float          # primary access cents now served by
    # replicas instead (subtract from the base report when combining)
    best_latency_ms: np.ndarray       # (N,) fastest copy's backing latency

    @property
    def n_replicated(self) -> int:
        return int((self.copies > 1).sum())

    def latency_points(self, problem: "PlacementProblem",
                       assignment: Assignment,
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Serving-latency distribution with reads split across copies:
        ``(latency_ms_points, access_weights)`` for
        :func:`repro.core.cache.weighted_p99_ms`."""
        t = problem.table
        n = np.arange(problem.n)
        pts = [(t.ttfb_seconds[assignment.tier.astype(int)]
                + problem.D[n, assignment.scheme.astype(int)]) * 1e3]
        wts = [problem.rho / self.copies]
        for j in range(self.replica_tier.shape[1]):
            l_j = self.replica_tier[:, j]
            k_j = self.replica_scheme[:, j]
            has = l_j >= 0
            safe_l, safe_k = np.maximum(l_j, 0), np.maximum(k_j, 0)
            pts.append(np.where(
                has, (t.ttfb_seconds[safe_l] + problem.D[n, safe_k]) * 1e3,
                0.0))
            wts.append(np.where(has, problem.rho / self.copies, 0.0))
        return np.concatenate(pts), np.concatenate(wts)


# ------------------------------------------------------------------ stages
class PartitionStage:
    """G-PART merge (or per-dataset baseline) + partition materialization."""

    def __init__(self, cfg: ScopeConfig):
        self.cfg = cfg

    @staticmethod
    def _partition_tables(parts: Sequence[datapart.Partition],
                          file_rows: Dict[str, Tuple[Table, np.ndarray]],
                          ) -> List[Table]:
        """Materialize each partition as the concatenation of its files' rows."""
        out: List[Table] = []
        for p in parts:
            per_table: Dict[str, List[np.ndarray]] = {}
            for f in sorted(p.files):
                t, idx = file_rows[f]
                per_table.setdefault(t.name, []).append(idx)
            # A query family touches exactly one table in our workload; guard anyway.
            name = max(per_table, key=lambda n: sum(len(i) for i in per_table[n]))
            t0 = [file_rows[f][0] for f in sorted(p.files)
                  if file_rows[f][0].name == name][0]
            idx = np.sort(np.concatenate(per_table[name]))
            out.append(t0.select(idx))
        return out

    def __call__(self, parts: List[datapart.Partition],
                 file_rows: Dict[str, Tuple[Table, np.ndarray]],
                 ) -> PartitionedData:
        cfg = self.cfg
        if cfg.use_partitioning:
            med = float(np.median([p.span for p in parts])) if parts else 0.0
            mesh = None
            if cfg.partition_backend in ("jnp", "pallas"):
                from repro.distributed import ctx
                mesh = ctx.mesh()
            merged = datapart.g_part(parts, s_thresh=cfg.s_thresh_mult * med,
                                     rho_c=cfg.rho_c, rho_c_abs=cfg.rho_c_abs,
                                     backend=cfg.partition_backend,
                                     sample=cfg.partition_sample, mesh=mesh)
        else:
            # paper's non-partitioned baselines treat each DATASET (table) as
            # one partition: every access scans its whole table
            by_table: Dict[str, List[datapart.Partition]] = {}
            for p in parts:
                tname = sorted(p.files)[0].split("/")[0]
                by_table.setdefault(tname, []).append(p)
            merged = []
            for group in by_table.values():
                merged.extend(datapart.merge_all(group))
        tables = self._partition_tables(merged, file_rows)
        raw_bytes = [t.serialize(cfg.layout) for t in tables]
        spans_gb = np.array([len(b) / 1e9 for b in raw_bytes])
        rho = np.array([p.rho for p in merged])
        return PartitionedData(merged, tables, raw_bytes, spans_gb, rho)


class CompressStage:
    """Per-partition (ratio, decompression-time) matrices — measured ground
    truth or a fitted COMPREDICT model.

    With a fitted predictor, features for all N partitions are extracted by
    ``cfg.feature_backend`` ('numpy' per-partition loop, or the batched
    'jnp'/'pallas' device pipeline — one dispatch for the whole batch) and
    serialized sizes are reused from :class:`PartitionStage` instead of
    re-serializing every table."""

    def __init__(self, cfg: ScopeConfig):
        self.cfg = cfg

    def __call__(self, data: PartitionedData, table: CostTable,
                 ) -> PlacementProblem:
        cfg = self.cfg
        N = len(data.partitions)
        schemes = list(cfg.schemes) if cfg.use_compression else ["none"]
        K = len(schemes)
        R = np.ones((N, K))
        D = np.zeros((N, K))
        if cfg.use_compression:
            if cfg.predictor == "truth":
                for i, b in enumerate(data.raw_bytes):
                    for k, s in enumerate(schemes):
                        if s == "none":
                            continue
                        m = measure(codec_by_name(s), b)
                        R[i, k] = m.ratio
                        D[i, k] = m.decompress_sec_per_gb * (len(b) / 1e9)
            else:
                pred = cfg.predictor  # fitted CompressionPredictor instance
                Rm, Dm = pred.predict_matrix(
                    data.tables, schemes, cfg.layout,
                    sizes=[len(b) for b in data.raw_bytes],
                    feature_backend=cfg.feature_backend)
                R = Rm
                D = Dm * data.spans_gb[:, None]  # sec/GB -> sec per partition
        return PlacementProblem(
            spans_gb=data.spans_gb, rho=data.rho,
            current_tier=np.full(N, -1), R=R, D=D, schemes=schemes,
            table=table, cfg=cfg, partitions=data.partitions,
            raw_bytes=data.raw_bytes)


class AssignStage:
    """OPTASSIGN: cost tensor + feasibility mask + (greedy | capacitated)."""

    def __init__(self, table: CostTable, cfg: ScopeConfig):
        self.table = table
        self.cfg = cfg

    def serving_terms(self, problem: PlacementProblem,
                      ) -> Tuple[Optional[np.ndarray],
                                 Optional[np.ndarray]]:
        """``(cached, serving_cost)`` — the SLA + cache extension of the
        objective, as one additive (N,L,K) tensor.

        ``cached`` is the forecast-driven cache admission mask (None
        without a cache tier). The rho the solve sees is already the
        projected rate when a forecaster is attached, so admission is
        forecast-driven with zero extra plumbing. ``serving_cost`` is
        ``sla_lambda * penalty + cache access relief``; it is **None**
        whenever ``sla_lambda == 0`` and no cache tier is configured, so
        the default config leaves every solver input byte-identical to the
        pre-SLA engine (the bit-parity pin).
        """
        cfg = self.cfg
        cached = None
        extra = None
        if cfg.cache is not None:
            cached = forecast_admission(problem.rho, problem.spans_gb,
                                        cfg.cache)
            extra = cache_access_adjustment(
                problem.rho, problem.stored_matrix(), problem.D, self.table,
                cfg.weights, cached, cfg.cache.miss_rate)
        if cfg.sla_lambda > 0:
            sla = problem.effective_sla_ms()
            if bool(np.isfinite(sla).any()):
                pen = sla_penalty_tensor(problem.rho, sla, problem.D,
                                         self.table)
                if cached is not None:
                    # Admitted rows serve (1 - miss_rate) of reads at the
                    # cache hit latency: the backing-tier penalty scales to
                    # the miss traffic, plus a tier-independent term for
                    # hits that still miss an (aggressive) SLA target.
                    m = cfg.cache.miss_rate
                    hit_ex = np.where(
                        np.isfinite(sla),
                        np.maximum(cfg.cache.hit_latency_ms - sla, 0.0),
                        0.0)
                    hit_pen = ((1.0 - m) * problem.rho
                               * hit_ex)[:, None, None]
                    pen = np.where(cached[:, None, None],
                                   m * pen + hit_pen, pen)
                lam_pen = cfg.sla_lambda * pen
                extra = lam_pen if extra is None else extra + lam_pen
        return cached, extra

    def cost_and_feasibility(
        self, problem: PlacementProblem,
        extra_cost: Optional[np.ndarray] = None,      # (N,L,K) additive
        locked_scheme: Optional[np.ndarray] = None,   # (N,) -1 = free
    ) -> Tuple[np.ndarray, np.ndarray]:
        cfg, table = self.cfg, self.table
        N = problem.n
        cost = cost_tensor(problem.spans_gb, problem.rho, problem.current_tier,
                           problem.R, problem.D, table, cfg.weights,
                           months=cfg.months)
        if extra_cost is not None:
            cost = cost + extra_cost
        _, serving = self.serving_terms(problem)
        if serving is not None:
            cost = cost + serving
        feas = latency_feasible(problem.D, np.full(N, cfg.latency_sla_sec),
                                table)
        if cfg.tier_whitelist is not None:
            allowed = np.zeros(table.num_tiers, bool)
            allowed[list(cfg.tier_whitelist)] = True
            feas &= allowed[None, :, None]
        if cfg.provider_whitelist is not None:
            pnames = getattr(table, "provider_names", None)
            if pnames is None:
                raise ValueError("provider_whitelist requires a "
                                 "MultiCloudCostTable")
            unknown = set(cfg.provider_whitelist) - set(pnames)
            if unknown:
                raise ValueError(f"unknown providers {sorted(unknown)}; "
                                 f"table has {pnames}")
            wanted = np.array([p in cfg.provider_whitelist for p in pnames])
            feas &= wanted[table.provider_of_tier][None, :, None]
        if not cfg.use_tiering:
            fixed = cfg.fixed_tier if cfg.fixed_tier is not None else 0
            only = np.zeros(table.num_tiers, bool)
            only[fixed] = True
            feas &= only[None, :, None]
        if locked_scheme is not None:
            feas = lock_schemes(feas, locked_scheme)
        return cost, feas

    def solver_inputs(
        self, problem: PlacementProblem,
        extra_cost: Optional[np.ndarray] = None,
        locked_scheme: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray],
               Optional[np.ndarray], Optional[np.ndarray]]:
        """``(cost, feas, stored, cap, tier_groups, group_capacity_gb)``
        exactly as :meth:`__call__` hands them to the solver. ``cap`` is
        None when the config sets no per-tier capacities; the group fields
        are None unless the table carries finite provider capacities. The
        fleet path (:class:`repro.core.fleet.FleetEngine`) batches these
        per-tenant tuples into one ``capacitated_assign_batch`` dispatch."""
        cost, feas = self.cost_and_feasibility(problem, extra_cost,
                                               locked_scheme)
        # Multi-cloud tables carry per-provider capacity totals; finite ones
        # become group constraint rows in the capacitated solver.
        gcap = getattr(self.table, "provider_capacity_gb", None)
        has_gcap = gcap is not None and bool(np.isfinite(gcap).any())
        cap = (np.asarray(self.cfg.capacity_gb, np.float64)
               if self.cfg.capacity_gb is not None else None)
        return (cost, feas, problem.stored_matrix(), cap,
                self.table.provider_of_tier if has_gcap else None,
                gcap if has_gcap else None)

    def __call__(self, problem: PlacementProblem,
                 extra_cost: Optional[np.ndarray] = None,
                 locked_scheme: Optional[np.ndarray] = None) -> Assignment:
        cost, feas, stored, cap, tg, gcap = self.solver_inputs(
            problem, extra_cost, locked_scheme)
        if cap is None and tg is None:
            return greedy_assign(cost, feas)
        if cap is None:
            cap = np.full(self.table.num_tiers, np.inf)
        return capacitated_assign(cost, feas, stored, cap, tier_groups=tg,
                                  group_capacity_gb=gcap)


class BillingStage:
    """Steady-state bill of an assignment — pure array math, no Python loop."""

    def __init__(self, table: CostTable, cfg: ScopeConfig):
        self.table = table
        self.cfg = cfg

    def __call__(self, problem: PlacementProblem,
                 assignment: Assignment) -> PipelineReport:
        t, cfg = self.table, self.cfg
        l = assignment.tier.astype(int)
        k = assignment.scheme.astype(int)
        n_idx = np.arange(problem.n)
        stored = problem.spans_gb / problem.R[n_idx, k]
        d_sec = problem.D[n_idx, k]
        rho = problem.rho
        # Cache tier: admitted partitions only hit the backing tier on a
        # miss, and the cache's own storage/fill spend is real cents. The
        # admission mask is a pure function of (problem, cfg) — the same
        # mask the solver priced — so select()-re-billing stays consistent.
        cached = None
        cache_spend = 0.0
        rho_b = rho                       # backing-tier read traffic
        if cfg.cache is not None:
            cached = forecast_admission(rho, problem.spans_gb, cfg.cache)
            rho_b = np.where(cached, cfg.cache.miss_rate * rho, rho)
            cache_spend = cache_cents(problem.spans_gb, cached, cfg.cache,
                                      cfg.months)
        storage = float((stored * t.storage_cents_gb_month[l]).sum()
                        * cfg.months)
        read = float((rho_b * stored * t.read_cents_gb[l]).sum())
        decomp = float((rho_b * d_sec).sum() * t.compute_cents_sec)
        rho_tot = float(rho.sum())
        ttfb_acc = float((rho * t.ttfb_seconds[l]).sum())
        dlat_acc = float((rho * d_sec).sum())
        # Serving-latency metrics: raw penalty units and p99 — reported,
        # never billed (BillingMeter cents fields stay latency-free).
        lat_ms = (t.ttfb_seconds[l] + d_sec) * 1e3
        pts, w = served_latency_terms(rho, lat_ms, cached,
                                      cfg.cache if cached is not None
                                      else None)
        sla = problem.effective_sla_ms()
        sla_pts = np.concatenate([sla, sla]) if cached is not None else sla
        excess = np.where(np.isfinite(sla_pts),
                          np.maximum(pts - sla_pts, 0.0), 0.0)
        sla_penalty = float((w * excess).sum())
        p99 = weighted_p99_ms(pts, w)
        counts = np.bincount(l[l >= 0], minlength=t.num_tiers)
        prov = getattr(t, "provider_of_tier", None)
        provider_scheme = None
        if prov is not None:
            pc = np.bincount(np.asarray(prov, int)[l[l >= 0]],
                             minlength=len(t.provider_names))
            provider_scheme = [int(c) for c in pc]
        return PipelineReport(
            storage_cents=storage, decomp_cents=decomp, read_cents=read,
            total_cents=storage + decomp + read + cache_spend,
            read_latency_ttfb=ttfb_acc / max(rho_tot, 1e-12),
            decomp_latency_ms=1e3 * dlat_acc / max(rho_tot, 1e-12),
            tiering_scheme=[int(c) for c in counts],
            n_partitions=problem.n, assignment=assignment,
            spans_gb=problem.spans_gb, rho=rho, schemes=problem.schemes,
            provider_scheme=provider_scheme,
            sla_penalty=sla_penalty, p99_latency_ms=p99,
            cache_cents=cache_spend,
            n_cached=int(cached.sum()) if cached is not None else 0)


# ------------------------------------------------------------------ engine
class PlacementEngine:
    """Staged SCOPe pipeline + online re-optimization."""

    def __init__(self, table: CostTable, cfg: ScopeConfig):
        self.table = table
        self.cfg = cfg
        self.partition = PartitionStage(cfg)
        self.compress = CompressStage(cfg)
        self.assign = AssignStage(table, cfg)
        self.billing = BillingStage(table, cfg)

    # ------------------------------------------------------------- batch path
    def build_problem(self, parts: List[datapart.Partition],
                      file_rows: Dict[str, Tuple[Table, np.ndarray]],
                      ) -> PlacementProblem:
        return self.compress(self.partition(parts, file_rows), self.table)

    def solve(self, problem: PlacementProblem) -> PlacementPlan:
        assignment = self.assign(problem)
        report = self.billing(problem, assignment)
        return PlacementPlan(problem, assignment, report)

    def run(self, parts: List[datapart.Partition],
            file_rows: Dict[str, Tuple[Table, np.ndarray]]) -> PlacementPlan:
        return self.solve(self.build_problem(parts, file_rows))

    # ----------------------------------------------------------- replicas
    def plan_replicas(self, plan: PlacementPlan,
                      n_copies: Optional[np.ndarray] = None) -> ReplicaPlan:
        """Place extra read-locality copies of hot partitions.

        ``n_copies`` is the per-partition total copy count (primary
        included); by default partitions with ``rho >= cfg.replica_rho_min``
        get ``cfg.replicas`` copies and everything else one. Each extra
        copy is one additional **placement row** solved through the same
        cost tensor / solver as the primary: ingestion write + storage at
        the candidate tier plus the ``rho / copies`` read share it will
        serve, with the primary's compression scheme locked (replicas store
        the same encoded payload). Feasibility excludes every provider
        already hosting a copy (multi-cloud) or every tier already hosting
        one (single-cloud), so copies are placement-diverse by
        construction; replica passes respect residual per-tier capacities
        when ``cfg.capacity_gb`` is set. A partition whose remaining
        feasible set is empty simply gets fewer copies.

        The returned cents are additive bookkeeping against the base
        report: ``plan.report.total_cents - read_rebate_cents +
        replica_cents`` is the combined steady bill (the rebate is the
        share of the primary's access cost the replicas now serve).
        """
        prob = plan.problem
        cfg, t = self.cfg, self.table
        N = prob.n
        L = t.num_tiers
        if n_copies is None:
            want = np.where(prob.rho >= cfg.replica_rho_min,
                            max(int(cfg.replicas), 1), 1)
        else:
            want = np.maximum(np.asarray(n_copies, int), 1)
        rmax = int(want.max()) if N else 1
        prim_l = plan.assignment.tier.astype(int)
        prim_k = plan.assignment.scheme.astype(int)
        rep_tier = np.full((N, max(rmax - 1, 0)), -1, int)
        rep_scheme = np.full((N, max(rmax - 1, 0)), -1, int)
        copies = np.ones(N, int)
        if rmax <= 1 or N == 0:
            n_idx = np.arange(N)
            lat0 = (t.ttfb_seconds[np.maximum(prim_l, 0)]
                    + prob.D[n_idx, np.maximum(prim_k, 0)]) * 1e3
            return ReplicaPlan(copies, rep_tier, rep_scheme, 0.0, 0.0, lat0)

        prov = getattr(t, "provider_of_tier", None)
        used = np.zeros((N, L), bool)          # blocked tiers per partition
        safe_pl = np.maximum(prim_l, 0)
        if prov is None:
            used[np.arange(N), safe_pl] = True
        else:
            used = np.asarray(prov)[None, :] == np.asarray(prov)[safe_pl][:, None]

        # residual per-tier capacity, aged by the primaries + prior passes
        cap = (np.asarray(cfg.capacity_gb, np.float64).copy()
               if cfg.capacity_gb is not None else None)
        if cap is not None:
            usage = np.zeros(L)
            np.add.at(usage, safe_pl, plan.stored_gb)
            cap = cap - usage
        # replica rows must not re-trigger cache admission (the cache holds
        # one serving copy, fed by whichever replica is closest)
        cfg2 = dataclasses.replace(cfg, cache=None, capacity_gb=None)
        stage = AssignStage(t, cfg2)
        rep_cents = 0.0
        rebate = 0.0
        n_all = np.arange(N)
        for j in range(rmax - 1):
            rows = np.flatnonzero(want > j + 1)
            if rows.size == 0:
                break
            share = prob.rho[rows] / want[rows]
            sub = PlacementProblem(
                spans_gb=prob.spans_gb[rows], rho=share,
                current_tier=np.full(rows.size, -1),
                R=prob.R[rows], D=prob.D[rows], schemes=prob.schemes,
                table=t, cfg=cfg2,
                sla_ms=(prob.sla_ms[rows] if prob.sla_ms is not None
                        else None))
            cost, feas = stage.cost_and_feasibility(
                sub, locked_scheme=prim_k[rows])
            feas = feas & ~used[rows][:, :, None]
            ok = feas.any(axis=(1, 2))
            if not ok.any():
                continue
            rows = rows[ok]
            cost, feas = cost[ok], feas[ok]
            sub_stored = (prob.spans_gb[rows][:, None]
                          / prob.R[rows])[:, None, :].repeat(L, 1)
            if cap is not None:
                asg = capacitated_assign(cost, feas, sub_stored,
                                         np.maximum(cap, 0.0))
                if not asg.feasible:
                    continue
            else:
                asg = greedy_assign(cost, feas)
                if not asg.feasible:
                    continue
            l_j = asg.tier.astype(int)
            k_j = asg.scheme.astype(int)
            rep_tier[rows, j] = l_j
            rep_scheme[rows, j] = k_j
            copies[rows] += 1
            stored_j = prob.spans_gb[rows] / prob.R[rows, k_j]
            # real cents only — never the lambda-weighted penalty the
            # solver may have folded into `cost`
            rep_cents += float(
                (stored_j * (t.storage_cents_gb_month[l_j] * cfg.months
                             + t.write_cents_gb[l_j])).sum()
                + cfg.weights.beta * (share[ok] * (
                    stored_j * t.read_cents_gb[l_j]
                    + prob.D[rows, k_j] * t.compute_cents_sec)).sum())
            if prov is None:
                used[rows, l_j] = True
            else:
                used[rows] |= (np.asarray(prov)[None, :]
                               == np.asarray(prov)[l_j][:, None])
            if cap is not None:
                np.add.at(cap, l_j, -stored_j)

        # read share the replicas serve, priced at the PRIMARY's cell —
        # that is the traffic the base report no longer has to bill
        rep_n = copies > 1
        if rep_n.any():
            stored_p = prob.spans_gb / prob.R[n_all, np.maximum(prim_k, 0)]
            prim_access = cfg.weights.beta * prob.rho * (
                stored_p * t.read_cents_gb[safe_pl]
                + prob.D[n_all, np.maximum(prim_k, 0)] * t.compute_cents_sec)
            rebate = float((prim_access[rep_n]
                            * (copies[rep_n] - 1) / copies[rep_n]).sum())

        lat = (t.ttfb_seconds[safe_pl]
               + prob.D[n_all, np.maximum(prim_k, 0)]) * 1e3
        best = lat.copy()
        for j in range(rep_tier.shape[1]):
            has = rep_tier[:, j] >= 0
            sl = np.maximum(rep_tier[:, j], 0)
            sk = np.maximum(rep_scheme[:, j], 0)
            lat_j = (t.ttfb_seconds[sl] + prob.D[n_all, sk]) * 1e3
            best = np.where(has, np.minimum(best, lat_j), best)
        return ReplicaPlan(copies, rep_tier, rep_scheme, rep_cents, rebate,
                           best)

    # ------------------------------------------------------------ online path
    def reoptimize(self, plan: PlacementPlan, new_rho: np.ndarray,
                   months_held: "float | np.ndarray" = 0.0,
                   lock_unchanged: bool = True,
                   rho_rel_tol: float = 0.25,
                   rho_abs_tol: float = 0.0,
                   rho_ref: Optional[np.ndarray] = None) -> MigrationPlan:
        """Incremental migration plan for drifted access rates.

        The assignment objective is the steady-state cost under ``new_rho``
        **plus** the one-off cost of getting there: tier-change transfer
        (already in the cost tensor via ``current_tier`` and Delta_{u,v}),
        same-tier re-compression transfer, and early-deletion penalties for
        leaving a tier before its minimum stay (``months_held`` months after
        the last placement). ``months_held`` may be a scalar or an (N,)
        array — partitions placed at different times (e.g. a daemon's
        survivors vs. last cycle's movers) price their early-delete
        penalties with their own residency clocks. Partitions whose access
        rate drifted less than ``rho_rel_tol`` (relative, with the
        ``rho_abs_tol`` absolute floor — see :func:`drift_gate`) keep their
        scheme locked, so stable data is never re-compressed. ``rho_ref``
        overrides the drift-lock base (default: the rates ``plan`` was
        solved under) — a daemon chaining reoptimize calls passes the rate
        each scheme was *chosen* under, so slow drift still accumulates and
        budget-deferred moves stay drifted (the streaming engine carries
        this base internally).
        """
        prob = plan.problem
        new_rho = np.asarray(new_rho, np.float64)
        cur_l = plan.assignment.tier.astype(int)
        cur_k = plan.assignment.scheme.astype(int)
        months_held = np.asarray(months_held, np.float64)
        if months_held.ndim not in (0, 1) or (
                months_held.ndim == 1 and months_held.shape[0] != prob.n):
            raise ValueError(f"months_held must be a scalar or shape "
                             f"({prob.n},), got {months_held.shape}")
        problem2 = dataclasses.replace(prob, rho=new_rho, current_tier=cur_l)
        ref = prob.rho if rho_ref is None else np.asarray(rho_ref, np.float64)
        return self._solve_migration(problem2, cur_l, cur_k, plan.stored_gb,
                                     months_held, lock_unchanged,
                                     rho_rel_tol, ref,
                                     rho_abs_tol=rho_abs_tol)

    def _migration_terms(self, problem2: PlacementProblem,
                         cur_l: np.ndarray, cur_k: np.ndarray,
                         old_stored: np.ndarray,
                         months_held: "float | np.ndarray",
                         lock_unchanged: bool, rho_rel_tol: float,
                         rho_ref: np.ndarray, rho_abs_tol: float = 0.0,
                         ) -> Tuple[np.ndarray, Optional[np.ndarray],
                                    np.ndarray]:
        """Everything that precedes the assignment dispatch of a migration
        solve: the ``(extra_cost, locked_scheme, penalty_cents_n)`` triple.
        Split out so the fleet path can build per-tenant terms, batch the
        assignment, and finish with :meth:`_finalize_migration` — the same
        three steps :meth:`_solve_migration` runs for one tenant."""
        table = self.table
        L = table.num_tiers
        K = len(problem2.schemes)

        drifted = drift_gate(problem2.rho, rho_ref, rho_rel_tol, rho_abs_tol)
        locked = None
        if lock_unchanged:
            locked = np.where(~drifted & (cur_k >= 0), cur_k, -1)

        new_stored_nk = problem2.spans_gb[:, None] / problem2.R   # (N,K)
        is_cur_cell = ((np.arange(L)[None, :, None] == cur_l[:, None, None])
                       & (np.arange(K)[None, None, :] == cur_k[:, None, None]))

        # Early-deletion penalty: charged whenever the object leaves its cell
        # (a tier change OR a re-compression re-put), mirroring TieredStore.
        penalty_gb = early_delete_penalty_gb(table, cur_l, months_held)  # (N,)
        penalty_cents_n = penalty_gb * old_stored                        # (N,)
        extra = self.cfg.weights.gamma * np.where(
            ~is_cur_cell, penalty_cents_n[:, None, None], 0.0)

        # Same-tier scheme change: Delta_{u,u} = 0 in the cost tensor, but a
        # re-put still pays read-out of the old payload + write-in of the new.
        safe_l = np.maximum(cur_l, 0)         # -1 rows are masked out below
        same_tier_new_scheme = ((np.arange(L)[None, :, None]
                                 == cur_l[:, None, None]) & ~is_cur_cell)
        recompress = (old_stored * table.read_cents_gb[safe_l])[:, None, None] \
            + new_stored_nk[:, None, :] * table.write_cents_gb[None, :, None]
        extra = extra + self.cfg.weights.gamma * np.where(
            same_tier_new_scheme, recompress, 0.0)

        # Cross-provider egress rides Delta in the cost tensor, which prices
        # it on the destination-compressed bytes (spans/R[k]); the bill (and
        # the store) charges it on the OLD stored payload — the bytes that
        # actually leave the provider. Re-base the objective so scheme
        # changes can't under/over-price the egress wall.
        if getattr(table, "provider_of_tier", None) is not None:
            eg_nl = move_egress_cents_gb(table, cur_l[:, None],
                                         np.arange(L)[None, :])      # (N, L)
            extra = extra + self.cfg.weights.gamma * (
                eg_nl[:, :, None]
                * (old_stored[:, None, None] - new_stored_nk[:, None, :]))
        return extra, locked, penalty_cents_n

    def _finalize_migration(self, problem2: PlacementProblem,
                            assignment: Assignment,
                            cur_l: np.ndarray, cur_k: np.ndarray,
                            old_stored: np.ndarray,
                            penalty_cents_n: np.ndarray) -> MigrationPlan:
        """Billing + per-move cents bookkeeping after the assignment solve."""
        table = self.table
        safe_l = np.maximum(cur_l, 0)
        report = self.billing(problem2, assignment)
        new_plan = PlacementPlan(problem2, assignment, report)

        new_l = assignment.tier.astype(int)
        new_k = assignment.scheme.astype(int)
        moved = (cur_l >= 0) & ((new_l != cur_l) | (new_k != cur_k))
        new_stored = new_plan.stored_gb
        # Transfer: read the old payload out of its tier; if the destination
        # tier belongs to a different provider, the old payload additionally
        # pays the source provider's egress (charged exactly once, on the
        # bytes that actually cross the provider boundary); then write the
        # (possibly re-compressed) payload into the destination tier.
        write_gb = np.where(new_k == cur_k, old_stored, new_stored)
        egress_gb = move_egress_cents_gb(table, cur_l, new_l)    # (N,)
        egress_n = np.where(moved, old_stored * egress_gb, 0.0)
        transfer_n = np.where(
            moved,
            old_stored * table.read_cents_gb[safe_l]
            + write_gb * table.write_cents_gb[new_l], 0.0)
        pen_n = np.where(moved, penalty_cents_n, 0.0)
        egress = float(egress_n.sum())
        migration = egress + float(transfer_n.sum())
        penalty = float(pen_n.sum())
        return MigrationPlan(
            plan=new_plan, moved=moved, old_tier=cur_l, new_tier=new_l,
            old_scheme=cur_k, new_scheme=new_k,
            migration_cents=migration, penalty_cents=penalty,
            egress_cents=egress, candidate=moved.copy(),
            move_transfer_cents=transfer_n, move_egress_cents=egress_n,
            move_penalty_cents=pen_n,
            old_stored_gb=np.asarray(old_stored, np.float64))

    def _solve_migration(self, problem2: PlacementProblem,
                         cur_l: np.ndarray, cur_k: np.ndarray,
                         old_stored: np.ndarray,
                         months_held: "float | np.ndarray",
                         lock_unchanged: bool, rho_rel_tol: float,
                         rho_ref: np.ndarray,
                         rho_abs_tol: float = 0.0) -> MigrationPlan:
        """Shared migration core for :meth:`reoptimize` and the streaming
        engine. ``cur_l``/``cur_k`` may contain -1 for partitions that are
        new to the placement (no penalty, no transfer — pure ingestion via
        the cost tensor's Delta_{-1,l} row); ``rho_ref`` is the access rate
        each partition's current scheme was chosen under (drift-lock base).
        """
        extra, locked, penalty_cents_n = self._migration_terms(
            problem2, cur_l, cur_k, old_stored, months_held, lock_unchanged,
            rho_rel_tol, rho_ref, rho_abs_tol)
        assignment = self.assign(problem2, extra_cost=extra,
                                 locked_scheme=locked)
        return self._finalize_migration(problem2, assignment, cur_l, cur_k,
                                        old_stored, penalty_cents_n)


# --------------------------------------------------------------- streaming
def compredict_rd_fn(predictor, file_rows: Dict[str, Tuple[Table, np.ndarray]],
                     *, layout: str = "col",
                     feature_backend: Optional[str] = None) -> Callable:
    """Build a :class:`StreamingEngine` ``rd_fn`` from a fitted
    ``CompressionPredictor``.

    Each batch, the current partitions are materialized from ``file_rows``
    (as in :class:`PartitionStage`) and the predictor's batched
    ``predict_matrix`` — feature extraction in one device dispatch under
    ``feature_backend`` — supplies (R, D) so per-batch re-prediction stays
    off the N×K Python-loop path. Materialized tables and serialized sizes
    are cached by partition file-set identity (the same key the engine
    carries placement state under), so partitions that survive a fold pay
    no re-materialization or re-serialization on later batches; the cache
    is pruned to the live partition set each call. Returned D is
    whole-partition seconds, as :class:`PlacementProblem` expects."""
    cache: Dict[FrozenSet[str], Tuple[Table, int]] = {}

    def rd_fn(parts: List[datapart.Partition],
              schemes: Sequence[str]) -> Tuple[np.ndarray, np.ndarray]:
        missing = [p for p in parts if p.files not in cache]
        if missing:
            for p, t in zip(missing,
                            PartitionStage._partition_tables(missing,
                                                             file_rows)):
                cache[p.files] = (t, t.nbytes(layout))
        for stale in set(cache) - {p.files for p in parts}:
            del cache[stale]
        tables = [cache[p.files][0] for p in parts]
        sizes = [cache[p.files][1] for p in parts]
        spans_gb = np.array([p.span for p in parts], np.float64)
        R, Dm = predictor.predict_matrix(tables, schemes, layout, sizes=sizes,
                                         feature_backend=feature_backend)
        return R, Dm * spans_gb[:, None]
    return rd_fn


@dataclasses.dataclass
class StreamStepReport:
    """Per-batch summary of an ``ingest_and_reoptimize`` step."""

    batch: int
    n_partitions: int
    n_new: int                        # partitions entering as new data
    n_moved: int                      # surviving partitions that migrated
    compacted: bool
    migration_cents: float
    penalty_cents: float
    steady_cents: float               # steady-state bill of the new plan
    egress_cents: float = 0.0         # cross-provider egress paid this step
    n_deferred: int = 0               # candidate moves a budget postponed
    n_failed: int = 0                 # selected moves whose execution did
    # not land (reverted; re-enter the candidate set next batch)


@dataclasses.dataclass
class _HeldState:
    """Placement state carried across batches for one partition file set."""

    tier: int
    scheme: int
    stored_gb: float
    rho_ref: float                    # rho the current scheme was chosen under
    months_held: float                # since last move (minimum-stay clock)


class StreamingEngine:
    """Rolling-window placement: ingest access-log batches, migrate deltas.

    Couples a :class:`~repro.core.stream.StreamingPartitioner` (incremental
    G-PART) with :class:`PlacementEngine`'s migration solver.  Placement
    state is carried across batches by partition **file-set identity**:
    partitions that survive a fold unchanged keep their current tier and
    minimum-stay clock, so the optimizer internalizes the full cost of
    moving them (tier-change transfer, re-compression, early-deletion
    penalties); merged or newly seen partitions enter as new data
    (``current_tier = -1`` — pure ingestion write cost).

    ``rd_fn(partitions, schemes) -> (R, D)`` optionally supplies
    compression ratio / decompression-time matrices (e.g.
    :func:`compredict_rd_fn` wrapping a fitted COMPREDICT model with
    batched device feature extraction); without it the stream is placed
    uncompressed, which is the right default when only access-log metadata
    is available.
    """

    def __init__(self, table: CostTable, cfg: ScopeConfig,
                 sizes: "datapart.FileSizes | Dict[str, float]", *,
                 s_thresh: Optional[float] = None,
                 decay: float = 1.0, window: Optional[int] = None,
                 drift_threshold: float = 0.5, rho_rel_tol: float = 0.25,
                 rho_abs_tol: float = 0.0,
                 rd_fn: Optional[Callable[[List[datapart.Partition],
                                           Sequence[str]],
                                          Tuple[np.ndarray, np.ndarray]]]
                 = None):
        self.table = table
        self.cfg = cfg
        self.engine = PlacementEngine(table, cfg)
        self.sizes = (sizes if isinstance(sizes, datapart.FileSizes)
                      else datapart.FileSizes(sizes))
        self._s_thresh = s_thresh
        self._decay = decay
        self._window = window
        self._drift_threshold = drift_threshold
        self.rho_rel_tol = rho_rel_tol
        self.rho_abs_tol = rho_abs_tol
        self.rd_fn = rd_fn
        self.partitioner: Optional[StreamingPartitioner] = None
        self.plan: Optional[PlacementPlan] = None
        self.history: List[StreamStepReport] = []
        # file set -> held states, a LIST because two live partitions can
        # share a file set (a family can coexist with a merge producing the
        # same union); matched positionally in plan order
        self._held: Dict[FrozenSet[str], List[_HeldState]] = {}

    # ----------------------------------------------------------- internals
    def _ensure_partitioner(self, batch: QueryFamilies,
                            ) -> Optional[StreamingPartitioner]:
        if self.partitioner is None:
            s = self._s_thresh
            if s is None:
                spans = [self.sizes.span(frozenset(f)) for f, _ in batch if f]
                if not spans:
                    # no evidence to size the span cap yet — defer creation
                    # so an empty first batch can't freeze s_thresh at a
                    # value that never seals a merge product
                    return None
                s = self.cfg.s_thresh_mult * float(np.median(spans))
            self.partitioner = StreamingPartitioner(
                self.sizes, s_thresh=s, rho_c=self.cfg.rho_c,
                rho_c_abs=self.cfg.rho_c_abs, decay=self._decay,
                window=self._window,
                drift_threshold=self._drift_threshold)
        return self.partitioner

    def _build_problem(self, parts: List[datapart.Partition],
                       cur_l: np.ndarray) -> PlacementProblem:
        N = len(parts)
        spans_gb = np.array([p.span for p in parts], np.float64)
        rho = np.array([p.rho for p in parts], np.float64)
        if self.rd_fn is not None and self.cfg.use_compression:
            schemes = list(self.cfg.schemes)
            R, D = self.rd_fn(parts, schemes)
        else:
            schemes = ["none"]
            R = np.ones((N, 1))
            D = np.zeros((N, 1))
        return PlacementProblem(
            spans_gb=spans_gb, rho=rho, current_tier=cur_l, R=R, D=D,
            schemes=schemes, table=self.table, cfg=self.cfg,
            partitions=list(parts), raw_bytes=None)

    def _empty_migration(self) -> MigrationPlan:
        # constructs the SAME field set as the live _solve_migration path —
        # empty steps must not fall back to defaulted/missing fields
        z = np.zeros(0, int)
        zf = np.zeros(0, np.float64)
        problem = self._build_problem([], z)
        assignment = Assignment(tier=z.copy(), scheme=z.copy(),
                                cost=0.0, feasible=True)
        report = self.engine.billing(problem, assignment)
        plan = PlacementPlan(problem, assignment, report)
        return MigrationPlan(
            plan=plan, moved=np.zeros(0, bool), old_tier=z.copy(),
            new_tier=z.copy(), old_scheme=z.copy(), new_scheme=z.copy(),
            migration_cents=0.0, penalty_cents=0.0, egress_cents=0.0,
            candidate=np.zeros(0, bool), move_transfer_cents=zf.copy(),
            move_egress_cents=zf.copy(), move_penalty_cents=zf.copy(),
            old_stored_gb=zf.copy())

    # ---------------------------------------------------------------- steps
    def ingest_and_reoptimize(self, query_files: QueryFamilies,
                              months: float = 1.0, *,
                              select_moves: Optional[
                                  Callable[[MigrationPlan], np.ndarray]]
                              = None,
                              project_rho: Optional[
                                  Callable[[List[datapart.Partition],
                                            np.ndarray], np.ndarray]]
                              = None,
                              execute_moves: Optional[
                                  Callable[[MigrationPlan], np.ndarray]]
                              = None) -> MigrationPlan:
        """Fold one access-log batch in, compact if drifted, re-optimize.

        ``months`` is the logical time elapsed since the previous batch; it
        ages every held partition's minimum-stay clock before early-deletion
        penalties are priced. Returns the :class:`MigrationPlan` (``moved``
        covers surviving partitions only; new ones appear in the plan with
        ingestion write cost already internalized by the cost tensor).

        ``project_rho(parts, rho_observed) -> rho_projected`` optionally
        replaces the partitioner's observed rates with a forecast before
        the solve (the daemon's forecast hook); the drift gate and lock
        bookkeeping then operate on the projected rates. ``select_moves``
        turns the step into a **partial** one: it receives the full
        candidate :class:`MigrationPlan` and returns a boolean keep mask —
        deferred candidates stay at their old tier/scheme, keep their
        lock base (so they re-surface as drifted next batch) and their
        minimum-stay clock keeps running.

        ``execute_moves(mig) -> unapplied_mask`` hands the selected plan
        to an execution plane (e.g. ``AsyncMigrator.execute_sync``) and
        returns an (N,) bool mask of rows that did **not** land (failed or
        budget-stopped). Those rows are folded back via
        :meth:`MigrationPlan.land` — reverted to deferred-candidate status
        with their lock base kept, so they re-enter the candidate set next
        batch; a new partition whose ingestion put failed re-enters as new
        data (no held state). With the hook absent or an all-False mask
        the step is bit-identical to the synchronous path.
        """
        sp = self._ensure_partitioner(query_files)
        compacted = False
        if sp is not None:
            sp.ingest(query_files)
            compacted = sp.compact()
        parts = sp.partitions if sp is not None else []
        N = len(parts)
        if N == 0:
            # empty stream state (empty batches, or the whole window
            # expired): a no-op step — the solvers don't accept N=0.
            # Construct the report with the live path's full field set.
            mig = self._empty_migration()
            self.plan = mig.plan
            self.history.append(StreamStepReport(
                batch=len(self.history), n_partitions=0, n_new=0, n_moved=0,
                compacted=compacted, migration_cents=0.0, penalty_cents=0.0,
                steady_cents=0.0, egress_cents=0.0, n_deferred=0,
                n_failed=0))
            return mig
        cur_l = np.full(N, -1, int)
        cur_k = np.full(N, -1, int)
        old_stored = np.zeros(N)
        held_months = np.zeros(N)
        rho_ref = np.array([p.rho for p in parts], np.float64)
        for i, p in enumerate(parts):
            states = self._held.get(p.files)
            if states:
                st = states.pop(0)
                cur_l[i], cur_k[i] = st.tier, st.scheme
                old_stored[i] = st.stored_gb
                rho_ref[i] = st.rho_ref
                held_months[i] = st.months_held + months

        problem = self._build_problem(parts, cur_l)
        if project_rho is not None:
            proj = np.asarray(project_rho(parts, problem.rho), np.float64)
            if proj.shape != problem.rho.shape:
                raise ValueError(f"project_rho must return shape "
                                 f"{problem.rho.shape}, got {proj.shape}")
            problem = dataclasses.replace(problem, rho=proj)
        mig = self.engine._solve_migration(
            problem, cur_l, cur_k, old_stored, held_months,
            lock_unchanged=True, rho_rel_tol=self.rho_rel_tol,
            rho_ref=rho_ref, rho_abs_tol=self.rho_abs_tol)
        if select_moves is not None:
            mig = mig.select(np.asarray(select_moves(mig), bool))
        exec_failed = np.zeros(N, bool)
        n_failed = 0
        if execute_moves is not None:
            exec_failed = np.asarray(execute_moves(mig), bool)
            if exec_failed.shape != (N,):
                raise ValueError(f"execute_moves must return shape "
                                 f"({N},), got {exec_failed.shape}")
            n_failed = int((exec_failed & mig.moved).sum())
            mig = mig.land(exec_failed)

        drifted = drift_gate(problem.rho, rho_ref, self.rho_rel_tol,
                             self.rho_abs_tol)
        deferred = mig.deferred
        new_stored = mig.plan.stored_gb
        self._held = {}
        for i, p in enumerate(parts):
            if exec_failed[i] and cur_l[i] < 0:
                # ingestion put failed: the object does not exist, so the
                # partition must re-enter as new data next batch
                continue
            surviving = cur_l[i] >= 0 and not mig.moved[i]
            self._held.setdefault(p.files, []).append(_HeldState(
                tier=int(mig.new_tier[i]), scheme=int(mig.new_scheme[i]),
                stored_gb=float(new_stored[i]),
                # the scheme was (re-)decided now unless the partition was
                # locked: keep the lock base so slow drift still accumulates.
                # Deferred moves also keep it — they must stay "drifted"
                # and re-enter the candidate set next batch.
                rho_ref=(float(rho_ref[i])
                         if surviving and (not drifted[i] or deferred[i])
                         else float(problem.rho[i])),
                months_held=float(held_months[i]) if surviving else 0.0))
        self.plan = mig.plan
        self.history.append(StreamStepReport(
            batch=len(self.history), n_partitions=N,
            n_new=int((cur_l < 0).sum()), n_moved=mig.n_moved,
            compacted=compacted, migration_cents=mig.migration_cents,
            penalty_cents=mig.penalty_cents,
            steady_cents=mig.plan.report.total_cents,
            egress_cents=mig.egress_cents,
            n_deferred=int(deferred.sum()), n_failed=n_failed))
        return mig
