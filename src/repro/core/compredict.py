"""COMPREDICT — compression ratio / decompression-speed prediction (paper §V).

Core pieces, mirroring the paper's ablation axes:
 * features   : 'size' (naive) vs 'weighted_entropy' H(P,d) per dtype
                (+ 'bucketed' variant: entropy of each successive 20% of rows);
 * sampling   : 'random' row samples vs 'queries' (query-result samples);
 * layouts    : 'row' (CSV-like) vs 'col' (parquet-like);
 * schemes    : real codecs measured on the serialized bytes;
 * models     : RandomForest / MLP / KernelRidge(SVR) / Averaging (core.ml).

Everything here is label-generation + feature extraction; models come from
:mod:`repro.core.ml`, codecs from :mod:`repro.storage.codecs`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ml
from repro.data.tables import (ClassCodes, Table, dtype_class,
                               encode_dtype_classes, DTYPE_CLASSES)
from repro.storage.codecs import Codec, default_codecs, measure

#: Selectable feature-extraction backends (see :func:`extract_features_batch`):
#: 'numpy' is the per-partition string/unique loop; 'jnp' and 'pallas' run
#: the batched device pipeline in kernels/entropy_features.py on a one-pass
#: dictionary encoding of all N partitions.
FEATURE_BACKENDS = ("numpy", "jnp", "pallas")


def _bucket_edges(n: int, n_buckets: int) -> np.ndarray:
    """Exact integer bucket edges: edge[b] = floor(b*n/n_buckets).

    Computed in integer arithmetic so every row is covered exactly once —
    ``np.linspace(0, n, k+1).astype(int)`` truncates *float* intermediates,
    and a representation error of one ulp below b*n/k would drop a row at
    the bucket boundary (pinned by tests/test_compredict_backends.py).
    """
    return (np.arange(n_buckets + 1, dtype=np.int64) * int(n)) // n_buckets


# ------------------------------------------------------------------ features
def weighted_entropy(table: Table) -> Dict[str, float]:
    """H(P,d) = -sum_{s in P[:,d]} len(s) * pr(s) * log pr(s), one per dtype.

    pr(s) is the empirical probability of string value s among the values of
    all columns with dtype-class d; len(s) its string length (paper §V).
    """
    by_dtype: Dict[str, List[np.ndarray]] = {d: [] for d in DTYPE_CLASSES}
    for name, col in table.columns.items():
        by_dtype[dtype_class(col)].append(table._col_str(col))
    out = {}
    for d, cols in by_dtype.items():
        if not cols:
            out[d] = 0.0
            continue
        vals = np.concatenate(cols)
        uniq, counts = np.unique(vals, return_counts=True)
        pr = counts / counts.sum()
        lens = np.char.str_len(uniq.astype(str))
        out[d] = float(-(lens * pr * np.log(pr + 1e-300)).sum())
    return out


def bucketed_weighted_entropy(table: Table, n_buckets: int = 5) -> List[float]:
    """Entropy of each successive 1/n_buckets of rows (paper's sorted-data
    feature): captures local repetition that column sorting creates."""
    n = table.num_rows
    feats: List[float] = []
    edges = _bucket_edges(n, n_buckets)
    for lo, hi in zip(edges[:-1], edges[1:]):
        h = weighted_entropy(table.select(slice(lo, hi)))
        feats.extend(h[d] for d in DTYPE_CLASSES)
    return feats


def _entropy_block(table: Table) -> List[float]:
    """Per-dtype feature block: [H(P,d), plain entropy, distinct fraction,
    mean value length, #columns] for d in {int,float,str}."""
    by_dtype: Dict[str, List[np.ndarray]] = {d: [] for d in DTYPE_CLASSES}
    for col in table.columns.values():
        by_dtype[dtype_class(col)].append(table._col_str(col))
    feats: List[float] = []
    for d in DTYPE_CLASSES:
        cols = by_dtype[d]
        if not cols:
            feats += [0.0] * 5
            continue
        vals = np.concatenate(cols)
        uniq, counts = np.unique(vals, return_counts=True)
        pr = counts / max(counts.sum(), 1)    # 0-row partitions: all zeros
        lens = np.char.str_len(uniq.astype(str))
        feats += [float(-(lens * pr * np.log(pr + 1e-300)).sum()),   # H(P,d)
                  float(-(pr * np.log(pr + 1e-300)).sum()),
                  len(uniq) / max(len(vals), 1),
                  float(lens @ pr),
                  float(len(cols))]
    return feats


def extract_features(table: Table, layout: str, kind: str = "weighted_entropy",
                     *, size: Optional[int] = None,
                     n_buckets: int = 5) -> np.ndarray:
    """Feature vector for one partition. ``size`` short-circuits the
    serialized-size probe when the caller already holds the raw bytes."""
    if size is None:
        size = table.nbytes(layout)
    n_rows = max(table.num_rows, 1)
    if kind == "size":
        return np.array([np.log1p(size), np.log1p(n_rows),
                         len(table.columns)], float)
    base = [np.log1p(size), np.log1p(n_rows), size / n_rows]
    if kind == "weighted_entropy":
        return np.array(base + _entropy_block(table), float)
    if kind == "bucketed":
        return np.array(base + _entropy_block(table)
                        + bucketed_weighted_entropy(table, n_buckets), float)
    raise ValueError(kind)


# ------------------------------------------------------- batched extraction
@functools.lru_cache(maxsize=8)
def _jit_wef_ref(n_buckets: int):
    import jax
    from repro.kernels.entropy_features import weighted_entropy_features_ref
    return jax.jit(functools.partial(weighted_entropy_features_ref,
                                     n_buckets=n_buckets))


def _batched_entropy_columns(cc: ClassCodes, n_buckets: int, backend: str,
                             interpret: Optional[bool]) -> Tuple[np.ndarray,
                                                                 np.ndarray]:
    """(summary (N,4), bucket_H (N,n_buckets)) for one dtype class via the
    selected device path."""
    if backend == "jnp":
        summary, buck = _jit_wef_ref(n_buckets)(
            cc.codes, cc.n_valid, cc.n_rows, cc.n_cols, cc.lengths)
    else:                                    # 'pallas'
        import jax
        from repro.kernels.entropy_features import weighted_entropy_features
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        summary, buck = weighted_entropy_features(
            cc.codes, cc.n_valid, cc.n_rows, cc.n_cols, cc.lengths,
            n_buckets=n_buckets, interpret=interpret)
    return np.asarray(summary, np.float64), np.asarray(buck, np.float64)


def extract_features_batch(tables: Sequence[Table], layout: str,
                           kind: str = "weighted_entropy",
                           backend: str = "numpy", *,
                           sizes: Optional[Sequence[int]] = None,
                           n_buckets: int = 5,
                           encoded: Optional[Dict[str, ClassCodes]] = None,
                           interpret: Optional[bool] = None) -> np.ndarray:
    """(N, F) feature matrix for N partitions in one pass.

    backend 'numpy' loops :func:`extract_features`; 'jnp' and 'pallas'
    dictionary-encode all partitions once (or reuse ``encoded`` from
    :func:`repro.data.tables.encode_dtype_classes`) and compute every
    entropy feature in a single batched device dispatch — the COMPREDICT
    hot path for ``CompressStage``/``StreamingEngine`` re-prediction.
    'pallas' auto-selects interpret mode off-TPU unless ``interpret`` is
    forced. All backends agree to ~1e-5 (tests/test_compredict_backends.py).
    """
    if backend not in FEATURE_BACKENDS:
        raise ValueError(f"backend must be one of {FEATURE_BACKENDS}, "
                         f"got {backend!r}")
    N = len(tables)
    if sizes is None:
        sizes = [t.nbytes(layout) for t in tables]
    if N == 0:
        width = {"size": 3, "weighted_entropy": 3 + 5 * len(DTYPE_CLASSES),
                 "bucketed": 3 + (5 + n_buckets) * len(DTYPE_CLASSES)}[kind]
        return np.zeros((0, width), float)
    if backend == "numpy" or kind == "size":
        return np.stack([extract_features(t, layout, kind, size=s,
                                          n_buckets=n_buckets)
                         for t, s in zip(tables, sizes)])
    if kind not in ("weighted_entropy", "bucketed"):
        raise ValueError(kind)
    enc = encoded if encoded is not None else encode_dtype_classes(tables)
    per_class = {d: _batched_entropy_columns(
        enc[d], n_buckets if kind == "bucketed" else 1, backend, interpret)
        for d in DTYPE_CLASSES}
    sizes_a = np.asarray(sizes, float)
    n_rows = np.maximum(np.array([t.num_rows for t in tables], float), 1.0)
    cols = [np.log1p(sizes_a), np.log1p(n_rows), sizes_a / n_rows]
    for d in DTYPE_CLASSES:
        summary, _ = per_class[d]
        has = (enc[d].n_cols > 0).astype(float)    # no columns -> all zeros
        cols += [summary[:, 0] * has, summary[:, 1] * has,
                 summary[:, 2] * has, summary[:, 3] * has,
                 enc[d].n_cols.astype(float)]
    if kind == "bucketed":
        for b in range(n_buckets):
            for d in DTYPE_CLASSES:
                _, buck = per_class[d]
                cols.append(buck[:, b] * (enc[d].n_cols > 0))
    return np.stack(cols, axis=1)


# ------------------------------------------------------------------ sampling
def random_samples(table: Table, n_samples: int, rows_each: int,
                   seed: int = 0) -> List[Table]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_samples):
        k = min(rows_each, table.num_rows)
        idx = rng.choice(table.num_rows, size=k, replace=False)
        out.append(table.select(np.sort(idx)))
    return out


def query_samples(queries, db_tables: Dict[str, Table],
                  max_rows: int = 4000) -> List[Table]:
    """Partitions derived from query results — the paper's better sampler."""
    out = []
    for q in queries:
        t = db_tables[q.table]
        rows = q.rows[:max_rows]
        if len(rows) == 0:
            continue
        out.append(t.select(rows))
    return out


# -------------------------------------------------------------------- labels
@dataclasses.dataclass
class LabeledSet:
    X: np.ndarray                  # (n, f) features
    ratio: np.ndarray              # (n,)   compression ratio R
    dspeed: np.ndarray             # (n,)   decompression sec/GB D'
    scheme: str
    layout: str
    feature_kind: str


def build_dataset(samples: Sequence[Table], codec: Codec, layout: str,
                  feature_kind: str = "weighted_entropy") -> LabeledSet:
    X, R, D = [], [], []
    for t in samples:
        raw = t.serialize(layout)
        if len(raw) < 64:
            continue
        m = measure(codec, raw)
        X.append(extract_features(t, layout, feature_kind))
        R.append(m.ratio)
        D.append(m.decompress_sec_per_gb)
    return LabeledSet(np.stack(X), np.array(R), np.array(D),
                      codec.name, layout, feature_kind)


# ------------------------------------------------------------------ pipeline
MODELS = {
    "Averaging": lambda: ml.Averaging(),
    "RandomForest": lambda: ml.RandomForest(n_trees=30, max_depth=12),
    "NeuralNetwork": lambda: ml.MLP(hidden=(64, 64), epochs=500),
    "SVR": lambda: ml.KernelRidge(alpha=1e-2),
}


@dataclasses.dataclass
class EvalResult:
    model: str
    target: str               # 'ratio' | 'dspeed'
    mae: float
    mape: float
    r2: float


def train_eval(ds: LabeledSet, model_name: str, target: str,
               train_frac: float = 0.7, seed: int = 0) -> Tuple[object, EvalResult]:
    rng = np.random.default_rng(seed)
    n = len(ds.X)
    order = rng.permutation(n)
    cut = max(int(n * train_frac), 1)
    tr, te = order[:cut], order[cut:]
    y = ds.ratio if target == "ratio" else ds.dspeed
    model = MODELS[model_name]()
    model.fit(ds.X[tr], y[tr])
    pred = model.predict(ds.X[te] if len(te) else ds.X[tr])
    ytrue = y[te] if len(te) else y[tr]
    res = EvalResult(model_name, target, ml.mae(ytrue, pred),
                     ml.mape(ytrue, pred), ml.r2(ytrue, pred))
    return model, res


class CompressionPredictor:
    """Production interface: per-(scheme, layout) RF models predicting
    (ratio, decompression sec/GB) from weighted-entropy features.

    ``feature_backend`` selects how :meth:`predict_matrix` extracts
    features for a batch of partitions ('numpy' | 'jnp' | 'pallas', see
    :func:`extract_features_batch`); training always uses the NumPy path
    (label measurement dominates there anyway)."""

    def __init__(self, feature_kind: str = "weighted_entropy",
                 model_name: str = "RandomForest",
                 feature_backend: str = "numpy"):
        if feature_backend not in FEATURE_BACKENDS:
            raise ValueError(f"feature_backend must be one of "
                             f"{FEATURE_BACKENDS}, got {feature_backend!r}")
        self.feature_kind = feature_kind
        self.model_name = model_name
        self.feature_backend = feature_backend
        self.models: Dict[Tuple[str, str, str], object] = {}

    def fit(self, samples: Sequence[Table], layouts: Sequence[str] = ("row", "col"),
            codecs: Optional[Sequence[Codec]] = None) -> "CompressionPredictor":
        codecs = codecs or [c for c in default_codecs() if c.name != "none"]
        for layout in layouts:
            for codec in codecs:
                ds = build_dataset(samples, codec, layout, self.feature_kind)
                for target in ("ratio", "dspeed"):
                    m = MODELS[self.model_name]()
                    y = ds.ratio if target == "ratio" else ds.dspeed
                    m.fit(ds.X, y)
                    self.models[(codec.name, layout, target)] = m
        return self

    def predict(self, table: Table, scheme: str, layout: str) -> Tuple[float, float]:
        """Returns (ratio, decompression sec/GB); scheme 'none' is (1, 0)."""
        if scheme == "none":
            return 1.0, 0.0
        x = extract_features(table, layout, self.feature_kind)[None, :]
        r = float(self.models[(scheme, layout, "ratio")].predict(x)[0])
        d = float(self.models[(scheme, layout, "dspeed")].predict(x)[0])
        return max(r, 1.0), max(d, 0.0)

    def predict_matrix(self, tables: Sequence[Table], schemes: Sequence[str],
                       layout: str, *,
                       sizes: Optional[Sequence[int]] = None,
                       feature_backend: Optional[str] = None,
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """(N,K) ratio and decompression-sec/GB matrices for OPTASSIGN.

        Features are extracted once for all N partitions via
        :func:`extract_features_batch` (backend from ``feature_backend`` or
        the constructor default) and each per-(scheme, target) model
        predicts the whole batch in one call — no N×K Python loop.
        ``sizes`` forwards known serialized byte counts."""
        N, K = len(tables), len(schemes)
        R = np.ones((N, K))
        D = np.zeros((N, K))
        if N == 0:
            return R, D
        backend = feature_backend or self.feature_backend
        X = extract_features_batch(tables, layout, self.feature_kind,
                                   backend, sizes=sizes)
        for k, s in enumerate(schemes):
            if s == "none":
                continue                       # (1, 0) by definition
            R[:, k] = np.maximum(
                self.models[(s, layout, "ratio")].predict(X), 1.0)
            D[:, k] = np.maximum(
                self.models[(s, layout, "dspeed")].predict(X), 0.0)
        return R, D
