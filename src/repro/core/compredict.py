"""COMPREDICT — compression ratio / decompression-speed prediction (paper §V).

Core pieces, mirroring the paper's ablation axes:
 * features   : 'size' (naive) vs 'weighted_entropy' H(P,d) per dtype
                (+ 'bucketed' variant: entropy of each successive 20% of rows);
 * sampling   : 'random' row samples vs 'queries' (query-result samples);
 * layouts    : 'row' (CSV-like) vs 'col' (parquet-like);
 * schemes    : real codecs measured on the serialized bytes;
 * models     : RandomForest / MLP / KernelRidge(SVR) / Averaging (core.ml).

Everything here is label-generation + feature extraction; models come from
:mod:`repro.core.ml`, codecs from :mod:`repro.storage.codecs`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ml
from repro.data.tables import Table, dtype_class, DTYPE_CLASSES
from repro.storage.codecs import Codec, default_codecs, measure


# ------------------------------------------------------------------ features
def weighted_entropy(table: Table) -> Dict[str, float]:
    """H(P,d) = -sum_{s in P[:,d]} len(s) * pr(s) * log pr(s), one per dtype.

    pr(s) is the empirical probability of string value s among the values of
    all columns with dtype-class d; len(s) its string length (paper §V).
    """
    by_dtype: Dict[str, List[np.ndarray]] = {d: [] for d in DTYPE_CLASSES}
    for name, col in table.columns.items():
        by_dtype[dtype_class(col)].append(table._col_str(col))
    out = {}
    for d, cols in by_dtype.items():
        if not cols:
            out[d] = 0.0
            continue
        vals = np.concatenate(cols)
        uniq, counts = np.unique(vals, return_counts=True)
        pr = counts / counts.sum()
        lens = np.char.str_len(uniq.astype(str))
        out[d] = float(-(lens * pr * np.log(pr + 1e-300)).sum())
    return out


def bucketed_weighted_entropy(table: Table, n_buckets: int = 5) -> List[float]:
    """Entropy of each successive 1/n_buckets of rows (paper's sorted-data
    feature): captures local repetition that column sorting creates."""
    n = table.num_rows
    feats: List[float] = []
    edges = np.linspace(0, n, n_buckets + 1).astype(int)
    for lo, hi in zip(edges[:-1], edges[1:]):
        h = weighted_entropy(table.select(slice(lo, hi)))
        feats.extend(h[d] for d in DTYPE_CLASSES)
    return feats


def _entropy_block(table: Table) -> List[float]:
    """Per-dtype feature block: [H(P,d), plain entropy, distinct fraction,
    mean value length, #columns] for d in {int,float,str}."""
    by_dtype: Dict[str, List[np.ndarray]] = {d: [] for d in DTYPE_CLASSES}
    for col in table.columns.values():
        by_dtype[dtype_class(col)].append(table._col_str(col))
    feats: List[float] = []
    for d in DTYPE_CLASSES:
        cols = by_dtype[d]
        if not cols:
            feats += [0.0] * 5
            continue
        vals = np.concatenate(cols)
        uniq, counts = np.unique(vals, return_counts=True)
        pr = counts / counts.sum()
        lens = np.char.str_len(uniq.astype(str))
        feats += [float(-(lens * pr * np.log(pr + 1e-300)).sum()),   # H(P,d)
                  float(-(pr * np.log(pr + 1e-300)).sum()),
                  len(uniq) / len(vals),
                  float(lens @ pr),
                  float(len(cols))]
    return feats


def extract_features(table: Table, layout: str, kind: str = "weighted_entropy",
                     ) -> np.ndarray:
    size = table.nbytes(layout)
    n_rows = max(table.num_rows, 1)
    if kind == "size":
        return np.array([np.log1p(size), np.log1p(n_rows),
                         len(table.columns)], float)
    base = [np.log1p(size), np.log1p(n_rows), size / n_rows]
    if kind == "weighted_entropy":
        return np.array(base + _entropy_block(table), float)
    if kind == "bucketed":
        return np.array(base + _entropy_block(table)
                        + bucketed_weighted_entropy(table), float)
    raise ValueError(kind)


# ------------------------------------------------------------------ sampling
def random_samples(table: Table, n_samples: int, rows_each: int,
                   seed: int = 0) -> List[Table]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_samples):
        k = min(rows_each, table.num_rows)
        idx = rng.choice(table.num_rows, size=k, replace=False)
        out.append(table.select(np.sort(idx)))
    return out


def query_samples(queries, db_tables: Dict[str, Table],
                  max_rows: int = 4000) -> List[Table]:
    """Partitions derived from query results — the paper's better sampler."""
    out = []
    for q in queries:
        t = db_tables[q.table]
        rows = q.rows[:max_rows]
        if len(rows) == 0:
            continue
        out.append(t.select(rows))
    return out


# -------------------------------------------------------------------- labels
@dataclasses.dataclass
class LabeledSet:
    X: np.ndarray                  # (n, f) features
    ratio: np.ndarray              # (n,)   compression ratio R
    dspeed: np.ndarray             # (n,)   decompression sec/GB D'
    scheme: str
    layout: str
    feature_kind: str


def build_dataset(samples: Sequence[Table], codec: Codec, layout: str,
                  feature_kind: str = "weighted_entropy") -> LabeledSet:
    X, R, D = [], [], []
    for t in samples:
        raw = t.serialize(layout)
        if len(raw) < 64:
            continue
        m = measure(codec, raw)
        X.append(extract_features(t, layout, feature_kind))
        R.append(m.ratio)
        D.append(m.decompress_sec_per_gb)
    return LabeledSet(np.stack(X), np.array(R), np.array(D),
                      codec.name, layout, feature_kind)


# ------------------------------------------------------------------ pipeline
MODELS = {
    "Averaging": lambda: ml.Averaging(),
    "RandomForest": lambda: ml.RandomForest(n_trees=30, max_depth=12),
    "NeuralNetwork": lambda: ml.MLP(hidden=(64, 64), epochs=500),
    "SVR": lambda: ml.KernelRidge(alpha=1e-2),
}


@dataclasses.dataclass
class EvalResult:
    model: str
    target: str               # 'ratio' | 'dspeed'
    mae: float
    mape: float
    r2: float


def train_eval(ds: LabeledSet, model_name: str, target: str,
               train_frac: float = 0.7, seed: int = 0) -> Tuple[object, EvalResult]:
    rng = np.random.default_rng(seed)
    n = len(ds.X)
    order = rng.permutation(n)
    cut = max(int(n * train_frac), 1)
    tr, te = order[:cut], order[cut:]
    y = ds.ratio if target == "ratio" else ds.dspeed
    model = MODELS[model_name]()
    model.fit(ds.X[tr], y[tr])
    pred = model.predict(ds.X[te] if len(te) else ds.X[tr])
    ytrue = y[te] if len(te) else y[tr]
    res = EvalResult(model_name, target, ml.mae(ytrue, pred),
                     ml.mape(ytrue, pred), ml.r2(ytrue, pred))
    return model, res


class CompressionPredictor:
    """Production interface: per-(scheme, layout) RF models predicting
    (ratio, decompression sec/GB) from weighted-entropy features."""

    def __init__(self, feature_kind: str = "weighted_entropy",
                 model_name: str = "RandomForest"):
        self.feature_kind = feature_kind
        self.model_name = model_name
        self.models: Dict[Tuple[str, str, str], object] = {}

    def fit(self, samples: Sequence[Table], layouts: Sequence[str] = ("row", "col"),
            codecs: Optional[Sequence[Codec]] = None) -> "CompressionPredictor":
        codecs = codecs or [c for c in default_codecs() if c.name != "none"]
        for layout in layouts:
            for codec in codecs:
                ds = build_dataset(samples, codec, layout, self.feature_kind)
                for target in ("ratio", "dspeed"):
                    m = MODELS[self.model_name]()
                    y = ds.ratio if target == "ratio" else ds.dspeed
                    m.fit(ds.X, y)
                    self.models[(codec.name, layout, target)] = m
        return self

    def predict(self, table: Table, scheme: str, layout: str) -> Tuple[float, float]:
        """Returns (ratio, decompression sec/GB); scheme 'none' is (1, 0)."""
        if scheme == "none":
            return 1.0, 0.0
        x = extract_features(table, layout, self.feature_kind)[None, :]
        r = float(self.models[(scheme, layout, "ratio")].predict(x)[0])
        d = float(self.models[(scheme, layout, "dspeed")].predict(x)[0])
        return max(r, 1.0), max(d, 0.0)

    def predict_matrix(self, tables: Sequence[Table], schemes: Sequence[str],
                       layout: str) -> Tuple[np.ndarray, np.ndarray]:
        """(N,K) ratio and decompression-sec/GB matrices for OPTASSIGN."""
        N, K = len(tables), len(schemes)
        R = np.ones((N, K))
        D = np.zeros((N, K))
        for i, t in enumerate(tables):
            for k, s in enumerate(schemes):
                R[i, k], D[i, k] = self.predict(t, s, layout)
        return R, D
