"""Serving cache tier: forecast-driven admission in front of the tiered store.

A :class:`CacheConfig` on ``ScopeConfig.cache`` puts a fixed-capacity hot
cache in front of the placement: admitted partitions serve ``1 -
miss_rate`` of their reads at ``hit_latency_ms`` (no backing read, no
decompression), so the solver can park their backing bytes on a cheap cold
tier without eating the SLA penalty.

Admission is **forecast-driven** (:func:`forecast_admission`): the rho the
solve sees is already the projected rate when a forecaster is attached
(the daemon's ``forecast_fn`` / the streaming engine's ``project_rho``
replace observed rates before the solve), so ranking candidates by
projected-rho density pre-warms the cache one cycle before a spike lands.
An optional calibrated ``p_hot`` vector (``AccessForecaster.predict_p_hot``
probabilities, stashed as ``last_p_hot_`` by ``forecast_rho``) gates
admission to partitions the forecaster actually believes will be hot.

:class:`ReactiveLRUCache` is the baseline the benchmark compares against:
admit on access, evict least-recently-used — it warms only *after* the
spike has already been served cold.

Accounting contract: cache **storage/fill spend is real cents**
(``cache_cents`` in the report, included in ``total_cents``); SLA
**latency penalties are not cents** and are reported separately
(``sla_penalty``), never metered by ``BillingMeter``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.costs import CostTable, Weights

__all__ = ["CacheConfig", "forecast_admission", "cache_access_adjustment",
           "cache_cents", "served_latency_terms", "weighted_p99_ms",
           "ReactiveLRUCache"]


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Serving cache tier parameters.

    Attributes
    ----------
    capacity_gb : total cache footprint; admission fills it greedily by
        projected-rho density (hottest bytes first).
    hit_latency_ms : retrieval latency of a cache hit. Hits serve the raw
        (decoded) partition — no backing read, no decompression.
    storage_cents_gb_month : cache storage price (premium-class by
        default; a cache that were cheaper than Premium would dominate it).
    fill_cents_gb : one-off cents/GB charged when a partition is admitted
        (the write into the cache).
    miss_rate : fraction of an admitted partition's reads that still fall
        through to the backing tier (cold start, eviction races).
    min_rho : admission floor — never cache partitions projected colder.
    p_hot_threshold : when a calibrated ``p_hot`` vector is supplied,
        candidates additionally need ``p_hot >= p_hot_threshold``.
    """

    capacity_gb: float
    hit_latency_ms: float = 1.0
    storage_cents_gb_month: float = 25.0
    fill_cents_gb: float = 0.0
    miss_rate: float = 0.05
    min_rho: float = 0.0
    p_hot_threshold: float = 0.5


def forecast_admission(rho: np.ndarray, spans_gb: np.ndarray,
                       config: CacheConfig,
                       p_hot: Optional[np.ndarray] = None) -> np.ndarray:
    """(N,) bool admission mask: greedy by rho density under the capacity.

    Candidates (``rho >= min_rho``, and ``p_hot >= p_hot_threshold`` when a
    probability vector is given) are ranked by projected accesses per GB —
    the marginal latency relief per cache byte — and admitted while they
    fit ``capacity_gb``. Deterministic: ties broken by partition index.
    """
    rho = np.asarray(rho, np.float64)
    spans = np.asarray(spans_gb, np.float64)
    ok = rho >= config.min_rho
    if p_hot is not None:
        ok &= np.asarray(p_hot, np.float64) >= config.p_hot_threshold
    ok &= spans <= config.capacity_gb          # a partition must fit at all
    cached = np.zeros(rho.shape[0], bool)
    if not ok.any():
        return cached
    density = np.where(spans > 0, rho / np.maximum(spans, 1e-12), np.inf)
    # stable sort on -density -> density desc, index asc on ties
    order = np.argsort(-density[ok], kind="stable")
    idx = np.flatnonzero(ok)[order]
    free = float(config.capacity_gb)
    for i in idx:
        if spans[i] <= free:
            cached[i] = True
            free -= float(spans[i])
    return cached


def cache_access_adjustment(rho: np.ndarray, stored_nlk: np.ndarray,
                            decomp_sec: np.ndarray, table: CostTable,
                            weights: Weights, cached: np.ndarray,
                            miss_rate: float) -> np.ndarray:
    """(N,L,K) additive cost delta for cache-served reads.

    An admitted partition's backing tier only sees ``miss_rate * rho``
    reads, so its access cost drops by ``(1 - miss_rate)`` of the cost
    tensor's access term — exactly ``beta * rho * (C^c * D_nk +
    C^r_l * stored_nlk)``. Non-cached rows get exactly 0.0.
    """
    access = (table.compute_cents_sec * decomp_sec[:, None, :]
              + table.read_cents_gb[None, :, None] * stored_nlk)
    relief = (weights.beta * (1.0 - float(miss_rate))
              * np.asarray(rho, np.float64)[:, None, None] * access)
    return np.where(np.asarray(cached, bool)[:, None, None], -relief, 0.0)


def cache_cents(spans_gb: np.ndarray, cached: np.ndarray,
                config: CacheConfig, months: float) -> float:
    """Steady cache spend: storage of admitted raw bytes over ``months``
    plus the one-off fill write. Real cents — unlike the SLA penalty."""
    gb = float(np.asarray(spans_gb, np.float64)[np.asarray(cached, bool)]
               .sum())
    return gb * (config.storage_cents_gb_month * float(months)
                 + config.fill_cents_gb)


def served_latency_terms(rho: np.ndarray, lat_ms: np.ndarray,
                         cached: Optional[np.ndarray],
                         config: Optional[CacheConfig],
                         ):
    """Access-weighted serving latency distribution.

    Returns ``(lat_points_ms, weights)`` — each partition contributes its
    backing latency weighted by its (miss) traffic, and admitted
    partitions additionally contribute ``hit_latency_ms`` weighted by
    their hit traffic. Feed the pair to :func:`weighted_p99_ms`.
    """
    rho = np.asarray(rho, np.float64)
    lat_ms = np.asarray(lat_ms, np.float64)
    if cached is None or config is None:
        return lat_ms, rho
    cached = np.asarray(cached, bool)
    m = float(config.miss_rate)
    backing_w = np.where(cached, m * rho, rho)
    hit_w = np.where(cached, (1.0 - m) * rho, 0.0)
    return (np.concatenate([lat_ms, np.full(rho.shape[0],
                                            config.hit_latency_ms)]),
            np.concatenate([backing_w, hit_w]))


def weighted_p99_ms(lat_ms: np.ndarray, weights: np.ndarray,
                    q: float = 0.99) -> float:
    """Weighted latency quantile: smallest latency covering ``q`` of the
    access mass. 0.0 when there is no traffic at all."""
    lat_ms = np.asarray(lat_ms, np.float64)
    w = np.asarray(weights, np.float64)
    total = float(w.sum())
    if total <= 0.0 or lat_ms.size == 0:
        return 0.0
    order = np.argsort(lat_ms, kind="stable")
    cum = np.cumsum(w[order])
    i = int(np.searchsorted(cum, q * total, side="left"))
    return float(lat_ms[order][min(i, lat_ms.size - 1)])


class ReactiveLRUCache:
    """Reactive admit-on-access LRU cache — the benchmark baseline.

    No forecast: a partition enters the cache only when it is actually
    read, so the first (spiky) month of traffic is always served from the
    backing tier. Eviction is least-recently-used by access order.
    """

    def __init__(self, capacity_gb: float):
        self.capacity_gb = float(capacity_gb)
        self._sizes: Dict[int, float] = {}     # key -> GB, insertion = LRU
        self._used = 0.0

    @property
    def used_gb(self) -> float:
        return self._used

    def contains(self, key: int) -> bool:
        return key in self._sizes

    def access(self, key: int, gb: float) -> bool:
        """Touch ``key``; admit (evicting LRU victims) if absent.

        Returns True when the access was a HIT (already resident)."""
        hit = key in self._sizes
        if hit:
            self._sizes[key] = self._sizes.pop(key)   # move to MRU end
            return True
        gb = float(gb)
        if gb > self.capacity_gb:
            return False                              # can never fit
        while self._used + gb > self.capacity_gb and self._sizes:
            lru = next(iter(self._sizes))             # oldest insertion
            self._used -= self._sizes.pop(lru)
        self._sizes[key] = gb
        self._used += gb
        return False

    def mask(self, n: int) -> np.ndarray:
        """(n,) bool residency mask over integer keys ``0..n-1``."""
        out = np.zeros(n, bool)
        for k in self._sizes:
            if 0 <= k < n:
                out[k] = True
        return out
