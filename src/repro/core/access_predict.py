"""Access-pattern / optimal-tier prediction (paper §IV-C).

A RandomForest classifier maps (size, age, recent monthly read/write
aggregates) to the *optimal tier* label, where ground-truth labels are
produced by running OPTASSIGN with the true future access counts — exactly
the paper's training procedure ("We used OPTASSIGN to assign the ground truth
label encoding (i.e. the optimal tier) for each dataset while training").

Out-of-time evaluation: train at month t on labels from [t, t+h), test at
month t+h on labels from [t+h, t+2h).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import ml
from repro.core.costs import CostTable, Weights, cost_tensor, latency_feasible
from repro.core.optassign import greedy_assign
from repro.data.workloads import Workload, feature_matrix


def optimal_tiers(w: Workload, table: CostTable, lo: int, hi: int,
                  tiers: Sequence[int], read_fraction: float = 1.0,
                  latency_sla: float = np.inf) -> np.ndarray:
    """Ground-truth labels: per-dataset cost-optimal tier for months [lo,hi),
    restricted to the given tier subset (e.g. Hot/Cool for Table III)."""
    lo, hi = int(lo), int(hi)
    if hi <= lo:
        raise ValueError(f"optimal_tiers needs a non-empty month window: "
                         f"got [{lo}, {hi})")
    if lo < 0 or hi > w.n_months:
        raise ValueError(f"label window [{lo}, {hi}) falls outside the "
                         f"workload's [0, {w.n_months}) months")
    spans = np.array([d.size_gb for d in w.datasets])
    rho = w.reads_in(lo, hi) * read_fraction
    months = hi - lo
    N = len(spans)
    R = np.ones((N, 1))
    D = np.zeros((N, 1))
    cur = np.full(N, -1)
    cost = cost_tensor(spans, rho, cur, R, D, table, Weights(), months=months)
    feas = latency_feasible(D, np.full(N, latency_sla), table)
    allowed = np.zeros(table.num_tiers, bool)
    allowed[list(tiers)] = True
    feas = feas & allowed[None, :, None]
    a = greedy_assign(cost, feas)
    return a.tier


@dataclasses.dataclass
class TierPredictionReport:
    confusion: np.ndarray
    f1: float
    accuracy: float
    label_names: Tuple[str, ...]


def train_tier_predictor(
    w: Workload, table: CostTable, train_month: int, horizon: int,
    tiers: Sequence[int] = (1, 2), history: int = 4,
    model: Optional[object] = None,
) -> Tuple[object, TierPredictionReport]:
    """Out-of-time: fit on [train_month, +h) labels, test on the next window.

    Requires ``train_month + horizon < w.n_months`` so the test window
    ``[t+h, min(t+2h, n_months))`` is non-empty — otherwise the metrics
    would be computed on zero labels (or an inverted slice).
    """
    train_month, horizon = int(train_month), int(horizon)
    if horizon < 1:
        raise ValueError(f"horizon must be >= 1 month, got {horizon}")
    if train_month < 0:
        raise ValueError(f"train_month must be >= 0, got {train_month}")
    if train_month + horizon >= w.n_months:
        raise ValueError(
            f"out-of-time test window [{train_month + horizon}, "
            f"{min(train_month + 2 * horizon, w.n_months)}) is empty: "
            f"train_month + horizon must be < n_months "
            f"(= {w.n_months}); shrink train_month or horizon")
    tiers = list(tiers)
    y_tr = optimal_tiers(w, table, train_month, train_month + horizon, tiers)
    y_te = optimal_tiers(w, table, train_month + horizon,
                         min(train_month + 2 * horizon, w.n_months), tiers)
    X_tr = feature_matrix(w, train_month, history)
    X_te = feature_matrix(w, train_month + horizon, history)
    # map tier ids -> class indices
    tier_to_class = {t: i for i, t in enumerate(tiers)}
    c_tr = np.array([tier_to_class[t] for t in y_tr])
    c_te = np.array([tier_to_class[t] for t in y_te])
    clf = model or ml.RandomForest(n_trees=40, max_depth=10, task="clf",
                                   n_classes=len(tiers))
    clf.fit(X_tr, c_tr)
    pred = clf.predict(X_te).astype(int)
    conf = ml.confusion(c_te, pred, len(tiers))
    # binary F1 when 2 tiers; macro-F1 otherwise
    if len(tiers) == 2:
        f1 = ml.f1_binary(c_te, pred)
    else:
        f1s = []
        for c in range(len(tiers)):
            f1s.append(ml.f1_binary((c_te == c).astype(int),
                                    (pred == c).astype(int)))
        f1 = float(np.mean(f1s))
    acc = float((pred == c_te).mean())
    from repro.core.costs import TIER_NAMES
    return clf, TierPredictionReport(conf, f1, acc,
                                     tuple(TIER_NAMES[t] for t in tiers))


def predicted_tiers(clf, w: Workload, at_month: int,
                    tiers: Sequence[int] = (1, 2),
                    history: int = 4) -> np.ndarray:
    X = feature_matrix(w, at_month, history)
    cls = clf.predict(X).astype(int)
    return np.array([list(tiers)[c] for c in cls])
