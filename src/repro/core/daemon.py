"""Continuous re-optimization daemon — budget-capped online migration.

The paper's optimizer is only as good as its online loop: access rates
drift, and the minimum-stay / tier-change machinery exists precisely so
re-optimization can run continuously without churning storage.
:class:`ReoptimizationDaemon` closes that loop. Each cycle it

1. observes new access rates (batch mode: an (N,) rho vector; streaming
   mode: a query-family batch folded in by the
   :class:`~repro.core.engine.StreamingEngine`), optionally replaced by a
   **forecast** (``forecast_fn`` — e.g. a linear trend over the recent
   rho history, or an ``access_predict``-style fitted model),
2. solves the migration problem with the full hysteresis stack — the
   ``rho_rel_tol`` scheme lock plus the ``rho_abs_tol`` absolute floor
   (:func:`~repro.core.engine.drift_gate`), early-delete penalties priced
   on per-partition residency clocks,
3. **selects** which candidate moves to execute under a per-cycle
   :class:`MigrationBudget` (cents and/or GB) via the savings-per-
   migration-cent knapsack (:func:`~repro.core.optassign.budgeted_moves`).
   Unselected moves are deferred, tracked, and re-scored next cycle with
   a priority-aging boost so long-postponed moves eventually win; moves
   whose early-delete penalty still exceeds their projected steady-state
   savings are postponed outright (min-stay-aware deferral),
4. applies the partial :class:`~repro.core.engine.MigrationPlan` — to the
   engine state, and to an attached :class:`~repro.storage.store.
   TieredStore` (``migrate`` in batch mode, ``sync_plan`` in streaming
   mode) with exact metering.

With an infinite budget and ``rho_abs_tol=0`` every cycle is bit-identical
to a plain ``reoptimize`` / ``ingest_and_reoptimize`` call — the daemon
adds control, never drift (pinned by ``tests/test_daemon.py`` parity
tests). Budget selection only ever *postpones* spend: deferral bookkeeping
keeps charge-once semantics, so cumulative cost converges to the
unbudgeted trajectory (``benchmarks/bench_daemon.py``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.engine import (MigrationPlan, PlacementEngine, PlacementPlan,
                               StreamingEngine, drift_gate)
from repro.core.fleet import FleetEngine
# the shared forecasting sanity layer lives in core/forecast.py;
# re-exported here because linear_trend_forecast is the daemon's default
# forecast_fn building block (and the historical import location)
from repro.core.forecast import clamp_rho, linear_trend_forecast  # noqa: F401
from repro.core.optassign import budgeted_moves
from repro.core.stream import occurrence_keys


@dataclasses.dataclass(frozen=True)
class MigrationBudget:
    """Per-cycle caps on one-off migration spend.

    ``cents_per_cycle`` bounds the cycle's transfer + egress + early-delete
    penalty cents; ``gb_per_cycle`` bounds the stored bytes leaving their
    current cell. ``np.inf`` (the default) disables a cap.
    """

    cents_per_cycle: float = np.inf
    gb_per_cycle: float = np.inf

    @property
    def finite(self) -> bool:
        return bool(np.isfinite(self.cents_per_cycle)
                    or np.isfinite(self.gb_per_cycle))


@dataclasses.dataclass
class DaemonCycleReport:
    """What one daemon cycle observed, selected, deferred, and paid.

    ``migration_cents`` here is the read-out + write-in transfer
    **excluding** egress (unlike ``MigrationPlan.migration_cents``, which
    folds egress in), so ``migration_cents + egress_cents + penalty_cents
    == spent_cents`` — the exact budget charge, guaranteed <= the cap.
    """

    cycle: int
    n_partitions: int
    n_candidates: int                 # moves the solver proposed
    n_selected: int                   # moves executed this cycle
    n_deferred: int                   # moves postponed by the budget
    migration_cents: float            # transfer (read+write), egress excluded
    egress_cents: float
    penalty_cents: float
    spent_cents: float                # migration + egress + penalty
    moved_gb: float                   # stored bytes that left their cell
    steady_cents: float               # steady-state bill of the cycle's plan
    max_deferral_age: int             # oldest pending deferral, in cycles
    n_tenants: int = 1                # > 1 only in fleet mode
    installment_cents: float = 0.0    # banked toward oversized moves this cycle
    prepaid_used_cents: float = 0.0   # prior installments consumed by landings
    # execution-plane outcome (populated when a migrator is attached):
    # moves that failed terminally this cycle are *reverted* in the plan
    # (MigrationPlan.land) and re-enter the candidate set next cycle —
    # spent_cents covers landed moves only, the failure cost is metered
    # separately so no move is ever double-billed
    sla_penalty: float = 0.0          # rho-weighted excess-ms of the
    # cycle's plan (PipelineReport.sla_penalty) — reported, never part of
    # spent_cents/steady_cents accounting as money
    n_failed: int = 0                 # selected moves that failed to land
    retry_cents: float = 0.0          # wasted attempts of landed moves
    failed_cents: float = 0.0         # cents burned by failed moves
    attempted_cents: float = 0.0      # spent + retry + failed — what the
    # per-cycle budget cap is enforced against (== spent_cents without a
    # migrator: the synchronous path lands everything it bills)


class ReoptimizationDaemon:
    """Drives ``reoptimize`` / ``ingest_and_reoptimize`` in a cycle loop
    with budget-capped, hysteresis-guarded migrations.

    Three modes, chosen by the engine handed in:

    * **batch** — ``ReoptimizationDaemon(placement_engine, plan=plan0)``;
      each :meth:`step` takes the cycle's observed (N,) rho vector. The
      daemon owns per-partition residency clocks (``months_held``) and
      deferral ages.
    * **streaming** — ``ReoptimizationDaemon(streaming_engine)``; each
      :meth:`step` takes a query-family batch. Hysteresis tolerances come
      from the streaming engine itself (``rho_rel_tol`` / ``rho_abs_tol``
      constructor args); deferral ages are keyed by partition file-set
      identity so they survive re-partitioning.
    * **fleet** — ``ReoptimizationDaemon(fleet_engine, plans=[...])``;
      each :meth:`step` takes a list of per-tenant rho vectors. All
      tenants' migration solves run in ONE batched assignment dispatch
      and the budget knapsack runs ONCE over the concatenated candidate
      moves — the per-cycle budget is shared fleet-wide. With an
      unbounded budget every tenant's trajectory is bit-identical to its
      own batch-mode daemon.

    ``amortize_oversized=True`` (batch mode) splits a move whose charge
    exceeds the whole per-cycle cents cap across cycles: leftover budget
    is banked into the best such move each cycle (report field
    ``installment_cents``) until its residual charge fits the cap and it
    lands (consuming ``prepaid_used_cents``). Without it such a move is
    deferred forever.

    ``budget=None`` (or an all-inf :class:`MigrationBudget`) reproduces the
    underlying engine's results bit-for-bit. ``store=`` mirrors every
    applied (partial) plan into a metered ``TieredStore``: batch mode calls
    ``store.migrate`` (the store must already hold the initial plan via
    ``apply_plan``; pass ``store_keys`` if you used custom keys), streaming
    mode calls ``store.sync_plan`` with payloads from ``payload_fn``.

    ``migrator=`` (batch/streaming; mutually exclusive with ``store=``)
    routes execution through an :class:`~repro.core.migrator.AsyncMigrator`
    instead of the synchronous store calls: moves that fail terminally in a
    cycle are folded back via :meth:`MigrationPlan.land` — reverted in the
    daemon's state, re-planned next cycle as still-candidates — with their
    burned cents metered on the report (``retry_cents`` / ``failed_cents``
    / ``n_failed``), and the per-cycle cents cap is enforced by the
    migrator over *attempted* spend, so retries cannot blow the budget.
    Fleet mode takes ``migrators=`` (one per tenant, wrapping each
    tenant's own store); the shared budget decrements tenant-by-tenant by
    attempted cents. With zero faults the migrator path is bit-identical
    to ``store=``. ``amortize_oversized`` is incompatible with a migrator:
    its budget ledger reasons over residual charges, the execution plane
    over full per-move charges.
    """

    def __init__(self, engine: "PlacementEngine | StreamingEngine | FleetEngine",
                 plan: Optional[PlacementPlan] = None, *,
                 plans: Optional[Sequence[PlacementPlan]] = None,
                 budget: Optional[MigrationBudget] = None,
                 rho_rel_tol: Optional[float] = None,
                 rho_abs_tol: Optional[float] = None,
                 aging: float = 0.5,
                 horizon_months: Optional[float] = None,
                 min_stay_defer: bool = True,
                 selection: str = "auto",
                 amortize_oversized: bool = False,
                 forecast_fn: Optional[Callable] = None,
                 forecast_window: int = 6,
                 store=None, store_keys: Optional[list] = None,
                 payload_fn: Optional[Callable] = None,
                 migrator=None, migrators: Optional[Sequence] = None):
        self.streaming = isinstance(engine, StreamingEngine)
        self.fleet = isinstance(engine, FleetEngine)
        self.engine = engine
        self.budget = budget or MigrationBudget()
        self.aging = float(aging)
        self.horizon_months = horizon_months
        self.min_stay_defer = min_stay_defer
        self.selection = selection
        self.amortize_oversized = amortize_oversized
        self.forecast_fn = forecast_fn
        self.forecast_window = int(forecast_window)
        self.store = store
        self.store_keys = store_keys
        self.payload_fn = payload_fn
        self.migrator = migrator
        self.migrators = list(migrators) if migrators is not None else None
        self.history: List[DaemonCycleReport] = []
        if plans is not None and not self.fleet:
            raise ValueError("plans= is fleet mode — hand the daemon a "
                             "FleetEngine (single-tenant modes take plan=)")
        if isinstance(forecast_fn, (list, tuple)):
            if not self.fleet:
                raise ValueError("a forecast_fn sequence is fleet mode "
                                 "(one per tenant); single-tenant modes "
                                 "take a single callable")
            if plans is not None and len(forecast_fn) != len(plans):
                raise ValueError(f"forecast_fn= needs one callable per "
                                 f"tenant ({len(plans)}), got "
                                 f"{len(forecast_fn)}")
            self.forecast_fn = list(forecast_fn)
        if amortize_oversized and (self.streaming or self.fleet):
            raise ValueError("amortize_oversized is batch-mode only")
        if amortize_oversized and migrator is not None:
            raise ValueError("amortize_oversized is incompatible with a "
                             "migrator: the installment ledger budgets "
                             "residual charges, the execution plane full "
                             "per-move charges")
        if store is not None and migrator is not None:
            raise ValueError("pass either store= (synchronous mirroring) or "
                             "migrator= (resilient execution), not both — "
                             "the migrator wraps its own store")
        if migrators is not None and not self.fleet:
            raise ValueError("migrators= is fleet mode (one per tenant); "
                             "single-tenant modes take migrator=")
        if self.fleet:
            if plan is not None:
                raise ValueError("fleet mode takes plans= (one per tenant), "
                                 "not plan=")
            if plans is None:
                raise ValueError("fleet mode needs the initial per-tenant "
                                 "PlacementPlans (plans=)")
            if store is not None:
                raise ValueError("store mirroring is single-tenant; attach "
                                 "stores outside the fleet daemon")
            if migrator is not None:
                raise ValueError("fleet mode takes migrators= (one per "
                                 "tenant), not migrator=")
            if migrators is not None and len(migrators) != len(plans):
                raise ValueError(f"migrators= needs one migrator per tenant "
                                 f"({len(plans)}), got {len(migrators)}")
            if migrators is not None and store_keys is not None \
                    and len(store_keys) != len(plans):
                raise ValueError("fleet store_keys= must be a per-tenant "
                                 "list of key lists")
            self.plans: List[PlacementPlan] = list(plans)
            self.rho_rel_tol = 0.25 if rho_rel_tol is None else rho_rel_tol
            self.rho_abs_tol = 0.0 if rho_abs_tol is None else rho_abs_tol
            self._months_held_f = [np.zeros(p.problem.n) for p in self.plans]
            self._age_f = [np.zeros(p.problem.n, int) for p in self.plans]
            self._rho_ref_f = [np.asarray(p.problem.rho, np.float64).copy()
                               for p in self.plans]
            self._hist_f = [collections.deque(maxlen=self.forecast_window)
                            for _ in self.plans]
        elif self.streaming:
            if plan is not None:
                raise ValueError("streaming mode derives its plan from the "
                                 "engine; don't pass plan=")
            if rho_rel_tol is not None or rho_abs_tol is not None:
                raise ValueError("hysteresis lives on the StreamingEngine "
                                 "in streaming mode — pass rho_rel_tol/"
                                 "rho_abs_tol to its constructor instead")
            self._ages: Dict[Tuple, int] = {}
            self._rho_hist: Dict[Tuple, collections.deque] = {}
            # consecutive batches each tracked partition has been absent —
            # history is retired only after forecast_window misses, so
            # rolling-window churn doesn't reset calibration for
            # partitions that reappear a batch later
            self._rho_miss: Dict[Tuple, int] = {}
        else:
            if plan is None:
                raise ValueError("batch mode needs the initial "
                                 "PlacementPlan (plan=)")
            self.plan: Optional[PlacementPlan] = plan
            self.rho_rel_tol = 0.25 if rho_rel_tol is None else rho_rel_tol
            self.rho_abs_tol = 0.0 if rho_abs_tol is None else rho_abs_tol
            n = plan.problem.n
            self._months_held = np.zeros(n)
            self._age_arr = np.zeros(n, int)
            # drift-lock base: the rate each scheme was CHOSEN under — kept
            # for locked and deferred partitions (mirrors the streaming
            # engine) so slow drift accumulates and deferred moves stay in
            # the candidate set instead of re-basing away each cycle
            self._rho_ref = np.asarray(plan.problem.rho, np.float64).copy()
            self._batch_hist: collections.deque = collections.deque(
                maxlen=self.forecast_window)
            # amortized move-splitting ledger: cents already banked toward
            # each partition's (oversized) pending move
            self._paid = np.zeros(n)

    # ---------------------------------------------------------- selection
    def _terms(self, mig: MigrationPlan) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray]:
        """(savings, charge, eligible) knapsack inputs for one plan's moves."""
        savings = mig.steady_savings_cents(self.horizon_months)
        charge = (mig.move_transfer_cents + mig.move_egress_cents
                  + mig.move_penalty_cents)
        eligible = mig.candidate.copy()
        if self.min_stay_defer:
            # postpone while the early-delete penalty still exceeds the
            # projected steady-state savings — the clock only helps: the
            # penalty prorates away while savings stay put
            eligible &= ~(mig.move_penalty_cents
                          > np.maximum(savings, 0.0) + 1e-12)
        return savings, charge, eligible

    def _choose(self, mig: MigrationPlan, ages: np.ndarray,
                paid: Optional[np.ndarray] = None) -> np.ndarray:
        """Budget knapsack over the candidate moves (all-True when the
        budget is unbounded — the parity fast path)."""
        cand = mig.candidate
        if not self.budget.finite or not cand.any():
            return np.ones(cand.shape[0], bool)
        savings, charge, eligible = self._terms(mig)
        return budgeted_moves(
            savings, charge, self.budget.cents_per_cycle,
            candidates=eligible, move_gb=mig.old_stored_gb,
            budget_gb=self.budget.gb_per_cycle,
            priority=1.0 + self.aging * np.maximum(ages, 0),
            method=self.selection, paid_cents=paid)

    def _choose_fleet(self, migs: List[MigrationPlan]) -> List[np.ndarray]:
        """ONE knapsack over the concatenated candidate moves of every
        tenant — the per-cycle budget is shared fleet-wide, so a cent spent
        on tenant A's move is a cent unavailable to tenant B."""
        sizes = [m.candidate.shape[0] for m in migs]
        if not self.budget.finite or not any(
                m.candidate.any() for m in migs):
            return [np.ones(s, bool) for s in sizes]
        terms = [self._terms(m) for m in migs]
        keep = budgeted_moves(
            np.concatenate([t[0] for t in terms]) if sizes else np.zeros(0),
            np.concatenate([t[1] for t in terms]),
            self.budget.cents_per_cycle,
            candidates=np.concatenate([t[2] for t in terms]),
            move_gb=np.concatenate([m.old_stored_gb for m in migs]),
            budget_gb=self.budget.gb_per_cycle,
            priority=1.0 + self.aging * np.concatenate(
                [np.maximum(a, 0) for a in self._age_f]),
            method=self.selection)
        out, off = [], 0
        for s in sizes:
            out.append(keep[off:off + s])
            off += s
        return out

    @staticmethod
    def _spent(mig: MigrationPlan) -> Tuple[float, float, float, float]:
        transfer = float(np.where(mig.moved, mig.move_transfer_cents,
                                  0.0).sum())
        egress = float(np.where(mig.moved, mig.move_egress_cents, 0.0).sum())
        penalty = float(np.where(mig.moved, mig.move_penalty_cents,
                                 0.0).sum())
        gb = float(np.where(mig.moved, mig.old_stored_gb, 0.0).sum())
        return transfer, egress, penalty, gb

    # ------------------------------------------------------------- cycles
    def step(self, observed, months: float = 1.0) -> DaemonCycleReport:
        """Run one cycle. ``observed`` is the (N,) rho vector (batch mode),
        the query-family batch (streaming mode), or a list of per-tenant
        rho vectors (fleet mode); ``months`` is the logical time elapsed
        since the previous cycle."""
        if self.fleet:
            return self._step_fleet(list(observed), months)
        if self.streaming:
            return self._step_stream(observed, months)
        return self._step_batch(np.asarray(observed, np.float64), months)

    def run(self, cycles: Iterable, months: float = 1.0,
            ) -> List[DaemonCycleReport]:
        """Drive :meth:`step` over an iterable of per-cycle observations
        (e.g. ``wl.stream_query_log(...)`` or a list of rho vectors)."""
        return [self.step(obs, months=months) for obs in cycles]

    # ---------------------------------------------------------- batch mode
    def _step_batch(self, rho_obs: np.ndarray, months: float,
                    ) -> DaemonCycleReport:
        self._batch_hist.append(rho_obs)
        rho = (np.asarray(self.forecast_fn(list(self._batch_hist)),
                          np.float64)
               if self.forecast_fn is not None else rho_obs)
        held = self._months_held + months
        full = self.engine.reoptimize(
            self.plan, rho, months_held=held,
            rho_rel_tol=self.rho_rel_tol, rho_abs_tol=self.rho_abs_tol,
            rho_ref=self._rho_ref)
        paid = self._paid if self.amortize_oversized else None
        keep = self._choose(full, self._age_arr, paid=paid)
        mig = full.select(keep)

        exec_rep = None
        if self.migrator is not None:
            # execute BEFORE the state updates: moves that fail to land
            # must revert (deferred-candidate status) so every clock, age
            # and lock base below sees the state actually reached
            self.migrator.store.advance_months(months)
            exec_rep = self.migrator.execute(
                mig, self.store_keys, budget_cents=self._cycle_cap())
            mig = mig.land(exec_rep.unapplied_mask())

        installment = prepaid_used = 0.0
        if self.amortize_oversized and self.budget.finite \
                and np.isfinite(self.budget.cents_per_cycle):
            _, charge, eligible = self._terms(full)
            residual = np.maximum(charge - self._paid, 0.0)
            # landed moves consume their banked credit; the budget charged
            # this cycle was only the residual (budgeted_moves weighed it)
            prepaid_used = float(np.minimum(
                self._paid, charge)[mig.moved].sum())
            self._paid[mig.moved] = 0.0
            # bank the cycle's leftover budget into the best oversized move
            # — one whose residual charge exceeds the whole per-cycle cap,
            # so it could never land outright
            spent = float(residual[mig.moved].sum())
            left = self.budget.cents_per_cycle - spent
            over = eligible & ~keep & (residual
                                       > self.budget.cents_per_cycle)
            if left > 1e-9 and over.any():
                savings = full.steady_savings_cents(self.horizon_months)
                rank = np.where(
                    over,
                    (1.0 + self.aging * np.maximum(self._age_arr, 0))
                    * np.maximum(savings, 1e-9) / np.maximum(residual, 1e-9),
                    -np.inf)
                n = int(rank.argmax())
                installment = float(min(left, residual[n]))
                self._paid[n] += installment

        self._months_held = np.where(mig.moved, 0.0, held)
        deferred = mig.deferred
        self._age_arr = np.where(deferred, self._age_arr + 1, 0)
        # keep the lock base for locked survivors (slow drift accumulates)
        # and for deferred moves (they must re-enter the candidate set);
        # re-base everything that moved or was re-decided while unlocked
        drifted = drift_gate(rho, self._rho_ref, self.rho_rel_tol,
                             self.rho_abs_tol)
        self._rho_ref = np.where(~mig.moved & (~drifted | deferred),
                                 self._rho_ref, rho)
        self.plan = mig.plan
        if self.store is not None:
            self.store.advance_months(months)
            self.store.migrate(mig, self.store_keys)
        return self._report(mig, deferred,
                            int(self._age_arr.max()) if deferred.any()
                            else 0, installment_cents=installment,
                            prepaid_used_cents=prepaid_used,
                            exec_rep=exec_rep)

    def _cycle_cap(self) -> Optional[float]:
        """The cents cap handed to the execution plane (None = uncapped)."""
        cap = self.budget.cents_per_cycle
        return float(cap) if np.isfinite(cap) else None

    # ------------------------------------------------------------ fleet mode
    def _step_fleet(self, rho_obs: List[np.ndarray], months: float,
                    ) -> DaemonCycleReport:
        """One fleet cycle: T migration solves in one batched assignment
        dispatch, then ONE shared-budget knapsack over every tenant's
        candidate moves. With an unbounded budget each tenant's trajectory
        is bit-identical to its own batch-mode daemon (the fleet parity
        contract)."""
        T = len(self.plans)
        if len(rho_obs) != T:
            raise ValueError(f"fleet step expects {T} rho vectors, "
                             f"got {len(rho_obs)}")
        rhos = []
        for t in range(T):
            obs = np.asarray(rho_obs[t], np.float64)
            self._hist_f[t].append(obs)
            fn = (self.forecast_fn[t]
                  if isinstance(self.forecast_fn, list)
                  else self.forecast_fn)
            rhos.append(np.asarray(fn(list(self._hist_f[t])), np.float64)
                        if fn is not None else obs)
        held = [mh + months for mh in self._months_held_f]
        migs, _ = self.engine.reoptimize(
            self.plans, rhos, months_held=held,
            rho_rel_tol=self.rho_rel_tol, rho_abs_tol=self.rho_abs_tol,
            rho_refs=self._rho_ref_f)
        keeps = self._choose_fleet(migs)
        migs = [m.select(k) for m, k in zip(migs, keeps)]

        exec_reps = []
        if self.migrators is not None:
            # sequential per-tenant execution against a SHARED attempted-
            # spend ledger: each tenant's cap is what the fleet has left
            remaining = self._cycle_cap()
            for t, mig in enumerate(migs):
                self.migrators[t].store.advance_months(months)
                keys_t = (self.store_keys[t]
                          if self.store_keys is not None else None)
                rep_t = self.migrators[t].execute(
                    mig, keys_t, budget_cents=remaining)
                exec_reps.append(rep_t)
                if remaining is not None:
                    remaining = max(0.0, remaining - rep_t.attempted_cents)
                migs[t] = mig.land(rep_t.unapplied_mask())

        max_age = 0
        for t, mig in enumerate(migs):
            self._months_held_f[t] = np.where(mig.moved, 0.0, held[t])
            deferred = mig.deferred
            self._age_f[t] = np.where(deferred, self._age_f[t] + 1, 0)
            drifted = drift_gate(rhos[t], self._rho_ref_f[t],
                                 self.rho_rel_tol, self.rho_abs_tol)
            self._rho_ref_f[t] = np.where(
                ~mig.moved & (~drifted | deferred),
                self._rho_ref_f[t], rhos[t])
            self.plans[t] = mig.plan
            if deferred.any():
                max_age = max(max_age, int(self._age_f[t].max()))

        spent = [self._spent(m) for m in migs]
        transfer = sum(s[0] for s in spent)
        egress = sum(s[1] for s in spent)
        penalty = sum(s[2] for s in spent)
        gb = sum(s[3] for s in spent)
        deferreds = [m.deferred for m in migs]
        spent_cents = transfer + egress + penalty
        rep = DaemonCycleReport(
            cycle=len(self.history),
            n_partitions=sum(m.plan.problem.n for m in migs),
            n_candidates=sum(m.n_candidates for m in migs),
            n_selected=sum(m.n_moved for m in migs),
            n_deferred=int(sum(d.sum() for d in deferreds)),
            migration_cents=transfer, egress_cents=egress,
            penalty_cents=penalty,
            spent_cents=spent_cents, moved_gb=gb,
            steady_cents=float(sum(m.plan.report.total_cents
                                   for m in migs)),
            sla_penalty=float(sum(m.plan.report.sla_penalty
                                  for m in migs)),
            max_deferral_age=max_age, n_tenants=T,
            n_failed=sum(r.n_failed for r in exec_reps),
            retry_cents=float(sum(r.retry_cents for r in exec_reps)),
            failed_cents=float(sum(r.failed_cents for r in exec_reps)),
            attempted_cents=(float(sum(r.attempted_cents
                                       for r in exec_reps))
                             if exec_reps else spent_cents))
        self.history.append(rep)
        return rep

    # ------------------------------------------------------ streaming mode
    def _project_stream(self, parts, rho_obs: np.ndarray) -> np.ndarray:
        keys = occurrence_keys(parts)
        out = rho_obs.astype(np.float64).copy()
        # context protocol: a forecast_fn carrying stream_context=True
        # (e.g. AccessForecaster.stream_forecast_fn) also receives the
        # partition's file-set key and stored span — the paper's
        # strongest feature — alongside the scalar rho history
        wants_ctx = bool(getattr(self.forecast_fn, "stream_context", False))
        for i, k in enumerate(keys):
            h = self._rho_hist.setdefault(
                k, collections.deque(maxlen=self.forecast_window))
            h.append(float(rho_obs[i]))
            self._rho_miss.pop(k, None)
            if wants_ctx:
                out[i] = float(self.forecast_fn(
                    list(h), key=k, span_gb=float(parts[i].span)))
            else:
                out[i] = float(self.forecast_fn(list(h)))
        # retire history only after forecast_window CONSECUTIVE absences:
        # a partition that drops out of one batch and reappears in the
        # next (rolling-window churn) keeps its calibration
        for absent in set(self._rho_hist) - set(keys):
            misses = self._rho_miss.get(absent, 0) + 1
            if misses >= self.forecast_window:
                del self._rho_hist[absent]
                self._rho_miss.pop(absent, None)
            else:
                self._rho_miss[absent] = misses
        return out

    def _step_stream(self, batch, months: float) -> DaemonCycleReport:
        captured: Dict[str, object] = {}

        def select(mig: MigrationPlan) -> np.ndarray:
            keys = occurrence_keys(mig.plan.problem.partitions)
            ages = np.array([self._ages.get(k, 0) for k in keys], int)
            captured["keys"] = keys
            return self._choose(mig, ages)

        def execute(mig: MigrationPlan) -> np.ndarray:
            # same store-op order as the synchronous path below:
            # advance the billing clock, then reconcile the plan
            self.migrator.store.advance_months(months)
            parts = mig.plan.problem.partitions or []
            payloads = ([self.payload_fn(p) for p in parts]
                        if self.payload_fn is not None else None)
            rep = self.migrator.execute_sync(
                mig, payloads, budget_cents=self._cycle_cap())
            captured["exec"] = rep
            return rep.unapplied_mask()

        mig = self.engine.ingest_and_reoptimize(
            batch, months=months,
            select_moves=select if self.budget.finite else None,
            project_rho=(self._project_stream
                         if self.forecast_fn is not None else None),
            execute_moves=execute if self.migrator is not None else None)
        if self.migrator is not None and "exec" not in captured:
            # empty step (N == 0): the hook never ran, but the billing
            # clock still advances — identical to the synchronous path
            self.migrator.store.advance_months(months)
        keys = captured.get(
            "keys", occurrence_keys(mig.plan.problem.partitions or []))
        deferred = mig.deferred
        self._ages = {k: self._ages.get(k, 0) + 1
                      for k, d in zip(keys, deferred) if d}
        if self.store is not None:
            self.store.advance_months(months)
            parts = mig.plan.problem.partitions or []
            payloads = ([self.payload_fn(p) for p in parts]
                        if self.payload_fn is not None else None)
            if parts:
                self.store.sync_plan(mig.plan, payloads=payloads)
        return self._report(mig, deferred,
                            max(self._ages.values(), default=0),
                            exec_rep=captured.get("exec"))

    # ------------------------------------------------------------- report
    def _report(self, mig: MigrationPlan, deferred: np.ndarray,
                max_age: int, installment_cents: float = 0.0,
                prepaid_used_cents: float = 0.0,
                exec_rep=None) -> DaemonCycleReport:
        transfer, egress, penalty, gb = self._spent(mig)
        spent = transfer + egress + penalty
        rep = DaemonCycleReport(
            cycle=len(self.history),
            n_partitions=mig.plan.problem.n,
            n_candidates=mig.n_candidates, n_selected=mig.n_moved,
            n_deferred=int(deferred.sum()),
            migration_cents=transfer, egress_cents=egress,
            penalty_cents=penalty,
            spent_cents=spent,
            moved_gb=gb, steady_cents=mig.plan.report.total_cents,
            sla_penalty=mig.plan.report.sla_penalty,
            max_deferral_age=max_age,
            installment_cents=installment_cents,
            prepaid_used_cents=prepaid_used_cents,
            n_failed=exec_rep.n_failed if exec_rep is not None else 0,
            retry_cents=(exec_rep.retry_cents
                         if exec_rep is not None else 0.0),
            failed_cents=(exec_rep.failed_cents
                          if exec_rep is not None else 0.0),
            attempted_cents=(exec_rep.attempted_cents
                             if exec_rep is not None else spent))
        self.history.append(rep)
        return rep
