"""DATAPART — access-pattern-aware data partitioning (paper §VI).

* Initial partitions = query families (the file sets each distinct query
  touches), built from access logs.
* G-PART (Algorithm 1): greedy max-heap merging on fractional-overlap edge
  weights, with access-comparability feasibility and an S_thresh span cap.
* Ordered (time-series) case: exact pseudo-polynomial DP (Thm 5) + the
  epsilon-bucketed (1, 1+N*eps) bi-criteria approximation (Thm 6).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """A set of files with sizes; rho = projected access count."""

    files: FrozenSet[str]
    rho: float
    sizes: "FileSizes"

    @property
    def span(self) -> float:
        return self.sizes.span(self.files)


class FileSizes:
    """File-id -> size lookup shared by all partitions of a dataset."""

    def __init__(self, sizes: Dict[str, float]):
        self._s = dict(sizes)

    def span(self, files: FrozenSet[str]) -> float:
        return float(sum(self._s[f] for f in files))

    def __getitem__(self, f: str) -> float:
        return self._s[f]


def make_partitions(query_files: Sequence[Tuple[Tuple[str, ...], float]],
                    sizes: Dict[str, float]) -> List[Partition]:
    """Collapse queries touching identical file sets into query families."""
    fs = FileSizes(sizes)
    fam: Dict[FrozenSet[str], float] = {}
    for files, rho in query_files:
        key = frozenset(files)
        if not key:
            continue
        fam[key] = fam.get(key, 0.0) + rho
    return [Partition(k, r, fs) for k, r in fam.items()]


def overlap(a: Partition, b: Partition) -> float:
    return a.sizes.span(a.files & b.files)


def fractional_overlap(a: Partition, b: Partition) -> float:
    # exact-zero for disjoint sets: summing spans in set-iteration order is
    # PYTHONHASHSEED-dependent, and a +1e-16 residue here would let G-PART
    # merge unrelated partitions (also a fast path — most pairs are disjoint)
    if not (a.files & b.files):
        return 0.0
    u = a.sizes.span(a.files | b.files)
    return (a.span + b.span - u) / max(u, 1e-12)


def feasible_pair(a: Partition, b: Partition, rho_c: float,
                  rho_c_abs: float) -> bool:
    """Access-comparability (paper §VI-A): ratio within rho_c OR abs diff
    within rho_c_abs."""
    hi = max(a.rho, b.rho)
    lo = max(min(a.rho, b.rho), 1e-12)
    return (hi / lo) <= rho_c or abs(a.rho - b.rho) <= rho_c_abs


def read_cost(parts: Sequence[Partition]) -> float:
    """C(Z) = sum Sp(M) * rho(M) — expected scan volume."""
    return float(sum(p.span * p.rho for p in parts))


def duplication(parts: Sequence[Partition]) -> float:
    """1 - distinct/total span (paper Fig 7 footnote)."""
    total = sum(p.span for p in parts)
    if total <= 0:
        return 0.0
    distinct_files = frozenset(itertools.chain.from_iterable(p.files for p in parts))
    distinct = parts[0].sizes.span(distinct_files) if parts else 0.0
    return 1.0 - distinct / total


# --------------------------------------------------------------------- G-PART
def g_part(parts: List[Partition], s_thresh: float, rho_c: float = 4.0,
           rho_c_abs: float = 10.0) -> List[Partition]:
    """Algorithm 1. Lazy-deletion max-heap keyed on fractional overlap."""
    parts = list(parts)
    live: Dict[int, Partition] = dict(enumerate(parts))
    next_id = len(parts)
    heap: List[Tuple[float, int, int]] = []

    def push_edges(i: int) -> None:
        pi = live[i]
        for j, pj in live.items():
            if j == i:
                continue
            if not feasible_pair(pi, pj, rho_c, rho_c_abs):
                continue
            w = fractional_overlap(pi, pj)
            if w > 0.0:
                heapq.heappush(heap, (-w, min(i, j), max(i, j)))

    ids = list(live)
    for a_i in range(len(ids)):
        pi = live[ids[a_i]]
        for b_i in range(a_i + 1, len(ids)):
            pj = live[ids[b_i]]
            if feasible_pair(pi, pj, rho_c, rho_c_abs):
                w = fractional_overlap(pi, pj)
                if w > 0.0:
                    heapq.heappush(heap, (-w, ids[a_i], ids[b_i]))

    dead: set = set()
    while heap:
        negw, i, j = heapq.heappop(heap)
        if i in dead or j in dead:
            continue
        a, b = live[i], live[j]
        # weight may be stale after other merges — recheck feasibility
        if not feasible_pair(a, b, rho_c, rho_c_abs):
            continue
        merged = Partition(a.files | b.files, a.rho + b.rho, a.sizes)
        dead.update((i, j))
        del live[i], live[j]
        mid = next_id
        next_id += 1
        live[mid] = merged
        if merged.span < s_thresh:
            push_edges(mid)
    return list(live.values())


def merge_all(parts: List[Partition]) -> List[Partition]:
    """Baseline: one partition with everything."""
    if not parts:
        return []
    files = frozenset(itertools.chain.from_iterable(p.files for p in parts))
    return [Partition(files, sum(p.rho for p in parts), parts[0].sizes)]


# --------------------------------------------------- ordered (time-series) DP
@dataclasses.dataclass
class OrderedSolution:
    groups: List[Tuple[int, int]]   # inclusive [lo, hi] runs over partition idx
    space: float
    cost: float


def _run_spans(parts: List[Partition]) -> np.ndarray:
    """span[i][k] = Sp(P_{i-k} u ... u P_i), shape (N, N) (upper-tri by k<=i)."""
    N = len(parts)
    spans = np.zeros((N, N))
    for i in range(N):
        acc: FrozenSet[str] = frozenset()
        for k in range(i + 1):
            acc = acc | parts[i - k].files
            spans[i, k] = parts[0].sizes.span(acc)
    return spans


def ordered_dp(parts: List[Partition], c_thresh: float,
               n_buckets: int = 200) -> Optional[OrderedSolution]:
    """Thm 5 DP with cost discretized onto ``n_buckets`` units.

    ALG[i][c] = min span to cover P_1..P_i within cost budget c.
    Exact in the bucketed cost; Thm 6's scheme = call with
    n_buckets = ceil(N/eps) and budget stretched to (1+N*eps)*C.
    """
    N = len(parts)
    if N == 0:
        return OrderedSolution([], 0.0, 0.0)
    spans = _run_spans(parts)
    rho_prefix = np.concatenate([[0.0], np.cumsum([p.rho for p in parts])])
    unit = c_thresh / n_buckets if c_thresh > 0 else 1.0

    def cost_units(i: int, k: int) -> int:
        rho = rho_prefix[i + 1] - rho_prefix[i - k]
        return int(np.ceil(spans[i, k] * rho / unit - 1e-12))

    INF = float("inf")
    # dp[i][c] = min space covering first i partitions (i in 0..N) w/ budget c
    dp = np.full((N + 1, n_buckets + 1), INF)
    choice = np.full((N + 1, n_buckets + 1), -1, int)
    dp[0, :] = 0.0
    for i in range(1, N + 1):
        for k in range(i):                  # merge [i-k .. i] (1-indexed)
            cu = cost_units(i - 1, k)
            if cu > n_buckets:
                continue
            sp = spans[i - 1, k]
            prev = i - k - 1
            for c in range(cu, n_buckets + 1):
                cand = dp[prev, c - cu] + sp
                if cand < dp[i, c] - 1e-12:
                    dp[i, c] = cand
                    choice[i, c] = k
    if not np.isfinite(dp[N, n_buckets]):
        return None
    # backtrack
    groups: List[Tuple[int, int]] = []
    i, c = N, n_buckets
    total_cost = 0.0
    while i > 0:
        k = choice[i, c]
        groups.append((i - k - 1, i - 1))
        cu = cost_units(i - 1, k)
        rho = rho_prefix[i] - rho_prefix[i - k - 1]
        total_cost += spans[i - 1, k] * rho
        i, c = i - k - 1, c - cu
    groups.reverse()
    return OrderedSolution(groups, float(dp[N, n_buckets]), total_cost)


def ordered_approx(parts: List[Partition], c_thresh: float,
                   eps: float) -> Optional[OrderedSolution]:
    """Thm 6: (1, 1+N*eps) bi-criteria — bucket by eps*C, extend budget."""
    N = len(parts)
    stretched = c_thresh * (1.0 + N * eps)
    n_buckets = int(np.ceil((1.0 + N * eps) / eps))
    return ordered_dp(parts, stretched, n_buckets=n_buckets)


def ordered_brute_force(parts: List[Partition],
                        c_thresh: float) -> Optional[OrderedSolution]:
    """Exact oracle over all contiguous groupings (2^(N-1)) — tests only."""
    N = len(parts)
    spans = _run_spans(parts)
    rho_prefix = np.concatenate([[0.0], np.cumsum([p.rho for p in parts])])
    best: Optional[OrderedSolution] = None
    for cuts in itertools.product([0, 1], repeat=max(N - 1, 0)):
        groups, lo = [], 0
        for i, c in enumerate(cuts):
            if c:
                groups.append((lo, i))
                lo = i + 1
        groups.append((lo, N - 1))
        space = cost = 0.0
        for a, b in groups:
            sp = spans[b, b - a]
            rho = rho_prefix[b + 1] - rho_prefix[a]
            space += sp
            cost += sp * rho
        if cost <= c_thresh + 1e-9 and (best is None or space < best.space - 1e-12):
            best = OrderedSolution(groups, space, cost)
    return best
