"""DATAPART — access-pattern-aware data partitioning (paper §VI).

* Initial partitions = query families (the file sets each distinct query
  touches), built from access logs.
* G-PART (Algorithm 1): greedy max-heap merging on fractional-overlap edge
  weights, with access-comparability feasibility and an S_thresh span cap.
* Ordered (time-series) case: exact pseudo-polynomial DP (Thm 5) + the
  epsilon-bucketed (1, 1+N*eps) bi-criteria approximation (Thm 6).

Array-native core (the scalability refactor, ROADMAP "G-PART at millions
of files"): :class:`PartitionIndex` interns file ids into int32 codes and
stores family membership as a CSR matrix, with lossless round-trip to the
``Partition`` objects the rest of the engine consumes. :func:`g_part`
rebuilds Algorithm 1 on top of it — candidate-graph construction (an
inverted-index join, a device overlap-matrix kernel, or a MinHash-style
row-sampled estimator) followed by the *identical* lazy-deletion heap
merge semantics — and :func:`g_part_ref` keeps the original pair-by-pair
``frozenset`` implementation as the equivalence oracle: on any instance
whose edge weights are distinct (all seeded test instances; exactly so
for integer file sizes) the two return identical partitions and
``read_cost``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import (Dict, FrozenSet, Iterable, List, Optional, Sequence,
                    Set, Tuple)

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """A set of files with sizes; rho = projected access count."""

    files: FrozenSet[str]
    rho: float
    sizes: "FileSizes"

    @property
    def span(self) -> float:
        return self.sizes.span(self.files)


class FileSizes:
    """File-id -> size lookup shared by all partitions of a dataset.

    ``span`` is memoized per frozenset: ``g_part``'s merge loop, the
    ordered DPs, and ``read_cost`` all re-query the same unions, and each
    lookup used to re-sum O(|files|) floats. Summation iterates files in
    sorted order so the result is PYTHONHASHSEED-independent (the same
    bug class as the PR 2 disjoint-overlap fix). The cache holds every
    distinct frozenset queried over the object's lifetime — bounded by
    the partitions a dataset's merge/DP sweeps actually materialize.
    """

    def __init__(self, sizes: Dict[str, float]):
        self._s = dict(sizes)
        self._span_cache: Dict[FrozenSet[str], float] = {}

    def span(self, files: FrozenSet[str]) -> float:
        v = self._span_cache.get(files)
        if v is None:
            s = 0.0
            for f in sorted(files):
                s += self._s[f]
            v = self._span_cache[files] = float(s)
        return v

    def __getitem__(self, f: str) -> float:
        return self._s[f]

    def items(self):
        return self._s.items()


def make_partitions(query_files: Sequence[Tuple[Tuple[str, ...], float]],
                    sizes: Dict[str, float]) -> List[Partition]:
    """Collapse queries touching identical file sets into query families."""
    fs = FileSizes(sizes)
    fam: Dict[FrozenSet[str], float] = {}
    for files, rho in query_files:
        key = frozenset(files)
        if not key:
            continue
        fam[key] = fam.get(key, 0.0) + rho
    return [Partition(k, r, fs) for k, r in fam.items()]


def overlap(a: Partition, b: Partition) -> float:
    return a.sizes.span(a.files & b.files)


def fractional_overlap(a: Partition, b: Partition) -> float:
    # exact-zero for disjoint sets: summing spans in set-iteration order is
    # PYTHONHASHSEED-dependent, and a +1e-16 residue here would let G-PART
    # merge unrelated partitions (also a fast path — most pairs are disjoint)
    if not (a.files & b.files):
        return 0.0
    u = a.sizes.span(a.files | b.files)
    return (a.span + b.span - u) / max(u, 1e-12)


def feasible_pair(a: Partition, b: Partition, rho_c: float,
                  rho_c_abs: float) -> bool:
    """Access-comparability (paper §VI-A): ratio within rho_c OR abs diff
    within rho_c_abs."""
    hi = max(a.rho, b.rho)
    lo = max(min(a.rho, b.rho), 1e-12)
    return (hi / lo) <= rho_c or abs(a.rho - b.rho) <= rho_c_abs


def read_cost(parts: Sequence[Partition]) -> float:
    """C(Z) = sum Sp(M) * rho(M) — expected scan volume."""
    return float(sum(p.span * p.rho for p in parts))


def duplication(parts: Sequence[Partition]) -> float:
    """1 - distinct/total span (paper Fig 7 footnote)."""
    total = sum(p.span for p in parts)
    if total <= 0:
        return 0.0
    distinct_files = frozenset(itertools.chain.from_iterable(p.files for p in parts))
    distinct = parts[0].sizes.span(distinct_files) if parts else 0.0
    return 1.0 - distinct / total


# ------------------------------------------------------- array-native index
class FileInterner:
    """file id <-> dense int32 code, with a parallel f64 size array.

    Codes are assigned in first-intern order. ``StreamingPartitioner`` and
    ``PartitionIndex.from_partitions`` both intern each family's files in
    sorted order as the family is first seen, so a stream and the batch
    rebuild of its concatenated log produce the *same* code assignment —
    part of the batch-equivalence contract.
    """

    def __init__(self):
        self._code: Dict[str, int] = {}
        self._ids: List[str] = []
        self._size_list: List[float] = []
        self._sizes_arr: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self._ids)

    @property
    def file_ids(self) -> List[str]:
        return self._ids

    @property
    def sizes(self) -> np.ndarray:
        """(F,) float64 size per code (cached; rebuilt after growth)."""
        if self._sizes_arr is None or len(self._sizes_arr) != len(self._ids):
            self._sizes_arr = np.asarray(self._size_list, np.float64)
        return self._sizes_arr

    def intern(self, fid: str, size: float) -> int:
        c = self._code.get(fid)
        if c is None:
            c = len(self._ids)
            self._code[fid] = c
            self._ids.append(fid)
            self._size_list.append(float(size))
        return c

    def codes_of(self, files: Iterable[str], sizes: FileSizes) -> np.ndarray:
        """Ascending int32 codes of ``files`` (interning new ids)."""
        out = [self.intern(f, sizes[f]) for f in sorted(files)]
        out.sort()
        return np.asarray(out, np.int32)


@dataclasses.dataclass
class PartitionIndex:
    """CSR view of a partition list over interned int32 file codes.

    ``indices[indptr[i]:indptr[i+1]]`` are partition *i*'s file codes in
    ascending order; ``rho`` carries access rates; ``interner`` maps codes
    back to file ids and sizes. Round-trip with :meth:`from_partitions` /
    :meth:`to_partitions` is lossless (same frozensets, same rho, same
    shared :class:`FileSizes`, so memoized spans — and therefore
    ``read_cost`` — are bit-identical).
    """

    indptr: np.ndarray                 # (N+1,) int64
    indices: np.ndarray                # (nnz,) int32, ascending per row
    rho: np.ndarray                    # (N,)  float64
    interner: FileInterner
    file_sizes: Optional[FileSizes] = None   # shared lookup for round-trip

    @classmethod
    def from_partitions(cls, parts: Sequence[Partition],
                        interner: Optional[FileInterner] = None,
                        ) -> "PartitionIndex":
        interner = interner or FileInterner()
        fs = parts[0].sizes if parts else None
        rows = [interner.codes_of(p.files, p.sizes) for p in parts]
        indptr = np.zeros(len(parts) + 1, np.int64)
        if rows:
            np.cumsum([len(r) for r in rows], out=indptr[1:])
        indices = (np.concatenate(rows) if rows
                   else np.zeros(0, np.int32)).astype(np.int32)
        rho = np.asarray([p.rho for p in parts], np.float64)
        return cls(indptr, indices, rho, interner, fs)

    # ------------------------------------------------------------- basics
    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_files(self) -> int:
        return len(self.interner)

    def row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def to_partitions(self) -> List[Partition]:
        fs = self.file_sizes
        if fs is None:
            fs = FileSizes(dict(zip(self.interner.file_ids,
                                    self.interner.sizes.tolist())))
        ids = self.interner.file_ids
        return [Partition(frozenset(ids[c] for c in self.row(i)),
                          float(self.rho[i]), fs) for i in range(self.n)]

    # --------------------------------------------------- vectorized lookups
    def span(self) -> np.ndarray:
        """(N,) partition spans — one segmented reduction over the CSR."""
        if self.n == 0:
            return np.zeros(0)
        sizes = self.interner.sizes
        out = np.add.reduceat(
            np.concatenate([sizes[self.indices], [0.0]]),
            np.minimum(self.indptr[:-1], len(self.indices)))
        out[self.indptr[:-1] == self.indptr[1:]] = 0.0
        return out[: self.n]

    def read_cost(self) -> float:
        """Vectorized C(Z) = sum span * rho (== :func:`read_cost` to fp)."""
        return float(np.dot(self.span(), self.rho))

    def duplication(self) -> float:
        """Vectorized 1 - distinct/total span."""
        total = float(self.span().sum())
        if total <= 0:
            return 0.0
        distinct = float(self.interner.sizes[np.unique(self.indices)].sum())
        return 1.0 - distinct / total

    def overlap(self, i: int, j: int) -> float:
        """Intersection span of partitions i and j."""
        inter = np.intersect1d(self.row(i), self.row(j),
                               assume_unique=True)
        return float(self.interner.sizes[inter].sum())

    def fractional_overlap(self, i: int, j: int) -> float:
        inter = self.pair_overlap_spans(np.array([i]), np.array([j]))
        span = self.span()
        return float(_pair_weights(span[i:i + 1], span[j:j + 1], inter)[0])

    def pair_overlap_spans(self, pi: np.ndarray, pj: np.ndarray,
                           ) -> np.ndarray:
        """(P,) intersection spans for the pair list — one vectorized
        key-join over both sides' CSR rows (no Python per-pair loop)."""
        pi = np.asarray(pi, np.int64)
        pj = np.asarray(pj, np.int64)
        F = np.int64(max(self.n_files, 1))
        pos = np.arange(len(pi), dtype=np.int64)

        def keys(rows):
            lens = self.indptr[rows + 1] - self.indptr[rows]
            owner = np.repeat(pos, lens)
            cat = _gather_rows(self.indices, self.indptr, rows)
            return owner * F + cat
        common = np.intersect1d(keys(pi), keys(pj), assume_unique=True)
        inter = np.zeros(len(pi))
        np.add.at(inter, common // F, self.interner.sizes[common % F])
        return inter

    # ------------------------------------------------------ kernel layout
    def padded_codes(self, pad_multiple: int = 128,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dense ``(codes (N, M) int32 -1-padded, file sizes (F,) f32,
        spans (N,) f32)`` — the overlap-kernel input layout."""
        lens = np.diff(self.indptr)
        M = int(lens.max()) if self.n else 1
        M = max(-(-M // pad_multiple) * pad_multiple, pad_multiple)
        codes = np.full((self.n, M), -1, np.int32)
        mask = np.arange(M)[None, :] < lens[:, None]
        codes[mask] = self.indices
        return (codes, self.interner.sizes.astype(np.float32),
                self.span().astype(np.float32))

    # ------------------------------------------------- candidate generation
    def candidate_pairs(self, sample: Optional[float] = None, seed: int = 0,
                        max_degree: Optional[int] = None,
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """(i, j) candidate edges (i < j): every pair sharing >= 1 sampled
        file code, via an inverted-index join — the dense (N, N) matrix is
        never materialized.

        ``sample=None`` (or 1.0, no degree cap) keeps every code: the
        candidate set is then *exactly* ``{(i, j): overlap > 0}``.
        ``sample=r`` keeps each code with probability r (MinHash-style row
        sampling), and ``max_degree`` subsamples the partition group of
        hot codes — both shrink the join for N >= 1e6 files at the cost of
        possibly missing low-overlap edges.
        """
        if self.n < 2 or len(self.indices) == 0:
            e = np.zeros(0, np.int64)
            return e, e
        row_of = np.repeat(np.arange(self.n, dtype=np.int64),
                           np.diff(self.indptr))
        codes = self.indices.astype(np.int64)
        if sample is not None and sample < 1.0:
            rng = np.random.default_rng(seed)
            keep_code = rng.random(self.n_files) < sample
            m = keep_code[codes]
            codes, row_of = codes[m], row_of[m]
        if len(codes) == 0:
            e = np.zeros(0, np.int64)
            return e, e
        order = np.lexsort((row_of, codes))
        codes, rows = codes[order], row_of[order]
        starts = np.flatnonzero(np.diff(codes, prepend=codes[0] - 1))
        counts = np.diff(np.append(starts, len(codes)))
        if max_degree is not None and int(counts.max()) > max_degree:
            rng = np.random.default_rng(seed + 1)
            keep = np.ones(len(rows), bool)
            for s, c in zip(starts[counts > max_degree],
                            counts[counts > max_degree]):
                drop = rng.choice(c, c - max_degree, replace=False)
                keep[s + drop] = False
            rows = rows[keep]
            codes = codes[keep]
            starts = np.flatnonzero(np.diff(codes, prepend=codes[0] - 1))
            counts = np.diff(np.append(starts, len(codes)))
        # all intra-group pairs, vectorized by shift distance k
        start_rep = np.repeat(starts, counts)
        posn = np.arange(len(rows)) - start_rep
        cnt_rep = np.repeat(counts, counts)
        ai, bj = [], []
        for k in range(1, int(counts.max())):
            sel = posn + k < cnt_rep
            if not sel.any():
                break
            ai.append(rows[np.flatnonzero(sel)])
            bj.append(rows[np.flatnonzero(sel) + k])
        if not ai:
            e = np.zeros(0, np.int64)
            return e, e
        a = np.concatenate(ai)
        b = np.concatenate(bj)
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        m = lo != hi
        key = np.unique(lo[m] * np.int64(self.n) + hi[m])
        return key // self.n, key % self.n

    # ------------------------------------------------------- matrix sweeps
    def overlap_matrix(self, backend: str = "numpy", *, block: int = 2048,
                       mesh=None) -> np.ndarray:
        """(N, N) fractional-overlap matrix.

        backend 'numpy' runs a blocked host sweep (f64, never more than a
        ``(block, F)`` one-hot slab live at once); 'jnp' / 'pallas' /
        'interpret' dispatch the device kernel through
        :func:`repro.kernels.ops.fractional_overlap_matrix` (f32). With a
        ``mesh``, row blocks are sharded across devices via the
        ``repro.compat`` shard_map shim (single-device mesh falls back
        bit-identically).
        """
        if backend == "numpy":
            return self._overlap_matrix_numpy(block=block)
        codes, sizes, spans = self.padded_codes()
        from repro.kernels import ops
        if mesh is not None:
            w = _overlap_matrix_sharded(codes, sizes, spans, mesh,
                                        impl=backend)
        else:
            w = ops.fractional_overlap_matrix(codes, sizes, spans,
                                              impl=backend)
        return np.asarray(w)[: self.n, : self.n]

    def _overlap_matrix_numpy(self, block: int = 2048) -> np.ndarray:
        N, F = self.n, self.n_files
        spans = self.span()
        sizes = self.interner.sizes
        out = np.zeros((N, N))
        oh = np.zeros((min(block, max(N, 1)), max(F, 1)))
        row_of = np.repeat(np.arange(N, dtype=np.int64),
                           np.diff(self.indptr))
        for i0 in range(0, N, block):
            i1 = min(i0 + block, N)
            oh[: i1 - i0].fill(0.0)
            m = (row_of >= i0) & (row_of < i1)
            oh[row_of[m] - i0, self.indices[m]] = sizes[self.indices[m]]
            for j0 in range(0, N, block):
                j1 = min(j0 + block, N)
                ohj = np.zeros((j1 - j0, max(F, 1)))
                mj = (row_of >= j0) & (row_of < j1)
                ohj[row_of[mj] - j0, self.indices[mj]] = 1.0
                out[i0:i1, j0:j1] = oh[: i1 - i0] @ ohj.T
        den = spans[:, None] + spans[None, :] - out
        return np.where(out > 0.0, out / np.maximum(den, 1e-12), 0.0)


def _gather_rows(indices: np.ndarray, indptr: np.ndarray,
                 rows: np.ndarray) -> np.ndarray:
    """Concatenate CSR rows ``rows`` (order preserved) without a loop."""
    lens = indptr[rows + 1] - indptr[rows]
    offs = np.repeat(indptr[rows], lens)
    local = np.arange(int(lens.sum()), dtype=np.int64) \
        - np.repeat(np.cumsum(lens) - lens, lens)
    return indices[offs + local]


def _pair_weights(span_a: np.ndarray, span_b: np.ndarray,
                  inter: np.ndarray) -> np.ndarray:
    """Fractional overlap from spans + intersection span; exact 0 for
    disjoint pairs (``inter == 0`` propagates, no fp residue)."""
    den = span_a + span_b - inter
    return np.where(inter > 0.0, inter / np.maximum(den, 1e-12), 0.0)


def _feasible_mask(rho_a, rho_b, rho_c: float, rho_c_abs: float):
    """Vectorized :func:`feasible_pair` (same ops, same guards)."""
    hi = np.maximum(rho_a, rho_b)
    lo = np.maximum(np.minimum(rho_a, rho_b), 1e-12)
    return (hi / lo <= rho_c) | (np.abs(rho_a - rho_b) <= rho_c_abs)


class _NodeStore:
    """Mutable merge-time state shared by array ``g_part`` and the
    streaming partitioner: per-node ascending code arrays + span + rho,
    with vectorized one-vs-many overlap weights against the live set."""

    def __init__(self, interner: FileInterner):
        self.interner = interner
        self.codes: Dict[int, np.ndarray] = {}   # insertion-ordered
        self.span: Dict[int, float] = {}
        self.rho: Dict[int, float] = {}

    def add(self, nid: int, codes: np.ndarray, rho: float,
            span: Optional[float] = None) -> None:
        self.codes[nid] = codes
        if span is None:
            # sequential reduction in ascending-code order — the SAME
            # summation ``PartitionIndex.span`` performs (reduceat), so
            # streaming folds and batch sweeps see bit-identical spans
            s = self.interner.sizes[codes]
            span = float(np.add.reduceat(s, [0])[0]) if len(s) else 0.0
        self.span[nid] = float(span)
        self.rho[nid] = float(rho)

    def remove(self, nid: int) -> None:
        del self.codes[nid], self.span[nid], self.rho[nid]

    def merge(self, i: int, j: int, mid: int) -> None:
        codes = np.union1d(self.codes[i], self.codes[j])
        rho = self.rho[i] + self.rho[j]
        self.remove(i)
        self.remove(j)
        self.add(mid, codes.astype(np.int32), rho)

    def weights_against(self, q: int, others: Sequence[int],
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """``(weights, feasible_and_positive_mask_inputs)`` — fractional
        overlap of node ``q`` vs each of ``others`` in one vectorized
        pass (mask over interned codes + a single bincount)."""
        others = list(others)
        if not others:
            return np.zeros(0), np.zeros(0)
        sizes = self.interner.sizes
        mask = np.zeros(len(self.interner), bool)
        mask[self.codes[q]] = True
        cat = np.concatenate([self.codes[o] for o in others])
        seg = np.repeat(np.arange(len(others)),
                        [len(self.codes[o]) for o in others])
        hit = mask[cat]
        inter = np.bincount(seg[hit], weights=sizes[cat[hit]],
                            minlength=len(others))
        span_o = np.asarray([self.span[o] for o in others])
        w = _pair_weights(np.full(len(others), self.span[q]), span_o, inter)
        return w, np.asarray([self.rho[o] for o in others])


def _merge_loop(store: _NodeStore, heap: List[Tuple[float, int, int]],
                next_id: int, s_thresh: float, rho_c: float,
                rho_c_abs: float,
                neighbors: Optional[Dict[int, Set[int]]] = None,
                new_edge_targets=None,
                on_merge=None) -> int:
    """Algorithm 1's lazy-deletion heap loop over a :class:`_NodeStore`.

    Operationally identical to :func:`g_part_ref`'s loop: pop the max
    stale-tolerant edge, re-check access-comparability with current rho,
    merge, and (iff the product's span is under ``s_thresh``) push fresh
    edges from the product. New-edge targets come from ``neighbors``
    (the candidate graph is closed under merging: the product overlaps k
    iff i or j did) when provided, else from ``new_edge_targets()``
    (every live node — the streaming fold path, which has no global
    candidate graph). Returns the number of merges.
    """
    n_merges = 0
    dead: Set[int] = set()
    while heap:
        _, i, j = heapq.heappop(heap)
        if i in dead or j in dead:
            continue
        if not _feasible_mask(store.rho[i], store.rho[j], rho_c, rho_c_abs):
            continue
        mid = next_id
        next_id += 1
        store.merge(i, j, mid)
        dead.update((i, j))
        n_merges += 1
        if neighbors is not None:
            nb = (neighbors.pop(i, set()) | neighbors.pop(j, set())) - dead
            nb.discard(mid)
            neighbors[mid] = nb
            for k in nb:
                neighbors[k].add(mid)
            targets = sorted(nb)
        else:
            targets = [k for k in new_edge_targets() if k != mid]
        if on_merge is not None:
            on_merge(i, j, mid)
        if store.span[mid] >= s_thresh or not targets:
            continue
        w, rho_o = store.weights_against(mid, targets)
        ok = (w > 0.0) & _feasible_mask(store.rho[mid], rho_o,
                                        rho_c, rho_c_abs)
        for t in np.flatnonzero(ok):
            k = targets[t]
            heapq.heappush(heap, (-float(w[t]), min(mid, k), max(mid, k)))
    return n_merges


# --------------------------------------------------------------------- G-PART
def g_part_ref(parts: List[Partition], s_thresh: float, rho_c: float = 4.0,
               rho_c_abs: float = 10.0) -> List[Partition]:
    """Algorithm 1, original pair-by-pair form — the equivalence oracle.
    Lazy-deletion max-heap keyed on fractional overlap."""
    parts = list(parts)
    live: Dict[int, Partition] = dict(enumerate(parts))
    next_id = len(parts)
    heap: List[Tuple[float, int, int]] = []

    def push_edges(i: int) -> None:
        pi = live[i]
        for j, pj in live.items():
            if j == i:
                continue
            if not feasible_pair(pi, pj, rho_c, rho_c_abs):
                continue
            w = fractional_overlap(pi, pj)
            if w > 0.0:
                heapq.heappush(heap, (-w, min(i, j), max(i, j)))

    ids = list(live)
    for a_i in range(len(ids)):
        pi = live[ids[a_i]]
        for b_i in range(a_i + 1, len(ids)):
            pj = live[ids[b_i]]
            if feasible_pair(pi, pj, rho_c, rho_c_abs):
                w = fractional_overlap(pi, pj)
                if w > 0.0:
                    heapq.heappush(heap, (-w, ids[a_i], ids[b_i]))

    dead: set = set()
    while heap:
        negw, i, j = heapq.heappop(heap)
        if i in dead or j in dead:
            continue
        a, b = live[i], live[j]
        # weight may be stale after other merges — recheck feasibility
        if not feasible_pair(a, b, rho_c, rho_c_abs):
            continue
        merged = Partition(a.files | b.files, a.rho + b.rho, a.sizes)
        dead.update((i, j))
        del live[i], live[j]
        mid = next_id
        next_id += 1
        live[mid] = merged
        if merged.span < s_thresh:
            push_edges(mid)
    return list(live.values())


def g_part(parts: List[Partition], s_thresh: float, rho_c: float = 4.0,
           rho_c_abs: float = 10.0, *, backend: str = "numpy",
           sample: Optional[float] = None, sample_seed: int = 0,
           max_degree: Optional[int] = None, mesh=None,
           ) -> List[Partition]:
    """Algorithm 1 on the array-native core.

    Candidate edges (pairs with positive overlap) come from ``backend``:

    * ``'ref'`` — delegate entirely to :func:`g_part_ref` (no index);
    * ``'numpy'`` (default) — exact inverted-index join on the CSR, no
      dense matrix, no device;
    * ``'jnp'`` / ``'pallas'`` / ``'interpret'`` — the batched
      fractional-overlap matrix kernel (``repro.kernels.overlap``), one
      device dispatch; ``mesh`` shards its row blocks.

    ``sample`` (with any backend but 'ref') switches to the MinHash-style
    row-sampled estimator: only pairs sharing a *sampled* code enter the
    heap, so the candidate graph for N >= 1e6 files never goes quadratic.
    Heap weights are always recomputed in f64 from the index, and the
    merge loop replays :func:`g_part_ref`'s semantics exactly — with
    exact candidates the two implementations return identical partitions
    whenever edge weights are distinct (all pinned test instances).
    """
    if backend == "ref":
        return g_part_ref(parts, s_thresh, rho_c, rho_c_abs)
    if not parts:
        return []
    index = PartitionIndex.from_partitions(parts)
    if sample is not None or backend == "numpy":
        pi, pj = index.candidate_pairs(sample=sample, seed=sample_seed,
                                       max_degree=max_degree)
    else:
        w_mat = index.overlap_matrix(backend=backend, mesh=mesh)
        pi, pj = np.nonzero(np.triu(w_mat, 1) > 0.0)
    spans = index.span()
    inter = index.pair_overlap_spans(pi, pj)
    w = _pair_weights(spans[pi], spans[pj], inter)
    ok = (w > 0.0) & _feasible_mask(index.rho[pi], index.rho[pj],
                                    rho_c, rho_c_abs)

    store = _NodeStore(index.interner)
    for i in range(index.n):
        store.add(i, index.row(i), float(index.rho[i]),
                  span=float(spans[i]))
    neighbors: Dict[int, Set[int]] = {i: set() for i in range(index.n)}
    for a, b in zip(pi, pj):           # the w>0 graph, kept for merges
        neighbors[int(a)].add(int(b))
        neighbors[int(b)].add(int(a))
    heap = [(-float(w[t]), int(pi[t]), int(pj[t]))
            for t in np.flatnonzero(ok)]
    heapq.heapify(heap)
    _merge_loop(store, heap, index.n, s_thresh, rho_c, rho_c_abs,
                neighbors=neighbors)
    fs = parts[0].sizes
    ids = index.interner.file_ids
    return [Partition(frozenset(ids[c] for c in codes),
                      store.rho[nid], fs)
            for nid, codes in store.codes.items()]


def merge_all(parts: List[Partition]) -> List[Partition]:
    """Baseline: one partition with everything."""
    if not parts:
        return []
    files = frozenset(itertools.chain.from_iterable(p.files for p in parts))
    return [Partition(files, sum(p.rho for p in parts), parts[0].sizes)]


# --------------------------------------------------- ordered (time-series) DP
@dataclasses.dataclass
class OrderedSolution:
    groups: List[Tuple[int, int]]   # inclusive [lo, hi] runs over partition idx
    space: float
    cost: float


def _run_spans(parts: List[Partition]) -> np.ndarray:
    """span[i][k] = Sp(P_{i-k} u ... u P_i), shape (N, N) (upper-tri by k<=i).

    Derived from the interned index: each row extends a running
    seen-files mask instead of re-summing the frozenset union at every
    (i, k) — O(N * nnz) rather than O(N^2 * union size).
    """
    N = len(parts)
    spans = np.zeros((N, N))
    if N == 0:
        return spans
    index = PartitionIndex.from_partitions(parts)
    sizes = index.interner.sizes
    seen = np.zeros(index.n_files, bool)
    for i in range(N):
        seen.fill(False)
        acc = 0.0
        for k in range(i + 1):
            c = index.row(i - k)
            new = c[~seen[c]]
            acc += float(sizes[new].sum())
            seen[c] = True
            spans[i, k] = acc
    return spans


def ordered_dp(parts: List[Partition], c_thresh: float,
               n_buckets: int = 200) -> Optional[OrderedSolution]:
    """Thm 5 DP with cost discretized onto ``n_buckets`` units.

    ALG[i][c] = min span to cover P_1..P_i within cost budget c.
    Exact in the bucketed cost; Thm 6's scheme = call with
    n_buckets = ceil(N/eps) and budget stretched to (1+N*eps)*C.
    """
    N = len(parts)
    if N == 0:
        return OrderedSolution([], 0.0, 0.0)
    spans = _run_spans(parts)
    rho_prefix = np.concatenate([[0.0], np.cumsum([p.rho for p in parts])])
    unit = c_thresh / n_buckets if c_thresh > 0 else 1.0

    def cost_units(i: int, k: int) -> int:
        rho = rho_prefix[i + 1] - rho_prefix[i - k]
        return int(np.ceil(spans[i, k] * rho / unit - 1e-12))

    INF = float("inf")
    # dp[i][c] = min space covering first i partitions (i in 0..N) w/ budget c
    dp = np.full((N + 1, n_buckets + 1), INF)
    choice = np.full((N + 1, n_buckets + 1), -1, int)
    dp[0, :] = 0.0
    for i in range(1, N + 1):
        for k in range(i):                  # merge [i-k .. i] (1-indexed)
            cu = cost_units(i - 1, k)
            if cu > n_buckets:
                continue
            sp = spans[i - 1, k]
            prev = i - k - 1
            for c in range(cu, n_buckets + 1):
                cand = dp[prev, c - cu] + sp
                if cand < dp[i, c] - 1e-12:
                    dp[i, c] = cand
                    choice[i, c] = k
    if not np.isfinite(dp[N, n_buckets]):
        return None
    # backtrack
    groups: List[Tuple[int, int]] = []
    i, c = N, n_buckets
    total_cost = 0.0
    while i > 0:
        k = choice[i, c]
        groups.append((i - k - 1, i - 1))
        cu = cost_units(i - 1, k)
        rho = rho_prefix[i] - rho_prefix[i - k - 1]
        total_cost += spans[i - 1, k] * rho
        i, c = i - k - 1, c - cu
    groups.reverse()
    return OrderedSolution(groups, float(dp[N, n_buckets]), total_cost)


def ordered_approx(parts: List[Partition], c_thresh: float,
                   eps: float) -> Optional[OrderedSolution]:
    """Thm 6: (1, 1+N*eps) bi-criteria — bucket by eps*C, extend budget."""
    N = len(parts)
    stretched = c_thresh * (1.0 + N * eps)
    n_buckets = int(np.ceil((1.0 + N * eps) / eps))
    return ordered_dp(parts, stretched, n_buckets=n_buckets)


def ordered_brute_force(parts: List[Partition],
                        c_thresh: float) -> Optional[OrderedSolution]:
    """Exact oracle over all contiguous groupings (2^(N-1)) — tests only."""
    N = len(parts)
    spans = _run_spans(parts)
    rho_prefix = np.concatenate([[0.0], np.cumsum([p.rho for p in parts])])
    best: Optional[OrderedSolution] = None
    for cuts in itertools.product([0, 1], repeat=max(N - 1, 0)):
        groups, lo = [], 0
        for i, c in enumerate(cuts):
            if c:
                groups.append((lo, i))
                lo = i + 1
        groups.append((lo, N - 1))
        space = cost = 0.0
        for a, b in groups:
            sp = spans[b, b - a]
            rho = rho_prefix[b + 1] - rho_prefix[a]
            space += sp
            cost += sp * rho
        if cost <= c_thresh + 1e-9 and (best is None or space < best.space - 1e-12):
            best = OrderedSolution(groups, space, cost)
    return best


# ---------------------------------------------------------- sharded matrix
def _overlap_matrix_sharded(codes: np.ndarray, sizes: np.ndarray,
                            spans: np.ndarray, mesh, impl: str = "jnp",
                            axis: Optional[str] = None) -> np.ndarray:
    """Row-block-sharded overlap matrix: each device computes its row
    slab against the full (replicated) code set through the same kernel
    dispatch, stitched with the ``repro.compat`` shard_map shim. A
    single-device mesh degrades to the unsharded call bit-identically."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.kernels import ops

    axis = axis or mesh.axis_names[0]
    ndev = int(mesh.shape[axis])
    N = codes.shape[0]
    pad = (-N) % ndev
    codes_p = np.pad(codes, ((0, pad), (0, 0)), constant_values=-1)
    spans_p = np.pad(spans, (0, pad))

    def block(codes_blk, spans_blk, codes_all, spans_all, sizes_all):
        return ops.fractional_overlap_matrix(
            codes_blk, sizes_all, spans_blk, codes_b=codes_all,
            spans_b=spans_all, impl=impl)

    fn = compat.shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None), P(None), P(None)),
        out_specs=P(axis, None), check_vma=False)
    out = fn(jnp.asarray(codes_p), jnp.asarray(spans_p),
             jnp.asarray(codes_p), jnp.asarray(spans_p), jnp.asarray(sizes))
    return np.asarray(out)[:N, :N]
