"""Fleet-scale SCOPe: T tenants' placement problems in one device dispatch.

A fleet daemon cycle over thousands of tenants previously paid Python
dispatch, jit re-tracing (every distinct N re-traces the scan), and
host<->device transfer *per tenant*. :class:`FleetEngine` batches the
AssignStage of every tenant into a single
:func:`~repro.core.optassign.capacitated_assign_batch` dispatch — ragged
problems padded to ``(T, N_max, L, K)``, one jitted Lagrangian scan,
optionally ``shard_map``-sharded over a device mesh — then finishes
billing / migration bookkeeping per tenant on host.

Parity contract (pinned by ``tests/test_fleet.py``): with no *shared*
fleet-wide capacity rows, every per-tenant result is **bit-identical** to
the per-tenant :class:`~repro.core.engine.PlacementEngine` path. Shared
rows (``fleet_provider_capacity_gb`` or explicit
``shared_tier_groups``/``shared_capacity_gb``) couple the tenants: one
provider's global capacity then binds the fleet total rather than each
tenant separately.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import (MigrationPlan, PlacementEngine, PlacementPlan,
                               PlacementProblem)
from repro.core.optassign import (FleetAssignment, capacitated_assign_batch,
                                  greedy_assign_batch)

__all__ = ["FleetEngine", "FleetPlan"]


@dataclasses.dataclass
class FleetPlan:
    """One fleet solve: per-tenant plans plus the fleet-level assignment."""

    plans: List[PlacementPlan]
    fleet: FleetAssignment

    @property
    def total_cents(self) -> float:
        return float(sum(p.report.total_cents for p in self.plans))


def _seq_or_scalar(x, T: int):
    """Per-tenant view of an argument that may be one scalar/array for all
    tenants or a length-T sequence of per-tenant values."""
    if isinstance(x, (list, tuple)):
        if len(x) != T:
            raise ValueError(f"expected a scalar or a length-{T} sequence, "
                             f"got length {len(x)}")
        return list(x)
    return [x] * T


class FleetEngine:
    """Batched AssignStage/BillingStage for a fleet of tenants.

    All tenants share one :class:`~repro.core.costs.CostTable` and one
    :class:`~repro.core.engine.ScopeConfig` (a fleet is one operator's
    pricing + policy applied to many datasets); per-tenant problems may
    have any sizes ``N_t`` including zero.

    ``fleet_provider_capacity_gb`` (``{provider_name: gb}``, multi-cloud
    tables only) is the convenience spelling of shared rows: each named
    provider's capacity caps the *fleet-wide* usage of its tiers. Explicit
    ``shared_tier_groups``/``shared_capacity_gb`` pass arbitrary shared
    rows straight to the solver. ``mesh`` (a ``jax.sharding.Mesh``)
    shards the batched scan over the mesh's first axis; on a single
    device the plain jitted batch runs — same results.
    """

    def __init__(self, table, cfg, *, mesh=None,
                 shared_tier_groups: Optional[np.ndarray] = None,
                 shared_capacity_gb: Optional[np.ndarray] = None,
                 fleet_provider_capacity_gb: Optional[dict] = None):
        self.engine = PlacementEngine(table, cfg)
        self.table = table
        self.cfg = cfg
        self.mesh = mesh
        if fleet_provider_capacity_gb is not None:
            if shared_tier_groups is not None or shared_capacity_gb is not None:
                raise ValueError("pass either fleet_provider_capacity_gb or "
                                 "explicit shared_tier_groups/"
                                 "shared_capacity_gb, not both")
            pnames = getattr(table, "provider_names", None)
            if pnames is None:
                raise ValueError("fleet_provider_capacity_gb requires a "
                                 "MultiCloudCostTable")
            unknown = set(fleet_provider_capacity_gb) - set(pnames)
            if unknown:
                raise ValueError(f"unknown providers {sorted(unknown)}; "
                                 f"table has {pnames}")
            caps = np.full(len(pnames), np.inf)
            for name, gb in fleet_provider_capacity_gb.items():
                caps[list(pnames).index(name)] = float(gb)
            shared_tier_groups = np.asarray(table.provider_of_tier, int)
            shared_capacity_gb = caps
        self.shared_tier_groups = shared_tier_groups
        self.shared_capacity_gb = shared_capacity_gb

    @property
    def coupled(self) -> bool:
        """True when finite shared rows actually couple the tenants."""
        return (self.shared_capacity_gb is not None
                and bool(np.isfinite(self.shared_capacity_gb).any()))

    # ------------------------------------------------------------- assign
    def assign_batch(self, problems: Sequence[PlacementProblem],
                     extra_costs: Optional[Sequence] = None,
                     locked_schemes: Optional[Sequence] = None,
                     ) -> FleetAssignment:
        """One batched solver dispatch for all tenants' assignments.

        Mirrors ``AssignStage.__call__`` exactly: the greedy batch when
        neither per-tier caps, provider caps, nor shared rows constrain
        anything, the capacitated batch otherwise.
        """
        T = len(problems)
        extra_costs = list(extra_costs) if extra_costs is not None \
            else [None] * T
        locked_schemes = list(locked_schemes) if locked_schemes is not None \
            else [None] * T
        ins = [self.engine.assign.solver_inputs(p, ec, lk)
               for p, ec, lk in zip(problems, extra_costs, locked_schemes)]
        costs = [i[0] for i in ins]
        feases = [i[1] for i in ins]
        if T == 0 or (ins[0][3] is None and ins[0][4] is None
                      and self.shared_tier_groups is None):
            assignments = greedy_assign_batch(costs, feases)
            feasible = all(a.feasible for a in assignments)
            cost = (float(sum(a.cost for a in assignments)) if feasible
                    else float("inf"))
            return FleetAssignment(assignments, cost, feasible, None)
        L = self.table.num_tiers
        caps = [i[3] if i[3] is not None else np.full(L, np.inf)
                for i in ins]
        tg = ins[0][4]
        gcaps = [i[5] for i in ins] if tg is not None else None
        return capacitated_assign_batch(
            costs, feases, [i[2] for i in ins], caps,
            tier_groups=tg, group_capacity_gb=gcaps,
            shared_tier_groups=self.shared_tier_groups,
            shared_capacity_gb=self.shared_capacity_gb,
            mesh=self.mesh)

    # -------------------------------------------------------------- solve
    def solve(self, problems: Sequence[PlacementProblem]) -> FleetPlan:
        """Assignment + billing for every tenant, one assignment dispatch."""
        fleet = self.assign_batch(problems)
        plans = [PlacementPlan(p, a, self.engine.billing(p, a))
                 for p, a in zip(problems, fleet.assignments)]
        return FleetPlan(plans, fleet)

    # --------------------------------------------------------- reoptimize
    def reoptimize(self, plans: Sequence[PlacementPlan], new_rhos: Sequence,
                   months_held=0.0, lock_unchanged: bool = True,
                   rho_rel_tol: float = 0.25, rho_abs_tol: float = 0.0,
                   rho_refs: Optional[Sequence] = None,
                   ) -> Tuple[List[MigrationPlan], FleetAssignment]:
        """T incremental migration solves in one assignment dispatch.

        Per tenant this is exactly :meth:`PlacementEngine.reoptimize` —
        the same pre-dispatch terms (drift gate, early-delete penalties,
        recompression and egress re-basing) and the same post-dispatch
        bookkeeping, with only the assignment solve batched. With no
        shared rows the returned plans are bit-identical to T independent
        ``reoptimize`` calls.
        """
        T = len(plans)
        held = _seq_or_scalar(months_held, T)
        refs = list(rho_refs) if rho_refs is not None else [None] * T
        probs2, curs, pens, extras, lockeds = [], [], [], [], []
        for t in range(T):
            prob = plans[t].problem
            new_rho = np.asarray(new_rhos[t], np.float64)
            cur_l = plans[t].assignment.tier.astype(int)
            cur_k = plans[t].assignment.scheme.astype(int)
            problem2 = dataclasses.replace(prob, rho=new_rho,
                                           current_tier=cur_l)
            ref = (np.asarray(prob.rho, np.float64) if refs[t] is None
                   else np.asarray(refs[t], np.float64))
            extra, locked, pen = self.engine._migration_terms(
                problem2, cur_l, cur_k, plans[t].stored_gb,
                np.asarray(held[t], np.float64), lock_unchanged,
                rho_rel_tol, ref, rho_abs_tol)
            probs2.append(problem2)
            curs.append((cur_l, cur_k))
            pens.append(pen)
            extras.append(extra)
            lockeds.append(locked)
        fleet = self.assign_batch(probs2, extras, lockeds)
        migs = [self.engine._finalize_migration(
                    probs2[t], fleet.assignments[t], curs[t][0], curs[t][1],
                    plans[t].stored_gb, pens[t])
                for t in range(T)]
        return migs, fleet
