"""Self-contained ML models for COMPREDICT and access prediction.

No sklearn in the container, so: CART trees + random forest (NumPy), an MLP
regressor/classifier trained with Adam (pure JAX), and kernel ridge regression
(the paper's SVR stand-in). All models share fit/predict and are deliberately
small — COMPREDICT's training sets are O(10^2..10^3) rows (paper §V:
"training the model takes a few seconds").
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------- metrics

def mae(y, p):
    return float(np.mean(np.abs(np.asarray(y) - np.asarray(p))))


def mape(y, p):
    y, p = np.asarray(y), np.asarray(p)
    return float(np.mean(np.abs(y - p) / np.maximum(np.abs(y), 1e-9))) * 100.0


def r2(y, p):
    y, p = np.asarray(y), np.asarray(p)
    ss_res = float(np.sum((y - p) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-12)


def f1_binary(y, p) -> float:
    y, p = np.asarray(y).astype(int), np.asarray(p).astype(int)
    tp = int(np.sum((y == 1) & (p == 1)))
    fp = int(np.sum((y == 0) & (p == 1)))
    fn = int(np.sum((y == 1) & (p == 0)))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return 2 * prec * rec / max(prec + rec, 1e-12)


def confusion(y, p, n_classes: int) -> np.ndarray:
    m = np.zeros((n_classes, n_classes), int)
    for a, b in zip(np.asarray(y).astype(int), np.asarray(p).astype(int)):
        m[a, b] += 1
    return m


def reliability_bins(p, y, n_bins: int = 10):
    """Reliability diagram data for binary probabilities: per bin over
    [0, 1], (count, mean predicted p, empirical positive fraction).
    Empty bins report count 0 and NaN means."""
    p = np.asarray(p, np.float64)
    y = np.asarray(y, np.float64)
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    idx = np.clip(np.digitize(p, edges[1:-1]), 0, n_bins - 1)
    count = np.bincount(idx, minlength=n_bins).astype(float)
    with np.errstate(invalid="ignore"):
        mean_p = np.bincount(idx, weights=p, minlength=n_bins) \
            / np.where(count > 0, count, np.nan)
        frac_pos = np.bincount(idx, weights=y, minlength=n_bins) \
            / np.where(count > 0, count, np.nan)
    return count, mean_p, frac_pos


def expected_calibration_error(p, y, n_bins: int = 10) -> float:
    """ECE: count-weighted mean |empirical frequency - mean predicted p|
    over occupied probability bins. 0 = perfectly calibrated."""
    count, mean_p, frac_pos = reliability_bins(p, y, n_bins)
    occ = count > 0
    if not occ.any():
        return 0.0
    gap = np.abs(frac_pos[occ] - mean_p[occ])
    return float((gap * count[occ]).sum() / count[occ].sum())


# ---------------------------------------------------------------- CART trees
@dataclasses.dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0         # mean (regression) or class probs index
    probs: Optional[np.ndarray] = None


class DecisionTree:
    """CART: variance reduction (regression) / gini (classification)."""

    def __init__(self, max_depth: int = 8, min_leaf: int = 2,
                 n_features: Optional[int] = None, task: str = "reg",
                 n_classes: int = 2, rng: Optional[np.random.Generator] = None):
        self.max_depth, self.min_leaf = max_depth, min_leaf
        self.n_features, self.task, self.n_classes = n_features, task, n_classes
        self.rng = rng or np.random.default_rng(0)
        self.root: Optional[_Node] = None

    def _leaf(self, y: np.ndarray) -> _Node:
        if self.task == "reg":
            return _Node(value=float(y.mean()))
        probs = np.bincount(y.astype(int), minlength=self.n_classes) / len(y)
        return _Node(value=float(probs.argmax()), probs=probs)

    def _impurity(self, y: np.ndarray) -> float:
        if self.task == "reg":
            return float(y.var()) * len(y)
        p = np.bincount(y.astype(int), minlength=self.n_classes) / len(y)
        return float(1.0 - np.sum(p ** 2)) * len(y)

    def _split(self, X: np.ndarray, y: np.ndarray, depth: int) -> _Node:
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or \
                np.all(y == y[0]):
            return self._leaf(y)
        d = X.shape[1]
        feats = self.rng.permutation(d)[: (self.n_features or d)]
        parent = self._impurity(y)
        best_gain, best = 1e-12, None
        for f in feats:
            order = np.argsort(X[:, f], kind="stable")
            xs, ys = X[order, f], y[order]
            # candidate thresholds between distinct values
            distinct = np.nonzero(np.diff(xs) > 1e-12)[0]
            if len(distinct) == 0:
                continue
            # subsample candidate split points for speed
            cand = distinct if len(distinct) <= 32 else \
                distinct[np.linspace(0, len(distinct) - 1, 32).astype(int)]
            for i in cand:
                nl = i + 1
                if nl < self.min_leaf or len(y) - nl < self.min_leaf:
                    continue
                gain = parent - self._impurity(ys[:nl]) - self._impurity(ys[nl:])
                if gain > best_gain:
                    best_gain = gain
                    best = (f, (xs[i] + xs[i + 1]) / 2.0)
        if best is None:
            return self._leaf(y)
        f, t = best
        mask = X[:, f] <= t
        return _Node(feature=int(f), thresh=float(t),
                     left=self._split(X[mask], y[mask], depth + 1),
                     right=self._split(X[~mask], y[~mask], depth + 1))

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DecisionTree":
        self.root = self._split(np.asarray(X, float), np.asarray(y), 0)
        return self

    def _pred_one(self, x: np.ndarray) -> _Node:
        node = self.root
        while node.left is not None:
            node = node.left if x[node.feature] <= node.thresh else node.right
        return node

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.array([self._pred_one(x).value for x in np.asarray(X, float)])

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return np.stack([self._pred_one(x).probs for x in np.asarray(X, float)])


class RandomForest:
    """Bootstrap-aggregated CART forest (paper's best model, §IV-C & §V)."""

    def __init__(self, n_trees: int = 40, max_depth: int = 10, min_leaf: int = 2,
                 task: str = "reg", n_classes: int = 2, seed: int = 0):
        self.task, self.n_classes = task, n_classes
        self.seed, self.n_trees = seed, n_trees
        self.max_depth, self.min_leaf = max_depth, min_leaf
        self.trees: List[DecisionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X, y = np.asarray(X, float), np.asarray(y)
        rng = np.random.default_rng(self.seed)
        n, d = X.shape
        mfeat = max(1, int(np.ceil(np.sqrt(d)))) if self.task == "clf" \
            else max(1, d // 3 + 1)
        self.trees = []
        for i in range(self.n_trees):
            idx = rng.integers(0, n, n)
            t = DecisionTree(self.max_depth, self.min_leaf, mfeat, self.task,
                             self.n_classes, np.random.default_rng(self.seed + i))
            self.trees.append(t.fit(X[idx], y[idx]))
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if self.task == "reg":
            return np.mean([t.predict(X) for t in self.trees], axis=0)
        return self.predict_proba(X).argmax(1)

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        """(n, n_classes) vote fractions across the forest. These are NOT
        calibrated probabilities — run them through a fitted
        :class:`IsotonicCalibrator` before treating them as such."""
        if self.task != "clf":
            raise ValueError("predict_proba is classification-only "
                             "(task='clf')")
        if not self.trees:
            raise ValueError("predict_proba before fit")
        return np.mean([t.predict_proba(X) for t in self.trees], axis=0)


class IsotonicCalibrator:
    """Monotone probability calibration by isotonic regression (PAVA).

    Maps raw classifier scores (e.g. :class:`RandomForest` vote fractions)
    to calibrated P(y=1): fit finds the least-squares *non-decreasing*
    function of the score on held-out (score, outcome) pairs via
    pool-adjacent-violators, so score ranking is preserved while the
    outputs become empirical frequencies. ``predict`` interpolates
    linearly between the fitted block means and clips to [0, 1] — the
    isotonic cousin of binned Platt scaling, but bin placement is learned
    from the violator structure instead of fixed.
    """

    def __init__(self):
        self.x_: Optional[np.ndarray] = None   # block score positions
        self.v_: Optional[np.ndarray] = None   # block calibrated values

    def fit(self, scores, outcomes) -> "IsotonicCalibrator":
        s = np.asarray(scores, np.float64).ravel()
        y = np.asarray(outcomes, np.float64).ravel()
        if s.shape != y.shape:
            raise ValueError(f"scores {s.shape} vs outcomes {y.shape}")
        if len(s) == 0:
            raise ValueError("cannot calibrate on an empty set")
        order = np.argsort(s, kind="stable")
        xs, ys = s[order], y[order]
        # pool adjacent violators: merge blocks while means decrease
        bx: List[float] = []     # weighted mean score per block
        bv: List[float] = []     # weighted mean outcome per block
        bw: List[float] = []     # block weight
        for x, t in zip(xs, ys):
            bx.append(float(x)); bv.append(float(t)); bw.append(1.0)
            while len(bv) > 1 and bv[-2] >= bv[-1]:
                w = bw[-2] + bw[-1]
                bv[-2] = (bv[-2] * bw[-2] + bv[-1] * bw[-1]) / w
                bx[-2] = (bx[-2] * bw[-2] + bx[-1] * bw[-1]) / w
                bw[-2] = w
                del bv[-1], bx[-1], bw[-1]
        x_ = np.asarray(bx)
        # interpolation needs strictly increasing x: nudge ties apart
        # (duplicate scores always land in one block, so ties are rare)
        for i in range(1, len(x_)):
            if x_[i] <= x_[i - 1]:
                x_[i] = np.nextafter(x_[i - 1], np.inf)
        self.x_ = x_
        self.v_ = np.clip(np.asarray(bv), 0.0, 1.0)
        return self

    def predict(self, scores) -> np.ndarray:
        if self.x_ is None:
            raise ValueError("predict before fit")
        s = np.asarray(scores, np.float64)
        return np.clip(np.interp(s, self.x_, self.v_), 0.0, 1.0)


# --------------------------------------------------------------------- (J)MLP
def _mlp_init(key, sizes: Tuple[int, ...]):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        params.append({"w": jax.random.normal(sub, (a, b)) * jnp.sqrt(2.0 / a),
                       "b": jnp.zeros((b,))})
    return params


def _mlp_apply(params, x):
    for layer in params[:-1]:
        x = jax.nn.gelu(x @ layer["w"] + layer["b"])
    last = params[-1]
    return x @ last["w"] + last["b"]


class MLP:
    """JAX MLP regressor/classifier with Adam; inputs standardized."""

    def __init__(self, hidden: Tuple[int, ...] = (64, 64), task: str = "reg",
                 n_classes: int = 2, lr: float = 3e-3, epochs: int = 600,
                 seed: int = 0):
        self.hidden, self.task, self.n_classes = hidden, task, n_classes
        self.lr, self.epochs, self.seed = lr, epochs, seed

    def fit(self, X: np.ndarray, y: np.ndarray) -> "MLP":
        X = np.asarray(X, np.float32)
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-8
        Xs = (X - self.mu) / self.sd
        if self.task == "reg":
            y = np.asarray(y, np.float32)[:, None]
            self.ymu, self.ysd = y.mean(), y.std() + 1e-8
            ys = (y - self.ymu) / self.ysd
            out = 1
        else:
            ys = np.asarray(y, np.int32)
            out = self.n_classes
        key = jax.random.PRNGKey(self.seed)
        params = _mlp_init(key, (X.shape[1], *self.hidden, out))

        if self.task == "reg":
            def loss_fn(p, xb, yb):
                return jnp.mean((_mlp_apply(p, xb) - yb) ** 2)
        else:
            def loss_fn(p, xb, yb):
                logits = _mlp_apply(p, xb)
                logp = jax.nn.log_softmax(logits)
                return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

        # Adam (hand-rolled; no optax in the container)
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        lr, b1, b2, eps = self.lr, 0.9, 0.999, 1e-8

        @jax.jit
        def step(p, m, v, t, xb, yb):
            g = jax.grad(loss_fn)(p, xb, yb)
            m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
            v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
            mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
            p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + eps),
                             p, mh, vh)
            return p, m, v

        xb = jnp.asarray(Xs)
        yb = jnp.asarray(ys)
        for t in range(1, self.epochs + 1):
            params, m, v = step(params, m, v, t, xb, yb)
        self.params = params
        return self

    def _raw(self, X):
        Xs = (np.asarray(X, np.float32) - self.mu) / self.sd
        return np.asarray(_mlp_apply(self.params, jnp.asarray(Xs)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = self._raw(X)
        if self.task == "reg":
            return out[:, 0] * float(self.ysd) + float(self.ymu)
        return out.argmax(1)


class KernelRidge:
    """RBF kernel ridge regression — the offline stand-in for the paper's SVR."""

    def __init__(self, alpha: float = 1e-2, gamma: Optional[float] = None):
        self.alpha, self.gamma = alpha, gamma

    def _kernel(self, A, B):
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-self.g * d2)

    def fit(self, X, y):
        X = np.asarray(X, float)
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-8
        self.Xtr = (X - self.mu) / self.sd
        self.g = self.gamma or 1.0 / X.shape[1]
        K = self._kernel(self.Xtr, self.Xtr)
        self.coef = np.linalg.solve(K + self.alpha * np.eye(len(K)),
                                    np.asarray(y, float))
        return self

    def predict(self, X):
        Xs = (np.asarray(X, float) - self.mu) / self.sd
        return self._kernel(Xs, self.Xtr) @ self.coef


class Averaging:
    """Paper's naive baseline: predict the training mean."""

    def fit(self, X, y):
        self.mean = float(np.mean(y))
        return self

    def predict(self, X):
        return np.full(len(X), self.mean)
