"""OPTASSIGN — optimal tier + compression-scheme assignment (paper §IV).

Solvers
-------
``greedy_assign``       exact for unbounded capacities (Thm 3), O(NLK); the
                        vectorized JAX version is the PB-scale production path.
``matching_assign``     exact for equal-size/no-compression with capacities
                        (Thm 2) via min-cost flow == min-weight bipartite
                        matching on tier copies.
``capacitated_assign``  general capacitated case (strongly NP-hard, Thm 1):
                        vectorized JAX Lagrangian dual ascent (jitted scan over
                        all N*L*K cells) + argsort-based greedy repair +
                        delta-matrix 1-swap local search; validated against
                        ``brute_force`` in tests.
``capacitated_assign_ref``  the original pure-Python solver, kept as the
                        correctness reference for the vectorized path.
``brute_force``         exact enumeration oracle for tiny instances.

All solvers consume the (N,L,K) cost tensor and (N,L,K) feasibility mask from
:mod:`repro.core.costs`, so objective-weight variants (alpha/beta/gamma,
pushdown fraction, scheme locking for existing partitions) are handled
uniformly upstream.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e18


@dataclasses.dataclass
class Assignment:
    tier: np.ndarray       # (N,) int
    scheme: np.ndarray     # (N,) int
    cost: float            # objective value of chosen cells
    feasible: bool         # capacity + latency respected


def _masked(cost: np.ndarray, feasible: np.ndarray) -> np.ndarray:
    return np.where(feasible, cost, BIG)


def lock_schemes(feasible: np.ndarray, locked_scheme: np.ndarray) -> np.ndarray:
    """Paper's last ILP constraint: existing partitions keep their scheme.

    ``locked_scheme[n] == -1`` means partition n is new (free choice).
    """
    K = feasible.shape[2]
    locked = np.asarray(locked_scheme).astype(int)
    keep = (locked[:, None] < 0) | (np.arange(K)[None, :] == locked[:, None])
    return feasible & keep[:, None, :]


# --------------------------------------------------------------------- greedy
@partial(jax.jit, static_argnames=())
def _greedy_jax(cost: jnp.ndarray, feasible: jnp.ndarray):
    masked = jnp.where(feasible, cost, BIG)
    flat = masked.reshape(masked.shape[0], -1)
    idx = jnp.argmin(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    K = masked.shape[2]
    return idx // K, idx % K, best


def greedy_assign(cost: np.ndarray, feasible: np.ndarray) -> Assignment:
    """Exact when capacities are unbounded (Thm 3). O(NLK)."""
    tier, scheme, best = map(np.asarray, _greedy_jax(jnp.asarray(cost),
                                                     jnp.asarray(feasible)))
    tier, scheme = tier.astype(int), scheme.astype(int)
    ok = bool((best < BIG).all())
    # argmin runs in f32 on device; re-total the objective in f64 for exactness
    n = np.arange(cost.shape[0])
    total = float(np.asarray(cost, np.float64)[n, tier, scheme].sum()) if ok \
        else float("inf")
    return Assignment(tier, scheme, total, ok)


# ------------------------------------------------------------------- matching
class _MCMF:
    """Successive-shortest-path min-cost max-flow (SPFA variant). Exact."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.cost: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add(self, u: int, v: int, cap: float, cost: float) -> None:
        self.head[u].append(len(self.to)); self.to.append(v)
        self.cap.append(cap); self.cost.append(cost)
        self.head[v].append(len(self.to)); self.to.append(u)
        self.cap.append(0.0); self.cost.append(-cost)

    def run(self, s: int, t: int):
        flow = cost = 0.0
        INF = float("inf")
        while True:
            dist = [INF] * self.n
            in_q = [False] * self.n
            prev_e = [-1] * self.n
            dist[s] = 0.0
            queue = collections.deque([s])
            in_q[s] = True
            while queue:
                u = queue.popleft()
                in_q[u] = False
                for e in self.head[u]:
                    if self.cap[e] > 1e-12 and dist[u] + self.cost[e] < dist[self.to[e]] - 1e-12:
                        dist[self.to[e]] = dist[u] + self.cost[e]
                        prev_e[self.to[e]] = e
                        if not in_q[self.to[e]]:
                            queue.append(self.to[e])
                            in_q[self.to[e]] = True
            if dist[t] == INF:
                return flow, cost
            # bottleneck
            push, v = INF, t
            while v != s:
                e = prev_e[v]
                push = min(push, self.cap[e])
                v = self.to[e ^ 1]
            v = t
            while v != s:
                e = prev_e[v]
                self.cap[e] -= push
                self.cap[e ^ 1] += push
                v = self.to[e ^ 1]
            flow += push
            cost += push * dist[t]


def matching_assign(cost_nl: np.ndarray, feasible_nl: np.ndarray,
                    capacity_units: np.ndarray) -> Assignment:
    """Equal-size partitions, no compression (Thm 2).

    Min-weight bipartite matching of N unit-size partitions onto Z_l tier
    copies; the tier-copy graph collapses to a transportation problem solved
    exactly by min-cost max-flow (source -> partition -> tier -> sink).
    """
    N, L = cost_nl.shape
    cost = _masked(cost_nl, feasible_nl)
    cap = np.minimum(capacity_units.astype(np.float64), N)
    S, T = N + L, N + L + 1
    g = _MCMF(N + L + 2)
    for n in range(N):
        g.add(S, n, 1.0, 0.0)
        for l in range(L):
            if cost[n, l] < BIG:
                g.add(n, N + l, 1.0, float(cost[n, l]))
    for l in range(L):
        g.add(N + l, T, float(cap[l]), 0.0)
    flow, total = g.run(S, T)
    if flow < N - 1e-9:
        return Assignment(np.full(N, -1), np.zeros(N, int), float("inf"), False)
    assign = np.full(N, -1, np.int64)
    for n in range(N):
        for e in g.head[n]:
            v = g.to[e]
            if N <= v < N + L and e % 2 == 0 and g.cap[e] < 0.5:
                assign[n] = v - N
    return Assignment(assign, np.zeros(N, int), float(total), True)


# ---------------------------------------------------------------- capacitated
def _chosen_usage(stored_gb: np.ndarray, tier: np.ndarray,
                  scheme: np.ndarray) -> np.ndarray:
    """Per-tier GB occupied by the chosen (tier, scheme) cells, shape (L,)."""
    use = np.zeros(stored_gb.shape[1])
    np.add.at(use, tier, stored_gb[np.arange(tier.shape[0]), tier, scheme])
    return use


def _constraint_rows(capacity_gb: np.ndarray,
                     tier_groups: Optional[np.ndarray],
                     group_capacity_gb: Optional[np.ndarray],
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Capacity constraints as a membership matrix ``A`` (C, L) + caps (C,).

    Rows 0..L-1 are the per-tier capacities (identity); optional group rows
    (e.g. per-provider totals over a block of flat tiers in the multi-cloud
    placement space) follow. A constraint is ``A[c] @ use <= cap_all[c]``.
    """
    if (tier_groups is None) != (group_capacity_gb is None):
        raise ValueError("tier_groups and group_capacity_gb must be "
                         "passed together")
    L = capacity_gb.shape[0]
    A = np.eye(L, dtype=bool)
    cap_all = np.asarray(capacity_gb, np.float64)
    if tier_groups is not None:
        g = np.asarray(tier_groups, int)
        gcap = np.asarray(group_capacity_gb, np.float64)
        G = gcap.shape[0]
        if g.min() < 0 or g.max() >= G:
            raise ValueError(f"tier_groups ids must lie in [0, {G}) to "
                             f"match group_capacity_gb")
        A = np.concatenate([A, np.arange(G)[:, None] == g[None, :]], 0)
        cap_all = np.concatenate([cap_all, gcap])
    return A, cap_all


@partial(jax.jit, static_argnames=("iters",))
def _lagrangian_scan(masked: jnp.ndarray, stored: jnp.ndarray,
                     cap: jnp.ndarray, finite_cap: jnp.ndarray,
                     group_of_tier: jnp.ndarray, gcap: jnp.ndarray,
                     finite_gcap: jnp.ndarray,
                     step0: jnp.ndarray, iters: int):
    """Jitted dual ascent over all N*L*K cells; one candidate per step.

    Dualizes both the per-tier capacities and the group (per-provider)
    capacities: a tier's effective multiplier is its own lambda plus its
    group's. With no groups the group lambdas stay exactly zero.
    """
    N, L, K = masked.shape
    G = gcap.shape[0]
    flat_cost = masked.reshape(N, -1)
    flat_stored = stored.reshape(N, -1)

    def body(lam, it):
        eff = lam[:L] + lam[L:][group_of_tier]
        adj = flat_cost + (eff[None, :, None] * stored).reshape(N, -1)
        idx = jnp.argmin(adj, axis=1)
        chosen = jnp.take_along_axis(flat_stored, idx[:, None], axis=1)[:, 0]
        use = jnp.zeros(L, masked.dtype).at[idx // K].add(chosen)
        use_g = jnp.zeros(G, masked.dtype).at[group_of_tier].add(use)
        grad = jnp.concatenate([jnp.where(finite_cap, use - cap, 0.0),
                                jnp.where(finite_gcap, use_g - gcap, 0.0)])
        lam = jnp.maximum(0.0, lam + step0 / (1.0 + it) * grad)
        return lam, idx

    _, cells = jax.lax.scan(body, jnp.zeros(L + G, masked.dtype),
                            jnp.arange(iters, dtype=masked.dtype))
    return cells                                    # (iters, N) flat indices


def _repair_vec(tier: np.ndarray, scheme: np.ndarray, masked: np.ndarray,
                stored: np.ndarray, A: np.ndarray, cap_all: np.ndarray,
                finite_all: np.ndarray) -> Optional[np.ndarray]:
    """Argsort-based greedy repair: evict cheapest-delta members of the most
    over-capacity constraint (a tier, or a group such as a provider) until
    every finite capacity is respected."""
    N, L, K = masked.shape
    use = _chosen_usage(stored, tier, scheme)
    Af = A & finite_all[:, None]                    # (C, L)
    for _ in range(4 * N + 8):
        use_c = A @ use
        over = np.where(finite_all & (use_c > cap_all + 1e-9))[0]
        if over.size == 0:
            return use
        c = over[np.argmax((use_c - cap_all)[over])]
        in_c = A[c]                                 # (L,) tiers in constraint
        members = np.where(in_c[tier])[0]
        if members.size == 0:
            return None
        cur = masked[members, tier[members], scheme[members]]
        # per-tier room = tightest finite constraint containing that tier
        slack_c = np.where(finite_all, cap_all - use_c, np.inf)
        room = np.where(Af, slack_c[:, None], np.inf).min(0)         # (L,)
        ok = (masked[members] < BIG) & (stored[members]
                                        <= room[None, :, None] + 1e-9)
        ok[:, in_c, :] = False                      # must leave the constraint
        delta = np.where(ok, masked[members] - cur[:, None, None],
                         np.inf).reshape(members.size, -1)
        best_cell = delta.argmin(1)
        best_delta = delta[np.arange(members.size), best_cell]
        moved = False
        for m in np.argsort(best_delta):
            if use_c[c] <= cap_all[c] + 1e-9:
                break
            if not np.isfinite(best_delta[m]):
                break
            l2, k2 = divmod(int(best_cell[m]), K)
            n = int(members[m])
            room2 = np.where(Af[:, l2], cap_all - use_c, np.inf).min() \
                if Af[:, l2].any() else np.inf
            if stored[n, l2, k2] > room2 + 1e-9:
                continue             # room shrank this batch; retry next round
            l1 = tier[n]
            s1, s2 = stored[n, l1, scheme[n]], stored[n, l2, k2]
            use[l1] -= s1
            use[l2] += s2
            use_c += A[:, l2] * s2 - A[:, l1] * s1
            tier[n], scheme[n] = l2, k2
            moved = True
        if not moved:
            return None
    return None


def _local_search_vec(tier: np.ndarray, scheme: np.ndarray, use: np.ndarray,
                      masked: np.ndarray, stored: np.ndarray, A: np.ndarray,
                      cap_all: np.ndarray, finite_all: np.ndarray) -> None:
    """Best-improvement 1-swap descent with a full (N,L,K) delta matrix."""
    N, L, K = masked.shape
    n_idx = np.arange(N)
    Af = A & finite_all[:, None]                    # (C, L)
    any_finite = bool(finite_all.any())
    for _ in range(8 * N + 64):
        cur = masked[n_idx, tier, scheme]
        stored_cur = stored[n_idx, tier, scheme]
        if any_finite:
            use_c = A @ use
            # slack[n, c]: room left in constraint c once n vacates its cell
            slack = ((cap_all - use_c)[None, :]
                     + A[:, tier].T * stored_cur[:, None])           # (N, C)
            # per-destination room = tightest finite constraint containing it
            room = np.where(Af[None, :, :], slack[:, :, None],
                            np.inf).min(1)                           # (N, L)
            ok = (masked < BIG) & (stored <= room[:, :, None] + 1e-9)
        else:
            ok = masked < BIG
        delta = np.where(ok, masked - cur[:, None, None], np.inf)
        j = int(delta.argmin())
        n, rem = divmod(j, L * K)
        l2, k2 = divmod(rem, K)
        if not delta[n, l2, k2] < -1e-12:
            break
        use[tier[n]] -= stored[n, tier[n], scheme[n]]
        use[l2] += stored[n, l2, k2]
        tier[n], scheme[n] = l2, k2


def capacitated_assign(
    cost: np.ndarray,            # (N,L,K)
    feasible: np.ndarray,        # (N,L,K)
    stored_gb: np.ndarray,       # (N,L,K) size occupied if cell chosen
    capacity_gb: np.ndarray,     # (L,)
    iters: int = 200,
    seed: int = 0,
    max_candidates: int = 16,
    tier_groups: Optional[np.ndarray] = None,       # (L,) group id per tier
    group_capacity_gb: Optional[np.ndarray] = None,  # (G,)
) -> Assignment:
    """Vectorized capacitated OPTASSIGN.

    The Lagrangian inner solves run as one jitted ``lax.scan`` on device; the
    distinct relaxed assignments it emits are then repaired (argsort eviction)
    and polished (delta-matrix 1-swap descent) in vectorized NumPy, scoring in
    f64. Matches :func:`brute_force` on tiny instances and is orders of
    magnitude faster than :func:`capacitated_assign_ref` at N >= 1000.

    ``tier_groups``/``group_capacity_gb`` add group capacity constraints on
    top of the per-tier ones: ``sum(use[tier_groups == g]) <= group_cap[g]``.
    This is how per-provider capacity rows of the flattened multi-cloud
    ``(provider, tier)`` space enter the solver — each group is one
    provider's block of flat tiers.
    """
    N, L, K = cost.shape
    masked = _masked(np.asarray(cost, np.float64), feasible)
    stored = np.asarray(stored_gb, np.float64)
    cap = np.asarray(capacity_gb, np.float64)
    finite_cap = np.isfinite(cap)
    A, cap_all = _constraint_rows(cap, tier_groups, group_capacity_gb)
    finite_all = np.isfinite(cap_all)

    # lam=0 greedy = the unconstrained optimum; if it fits the capacities it
    # is optimal outright and the dual ascent can be skipped entirely.
    cell0 = masked.reshape(N, -1).argmin(1)
    tier0, scheme0 = cell0 // K, cell0 % K
    use0 = _chosen_usage(stored, tier0, scheme0)
    if (~finite_all | (A @ use0 <= cap_all + 1e-9)).all():
        total = float(masked[np.arange(N), tier0, scheme0].sum())
        ok = bool(total < BIG)
        return Assignment(tier0, scheme0, total if ok else float("inf"), ok)

    finite_cells = masked[masked < BIG]
    step0 = (finite_cells.mean() / max(cap_all[finite_all].mean(), 1e-9)
             if finite_all.any() and finite_cells.size else 0.0)
    if tier_groups is None:
        g_of_t = np.zeros(L, np.int32)
        gcap = np.array([np.inf])
    else:
        g_of_t = np.asarray(tier_groups, np.int32)
        gcap = np.asarray(group_capacity_gb, np.float64)
    cells = np.asarray(_lagrangian_scan(
        jnp.asarray(masked), jnp.asarray(stored), jnp.asarray(cap),
        jnp.asarray(finite_cap), jnp.asarray(g_of_t), jnp.asarray(gcap),
        jnp.asarray(np.isfinite(gcap)), jnp.float32(step0), iters))

    uniq, seen = [], set()
    for row_ in cells:
        key = row_.tobytes()
        if key not in seen:
            seen.add(key)
            uniq.append(np.asarray(row_, np.int64))
    if len(uniq) > max_candidates:
        head = max_candidates // 4
        uniq = uniq[:head] + uniq[-(max_candidates - head):]

    best: Optional[Assignment] = None
    fallback: Optional[Tuple[np.ndarray, np.ndarray]] = None
    for cand in uniq:
        tier, scheme = cand // K, cand % K
        if fallback is None:
            fallback = (tier.copy(), scheme.copy())
        use = _repair_vec(tier, scheme, masked, stored, A, cap_all,
                          finite_all)
        if use is None:
            continue
        _local_search_vec(tier, scheme, use, masked, stored, A, cap_all,
                          finite_all)
        total = float(masked[np.arange(N), tier, scheme].sum())
        if total < BIG and (best is None or total < best.cost):
            best = Assignment(tier.copy(), scheme.copy(), total, True)
    if best is None:
        tier, scheme = fallback if fallback is not None else (
            np.zeros(N, np.int64), np.zeros(N, np.int64))
        return Assignment(tier, scheme, float("inf"), False)
    return best


def capacitated_assign_ref(
    cost: np.ndarray,            # (N,L,K)
    feasible: np.ndarray,        # (N,L,K)
    stored_gb: np.ndarray,       # (N,L,K) size occupied if cell chosen
    capacity_gb: np.ndarray,     # (L,)
    iters: int = 200,
    seed: int = 0,
) -> Assignment:
    """Pure-Python reference: Lagrangian + repair + local search (original)."""
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    lam = np.zeros(L)
    cap = capacity_gb.copy()
    finite_cap = np.isfinite(cap)
    best: Optional[Assignment] = None
    step0 = masked[masked < BIG].mean() / max(cap[finite_cap].mean(), 1e-9) \
        if finite_cap.any() else 0.0

    def solve(lam_vec: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        adj = masked + (lam_vec[None, :, None] * stored_gb)
        flat = adj.reshape(N, -1)
        idx = flat.argmin(1)
        return idx // K, idx % K

    def repair_and_score(tier: np.ndarray, scheme: np.ndarray) -> Assignment:
        tier, scheme = tier.copy(), scheme.copy()
        use = _chosen_usage(stored_gb, tier, scheme)
        # Greedy repair: move cheapest-delta items out of over-capacity tiers.
        for l in np.argsort(-(use - cap)):
            while finite_cap[l] and use[l] > cap[l] + 1e-9:
                members = [n for n in range(N) if tier[n] == l]
                best_mv, best_delta = None, np.inf
                for n in members:
                    cur = masked[n, l, scheme[n]]
                    for l2 in range(L):
                        if l2 == l:
                            continue
                        for k2 in range(K):
                            if masked[n, l2, k2] >= BIG:
                                continue
                            room = cap[l2] - use[l2] if finite_cap[l2] else np.inf
                            if stored_gb[n, l2, k2] > room + 1e-9:
                                continue
                            delta = masked[n, l2, k2] - cur
                            if delta < best_delta:
                                best_delta, best_mv = delta, (n, l2, k2)
                if best_mv is None:
                    return Assignment(tier, scheme, float("inf"), False)
                n, l2, k2 = best_mv
                use[l] -= stored_gb[n, l, scheme[n]]
                use[l2] += stored_gb[n, l2, k2]
                tier[n], scheme[n] = l2, k2
        # 1-move local search
        improved = True
        while improved:
            improved = False
            for n in range(N):
                cur_c = masked[n, tier[n], scheme[n]]
                for l2 in range(L):
                    for k2 in range(K):
                        if masked[n, l2, k2] >= cur_c - 1e-12:
                            continue
                        new_use_l2 = use[l2] + stored_gb[n, l2, k2] \
                            - (stored_gb[n, tier[n], scheme[n]] if l2 == tier[n] else 0)
                        if finite_cap[l2] and new_use_l2 > cap[l2] + 1e-9:
                            continue
                        use[tier[n]] -= stored_gb[n, tier[n], scheme[n]]
                        use[l2] += stored_gb[n, l2, k2]
                        tier[n], scheme[n] = l2, k2
                        improved = True
                        break
                    else:
                        continue
                    break
        total = float(sum(masked[n, tier[n], scheme[n]] for n in range(N)))
        ok = total < BIG
        return Assignment(tier, scheme, total if ok else float("inf"), ok)

    for it in range(iters):
        tier, scheme = solve(lam)
        cand = repair_and_score(tier, scheme)
        if cand.feasible and (best is None or cand.cost < best.cost):
            best = cand
        use = _chosen_usage(stored_gb, tier, scheme)
        grad = np.where(finite_cap, use - cap, 0.0)
        if np.all(grad <= 1e-9) and it > 0:
            break
        lam = np.maximum(0.0, lam + step0 / (1 + it) * grad)
    if best is None:
        tier, scheme = solve(lam)
        best = repair_and_score(tier, scheme)
    return best


# ------------------------------------------------------------ budgeted moves
@jax.jit
def _knapsack_scan(order: jnp.ndarray, cents: jnp.ndarray, gb: jnp.ndarray,
                   ok: jnp.ndarray, cap_cents: jnp.ndarray,
                   cap_gb: jnp.ndarray):
    """Greedy knapsack walk over pre-ranked items as one ``lax.scan``.

    Items arrive in ``order`` (best ratio first); each is taken iff it is
    eligible and fits both remaining budgets. Returns take flags in walk
    order (scatter back through ``order`` on the host)."""

    def body(carry, i):
        rem_c, rem_g = carry
        take = ok[i] & (cents[i] <= rem_c + 1e-9) & (gb[i] <= rem_g + 1e-9)
        rem_c = rem_c - jnp.where(take, cents[i], 0.0)
        rem_g = rem_g - jnp.where(take, gb[i], 0.0)
        return (rem_c, rem_g), take

    _, takes = jax.lax.scan(body, (cap_cents, cap_gb), order)
    return takes


def _exact_moves(savings: np.ndarray, cents: np.ndarray, gb: np.ndarray,
                 cand: np.ndarray, budget_cents: float, budget_gb: float,
                 ) -> np.ndarray:
    """Exact subset enumeration (vectorized bit-matrix), tiny instances only.

    Maximizes total (priority-weighted) savings subject to both caps;
    ties broken toward the cheaper subset, then the lexicographically
    first one, so the result is deterministic."""
    idx = np.where(cand)[0]
    n = idx.size
    M = ((np.arange(1 << n)[:, None] >> np.arange(n)) & 1).astype(bool)
    tot_c = M @ cents[idx]
    tot_g = M @ gb[idx]
    obj = M @ savings[idx]
    feas = (tot_c <= budget_cents + 1e-9) & (tot_g <= budget_gb + 1e-9)
    obj = np.where(feas, obj, -np.inf)
    # lexsort keys: last key is primary — max obj, then min cost, then the
    # smallest subset id (M rows are already in lexicographic order)
    best = int(np.lexsort((np.arange(1 << n), tot_c, -obj))[0])
    keep = np.zeros(savings.shape[0], bool)
    keep[idx[M[best]]] = True
    return keep


def budgeted_moves(
    savings_cents: np.ndarray,   # (N,) projected steady-state saving per move
    move_cents: np.ndarray,      # (N,) one-off charge per move (cents)
    budget_cents: float,         # per-cycle cents cap (np.inf = unbounded)
    *,
    candidates: Optional[np.ndarray] = None,   # (N,) bool; None = all
    move_gb: Optional[np.ndarray] = None,      # (N,) bytes leaving their cell
    budget_gb: float = np.inf,                 # per-cycle GB cap
    priority: Optional[np.ndarray] = None,     # (N,) aging boost (>= 1)
    method: str = "auto",                      # 'auto' | 'greedy' | 'exact'
    exact_max: int = 12,
) -> np.ndarray:
    """Select which candidate migrations to execute under a per-cycle budget.

    The savings-per-migration-cent knapsack of the re-optimization daemon:
    maximize total projected steady-state savings subject to a cents cap
    (and optionally a GB cap) on the one-off migration spend. The
    production path is a jnp-batched greedy-ratio walk — rank every
    candidate by ``priority * savings / cents`` on device (argsort), then
    take items in rank order while they fit both budgets (one jitted
    ``lax.scan``). ``method='exact'`` enumerates subsets instead (tiny
    instances; the validation oracle for the greedy path). ``'auto'``
    uses the exact path when there are at most ``exact_max`` candidates.

    Zero-cost moves rank first and never consume budget; with both caps
    infinite every candidate is selected (the daemon's parity mode).
    Candidates with non-positive projected savings stay eligible — the
    assignment solver already justified the move (its objective sees
    constraint and one-off terms this per-cell projection does not), and
    selection only schedules spend — but their selection value is floored
    at a priority-scaled epsilon, so they rank below every
    positive-savings candidate on BOTH paths and only fill leftover
    budget. Returns an (N,) boolean mask — always a subset of
    ``candidates``.
    """
    s = np.asarray(savings_cents, np.float64)
    c = np.asarray(move_cents, np.float64)
    N = s.shape[0]
    cand = (np.ones(N, bool) if candidates is None
            else np.asarray(candidates, bool).copy())
    g = (np.zeros(N) if move_gb is None
         else np.asarray(move_gb, np.float64))
    pr = np.ones(N) if priority is None else np.asarray(priority, np.float64)
    if N == 0 or not cand.any():
        return np.zeros(N, bool)
    if np.isinf(budget_cents) and np.isinf(budget_gb):
        return cand
    if method not in ("auto", "greedy", "exact"):
        raise ValueError(f"unknown method {method!r}")
    val = pr * s
    val = np.where(val > 0, val, 1e-9 * pr)   # take-if-fits, ranked last
    if method == "exact" or (method == "auto"
                             and int(cand.sum()) <= exact_max):
        return _exact_moves(val, c, g, cand, budget_cents, budget_gb)

    ratio = np.where(cand, val / np.maximum(c, 1e-12), -np.inf)
    order = jnp.argsort(-jnp.asarray(ratio))
    takes = np.asarray(_knapsack_scan(
        order, jnp.asarray(c), jnp.asarray(g), jnp.asarray(cand),
        jnp.asarray(budget_cents, jnp.float32),
        jnp.asarray(budget_gb, jnp.float32)))
    keep = np.zeros(N, bool)
    keep[np.asarray(order)] = takes
    keep &= cand
    # the scan ran in f32; re-walk the selected set in f64 and shed the
    # worst-ratio items if rounding let the total creep past a cap
    while keep.any() and (c[keep].sum() > budget_cents + 1e-9
                          or g[keep].sum() > budget_gb + 1e-9):
        sel = np.where(keep)[0]
        keep[sel[np.argmin(ratio[sel])]] = False
    return keep


# ---------------------------------------------------------------- brute force
def brute_force(cost: np.ndarray, feasible: np.ndarray,
                stored_gb: Optional[np.ndarray] = None,
                capacity_gb: Optional[np.ndarray] = None,
                tier_groups: Optional[np.ndarray] = None,
                group_capacity_gb: Optional[np.ndarray] = None) -> Assignment:
    """Exact oracle by enumeration — only for tiny test instances."""
    if (tier_groups is None) != (group_capacity_gb is None):
        raise ValueError("tier_groups and group_capacity_gb must be "
                         "passed together")
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    cells = [[(l, k) for l in range(L) for k in range(K)
              if masked[n, l, k] < BIG] for n in range(N)]
    best_cost, best_pick = float("inf"), None
    for pick in itertools.product(*cells):
        if capacity_gb is not None or group_capacity_gb is not None:
            use = np.zeros(L)
            for n, (l, k) in enumerate(pick):
                use[l] += stored_gb[n, l, k]
            if capacity_gb is not None and np.any(use > capacity_gb + 1e-9):
                continue
            if group_capacity_gb is not None:
                g = np.asarray(tier_groups, int)
                gcap = np.asarray(group_capacity_gb, np.float64)
                use_g = np.zeros(gcap.shape[0])
                np.add.at(use_g, g, use)
                if np.any(use_g > gcap + 1e-9):
                    continue
        c = sum(masked[n, l, k] for n, (l, k) in enumerate(pick))
        if c < best_cost:
            best_cost, best_pick = c, pick
    if best_pick is None:
        return Assignment(np.zeros(N, int), np.zeros(N, int), float("inf"), False)
    tier = np.array([l for l, _ in best_pick])
    scheme = np.array([k for _, k in best_pick])
    return Assignment(tier, scheme, float(best_cost), True)
