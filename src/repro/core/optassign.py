"""OPTASSIGN — optimal tier + compression-scheme assignment (paper §IV).

Solvers
-------
``greedy_assign``       exact for unbounded capacities (Thm 3), O(NLK); the
                        vectorized JAX version is the PB-scale production path.
``matching_assign``     exact for equal-size/no-compression with capacities
                        (Thm 2) via min-cost flow == min-weight bipartite
                        matching on tier copies.
``capacitated_assign``  general capacitated case (strongly NP-hard, Thm 1):
                        vectorized JAX Lagrangian dual ascent (jitted scan over
                        all N*L*K cells) + argsort-based greedy repair +
                        delta-matrix 1-swap local search; validated against
                        ``brute_force`` in tests.
``capacitated_assign_batch``  the fleet path: T ragged tenant problems padded
                        into one (T, N_max, L, K) batch and solved by a single
                        batched (optionally ``shard_map``-sharded) Lagrangian
                        scan dispatch. Bit-identical per tenant to
                        ``capacitated_assign`` when no *shared* (fleet-wide)
                        capacity rows couple the tenants.
``greedy_assign_batch``  batched unbounded path, one dispatch for T tenants.
``capacitated_assign_ref``  the original pure-Python solver, kept as the
                        correctness reference for the vectorized path.
``brute_force``         exact enumeration oracle for tiny instances.

All solvers consume the (N,L,K) cost tensor and (N,L,K) feasibility mask from
:mod:`repro.core.costs`, so objective-weight variants (alpha/beta/gamma,
pushdown fraction, scheme locking for existing partitions) are handled
uniformly upstream.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e18


@dataclasses.dataclass
class Assignment:
    tier: np.ndarray       # (N,) int
    scheme: np.ndarray     # (N,) int
    cost: float            # objective value of chosen cells
    feasible: bool         # capacity + latency respected


def _masked(cost: np.ndarray, feasible: np.ndarray) -> np.ndarray:
    return np.where(feasible, cost, BIG)


def lock_schemes(feasible: np.ndarray, locked_scheme: np.ndarray) -> np.ndarray:
    """Paper's last ILP constraint: existing partitions keep their scheme.

    ``locked_scheme[n] == -1`` means partition n is new (free choice).
    """
    K = feasible.shape[2]
    locked = np.asarray(locked_scheme).astype(int)
    keep = (locked[:, None] < 0) | (np.arange(K)[None, :] == locked[:, None])
    return feasible & keep[:, None, :]


# --------------------------------------------------------------------- greedy
@partial(jax.jit, static_argnames=())
def _greedy_jax(cost: jnp.ndarray, feasible: jnp.ndarray):
    masked = jnp.where(feasible, cost, BIG)
    flat = masked.reshape(masked.shape[0], -1)
    idx = jnp.argmin(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    K = masked.shape[2]
    return idx // K, idx % K, best


def greedy_assign(cost: np.ndarray, feasible: np.ndarray) -> Assignment:
    """Exact when capacities are unbounded (Thm 3). O(NLK)."""
    if cost.shape[0] == 0:
        z = np.zeros(0, np.int64)
        return Assignment(z, z.copy(), 0.0, True)
    tier, scheme, best = map(np.asarray, _greedy_jax(jnp.asarray(cost),
                                                     jnp.asarray(feasible)))
    tier, scheme = tier.astype(int), scheme.astype(int)
    ok = bool((best < BIG).all())
    # argmin runs in f32 on device; re-total the objective in f64 for exactness
    n = np.arange(cost.shape[0])
    total = float(np.asarray(cost, np.float64)[n, tier, scheme].sum()) if ok \
        else float("inf")
    return Assignment(tier, scheme, total, ok)


# ------------------------------------------------------------------- matching
class _MCMF:
    """Successive-shortest-path min-cost max-flow (SPFA variant). Exact."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.cost: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add(self, u: int, v: int, cap: float, cost: float) -> None:
        self.head[u].append(len(self.to)); self.to.append(v)
        self.cap.append(cap); self.cost.append(cost)
        self.head[v].append(len(self.to)); self.to.append(u)
        self.cap.append(0.0); self.cost.append(-cost)

    def run(self, s: int, t: int):
        flow = cost = 0.0
        INF = float("inf")
        while True:
            dist = [INF] * self.n
            in_q = [False] * self.n
            prev_e = [-1] * self.n
            dist[s] = 0.0
            queue = collections.deque([s])
            in_q[s] = True
            while queue:
                u = queue.popleft()
                in_q[u] = False
                for e in self.head[u]:
                    if self.cap[e] > 1e-12 and dist[u] + self.cost[e] < dist[self.to[e]] - 1e-12:
                        dist[self.to[e]] = dist[u] + self.cost[e]
                        prev_e[self.to[e]] = e
                        if not in_q[self.to[e]]:
                            queue.append(self.to[e])
                            in_q[self.to[e]] = True
            if dist[t] == INF:
                return flow, cost
            # bottleneck
            push, v = INF, t
            while v != s:
                e = prev_e[v]
                push = min(push, self.cap[e])
                v = self.to[e ^ 1]
            v = t
            while v != s:
                e = prev_e[v]
                self.cap[e] -= push
                self.cap[e ^ 1] += push
                v = self.to[e ^ 1]
            flow += push
            cost += push * dist[t]


def matching_assign(cost_nl: np.ndarray, feasible_nl: np.ndarray,
                    capacity_units: np.ndarray) -> Assignment:
    """Equal-size partitions, no compression (Thm 2).

    Min-weight bipartite matching of N unit-size partitions onto Z_l tier
    copies; the tier-copy graph collapses to a transportation problem solved
    exactly by min-cost max-flow (source -> partition -> tier -> sink).
    """
    N, L = cost_nl.shape
    cost = _masked(cost_nl, feasible_nl)
    cap = np.minimum(capacity_units.astype(np.float64), N)
    S, T = N + L, N + L + 1
    g = _MCMF(N + L + 2)
    for n in range(N):
        g.add(S, n, 1.0, 0.0)
        for l in range(L):
            if cost[n, l] < BIG:
                g.add(n, N + l, 1.0, float(cost[n, l]))
    for l in range(L):
        g.add(N + l, T, float(cap[l]), 0.0)
    flow, total = g.run(S, T)
    if flow < N - 1e-9:
        return Assignment(np.full(N, -1), np.zeros(N, int), float("inf"), False)
    assign = np.full(N, -1, np.int64)
    for n in range(N):
        for e in g.head[n]:
            v = g.to[e]
            if N <= v < N + L and e % 2 == 0 and g.cap[e] < 0.5:
                assign[n] = v - N
    return Assignment(assign, np.zeros(N, int), float(total), True)


# ---------------------------------------------------------------- capacitated
def _chosen_usage(stored_gb: np.ndarray, tier: np.ndarray,
                  scheme: np.ndarray) -> np.ndarray:
    """Per-tier GB occupied by the chosen (tier, scheme) cells, shape (L,)."""
    use = np.zeros(stored_gb.shape[1])
    np.add.at(use, tier, stored_gb[np.arange(tier.shape[0]), tier, scheme])
    return use


def _constraint_rows(capacity_gb: np.ndarray,
                     tier_groups: Optional[np.ndarray],
                     group_capacity_gb: Optional[np.ndarray],
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Capacity constraints as a membership matrix ``A`` (C, L) + caps (C,).

    Rows 0..L-1 are the per-tier capacities (identity); optional group rows
    (e.g. per-provider totals over a block of flat tiers in the multi-cloud
    placement space) follow. A constraint is ``A[c] @ use <= cap_all[c]``.
    """
    if (tier_groups is None) != (group_capacity_gb is None):
        raise ValueError("tier_groups and group_capacity_gb must be "
                         "passed together")
    L = capacity_gb.shape[0]
    A = np.eye(L, dtype=bool)
    cap_all = np.asarray(capacity_gb, np.float64)
    if tier_groups is not None:
        g = np.asarray(tier_groups, int)
        gcap = np.asarray(group_capacity_gb, np.float64)
        G = gcap.shape[0]
        if g.min() < 0 or g.max() >= G:
            raise ValueError(f"tier_groups ids must lie in [0, {G}) to "
                             f"match group_capacity_gb")
        A = np.concatenate([A, np.arange(G)[:, None] == g[None, :]], 0)
        cap_all = np.concatenate([cap_all, gcap])
    return A, cap_all


@partial(jax.jit, static_argnames=("iters",))
def _lagrangian_scan(masked: jnp.ndarray, stored: jnp.ndarray,
                     cap: jnp.ndarray, finite_cap: jnp.ndarray,
                     group_of_tier: jnp.ndarray, gcap: jnp.ndarray,
                     finite_gcap: jnp.ndarray,
                     step0: jnp.ndarray, iters: int):
    """Jitted dual ascent over all N*L*K cells; one candidate per step.

    Dualizes both the per-tier capacities and the group (per-provider)
    capacities: a tier's effective multiplier is its own lambda plus its
    group's. With no groups the group lambdas stay exactly zero.
    """
    N, L, K = masked.shape
    G = gcap.shape[0]
    flat_cost = masked.reshape(N, -1)
    flat_stored = stored.reshape(N, -1)

    def body(lam, it):
        eff = lam[:L] + lam[L:][group_of_tier]
        adj = flat_cost + (eff[None, :, None] * stored).reshape(N, -1)
        idx = jnp.argmin(adj, axis=1)
        chosen = jnp.take_along_axis(flat_stored, idx[:, None], axis=1)[:, 0]
        use = jnp.zeros(L, masked.dtype).at[idx // K].add(chosen)
        use_g = jnp.zeros(G, masked.dtype).at[group_of_tier].add(use)
        grad = jnp.concatenate([jnp.where(finite_cap, use - cap, 0.0),
                                jnp.where(finite_gcap, use_g - gcap, 0.0)])
        lam = jnp.maximum(0.0, lam + step0 / (1.0 + it) * grad)
        return lam, idx

    _, cells = jax.lax.scan(body, jnp.zeros(L + G, masked.dtype),
                            jnp.arange(iters, dtype=masked.dtype))
    return cells                                    # (iters, N) flat indices


def _repair_vec(tier: np.ndarray, scheme: np.ndarray, masked: np.ndarray,
                stored: np.ndarray, A: np.ndarray, cap_all: np.ndarray,
                finite_all: np.ndarray) -> Optional[np.ndarray]:
    """Argsort-based greedy repair: evict cheapest-delta members of the most
    over-capacity constraint (a tier, or a group such as a provider) until
    every finite capacity is respected."""
    N, L, K = masked.shape
    use = _chosen_usage(stored, tier, scheme)
    Af = A & finite_all[:, None]                    # (C, L)
    A_f = A.astype(np.float64)
    for _ in range(4 * N + 8):
        # einsum, not @: the batched fleet precheck replicates this exact
        # ascending-l accumulation, so round-0 decisions agree bitwise
        use_c = np.einsum("cl,l->c", A_f, use)
        over = np.where(finite_all & (use_c > cap_all + 1e-9))[0]
        if over.size == 0:
            return use
        c = over[np.argmax((use_c - cap_all)[over])]
        in_c = A[c]                                 # (L,) tiers in constraint
        members = np.where(in_c[tier])[0]
        if members.size == 0:
            return None
        cur = masked[members, tier[members], scheme[members]]
        # per-tier room = tightest finite constraint containing that tier
        slack_c = np.where(finite_all, cap_all - use_c, np.inf)
        room = np.where(Af, slack_c[:, None], np.inf).min(0)         # (L,)
        ok = (masked[members] < BIG) & (stored[members]
                                        <= room[None, :, None] + 1e-9)
        ok[:, in_c, :] = False                      # must leave the constraint
        delta = np.where(ok, masked[members] - cur[:, None, None],
                         np.inf).reshape(members.size, -1)
        best_cell = delta.argmin(1)
        best_delta = delta[np.arange(members.size), best_cell]
        moved = False
        for m in np.argsort(best_delta):
            if use_c[c] <= cap_all[c] + 1e-9:
                break
            if not np.isfinite(best_delta[m]):
                break
            l2, k2 = divmod(int(best_cell[m]), K)
            n = int(members[m])
            room2 = np.where(Af[:, l2], cap_all - use_c, np.inf).min() \
                if Af[:, l2].any() else np.inf
            if stored[n, l2, k2] > room2 + 1e-9:
                continue             # room shrank this batch; retry next round
            l1 = tier[n]
            s1, s2 = stored[n, l1, scheme[n]], stored[n, l2, k2]
            use[l1] -= s1
            use[l2] += s2
            use_c += A[:, l2] * s2 - A[:, l1] * s1
            tier[n], scheme[n] = l2, k2
            moved = True
        if not moved:
            return None
    return None


def _local_search_vec(tier: np.ndarray, scheme: np.ndarray, use: np.ndarray,
                      masked: np.ndarray, stored: np.ndarray, A: np.ndarray,
                      cap_all: np.ndarray, finite_all: np.ndarray,
                      max_moves: Optional[int] = None) -> None:
    """Best-improvement 1-swap descent with a full (N,L,K) delta matrix.

    ``max_moves`` overrides the default ``8 * N + 64`` budget so the
    lockstep fleet descent can hand its tail rows over mid-trajectory
    with their remaining budget intact.
    """
    N, L, K = masked.shape
    n_idx = np.arange(N)
    Af = A & finite_all[:, None]                    # (C, L)
    A_f = A.astype(np.float64)
    any_finite = bool(finite_all.any())
    for _ in range(8 * N + 64 if max_moves is None else max_moves):
        cur = masked[n_idx, tier, scheme]
        stored_cur = stored[n_idx, tier, scheme]
        if any_finite:
            # einsum, not @: the lockstep fleet descent replicates this
            # exact ascending-l accumulation for bitwise-equal trajectories
            use_c = np.einsum("cl,l->c", A_f, use)
            # slack[n, c]: room left in constraint c once n vacates its cell
            slack = ((cap_all - use_c)[None, :]
                     + A[:, tier].T * stored_cur[:, None])           # (N, C)
            # per-destination room = tightest finite constraint containing it
            room = np.where(Af[None, :, :], slack[:, :, None],
                            np.inf).min(1)                           # (N, L)
            ok = (masked < BIG) & (stored <= room[:, :, None] + 1e-9)
        else:
            ok = masked < BIG
        delta = np.where(ok, masked - cur[:, None, None], np.inf)
        j = int(delta.argmin())
        n, rem = divmod(j, L * K)
        l2, k2 = divmod(rem, K)
        if not delta[n, l2, k2] < -1e-12:
            break
        use[tier[n]] -= stored[n, tier[n], scheme[n]]
        use[l2] += stored[n, l2, k2]
        tier[n], scheme[n] = l2, k2


def _step0(masked: np.ndarray, cap_all: np.ndarray,
           finite_all: np.ndarray) -> float:
    """Dual-ascent step size heuristic: mean finite cell cost over mean
    finite capacity. Guarded against the all-infinite-capacity and
    empty-finite-cells corners (N=0 tenants, all-infeasible tenants) so the
    batched fleet path can never divide by an empty mean."""
    finite_cells = masked[masked < BIG]
    if not (finite_all.any() and finite_cells.size):
        return 0.0
    return float(finite_cells.mean() / max(cap_all[finite_all].mean(), 1e-9))


def _dedupe_candidates(rows, max_candidates: int) -> List[np.ndarray]:
    """Distinct relaxed assignments emitted by the dual ascent, in emission
    order, truncated head/tail to ``max_candidates``."""
    uniq, seen = [], set()
    for row_ in rows:
        key = row_.tobytes()
        if key not in seen:
            seen.add(key)
            uniq.append(np.asarray(row_, np.int64))
    if len(uniq) > max_candidates:
        head = max_candidates // 4
        uniq = uniq[:head] + uniq[-(max_candidates - head):]
    return uniq


def _dedupe_candidates_arr(arr: np.ndarray,
                           max_candidates: int) -> List[np.ndarray]:
    """:func:`_dedupe_candidates` for a contiguous (iters, N) matrix: one
    ``np.unique`` over row bytes instead of a Python set — same unique
    rows, same first-occurrence emission order, same head/tail truncation.
    """
    arr = np.ascontiguousarray(arr)
    if arr.shape[1] == 0:
        return [np.zeros(0, np.int64)]
    keys = arr.view(np.dtype((np.void, arr.dtype.itemsize * arr.shape[1])))
    _, first = np.unique(keys.ravel(), return_index=True)
    uniq = [arr[i].astype(np.int64) for i in np.sort(first)]
    if len(uniq) > max_candidates:
        head = max_candidates // 4
        uniq = uniq[:head] + uniq[-(max_candidates - head):]
    return uniq


def _best_from_candidates(uniq: List[np.ndarray], masked: np.ndarray,
                          stored: np.ndarray, A: np.ndarray,
                          cap_all: np.ndarray,
                          finite_all: np.ndarray) -> Assignment:
    """Repair + polish every candidate cell vector, keep the best f64 score.
    The shared tail of the single-tenant and (uncoupled) fleet solvers."""
    N, _, K = masked.shape
    best: Optional[Assignment] = None
    fallback: Optional[Tuple[np.ndarray, np.ndarray]] = None
    for cand in uniq:
        tier, scheme = cand // K, cand % K
        if fallback is None:
            fallback = (tier.copy(), scheme.copy())
        use = _repair_vec(tier, scheme, masked, stored, A, cap_all,
                          finite_all)
        if use is None:
            continue
        _local_search_vec(tier, scheme, use, masked, stored, A, cap_all,
                          finite_all)
        total = float(masked[np.arange(N), tier, scheme].sum())
        if total < BIG and (best is None or total < best.cost):
            best = Assignment(tier.copy(), scheme.copy(), total, True)
    if best is None:
        tier, scheme = fallback if fallback is not None else (
            np.zeros(N, np.int64), np.zeros(N, np.int64))
        return Assignment(tier, scheme, float("inf"), False)
    return best


def _lockstep_local_search(tier_r: np.ndarray, scheme_r: np.ndarray,
                           use_r: np.ndarray, alive: np.ndarray,
                           jrow: np.ndarray, masked_b: np.ndarray,
                           stored_b: np.ndarray, A_fb: np.ndarray,
                           Af_b: np.ndarray, cap_b: np.ndarray,
                           budget: np.ndarray) -> None:
    """Vectorized best-improvement 1-swap descent over independent rows.

    Replicates :func:`_local_search_vec` move-for-move for every (tenant,
    candidate) row at once: the same einsum ``use_c`` accumulation, the
    same slack/room/ok/delta expressions, the same first-occurrence argmin
    over the flattened cell grid (padding cells are ``+inf`` and can never
    win), and the same per-row iteration budget ``8 * N + 64``. Rows
    deactivate independently, so the Python-level loop runs once per step
    of the longest trajectory instead of once per row.
    """
    M, n_max = tier_r.shape
    L, K = masked_b.shape[2], masked_b.shape[3]
    n_idx = np.arange(n_max)
    while alive.size:
        if alive.size <= _LOCKSTEP_TAIL:
            # the few long-trajectory survivors finish sequentially: rows
            # are independent and the sequential descent applies the same
            # update rule, so continuing with the remaining per-row move
            # budget lands on the same fixed point bit-for-bit — without
            # paying a full vectorized round per move for a handful of rows
            for r in alive:
                j = jrow[r]
                _local_search_vec(tier_r[r], scheme_r[r], use_r[r],
                                  masked_b[j], stored_b[j],
                                  A_fb[j] != 0.0, cap_b[j],
                                  np.isfinite(cap_b[j]),
                                  max_moves=int(budget[r]))
            return
        jr = jrow[alive]
        mrows = masked_b[jr]                                  # (A, N, L, K)
        srows = stored_b[jr]
        tr, sc = tier_r[alive], scheme_r[alive]
        a_idx = np.arange(alive.size)[:, None]
        cur = mrows[a_idx, n_idx[None, :], tr, sc]            # (A, N)
        stored_cur = srows[a_idx, n_idx[None, :], tr, sc]
        use_c = np.einsum("acl,al->ac", A_fb[jr], use_r[alive])
        At = np.take_along_axis(A_fb[jr], tr[:, None, :], axis=2)
        slack = ((cap_b[jr] - use_c)[:, None, :]
                 + At.transpose(0, 2, 1) * stored_cur[:, :, None])
        room = np.where(Af_b[jr][:, None, :, :], slack[..., None],
                        np.inf).min(2)                        # (A, N, L)
        ok = (mrows < BIG) & (srows <= room[..., None] + 1e-9)
        delta = np.where(ok, mrows - cur[..., None, None], np.inf)
        flat = delta.reshape(alive.size, -1)
        jarg = flat.argmin(1)
        dmin = flat[np.arange(alive.size), jarg]
        g = np.where(dmin < -1e-12)[0]
        if g.size == 0:
            break
        rows = alive[g]
        n, rem = np.divmod(jarg[g], L * K)
        l2, k2 = np.divmod(rem, K)
        l1 = tier_r[rows, n]
        k1 = scheme_r[rows, n]
        jg = jrow[rows]
        use_r[rows, l1] -= stored_b[jg, n, l1, k1]
        use_r[rows, l2] += stored_b[jg, n, l2, k2]
        tier_r[rows, n] = l2
        scheme_r[rows, n] = k2
        budget[rows] -= 1
        alive = rows[budget[rows] > 0]


def _batch_candidate_finish(solve_idx, cells: np.ndarray,
                            masked_b: np.ndarray, stored_b: np.ndarray,
                            maskeds, storeds, As, cap_alls, finite_alls,
                            Ns, K: int, max_candidates: int) -> dict:
    """Vectorized repair + 1-swap finish for the uncoupled fleet batch.

    Bit-identical per tenant to running :func:`_dedupe_candidates` +
    :func:`_best_from_candidates` in a loop (pinned by
    ``tests/test_fleet.py``), but batched on host: one scatter computes
    every candidate's usage, one einsum makes every round-0 feasibility
    decision, only rows that actually violate a capacity fall back to the
    sequential :func:`_repair_vec`, and all surviving rows descend in one
    lockstep :func:`_lockstep_local_search`. This removes the per-row
    Python/numpy dispatch that otherwise dominates fleet solves.
    """
    iters_n, Tp, n_max = cells.shape
    L = masked_b.shape[2]
    rows_of: List[List[int]] = [[] for _ in range(Tp)]
    uniq_all: List[np.ndarray] = []
    row_j: List[int] = []
    for j in range(Tp):
        t = solve_idx[j]
        uniq = _dedupe_candidates_arr(cells[:, j, :Ns[t]], max_candidates)
        for cand in uniq:
            rows_of[j].append(len(uniq_all))
            uniq_all.append(cand)
            row_j.append(j)
    M = len(uniq_all)
    jrow = np.asarray(row_j)

    # constraint rows, padded to a common C with inert (cap=inf) rows
    C_max = max(As[t].shape[0] for t in solve_idx)
    A_b = np.zeros((Tp, C_max, L), bool)
    cap_b2 = np.full((Tp, C_max), np.inf)
    fin_b = np.zeros((Tp, C_max), bool)
    for j, t in enumerate(solve_idx):
        C = As[t].shape[0]
        A_b[j, :C] = As[t]
        cap_b2[j, :C] = cap_alls[t]
        fin_b[j, :C] = finite_alls[t]
    A_fb = A_b.astype(np.float64)
    Af_b = A_b & fin_b[:, :, None]

    # decode candidates; keep each tenant's first decode as the fallback
    tier_r = np.zeros((M, n_max), np.int64)
    scheme_r = np.zeros((M, n_max), np.int64)
    fallbacks = {}
    for m, cand in enumerate(uniq_all):
        tier_r[m, :cand.shape[0]] = cand // K
        scheme_r[m, :cand.shape[0]] = cand % K
        j = row_j[m]
        if j not in fallbacks:
            fallbacks[j] = (tier_r[m, :cand.shape[0]].copy(),
                            scheme_r[m, :cand.shape[0]].copy())

    # per-row usage: one scatter, ascending-n within each row, so it is
    # bit-identical to _chosen_usage (padding rows add exact 0.0)
    sval = stored_b[jrow[:, None], np.arange(n_max)[None, :], tier_r,
                    scheme_r]
    use_r = np.zeros((M, L))
    np.add.at(use_r, (np.repeat(np.arange(M), n_max), tier_r.ravel()),
              sval.ravel())

    # round-0 repair decision for every row at once; only violating rows
    # pay the sequential eviction loop
    use_c0 = np.einsum("acl,al->ac", A_fb[jrow], use_r)
    viol = (fin_b[jrow] & (use_c0 > cap_b2[jrow] + 1e-9)).any(1)
    dead = np.zeros(M, bool)
    for m in np.where(viol)[0]:
        j = row_j[m]
        t = solve_idx[j]
        use = _repair_vec(tier_r[m, :Ns[t]], scheme_r[m, :Ns[t]],
                          maskeds[t], storeds[t], As[t], cap_alls[t],
                          finite_alls[t])
        if use is None:
            dead[m] = True
        else:
            use_r[m] = use

    budget = 8 * np.asarray([Ns[solve_idx[j]] for j in row_j]) + 64
    _lockstep_local_search(tier_r, scheme_r, use_r, np.where(~dead)[0],
                           jrow, masked_b, stored_b, A_fb, Af_b, cap_b2,
                           budget)

    out = {}
    for j in range(Tp):
        t = solve_idx[j]
        n_t = Ns[t]
        best: Optional[Assignment] = None
        for m in rows_of[j]:
            if dead[m]:
                continue
            tr, sc = tier_r[m, :n_t], scheme_r[m, :n_t]
            total = float(maskeds[t][np.arange(n_t), tr, sc].sum())
            if total < BIG and (best is None or total < best.cost):
                best = Assignment(tr.copy(), sc.copy(), total, True)
        if best is None:
            ftr, fsc = fallbacks.get(
                j, (np.zeros(n_t, np.int64), np.zeros(n_t, np.int64)))
            best = Assignment(ftr, fsc, float("inf"), False)
        out[t] = best
    return out


def capacitated_assign(
    cost: np.ndarray,            # (N,L,K)
    feasible: np.ndarray,        # (N,L,K)
    stored_gb: np.ndarray,       # (N,L,K) size occupied if cell chosen
    capacity_gb: np.ndarray,     # (L,)
    iters: int = 200,
    seed: int = 0,
    max_candidates: int = 16,
    tier_groups: Optional[np.ndarray] = None,       # (L,) group id per tier
    group_capacity_gb: Optional[np.ndarray] = None,  # (G,)
    sla_penalty: Optional[np.ndarray] = None,        # (N,L,K) violation units
    sla_lambda: float = 0.0,
) -> Assignment:
    """Vectorized capacitated OPTASSIGN.

    The Lagrangian inner solves run as one jitted ``lax.scan`` on device; the
    distinct relaxed assignments it emits are then repaired (argsort eviction)
    and polished (delta-matrix 1-swap descent) in vectorized NumPy, scoring in
    f64. Matches :func:`brute_force` on tiny instances and is orders of
    magnitude faster than :func:`capacitated_assign_ref` at N >= 1000.

    ``tier_groups``/``group_capacity_gb`` add group capacity constraints on
    top of the per-tier ones: ``sum(use[tier_groups == g]) <= group_cap[g]``.
    This is how per-provider capacity rows of the flattened multi-cloud
    ``(provider, tier)`` space enter the solver — each group is one
    provider's block of flat tiers.

    ``sla_penalty``/``sla_lambda`` extend the objective to ``cost +
    sla_lambda * sla_penalty`` (soft per-partition latency SLAs,
    :func:`repro.core.costs.sla_penalty_tensor`): the weighted penalty
    rides through the jitted Lagrangian scan, the repair, and the 1-swap
    polish exactly like cost. ``sla_lambda=0`` (or no penalty) leaves
    every array untouched — bit-identical to the pre-SLA solver.
    """
    if sla_lambda and sla_penalty is not None:
        cost = (np.asarray(cost, np.float64)
                + float(sla_lambda) * np.asarray(sla_penalty, np.float64))
    N, L, K = cost.shape
    masked = _masked(np.asarray(cost, np.float64), feasible)
    stored = np.asarray(stored_gb, np.float64)
    cap = np.asarray(capacity_gb, np.float64)
    finite_cap = np.isfinite(cap)
    A, cap_all = _constraint_rows(cap, tier_groups, group_capacity_gb)
    finite_all = np.isfinite(cap_all)

    if N == 0:
        z = np.zeros(0, np.int64)
        return Assignment(z, z.copy(), 0.0, True)

    # lam=0 greedy = the unconstrained optimum; if it fits the capacities it
    # is optimal outright and the dual ascent can be skipped entirely.
    cell0 = masked.reshape(N, -1).argmin(1)
    tier0, scheme0 = cell0 // K, cell0 % K
    use0 = _chosen_usage(stored, tier0, scheme0)
    if (~finite_all | (A @ use0 <= cap_all + 1e-9)).all():
        total = float(masked[np.arange(N), tier0, scheme0].sum())
        ok = bool(total < BIG)
        return Assignment(tier0, scheme0, total if ok else float("inf"), ok)

    step0 = _step0(masked, cap_all, finite_all)
    if tier_groups is None:
        g_of_t = np.zeros(L, np.int32)
        gcap = np.array([np.inf])
    else:
        g_of_t = np.asarray(tier_groups, np.int32)
        gcap = np.asarray(group_capacity_gb, np.float64)
    cells = np.asarray(_lagrangian_scan(
        jnp.asarray(masked), jnp.asarray(stored), jnp.asarray(cap),
        jnp.asarray(finite_cap), jnp.asarray(g_of_t), jnp.asarray(gcap),
        jnp.asarray(np.isfinite(gcap)), jnp.float32(step0), iters))

    uniq = _dedupe_candidates(cells, max_candidates)
    return _best_from_candidates(uniq, masked, stored, A, cap_all,
                                 finite_all)


# ---------------------------------------------------------------- fleet batch
def _fleet_scan_core(masked, stored, cap, finite_cap, group_of_tier, gcap,
                     finite_gcap, sgroup_of_tier, scap, finite_scap,
                     step0, sstep0, *, iters: int,
                     axis_name: Optional[str] = None):
    """Batched dual ascent over a padded tenant batch (T, N, L, K).

    The per-tenant body is element-for-element the computation of
    :func:`_lagrangian_scan` with a leading tenant axis, so each tenant's
    dual trajectory (and hence its emitted candidate cells) is bit-identical
    to a standalone solve — padding rows carry BIG cost and zero stored
    bytes, contributing exactly 0.0 to every usage sum and gradient.

    On top ride the *shared* fleet-wide constraint rows: ``sgroup_of_tier``
    maps each tier to a shared group whose usage is summed over the whole
    tenant axis (and, under ``shard_map``, ``psum``-reduced over
    ``axis_name``) before being dualized by one fleet-global multiplier
    vector. With no finite shared caps those multipliers stay exactly zero
    and the uncoupled trajectories are untouched.
    """
    T, N, L, K = masked.shape
    G = gcap.shape[1]
    S = scap.shape[0]
    flat_cost = masked.reshape(T, N, -1)
    flat_stored = stored.reshape(T, N, -1)
    t_idx = jnp.arange(T)[:, None]
    g_b = jnp.broadcast_to(group_of_tier[None, :], (T, L))

    def body(carry, it):
        lam, lam_sh = carry                      # (T, L+G), (S,)
        eff = (lam[:, :L] + jnp.take_along_axis(lam[:, L:], g_b, axis=1)
               + lam_sh[sgroup_of_tier][None, :])
        adj = flat_cost + (eff[:, None, :, None] * stored).reshape(T, N, -1)
        idx = jnp.argmin(adj, axis=2)            # (T, N)
        chosen = jnp.take_along_axis(flat_stored, idx[:, :, None],
                                     axis=2)[:, :, 0]
        use = jnp.zeros((T, L), masked.dtype).at[t_idx, idx // K].add(chosen)
        use_g = jnp.zeros((T, G), masked.dtype).at[t_idx, g_b].add(use)
        use_s = jnp.zeros(S, masked.dtype).at[sgroup_of_tier].add(use.sum(0))
        if axis_name is not None:
            use_s = jax.lax.psum(use_s, axis_name)
        grad = jnp.concatenate(
            [jnp.where(finite_cap, use - cap, 0.0),
             jnp.where(finite_gcap, use_g - gcap, 0.0)], axis=1)
        sgrad = jnp.where(finite_scap, use_s - scap, 0.0)
        lam = jnp.maximum(0.0, lam + step0[:, None] / (1.0 + it) * grad)
        lam_sh = jnp.maximum(0.0, lam_sh + sstep0 / (1.0 + it) * sgrad)
        return (lam, lam_sh), idx

    init = (jnp.zeros((T, L + G), masked.dtype), jnp.zeros(S, masked.dtype))
    _, cells = jax.lax.scan(body, init,
                            jnp.arange(iters, dtype=masked.dtype))
    return cells                                 # (iters, T, N)


@partial(jax.jit, static_argnames=("iters",))
def _fleet_scan_single(masked, stored, cap, finite_cap, group_of_tier, gcap,
                       finite_gcap, sgroup_of_tier, scap, finite_scap,
                       step0, sstep0, iters):
    return _fleet_scan_core(masked, stored, cap, finite_cap, group_of_tier,
                            gcap, finite_gcap, sgroup_of_tier, scap,
                            finite_scap, step0, sstep0, iters=iters)


# uncoupled fleets run the lean kernel in fixed-size tenant chunks so one
# compiled (chunk, N_max) shape is reused for any fleet size
_FLEET_CHUNK = 64

# below this many alive rows the lockstep descent hands the stragglers to
# the sequential per-row search (same trajectory, no per-round overhead)
_LOCKSTEP_TAIL = 8


@partial(jax.jit, static_argnames=("iters",))
def _fleet_scan_plain(masked, stored, cap, finite_cap, step0, iters):
    """Per-tier-caps-only batched dual ascent — :func:`_fleet_scan_core`
    with the group and shared-row machinery elided.

    With no finite group or shared caps those multipliers stay exactly 0.0
    in the general kernel (their gradients are masked to zero), so every
    surviving expression here is element-for-element the same computation
    and the emitted cells are bit-identical — at roughly half the per-step
    op count, which matters on CPU where the scan is dispatch-bound.
    """
    T, N, L, K = masked.shape
    flat_cost = masked.reshape(T, N, -1)
    flat_stored = stored.reshape(T, N, -1)
    t_idx = jnp.arange(T)[:, None]

    def body(lam, it):
        adj = flat_cost + (lam[:, None, :, None] * stored).reshape(T, N, -1)
        idx = jnp.argmin(adj, axis=2)            # (T, N)
        chosen = jnp.take_along_axis(flat_stored, idx[:, :, None],
                                     axis=2)[:, :, 0]
        use = jnp.zeros((T, L), masked.dtype).at[t_idx, idx // K].add(chosen)
        grad = jnp.where(finite_cap, use - cap, 0.0)
        lam = jnp.maximum(0.0, lam + step0[:, None] / (1.0 + it) * grad)
        return lam, idx

    _, cells = jax.lax.scan(body, jnp.zeros((T, L), masked.dtype),
                            jnp.arange(iters, dtype=masked.dtype))
    return cells                                 # (iters, T, N)


def _run_fleet_scan(mesh, masked_b, stored_b, cap_b, gcap_b, g_of_t,
                    sg_of_t, scap, sstep0, step0_b, iters: int) -> np.ndarray:
    """Dispatch the batched scan — one ``shard_map`` over the tenant axis of
    ``mesh``'s first axis when it spans >1 device, plain jit otherwise."""
    args = lambda mb, sb, cb, s0: (
        jnp.asarray(mb), jnp.asarray(sb), jnp.asarray(cb),
        jnp.asarray(np.isfinite(cb)), jnp.asarray(g_of_t),
        jnp.asarray(gcap_b), jnp.asarray(np.isfinite(gcap_b)),
        jnp.asarray(sg_of_t), jnp.asarray(scap),
        jnp.asarray(np.isfinite(scap)), jnp.asarray(s0, jnp.float32),
        jnp.float32(sstep0))
    ndev = 1 if mesh is None else int(np.prod(list(mesh.shape.values())))
    if mesh is None or ndev <= 1:
        if not (np.isfinite(gcap_b).any() or np.isfinite(scap).any()):
            # group/shared duals provably stay 0.0 — use the lean kernel.
            # Tenants are fully independent here, so large fleets run in
            # fixed-size chunks: one compiled (chunk, N_max) shape serves
            # any T instead of re-compiling per fleet size, which is what
            # dominates cold solves at T >> chunk.
            T = masked_b.shape[0]
            fin_b = np.isfinite(cap_b)
            if T <= _FLEET_CHUNK:
                return np.asarray(_fleet_scan_plain(
                    jnp.asarray(masked_b), jnp.asarray(stored_b),
                    jnp.asarray(cap_b), jnp.asarray(fin_b),
                    jnp.asarray(step0_b, jnp.float32), iters))
            pad = (-T) % _FLEET_CHUNK
            if pad:
                # dummy tenants: BIG cost, zero stored bytes, unbounded
                # caps — their duals never move; sliced off below
                masked_b = np.concatenate(
                    [masked_b, np.full((pad,) + masked_b.shape[1:], BIG)])
                stored_b = np.concatenate(
                    [stored_b, np.zeros((pad,) + stored_b.shape[1:])])
                cap_b = np.concatenate(
                    [cap_b, np.full((pad,) + cap_b.shape[1:], np.inf)])
                fin_b = np.isfinite(cap_b)
                step0_b = np.concatenate([step0_b, np.zeros(pad)])
            chunks = [np.asarray(_fleet_scan_plain(
                jnp.asarray(masked_b[i:i + _FLEET_CHUNK]),
                jnp.asarray(stored_b[i:i + _FLEET_CHUNK]),
                jnp.asarray(cap_b[i:i + _FLEET_CHUNK]),
                jnp.asarray(fin_b[i:i + _FLEET_CHUNK]),
                jnp.asarray(step0_b[i:i + _FLEET_CHUNK], jnp.float32),
                iters)) for i in range(0, T + pad, _FLEET_CHUNK)]
            cells = np.concatenate(chunks, axis=1)
            return cells[:, :T] if pad else cells
        return np.asarray(_fleet_scan_single(
            *args(masked_b, stored_b, cap_b, step0_b), iters))
    from jax.sharding import PartitionSpec as P
    from repro.distributed import ctx as dist_ctx
    T = masked_b.shape[0]
    pad = (-T) % ndev
    if pad:
        # dummy tenants: BIG cost, zero stored bytes, unbounded caps —
        # their duals never move and they are sliced off below
        masked_b = np.concatenate(
            [masked_b, np.full((pad,) + masked_b.shape[1:], BIG)])
        stored_b = np.concatenate(
            [stored_b, np.zeros((pad,) + stored_b.shape[1:])])
        cap_b = np.concatenate(
            [cap_b, np.full((pad,) + cap_b.shape[1:], np.inf)])
        gcap_b = np.concatenate(
            [gcap_b, np.full((pad,) + gcap_b.shape[1:], np.inf)])
        step0_b = np.concatenate([step0_b, np.zeros(pad)])
    axis = mesh.axis_names[0]
    sharded = dist_ctx.shard_map(
        partial(_fleet_scan_core, iters=iters, axis_name=axis), mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(axis), P(axis),
                  P(), P(), P(), P(axis), P()),
        out_specs=P(None, axis, None), check_vma=False)
    cells = np.asarray(jax.jit(sharded)(
        *args(masked_b, stored_b, cap_b, step0_b)))
    return cells[:, :T] if pad else cells


@jax.jit
def _greedy_jax_batch(cost: jnp.ndarray, feasible: jnp.ndarray):
    masked = jnp.where(feasible, cost, BIG)
    flat = masked.reshape(masked.shape[0], masked.shape[1], -1)
    idx = jnp.argmin(flat, axis=2)
    best = jnp.take_along_axis(flat, idx[:, :, None], axis=2)[:, :, 0]
    K = masked.shape[3]
    return idx // K, idx % K, best


def greedy_assign_batch(costs: Sequence[np.ndarray],
                        feasibles: Sequence[np.ndarray]) -> List[Assignment]:
    """Unbounded-capacity assignment for T ragged tenants in one device
    dispatch. Bit-identical per tenant to :func:`greedy_assign` (same f32
    argmin, same f64 host re-total); padding rows are BIG-masked and
    sliced off before scoring."""
    T = len(costs)
    if T == 0:
        return []
    Ns = [int(c.shape[0]) for c in costs]
    L, K = costs[0].shape[1], costs[0].shape[2]
    n_max = max(Ns)
    if n_max == 0:
        z = np.zeros(0, np.int64)
        return [Assignment(z.copy(), z.copy(), 0.0, True) for _ in range(T)]
    cost_b = np.full((T, n_max, L, K), BIG)
    feas_b = np.zeros((T, n_max, L, K), bool)
    for t in range(T):
        cost_b[t, :Ns[t]] = costs[t]
        feas_b[t, :Ns[t]] = feasibles[t]
    tier_b, scheme_b, best_b = map(np.asarray, _greedy_jax_batch(
        jnp.asarray(cost_b), jnp.asarray(feas_b)))
    out = []
    for t in range(T):
        n = Ns[t]
        tier = tier_b[t, :n].astype(int)
        scheme = scheme_b[t, :n].astype(int)
        ok = bool((best_b[t, :n] < BIG).all())
        n_idx = np.arange(n)
        total = float(np.asarray(costs[t], np.float64)
                      [n_idx, tier, scheme].sum()) if ok else float("inf")
        out.append(Assignment(tier, scheme, total, ok))
    return out


def _fleet_repair_shared(tiers, schemes, uses, maskeds, storeds, As,
                         cap_alls, finite_alls, A_sh, cap_sh,
                         finite_sh) -> Optional[np.ndarray]:
    """Cross-tenant greedy eviction until every finite *shared* (fleet-wide)
    capacity row is respected; per-tenant rows stay respected throughout.
    Mirrors :func:`_repair_vec` at fleet scope: each round, the cheapest-
    delta members of the most over-capacity shared row move — across any
    tenant — to cells outside that row with room in both scopes. Returns
    the (S,) shared usage vector, or None if repair is impossible."""
    T = len(tiers)
    A_shf = A_sh & finite_sh[:, None]
    su = np.zeros(cap_sh.shape[0])
    for t in range(T):
        su += A_sh @ uses[t]
    total_n = sum(int(x.shape[0]) for x in tiers)
    for _ in range(4 * total_n + 8):
        over = np.where(finite_sh & (su > cap_sh + 1e-9))[0]
        if over.size == 0:
            return su
        s = over[np.argmax((su - cap_sh)[over])]
        in_s = A_sh[s]                              # (L,)
        slack_sh = np.where(finite_sh, cap_sh - su, np.inf)
        room_sh = np.where(A_shf, slack_sh[:, None], np.inf).min(0)   # (L,)
        moves = []                                  # (delta, t, n, l2, k2)
        for t in range(T):
            if tiers[t].shape[0] == 0:
                continue
            members = np.where(in_s[tiers[t]])[0]
            if members.size == 0:
                continue
            masked, stored = maskeds[t], storeds[t]
            K = masked.shape[2]
            Af = As[t] & finite_alls[t][:, None]
            use_c = As[t] @ uses[t]
            slack_own = np.where(finite_alls[t], cap_alls[t] - use_c, np.inf)
            room_own = np.where(Af, slack_own[:, None], np.inf).min(0)  # (L,)
            cur = masked[members, tiers[t][members], schemes[t][members]]
            cur_st = stored[members, tiers[t][members], schemes[t][members]]
            ok = (masked[members] < BIG) & (stored[members]
                                            <= room_own[None, :, None] + 1e-9)
            # leaving the row needs room in the destination's shared row;
            # staying inside it is allowed iff the move strictly shrinks the
            # row's usage (better compression) — shared rows are disjoint,
            # so an in-row move touches no other shared row
            ok &= np.where(in_s[None, :, None],
                           stored[members] < cur_st[:, None, None] - 1e-9,
                           stored[members] <= room_sh[None, :, None] + 1e-9)
            delta = np.where(ok, masked[members] - cur[:, None, None],
                             np.inf).reshape(members.size, -1)
            cell = delta.argmin(1)
            d = delta[np.arange(members.size), cell]
            for m in range(members.size):
                if np.isfinite(d[m]):
                    moves.append((float(d[m]), t, int(members[m]),
                                  int(cell[m]) // K, int(cell[m]) % K))
        if not moves:
            return None
        moves.sort()
        moved = False
        for d, t, n, l2, k2 in moves:
            if su[s] <= cap_sh[s] + 1e-9:
                break
            stored = storeds[t]
            if not in_s[tiers[t][n]]:
                continue
            l1, k1 = int(tiers[t][n]), int(schemes[t][n])
            s1, s2 = stored[n, l1, k1], stored[n, l2, k2]
            # room may have shrunk this round; re-check before applying
            Af = As[t] & finite_alls[t][:, None]
            use_c = As[t] @ uses[t]
            room_own = np.where(Af[:, l2], cap_alls[t] - use_c,
                                np.inf).min() if Af[:, l2].any() else np.inf
            if in_s[l2]:
                if s2 >= s1 - 1e-9:
                    continue                        # shrink no longer strict
                room_s2 = np.inf
            else:
                slack2 = np.where(finite_sh, cap_sh - su, np.inf)
                room_s2 = np.where(A_shf[:, l2], slack2, np.inf).min() \
                    if A_shf[:, l2].any() else np.inf
            if s2 > min(room_own, room_s2) + 1e-9:
                continue
            uses[t][l1] -= s1
            uses[t][l2] += s2
            su += A_sh[:, l2] * s2 - A_sh[:, l1] * s1
            tiers[t][n], schemes[t][n] = l2, k2
            moved = True
        if not moved:
            return None
    return None


def _fleet_polish(tiers, schemes, uses, maskeds, storeds, As, cap_alls,
                  finite_alls, A_sh, cap_sh, finite_sh,
                  su: np.ndarray) -> None:
    """Round-robin 1-swap descent under the shared rows: each tenant runs
    :func:`_local_search_vec` against its own constraints augmented with the
    shared rows at their *residual* caps (fleet cap minus the other tenants'
    usage), sweeping until a full pass changes nothing."""
    T = len(tiers)
    for _ in range(8):
        changed = False
        for t in range(T):
            if tiers[t].shape[0] == 0:
                continue
            own_sh = A_sh @ uses[t]
            A_aug = np.concatenate([As[t], A_sh], 0)
            cap_aug = np.concatenate([cap_alls[t], cap_sh - (su - own_sh)])
            fin_aug = np.concatenate([finite_alls[t], finite_sh])
            t0, k0 = tiers[t].copy(), schemes[t].copy()
            _local_search_vec(tiers[t], schemes[t], uses[t], maskeds[t],
                              storeds[t], A_aug, cap_aug, fin_aug)
            if not ((tiers[t] == t0).all() and (schemes[t] == k0).all()):
                changed = True
                su += A_sh @ uses[t] - own_sh
        if not changed:
            return


@dataclasses.dataclass
class FleetAssignment:
    """Result of one batched fleet solve.

    ``assignments[t]`` is tenant t's :class:`Assignment`; ``cost`` is the
    fleet-total objective (inf if any tenant is infeasible); ``feasible``
    requires every tenant feasible *and* the shared caps respected;
    ``shared_use_gb`` is the fleet usage per shared group (None when no
    shared rows were given).
    """

    assignments: List[Assignment]
    cost: float
    feasible: bool
    shared_use_gb: Optional[np.ndarray] = None


def _per_tenant_seq(x, T: int, name: str) -> list:
    """Broadcast one vector to all T tenants, or validate a per-tenant
    sequence (list/tuple of vectors, or a (T, ...) array)."""
    if x is None:
        return [None] * T
    if isinstance(x, np.ndarray) and x.ndim == 1:
        return [x] * T
    xs = list(x)
    if len(xs) != T:
        raise ValueError(f"{name}: expected one vector or a length-{T} "
                         f"sequence, got length {len(xs)}")
    return xs


def capacitated_assign_batch(
    costs: Sequence[np.ndarray],         # T x (N_t, L, K), ragged N_t
    feasibles: Sequence[np.ndarray],     # T x (N_t, L, K)
    stored_gbs: Sequence[np.ndarray],    # T x (N_t, L, K)
    capacity_gb,                         # (L,) for all tenants, or T x (L,)
    *,
    iters: int = 200,
    seed: int = 0,
    max_candidates: int = 16,
    tier_groups: Optional[np.ndarray] = None,        # (L,) — one tier space
    group_capacity_gb=None,                          # (G,) or T x (G,)
    shared_tier_groups: Optional[np.ndarray] = None,  # (L,) fleet-wide rows
    shared_capacity_gb: Optional[np.ndarray] = None,  # (S,)
    mesh=None,
    sla_penalties: Optional[Sequence] = None,        # T x (N_t,L,K) or None
    sla_lambda: float = 0.0,
) -> FleetAssignment:
    """Solve T tenants' capacitated OPTASSIGN problems in ONE device dispatch.

    Heterogeneous tenant problems are ragged-padded into a
    ``(T, N_max, L, K)`` batch (padding rows: BIG cost, zero stored bytes —
    they contribute zero cost and zero usage, so they never perturb duals or
    capacities) and run through one batched jitted Lagrangian scan; repair
    and 1-swap polish then run per tenant on host exactly as in
    :func:`capacitated_assign`. **With no shared constraints the per-tenant
    results are bit-identical to T independent** :func:`capacitated_assign`
    **calls** (pinned by ``tests/test_fleet.py``) — same greedy shortcut,
    same dual trajectories, same candidate set, same repair/polish.

    ``shared_tier_groups``/``shared_capacity_gb`` add *fleet-wide* capacity
    rows: ``sum over all tenants of use[shared_tier_groups == s] <=
    shared_capacity_gb[s]``. This is how one provider's global capacity caps
    the whole fleet rather than each tenant separately. Shared rows are
    dualized by fleet-global multipliers in the scan; on host a
    cross-tenant eviction repair (:func:`_fleet_repair_shared`) and a
    residual-cap round-robin polish (:func:`_fleet_polish`) enforce them
    exactly.

    ``mesh`` (a ``jax.sharding.Mesh``) optionally ``shard_map``s the tenant
    axis of the scan across the mesh's first axis; shared-row usage is
    ``psum``-reduced across devices. On a single device (the default) the
    plain jitted batch is dispatched — same results.
    """
    if (shared_tier_groups is None) != (shared_capacity_gb is None):
        raise ValueError("shared_tier_groups and shared_capacity_gb must be "
                         "passed together")
    # Soft-SLA term, exactly as in capacitated_assign: folded into the
    # per-tenant cost tensors before padding, so the weighted penalty rows
    # ride the batched/sharded fleet scan too. sla_lambda=0 touches nothing.
    if sla_lambda and sla_penalties is not None:
        costs = [c if p is None
                 else (np.asarray(c, np.float64)
                       + float(sla_lambda) * np.asarray(p, np.float64))
                 for c, p in zip(costs, sla_penalties)]
    T = len(costs)
    if T == 0:
        su = (np.zeros(np.asarray(shared_capacity_gb).shape[0])
              if shared_capacity_gb is not None else None)
        return FleetAssignment([], 0.0, True, su)
    L, K = int(costs[0].shape[1]), int(costs[0].shape[2])
    caps = [np.asarray(c, np.float64) for c in
            _per_tenant_seq(np.asarray(capacity_gb, np.float64)
                            if not isinstance(capacity_gb, (list, tuple))
                            else capacity_gb, T, "capacity_gb")]
    gcaps = _per_tenant_seq(group_capacity_gb, T, "group_capacity_gb")

    maskeds, storeds, As, cap_alls, finite_alls, Ns = [], [], [], [], [], []
    for t in range(T):
        maskeds.append(_masked(np.asarray(costs[t], np.float64),
                               feasibles[t]))
        storeds.append(np.asarray(stored_gbs[t], np.float64))
        A, cap_all = _constraint_rows(caps[t], tier_groups, gcaps[t])
        As.append(A)
        cap_alls.append(cap_all)
        finite_alls.append(np.isfinite(cap_all))
        Ns.append(int(costs[t].shape[0]))

    if shared_tier_groups is not None:
        sg = np.asarray(shared_tier_groups, int)
        scap = np.asarray(shared_capacity_gb, np.float64)
        S = scap.shape[0]
        if sg.shape != (L,) or (sg.size and (sg.min() < 0 or sg.max() >= S)):
            raise ValueError(f"shared_tier_groups ids must lie in [0, {S}) "
                             f"and have shape ({L},)")
        A_sh = np.arange(S)[:, None] == sg[None, :]
        finite_sh = np.isfinite(scap)
    else:
        sg = np.zeros(L, int)
        scap = np.array([np.inf])
        A_sh = np.ones((1, L), bool)
        finite_sh = np.zeros(1, bool)
    has_shared = bool(finite_sh.any())

    # lam=0 greedy shortcut, per tenant — identical to capacitated_assign's
    tier0s, scheme0s, use0s, own_ok = [], [], [], []
    for t in range(T):
        cell0 = maskeds[t].reshape(Ns[t], -1).argmin(1) if Ns[t] \
            else np.zeros(0, np.int64)
        tier0s.append(cell0 // K)
        scheme0s.append(cell0 % K)
        use0s.append(_chosen_usage(storeds[t], tier0s[t], scheme0s[t]))
        own_ok.append(bool((~finite_alls[t]
                            | (As[t] @ use0s[t]
                               <= cap_alls[t] + 1e-9)).all()))

    def greedy_result(t: int) -> Assignment:
        total = float(maskeds[t][np.arange(Ns[t]), tier0s[t],
                                 scheme0s[t]].sum())
        ok = bool(total < BIG)
        return Assignment(tier0s[t], scheme0s[t],
                          total if ok else float("inf"), ok)

    done: dict = {}
    if has_shared:
        su0 = A_sh @ np.sum(use0s, axis=0)
        if all(own_ok) and bool((~finite_sh | (su0 <= scap + 1e-9)).all()):
            solve_idx: List[int] = []
            done = {t: greedy_result(t) for t in range(T)}
        else:
            solve_idx = list(range(T))
    else:
        done = {t: greedy_result(t) for t in range(T) if own_ok[t]}
        solve_idx = [t for t in range(T) if not own_ok[t]]

    if solve_idx:
        n_max = max(Ns[t] for t in solve_idx)
        Tp = len(solve_idx)
        masked_b = np.full((Tp, n_max, L, K), BIG)
        stored_b = np.zeros((Tp, n_max, L, K))
        cap_b = np.zeros((Tp, L))
        step0_b = np.zeros(Tp)
        if tier_groups is None:
            g_of_t = np.zeros(L, np.int32)
            gcap_b = np.full((Tp, 1), np.inf)
        else:
            g_of_t = np.asarray(tier_groups, np.int32)
            gcap_b = np.stack([np.asarray(gcaps[t], np.float64)
                               for t in solve_idx])
        for j, t in enumerate(solve_idx):
            masked_b[j, :Ns[t]] = maskeds[t]
            stored_b[j, :Ns[t]] = storeds[t]
            cap_b[j] = caps[t]
            step0_b[j] = _step0(maskeds[t], cap_alls[t], finite_alls[t])
        if has_shared:
            fleet_cells = np.concatenate(
                [maskeds[t][maskeds[t] < BIG].ravel() for t in solve_idx])
            sstep0 = (fleet_cells.mean()
                      / max(scap[finite_sh].mean(), 1e-9)
                      if fleet_cells.size else 0.0)
        else:
            sstep0 = 0.0
        cells = np.asarray(_run_fleet_scan(mesh, masked_b, stored_b, cap_b,
                                           gcap_b, g_of_t,
                                           np.asarray(sg, np.int32), scap,
                                           sstep0, step0_b, iters))

        if not has_shared:
            done.update(_batch_candidate_finish(
                solve_idx, cells, masked_b, stored_b, maskeds, storeds, As,
                cap_alls, finite_alls, Ns, K, max_candidates))
        else:
            joint = _dedupe_candidates(
                (cells[r].ravel() for r in range(cells.shape[0])),
                max_candidates)
            best_score = float("inf")
            best_state = None
            fallback = None
            for cand in joint:
                grid = cand.reshape(Tp, n_max)
                tiers = [grid[j, :Ns[t]] // K
                         for j, t in enumerate(solve_idx)]
                schemes = [grid[j, :Ns[t]] % K
                           for j, t in enumerate(solve_idx)]
                if fallback is None:
                    fallback = ([x.copy() for x in tiers],
                                [x.copy() for x in schemes])
                m_l = [maskeds[t] for t in solve_idx]
                s_l = [storeds[t] for t in solve_idx]
                A_l = [As[t] for t in solve_idx]
                c_l = [cap_alls[t] for t in solve_idx]
                f_l = [finite_alls[t] for t in solve_idx]
                uses = []
                dead = False
                for j in range(Tp):
                    use = _repair_vec(tiers[j], schemes[j], m_l[j], s_l[j],
                                      A_l[j], c_l[j], f_l[j])
                    if use is None:
                        dead = True
                        break
                    uses.append(use)
                if dead:
                    continue
                su = _fleet_repair_shared(tiers, schemes, uses, m_l, s_l,
                                          A_l, c_l, f_l, A_sh, scap,
                                          finite_sh)
                if su is None:
                    continue
                _fleet_polish(tiers, schemes, uses, m_l, s_l, A_l, c_l, f_l,
                              A_sh, scap, finite_sh, su)
                score = sum(
                    float(m_l[j][np.arange(Ns[t]), tiers[j],
                                 schemes[j]].sum())
                    for j, t in enumerate(solve_idx))
                if score < BIG and score < best_score:
                    best_score = score
                    best_state = ([x.copy() for x in tiers],
                                  [x.copy() for x in schemes])
            if best_state is not None:
                tiers, schemes = best_state
                for j, t in enumerate(solve_idx):
                    total = float(maskeds[t][np.arange(Ns[t]), tiers[j],
                                             schemes[j]].sum())
                    done[t] = Assignment(tiers[j], schemes[j], total, True)
            else:
                tiers, schemes = fallback if fallback is not None else (
                    [np.zeros(Ns[t], np.int64) for t in solve_idx],
                    [np.zeros(Ns[t], np.int64) for t in solve_idx])
                for j, t in enumerate(solve_idx):
                    done[t] = Assignment(tiers[j], schemes[j],
                                         float("inf"), False)

    assignments = [done[t] for t in range(T)]
    feasible = all(a.feasible for a in assignments)
    shared_use = None
    if shared_tier_groups is not None:
        shared_use = np.zeros(scap.shape[0])
        for t, a in enumerate(assignments):
            if a.feasible and Ns[t]:
                shared_use += A_sh @ _chosen_usage(
                    storeds[t], a.tier.astype(int), a.scheme.astype(int))
        feasible = feasible and bool(
            (~finite_sh | (shared_use <= scap + 1e-9)).all())
    cost = (float(sum(a.cost for a in assignments))
            if feasible else float("inf"))
    return FleetAssignment(assignments, cost, feasible, shared_use)


def capacitated_assign_ref(
    cost: np.ndarray,            # (N,L,K)
    feasible: np.ndarray,        # (N,L,K)
    stored_gb: np.ndarray,       # (N,L,K) size occupied if cell chosen
    capacity_gb: np.ndarray,     # (L,)
    iters: int = 200,
    seed: int = 0,
) -> Assignment:
    """Pure-Python reference: Lagrangian + repair + local search (original)."""
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    lam = np.zeros(L)
    cap = capacity_gb.copy()
    finite_cap = np.isfinite(cap)
    best: Optional[Assignment] = None
    step0 = masked[masked < BIG].mean() / max(cap[finite_cap].mean(), 1e-9) \
        if finite_cap.any() else 0.0

    def solve(lam_vec: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        adj = masked + (lam_vec[None, :, None] * stored_gb)
        flat = adj.reshape(N, -1)
        idx = flat.argmin(1)
        return idx // K, idx % K

    def repair_and_score(tier: np.ndarray, scheme: np.ndarray) -> Assignment:
        tier, scheme = tier.copy(), scheme.copy()
        use = _chosen_usage(stored_gb, tier, scheme)
        # Greedy repair: move cheapest-delta items out of over-capacity tiers.
        for l in np.argsort(-(use - cap)):
            while finite_cap[l] and use[l] > cap[l] + 1e-9:
                members = [n for n in range(N) if tier[n] == l]
                best_mv, best_delta = None, np.inf
                for n in members:
                    cur = masked[n, l, scheme[n]]
                    for l2 in range(L):
                        if l2 == l:
                            continue
                        for k2 in range(K):
                            if masked[n, l2, k2] >= BIG:
                                continue
                            room = cap[l2] - use[l2] if finite_cap[l2] else np.inf
                            if stored_gb[n, l2, k2] > room + 1e-9:
                                continue
                            delta = masked[n, l2, k2] - cur
                            if delta < best_delta:
                                best_delta, best_mv = delta, (n, l2, k2)
                if best_mv is None:
                    return Assignment(tier, scheme, float("inf"), False)
                n, l2, k2 = best_mv
                use[l] -= stored_gb[n, l, scheme[n]]
                use[l2] += stored_gb[n, l2, k2]
                tier[n], scheme[n] = l2, k2
        # 1-move local search
        improved = True
        while improved:
            improved = False
            for n in range(N):
                cur_c = masked[n, tier[n], scheme[n]]
                for l2 in range(L):
                    for k2 in range(K):
                        if masked[n, l2, k2] >= cur_c - 1e-12:
                            continue
                        new_use_l2 = use[l2] + stored_gb[n, l2, k2] \
                            - (stored_gb[n, tier[n], scheme[n]] if l2 == tier[n] else 0)
                        if finite_cap[l2] and new_use_l2 > cap[l2] + 1e-9:
                            continue
                        use[tier[n]] -= stored_gb[n, tier[n], scheme[n]]
                        use[l2] += stored_gb[n, l2, k2]
                        tier[n], scheme[n] = l2, k2
                        improved = True
                        break
                    else:
                        continue
                    break
        total = float(sum(masked[n, tier[n], scheme[n]] for n in range(N)))
        ok = total < BIG
        return Assignment(tier, scheme, total if ok else float("inf"), ok)

    for it in range(iters):
        tier, scheme = solve(lam)
        cand = repair_and_score(tier, scheme)
        if cand.feasible and (best is None or cand.cost < best.cost):
            best = cand
        use = _chosen_usage(stored_gb, tier, scheme)
        grad = np.where(finite_cap, use - cap, 0.0)
        if np.all(grad <= 1e-9) and it > 0:
            break
        lam = np.maximum(0.0, lam + step0 / (1 + it) * grad)
    if best is None:
        tier, scheme = solve(lam)
        best = repair_and_score(tier, scheme)
    return best


# ------------------------------------------------------------ budgeted moves
@jax.jit
def _knapsack_scan(order: jnp.ndarray, cents: jnp.ndarray, gb: jnp.ndarray,
                   ok: jnp.ndarray, cap_cents: jnp.ndarray,
                   cap_gb: jnp.ndarray):
    """Greedy knapsack walk over pre-ranked items as one ``lax.scan``.

    Items arrive in ``order`` (best ratio first); each is taken iff it is
    eligible and fits both remaining budgets. Returns take flags in walk
    order (scatter back through ``order`` on the host)."""

    def body(carry, i):
        rem_c, rem_g = carry
        take = ok[i] & (cents[i] <= rem_c + 1e-9) & (gb[i] <= rem_g + 1e-9)
        rem_c = rem_c - jnp.where(take, cents[i], 0.0)
        rem_g = rem_g - jnp.where(take, gb[i], 0.0)
        return (rem_c, rem_g), take

    _, takes = jax.lax.scan(body, (cap_cents, cap_gb), order)
    return takes


def _exact_moves(savings: np.ndarray, cents: np.ndarray, gb: np.ndarray,
                 cand: np.ndarray, budget_cents: float, budget_gb: float,
                 ) -> np.ndarray:
    """Exact subset enumeration (vectorized bit-matrix), tiny instances only.

    Maximizes total (priority-weighted) savings subject to both caps;
    ties broken toward the cheaper subset, then the lexicographically
    first one, so the result is deterministic."""
    idx = np.where(cand)[0]
    n = idx.size
    M = ((np.arange(1 << n)[:, None] >> np.arange(n)) & 1).astype(bool)
    tot_c = M @ cents[idx]
    tot_g = M @ gb[idx]
    obj = M @ savings[idx]
    feas = (tot_c <= budget_cents + 1e-9) & (tot_g <= budget_gb + 1e-9)
    obj = np.where(feas, obj, -np.inf)
    # lexsort keys: last key is primary — max obj, then min cost, then the
    # smallest subset id (M rows are already in lexicographic order)
    best = int(np.lexsort((np.arange(1 << n), tot_c, -obj))[0])
    keep = np.zeros(savings.shape[0], bool)
    keep[idx[M[best]]] = True
    return keep


def budgeted_moves(
    savings_cents: np.ndarray,   # (N,) projected steady-state saving per move
    move_cents: np.ndarray,      # (N,) one-off charge per move (cents)
    budget_cents: float,         # per-cycle cents cap (np.inf = unbounded)
    *,
    candidates: Optional[np.ndarray] = None,   # (N,) bool; None = all
    move_gb: Optional[np.ndarray] = None,      # (N,) bytes leaving their cell
    budget_gb: float = np.inf,                 # per-cycle GB cap
    priority: Optional[np.ndarray] = None,     # (N,) aging boost (>= 1)
    method: str = "auto",                      # 'auto' | 'greedy' | 'exact'
    exact_max: int = 12,
    paid_cents: Optional[np.ndarray] = None,   # (N,) credit already banked
) -> np.ndarray:
    """Select which candidate migrations to execute under a per-cycle budget.

    The savings-per-migration-cent knapsack of the re-optimization daemon:
    maximize total projected steady-state savings subject to a cents cap
    (and optionally a GB cap) on the one-off migration spend. The
    production path is a jnp-batched greedy-ratio walk — rank every
    candidate by ``priority * savings / cents`` on device (argsort), then
    take items in rank order while they fit both budgets (one jitted
    ``lax.scan``). ``method='exact'`` enumerates subsets instead (tiny
    instances; the validation oracle for the greedy path). ``'auto'``
    uses the exact path when there are at most ``exact_max`` candidates.

    Zero-cost moves rank first and never consume budget; with both caps
    infinite every candidate is selected (the daemon's parity mode).
    Candidates with non-positive projected savings stay eligible — the
    assignment solver already justified the move (its objective sees
    constraint and one-off terms this per-cell projection does not), and
    selection only schedules spend — but their selection value is floored
    at a priority-scaled epsilon, so they rank below every
    positive-savings candidate on BOTH paths and only fill leftover
    budget. Returns an (N,) boolean mask — always a subset of
    ``candidates``.

    ``paid_cents`` is per-move credit already banked by earlier cycles
    (the daemon's amortized move-splitting): each candidate is weighed
    against the budgets at its *residual* charge ``max(move_cents -
    paid_cents, 0)``, so an oversized move whose installments have
    accumulated eventually fits the per-cycle cap and lands.
    """
    s = np.asarray(savings_cents, np.float64)
    c = np.asarray(move_cents, np.float64)
    if paid_cents is not None:
        c = np.maximum(c - np.asarray(paid_cents, np.float64), 0.0)
    N = s.shape[0]
    cand = (np.ones(N, bool) if candidates is None
            else np.asarray(candidates, bool).copy())
    g = (np.zeros(N) if move_gb is None
         else np.asarray(move_gb, np.float64))
    pr = np.ones(N) if priority is None else np.asarray(priority, np.float64)
    if N == 0 or not cand.any():
        return np.zeros(N, bool)
    if np.isinf(budget_cents) and np.isinf(budget_gb):
        return cand
    if method not in ("auto", "greedy", "exact"):
        raise ValueError(f"unknown method {method!r}")
    val = pr * s
    val = np.where(val > 0, val, 1e-9 * pr)   # take-if-fits, ranked last
    if method == "exact" or (method == "auto"
                             and int(cand.sum()) <= exact_max):
        return _exact_moves(val, c, g, cand, budget_cents, budget_gb)

    ratio = np.where(cand, val / np.maximum(c, 1e-12), -np.inf)
    order = jnp.argsort(-jnp.asarray(ratio))
    takes = np.asarray(_knapsack_scan(
        order, jnp.asarray(c), jnp.asarray(g), jnp.asarray(cand),
        jnp.asarray(budget_cents, jnp.float32),
        jnp.asarray(budget_gb, jnp.float32)))
    keep = np.zeros(N, bool)
    keep[np.asarray(order)] = takes
    keep &= cand
    # the scan ran in f32; re-walk the selected set in f64 and shed the
    # worst-ratio items if rounding let the total creep past a cap
    while keep.any() and (c[keep].sum() > budget_cents + 1e-9
                          or g[keep].sum() > budget_gb + 1e-9):
        sel = np.where(keep)[0]
        keep[sel[np.argmin(ratio[sel])]] = False
    return keep


# ---------------------------------------------------------------- brute force
def brute_force(cost: np.ndarray, feasible: np.ndarray,
                stored_gb: Optional[np.ndarray] = None,
                capacity_gb: Optional[np.ndarray] = None,
                tier_groups: Optional[np.ndarray] = None,
                group_capacity_gb: Optional[np.ndarray] = None) -> Assignment:
    """Exact oracle by enumeration — only for tiny test instances."""
    if (tier_groups is None) != (group_capacity_gb is None):
        raise ValueError("tier_groups and group_capacity_gb must be "
                         "passed together")
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    cells = [[(l, k) for l in range(L) for k in range(K)
              if masked[n, l, k] < BIG] for n in range(N)]
    best_cost, best_pick = float("inf"), None
    for pick in itertools.product(*cells):
        if capacity_gb is not None or group_capacity_gb is not None:
            use = np.zeros(L)
            for n, (l, k) in enumerate(pick):
                use[l] += stored_gb[n, l, k]
            if capacity_gb is not None and np.any(use > capacity_gb + 1e-9):
                continue
            if group_capacity_gb is not None:
                g = np.asarray(tier_groups, int)
                gcap = np.asarray(group_capacity_gb, np.float64)
                use_g = np.zeros(gcap.shape[0])
                np.add.at(use_g, g, use)
                if np.any(use_g > gcap + 1e-9):
                    continue
        c = sum(masked[n, l, k] for n, (l, k) in enumerate(pick))
        if c < best_cost:
            best_cost, best_pick = c, pick
    if best_pick is None:
        return Assignment(np.zeros(N, int), np.zeros(N, int), float("inf"), False)
    tier = np.array([l for l, _ in best_pick])
    scheme = np.array([k for _, k in best_pick])
    return Assignment(tier, scheme, float(best_cost), True)
