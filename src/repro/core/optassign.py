"""OPTASSIGN — optimal tier + compression-scheme assignment (paper §IV).

Solvers
-------
``greedy_assign``       exact for unbounded capacities (Thm 3), O(NLK); the
                        vectorized JAX version is the PB-scale production path.
``matching_assign``     exact for equal-size/no-compression with capacities
                        (Thm 2) via min-cost flow == min-weight bipartite
                        matching on tier copies.
``capacitated_assign``  general capacitated case (strongly NP-hard, Thm 1):
                        Lagrangian dual ascent + greedy repair + 1-swap local
                        search; validated against ``brute_force`` in tests.
``brute_force``         exact enumeration oracle for tiny instances.

All solvers consume the (N,L,K) cost tensor and (N,L,K) feasibility mask from
:mod:`repro.core.costs`, so objective-weight variants (alpha/beta/gamma,
pushdown fraction, scheme locking for existing partitions) are handled
uniformly upstream.
"""

from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e18


@dataclasses.dataclass
class Assignment:
    tier: np.ndarray       # (N,) int
    scheme: np.ndarray     # (N,) int
    cost: float            # objective value of chosen cells
    feasible: bool         # capacity + latency respected


def _masked(cost: np.ndarray, feasible: np.ndarray) -> np.ndarray:
    return np.where(feasible, cost, BIG)


def lock_schemes(feasible: np.ndarray, locked_scheme: np.ndarray) -> np.ndarray:
    """Paper's last ILP constraint: existing partitions keep their scheme.

    ``locked_scheme[n] == -1`` means partition n is new (free choice).
    """
    N, L, K = feasible.shape
    mask = feasible.copy()
    for n in range(N):
        k = int(locked_scheme[n])
        if k >= 0:
            keep = np.zeros(K, bool)
            keep[k] = True
            mask[n] &= keep[None, :]
    return mask


# --------------------------------------------------------------------- greedy
@partial(jax.jit, static_argnames=())
def _greedy_jax(cost: jnp.ndarray, feasible: jnp.ndarray):
    masked = jnp.where(feasible, cost, BIG)
    flat = masked.reshape(masked.shape[0], -1)
    idx = jnp.argmin(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    K = masked.shape[2]
    return idx // K, idx % K, best


def greedy_assign(cost: np.ndarray, feasible: np.ndarray) -> Assignment:
    """Exact when capacities are unbounded (Thm 3). O(NLK)."""
    tier, scheme, best = map(np.asarray, _greedy_jax(jnp.asarray(cost),
                                                     jnp.asarray(feasible)))
    tier, scheme = tier.astype(int), scheme.astype(int)
    ok = bool((best < BIG).all())
    # argmin runs in f32 on device; re-total the objective in f64 for exactness
    n = np.arange(cost.shape[0])
    total = float(np.asarray(cost, np.float64)[n, tier, scheme].sum()) if ok \
        else float("inf")
    return Assignment(tier, scheme, total, ok)


# ------------------------------------------------------------------- matching
class _MCMF:
    """Successive-shortest-path min-cost max-flow (SPFA variant). Exact."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.cost: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add(self, u: int, v: int, cap: float, cost: float) -> None:
        self.head[u].append(len(self.to)); self.to.append(v)
        self.cap.append(cap); self.cost.append(cost)
        self.head[v].append(len(self.to)); self.to.append(u)
        self.cap.append(0.0); self.cost.append(-cost)

    def run(self, s: int, t: int):
        flow = cost = 0.0
        INF = float("inf")
        while True:
            dist = [INF] * self.n
            in_q = [False] * self.n
            prev_e = [-1] * self.n
            dist[s] = 0.0
            queue = [s]
            in_q[s] = True
            while queue:
                u = queue.pop(0)
                in_q[u] = False
                for e in self.head[u]:
                    if self.cap[e] > 1e-12 and dist[u] + self.cost[e] < dist[self.to[e]] - 1e-12:
                        dist[self.to[e]] = dist[u] + self.cost[e]
                        prev_e[self.to[e]] = e
                        if not in_q[self.to[e]]:
                            queue.append(self.to[e])
                            in_q[self.to[e]] = True
            if dist[t] == INF:
                return flow, cost
            # bottleneck
            push, v = INF, t
            while v != s:
                e = prev_e[v]
                push = min(push, self.cap[e])
                v = self.to[e ^ 1]
            v = t
            while v != s:
                e = prev_e[v]
                self.cap[e] -= push
                self.cap[e ^ 1] += push
                v = self.to[e ^ 1]
            flow += push
            cost += push * dist[t]


def matching_assign(cost_nl: np.ndarray, feasible_nl: np.ndarray,
                    capacity_units: np.ndarray) -> Assignment:
    """Equal-size partitions, no compression (Thm 2).

    Min-weight bipartite matching of N unit-size partitions onto Z_l tier
    copies; the tier-copy graph collapses to a transportation problem solved
    exactly by min-cost max-flow (source -> partition -> tier -> sink).
    """
    N, L = cost_nl.shape
    cost = _masked(cost_nl, feasible_nl)
    cap = np.minimum(capacity_units.astype(np.float64), N)
    S, T = N + L, N + L + 1
    g = _MCMF(N + L + 2)
    for n in range(N):
        g.add(S, n, 1.0, 0.0)
        for l in range(L):
            if cost[n, l] < BIG:
                g.add(n, N + l, 1.0, float(cost[n, l]))
    for l in range(L):
        g.add(N + l, T, float(cap[l]), 0.0)
    flow, total = g.run(S, T)
    if flow < N - 1e-9:
        return Assignment(np.full(N, -1), np.zeros(N, int), float("inf"), False)
    assign = np.full(N, -1, np.int64)
    for n in range(N):
        for e in g.head[n]:
            v = g.to[e]
            if N <= v < N + L and e % 2 == 0 and g.cap[e] < 0.5:
                assign[n] = v - N
    return Assignment(assign, np.zeros(N, int), float(total), True)


# ---------------------------------------------------------------- capacitated
def _usage(stored_gb_nlk: np.ndarray, tier: np.ndarray, scheme: np.ndarray,
           L: int) -> np.ndarray:
    N = tier.shape[0]
    use = np.zeros(L)
    for n in range(N):
        use[tier[n]] += stored_gb_nlk[n, tier[n], scheme[n]]
    return use


def capacitated_assign(
    cost: np.ndarray,            # (N,L,K)
    feasible: np.ndarray,        # (N,L,K)
    stored_gb: np.ndarray,       # (N,L,K) size occupied if cell chosen
    capacity_gb: np.ndarray,     # (L,)
    iters: int = 200,
    seed: int = 0,
) -> Assignment:
    """General OPTASSIGN with capacities: Lagrangian + repair + local search."""
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    lam = np.zeros(L)
    cap = capacity_gb.copy()
    finite_cap = np.isfinite(cap)
    best: Optional[Assignment] = None
    step0 = masked[masked < BIG].mean() / max(cap[finite_cap].mean(), 1e-9) \
        if finite_cap.any() else 0.0

    def solve(lam_vec: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        adj = masked + (lam_vec[None, :, None] * stored_gb)
        flat = adj.reshape(N, -1)
        idx = flat.argmin(1)
        return idx // K, idx % K

    def repair_and_score(tier: np.ndarray, scheme: np.ndarray) -> Assignment:
        tier, scheme = tier.copy(), scheme.copy()
        use = _usage(stored_gb, tier, scheme, L)
        # Greedy repair: move cheapest-delta items out of over-capacity tiers.
        for l in np.argsort(-(use - cap)):
            while finite_cap[l] and use[l] > cap[l] + 1e-9:
                members = [n for n in range(N) if tier[n] == l]
                best_mv, best_delta = None, np.inf
                for n in members:
                    cur = masked[n, l, scheme[n]]
                    for l2 in range(L):
                        if l2 == l:
                            continue
                        for k2 in range(K):
                            if masked[n, l2, k2] >= BIG:
                                continue
                            room = cap[l2] - use[l2] if finite_cap[l2] else np.inf
                            if stored_gb[n, l2, k2] > room + 1e-9:
                                continue
                            delta = masked[n, l2, k2] - cur
                            if delta < best_delta:
                                best_delta, best_mv = delta, (n, l2, k2)
                if best_mv is None:
                    return Assignment(tier, scheme, float("inf"), False)
                n, l2, k2 = best_mv
                use[l] -= stored_gb[n, l, scheme[n]]
                use[l2] += stored_gb[n, l2, k2]
                tier[n], scheme[n] = l2, k2
        # 1-move local search
        improved = True
        while improved:
            improved = False
            for n in range(N):
                cur_c = masked[n, tier[n], scheme[n]]
                for l2 in range(L):
                    for k2 in range(K):
                        if masked[n, l2, k2] >= cur_c - 1e-12:
                            continue
                        new_use_l2 = use[l2] + stored_gb[n, l2, k2] \
                            - (stored_gb[n, tier[n], scheme[n]] if l2 == tier[n] else 0)
                        if finite_cap[l2] and new_use_l2 > cap[l2] + 1e-9:
                            continue
                        use[tier[n]] -= stored_gb[n, tier[n], scheme[n]]
                        use[l2] += stored_gb[n, l2, k2]
                        tier[n], scheme[n] = l2, k2
                        improved = True
                        break
                    else:
                        continue
                    break
        total = float(sum(masked[n, tier[n], scheme[n]] for n in range(N)))
        ok = total < BIG
        return Assignment(tier, scheme, total if ok else float("inf"), ok)

    for it in range(iters):
        tier, scheme = solve(lam)
        cand = repair_and_score(tier, scheme)
        if cand.feasible and (best is None or cand.cost < best.cost):
            best = cand
        use = _usage(stored_gb, tier, scheme, L)
        grad = np.where(finite_cap, use - cap, 0.0)
        if np.all(grad <= 1e-9) and it > 0:
            break
        lam = np.maximum(0.0, lam + step0 / (1 + it) * grad)
    if best is None:
        tier, scheme = solve(lam)
        best = repair_and_score(tier, scheme)
    return best


# ---------------------------------------------------------------- brute force
def brute_force(cost: np.ndarray, feasible: np.ndarray,
                stored_gb: Optional[np.ndarray] = None,
                capacity_gb: Optional[np.ndarray] = None) -> Assignment:
    """Exact oracle by enumeration — only for tiny test instances."""
    N, L, K = cost.shape
    masked = _masked(cost, feasible)
    cells = [[(l, k) for l in range(L) for k in range(K)
              if masked[n, l, k] < BIG] for n in range(N)]
    best_cost, best_pick = float("inf"), None
    for pick in itertools.product(*cells):
        if capacity_gb is not None:
            use = np.zeros(L)
            for n, (l, k) in enumerate(pick):
                use[l] += stored_gb[n, l, k]
            if np.any(use > capacity_gb + 1e-9):
                continue
        c = sum(masked[n, l, k] for n, (l, k) in enumerate(pick))
        if c < best_cost:
            best_cost, best_pick = c, pick
    if best_pick is None:
        return Assignment(np.zeros(N, int), np.zeros(N, int), float("inf"), False)
    tier = np.array([l for l, _ in best_pick])
    scheme = np.array([k for _, k in best_pick])
    return Assignment(tier, scheme, float(best_cost), True)
