"""Architecture registry: the 10 assigned configs (+ smoke reductions).

Sources are the public configs cited in the assignment; every entry lists
the exact published hyper-parameters. Whisper/vision modality frontends are
stubs — input_specs() (launch/shapes.py) feeds precomputed frame/patch
embeddings to cross-attention / encoder stages.
"""

from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig, Stage, reduced_for_smoke


def gemma2_9b() -> ModelConfig:
    # arXiv:2408.00118 — local(4096)+global alternating, logit softcaps,
    # GeGLU, sandwich norms, sqrt(d) embedding scale.
    return ModelConfig(
        name="gemma2-9b", family="dense", vocab_size=256000, d_model=3584,
        n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14336,
        stages=(Stage(("attn_local", "attn"), 21),),
        sliding_window=4096, attn_softcap=50.0, final_softcap=30.0,
        use_post_norm=True, embed_scale=True, mlp_act="geglu",
        rope_theta=10000.0, tie_embeddings=True)


def qwen3_4b() -> ModelConfig:
    # hf:Qwen/Qwen3-4B — GQA kv=8, per-head q/k RMS norm, SwiGLU.
    return ModelConfig(
        name="qwen3-4b", family="dense", vocab_size=151936, d_model=2560,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
        stages=(Stage(("attn",), 36),),
        qk_norm=True, rope_theta=1e6, tie_embeddings=True)


def qwen2_7b() -> ModelConfig:
    # arXiv:2407.10671 — GQA kv=4, QKV bias. 28 q-heads pad to 32 under TP.
    return ModelConfig(
        name="qwen2-7b", family="dense", vocab_size=152064, d_model=3584,
        n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944,
        stages=(Stage(("attn",), 28),),
        qkv_bias=True, rope_theta=1e6, tie_embeddings=False)


def yi_9b() -> ModelConfig:
    # arXiv:2403.04652 — llama-arch GQA kv=4.
    return ModelConfig(
        name="yi-9b", family="dense", vocab_size=64000, d_model=4096,
        n_heads=32, n_kv_heads=4, head_dim=128, d_ff=11008,
        stages=(Stage(("attn",), 48),),
        rope_theta=5e6, tie_embeddings=False)


def zamba2_2p7b() -> ModelConfig:
    # arXiv:2411.15242 — 54 Mamba2 layers with a weight-shared attention
    # block applied every 6 layers (single shared block here; the released
    # model alternates two shared blocks with per-use LoRA — DESIGN.md §8).
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", vocab_size=32000, d_model=2560,
        n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240,
        stages=(Stage(("mamba",) * 6 + ("shared_attn",), 9),),
        ssm_state=64, mamba_headdim=64, mamba_expand=2,
        rope_theta=10000.0, tie_embeddings=True, sub_quadratic=True)


def llama4_scout_17b() -> ModelConfig:
    # hf:meta-llama/Llama-4-Scout-17B-16E — MoE 16 routed top-1 + 1 shared
    # expert per layer; iRoPE NoPE layers approximated as RoPE (DESIGN.md §8).
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", vocab_size=202048,
        d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
        stages=(Stage(("moe",), 48),),
        n_experts=16, top_k=1, n_shared_experts=1, expert_d_ff=8192,
        moe_block_tokens=16384,   # §Perf it.4: fewer blocks -> fewer expert-
        rope_theta=500000.0,      # weight re-reads (16 experts are few+fat)
        tie_embeddings=False)


def deepseek_v2_lite() -> ModelConfig:
    # arXiv:2405.04434 — MLA kv_lora=512 (+64 rope), 27 layers: 1 dense MLP
    # then 26 MoE layers of 64 routed (top-6) + 2 shared experts, d_ff=1408.
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", vocab_size=102400,
        d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128, d_ff=10944,
        stages=(Stage(("mla_dense",), 1), Stage(("mla_moe",), 26)),
        kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        n_experts=64, top_k=6, n_shared_experts=2, expert_d_ff=1408,
        rope_theta=10000.0, tie_embeddings=False)


def llama32_vision_90b() -> ModelConfig:
    # hf:meta-llama/Llama-3.2-90B-Vision — backbone only: 100 layers as
    # 20 x (4 self-attn + 1 cross-attn to patch embeddings). Vision tower
    # is a stub (input_specs supplies (B, 4100, d) patch embeddings).
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm", vocab_size=128256,
        d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
        stages=(Stage(("attn", "attn", "attn", "attn", "cross"), 20),),
        cross_context=4100, rope_theta=500000.0, tie_embeddings=False)


def whisper_small() -> ModelConfig:
    # arXiv:2212.04356 — enc-dec, 12+12 layers, MHA, GeLU. Conv frontend is
    # a stub: encoder consumes precomputed 1500-frame embeddings. RoPE is
    # used in place of learned/sinusoidal positions (DESIGN.md §8). Vocab
    # 51865 pads to 51968 (x128) for TP.
    return ModelConfig(
        name="whisper-small", family="audio", vocab_size=51865, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        stages=(Stage(("decoder",), 12),),
        encoder_stages=(Stage(("attn",), 12),), encoder_context=1500,
        mlp_act="gelu", tie_embeddings=True)


def mamba2_780m() -> ModelConfig:
    # arXiv:2405.21060 — pure SSD, 48 layers, d_state=128, headdim=64.
    return ModelConfig(
        name="mamba2-780m", family="ssm", vocab_size=50280, d_model=1536,
        n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
        stages=(Stage(("mamba",), 48),),
        ssm_state=128, mamba_headdim=64, mamba_expand=2,
        tie_embeddings=True, sub_quadratic=True)


_FACTORIES = {
    "gemma2-9b": gemma2_9b,
    "qwen3-4b": qwen3_4b,
    "qwen2-7b": qwen2_7b,
    "yi-9b": yi_9b,
    "zamba2-2.7b": zamba2_2p7b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "whisper-small": whisper_small,
    "mamba2-780m": mamba2_780m,
}


def arch_names() -> List[str]:
    return list(_FACTORIES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    cfg = _FACTORIES[name]()
    return reduced_for_smoke(cfg) if smoke else cfg
