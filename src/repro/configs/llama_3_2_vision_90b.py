"""Config module for ``llama-3.2-vision-90b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "llama-3.2-vision-90b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
