"""Config module for ``qwen3-4b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "qwen3-4b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
