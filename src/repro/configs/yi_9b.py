"""Config module for ``yi-9b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "yi-9b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
