"""SCOPe pipeline defaults — the paper's own experimental configuration
(§VII): Azure cost table, 5.5-month window, no-archive tier set, the
Tables IX-XI variant grid, and the TPC-H capacity ratios of Table XII."""

import numpy as np

from repro.core.costs import azure_table, tpch_capacity_table
from repro.core.scope import ScopeConfig, paper_variants

COST_TABLE = azure_table()
EVAL_MONTHS = 5.5
TIERS_NO_ARCHIVE = (0, 1, 2)


def default_config() -> ScopeConfig:
    return ScopeConfig(tier_whitelist=TIERS_NO_ARCHIVE, months=EVAL_MONTHS)


def variant_grid(total_gb: float):
    """The 11 policy rows of Tables IX-XI for a dataset of ``total_gb``."""
    cap = np.array([0.163, 0.326, 0.4891, np.inf]) * total_gb * 3.0
    return paper_variants(cap)
