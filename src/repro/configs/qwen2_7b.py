"""Config module for ``qwen2-7b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "qwen2-7b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
