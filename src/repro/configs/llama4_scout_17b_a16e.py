"""Config module for ``llama4-scout-17b-a16e`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "llama4-scout-17b-a16e"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
