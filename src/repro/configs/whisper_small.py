"""Config module for ``whisper-small`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "whisper-small"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
