"""Config module for ``zamba2-2.7b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "zamba2-2.7b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
