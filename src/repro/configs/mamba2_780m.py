"""Config module for ``mamba2-780m`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "mamba2-780m"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
