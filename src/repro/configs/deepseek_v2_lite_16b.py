"""Config module for ``deepseek-v2-lite-16b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "deepseek-v2-lite-16b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
