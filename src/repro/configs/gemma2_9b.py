"""Config module for ``gemma2-9b`` (see configs/registry.py for source)."""

from repro.configs.registry import get_config

ARCH = "gemma2-9b"
CONFIG = get_config(ARCH)
SMOKE_CONFIG = get_config(ARCH, smoke=True)
