"""Distributed serving: seq-sharded flash-decoding + serve/prefill steps.

Decode cache layout (distributed/sharding.cache_specs): batch over DP axes,
cache *sequence* over the 'model' axis — uniform across kv-head counts (the
assigned archs have kv in {1..32}, which can't all head-shard 16 ways).
Attention per step:
  1. every model rank computes unnormalized (acc, m, l) over its local
     cache chunk (kernels ref partials / Pallas kernel on TPU);
  2. ranks combine with a log-sum-exp psum (flash-decoding):
       m* = pmax(m);  l* = psum(l e^{m-m*});  o = psum(acc e^{m-m*}) / l*.
Cache-bandwidth (the decode bottleneck) is thus split tp-ways; q/o are the
only per-layer cross-rank tensors (tiny: B x Hq x hd).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx
from repro.kernels import ref as kref
from repro.models import transformer as tr
from repro.models.config import ModelConfig


def sharded_decode_attention(q, k, v, kv_len, *, window=None, softcap=None):
    """q: (B,Hq,D) k/v: (B,S,Hkv,D/Dv) seq-sharded over 'model'."""
    mesh = ctx.mesh()
    tp = ctx.model_axis_size()
    dp = ctx.dp_axes()
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    B = q.shape[0]
    if B % dp_total != 0:      # e.g. long_500k batch=1: replicate over DP
        dps = None
    else:
        dps = dp if len(dp) > 1 else dp[0]
    S = k.shape[1]
    s_loc = S // tp

    def local(q, k, v, kv_len):
        idx = jax.lax.axis_index("model")
        start = idx * s_loc
        local_len = jnp.clip(kv_len - start, 0, s_loc)
        acc, m, l = kref.decode_attention_partials(
            q, k, v, local_len, offset=start, global_len=kv_len,
            window=window, softcap=softcap)
        m_star = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_star)
        l_star = jax.lax.psum(l * w, "model")
        o = jax.lax.psum(acc * w[..., None], "model")
        return (o / jnp.maximum(l_star, 1e-30)[..., None]).astype(q.dtype)

    return ctx.shard_map(
        local, mesh=mesh,
        in_specs=(P(dps, None, None), P(dps, "model", None, None),
                  P(dps, "model", None, None), P(dps)),
        out_specs=P(dps, None, None), check_vma=False,
    )(q, k, v, kv_len)


def make_decode_step(cfg: ModelConfig, mesh=None):
    """jit'd serve step: (params, cache, tokens (B,1), pos (B,), context?)."""

    def step(params, cache, tokens, pos, context=None):
        return tr.decode_step(params, cache, tokens, pos, cfg,
                              context=context)

    if mesh is None:
        return jax.jit(step)

    def traced(params, cache, tokens, pos, context=None):
        with ctx.activate(mesh):
            return jax.jit(step, donate_argnums=(1,))(
                params, cache, tokens, pos, context)

    return traced


def make_prefill_step(cfg: ModelConfig, mesh=None):
    """jit'd prefill: (params, tokens (B,S), context?) -> logits."""

    def step(params, tokens, context=None):
        if cfg.encoder_stages is not None:
            context = tr.encode(params, context, cfg)
        return tr.forward(params, tokens, cfg, context=context)

    if mesh is None:
        return jax.jit(step)

    def traced(params, tokens, context=None):
        with ctx.activate(mesh):
            return jax.jit(step)(params, tokens, context)

    return traced
