"""SCOPe-managed checkpointing: every checkpoint shard is a data partition
whose (tier, codec) is chosen by OPTASSIGN with COMPREDICT-style predicted
compression stats — the paper's pipeline applied to the framework's own
storage.

* save(step, tree): leaves are chunked into shards; a 64 KiB sample of each
  shard is measured against the candidate codecs (the on-the-fly predictor —
  sampling IS the paper's query-derived-sample idea applied to tensor bytes,
  with byte-entropy features available from kernels/entropy_features);
  OPTASSIGN (greedy, Thm 3) then picks (tier, codec) per shard given the
  projected restore rate, which decays with checkpoint age exactly like the
  paper's recency access pattern (Fig 1b).
* Each save re-optimizes OLD checkpoints' placement (the paper's
  beginning-of-billing-period batch re-run): stale checkpoints migrate to
  cool/archive through store.change_tier, paying tier-change costs.
* Writes are async (background thread); the manifest commits LAST, so a
  crash mid-save can never yield a half checkpoint — restore_latest() only
  trusts manifests (fault tolerance / restart path).
* restore(..., mesh=...) re-shards onto any mesh (elastic scaling).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.costs import CostTable, Weights, cost_tensor, latency_feasible
from repro.core.optassign import greedy_assign
from repro.storage.codecs import available_schemes, codec_by_name, measure
from repro.storage.store import TieredStore

SHARD_BYTES = 4 << 20          # 4 MiB shards
SAMPLE_BYTES = 64 << 10
CANDIDATE_CODECS = available_schemes(("none", "zlib-1", "zstd-3", "lzma-1"))


@dataclasses.dataclass
class _ShardMeta:
    key: str
    leaf_path: str
    offset: int
    nbytes: int
    codec: str
    tier: int
    sha256: str


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _restore_rate(age_steps: int, horizon: int = 5) -> float:
    """Projected restores per period: newest checkpoints are the live
    restart targets; older ones are kept for rollback/analysis (recency
    decay, paper Fig 1b)."""
    return 4.0 * float(np.exp(-age_steps / max(horizon, 1)))


class CheckpointManager:
    def __init__(self, store: TieredStore, prefix: str = "ckpt",
                 table: Optional[CostTable] = None,
                 latency_sla_sec: float = 120.0,
                 tier_whitelist: Tuple[int, ...] = (0, 1, 2, 3),
                 keep: int = 8):
        self.store = store
        self.table = table or store.table
        self.prefix = prefix
        self.latency_sla = latency_sla_sec
        self.tiers = tier_whitelist
        self.keep = keep
        self._manifests: Dict[int, dict] = {}
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ save
    def _choose_assignments(self, blobs: List[bytes], rho: float):
        """(tier, codec) per shard via greedy OPTASSIGN over measured
        sample compression stats."""
        N = len(blobs)
        K = len(CANDIDATE_CODECS)
        R = np.ones((N, K))
        D = np.zeros((N, K))
        spans = np.array([len(b) / 1e9 for b in blobs])
        for i, b in enumerate(blobs):
            sample = b[:SAMPLE_BYTES]
            for k, name in enumerate(CANDIDATE_CODECS):
                if name == "none":
                    continue
                m = measure(codec_by_name(name), sample)
                R[i, k] = max(m.ratio, 1.0)
                D[i, k] = m.decompress_sec_per_gb * spans[i]
        cost = cost_tensor(spans, np.full(N, rho), np.full(N, -1), R, D,
                           self.table, Weights(), months=1.0)
        feas = latency_feasible(D, np.full(N, self.latency_sla), self.table)
        allowed = np.zeros(self.table.num_tiers, bool)
        allowed[list(self.tiers)] = True
        feas &= allowed[None, :, None]
        a = greedy_assign(cost, feas)
        return a.tier, [CANDIDATE_CODECS[k] for k in a.scheme]

    def save(self, step: int, tree, blocking: bool = False) -> None:
        leaves = _leaf_paths(tree)
        blobs: List[Tuple[str, int, bytes]] = []
        for path, leaf in leaves:
            raw = np.asarray(leaf).tobytes()
            for off in range(0, max(len(raw), 1), SHARD_BYTES):
                blobs.append((path, off, raw[off:off + SHARD_BYTES]))
        tiers, codecs = self._choose_assignments([b for _, _, b in blobs],
                                                 rho=_restore_rate(0))
        metas: List[_ShardMeta] = []
        specs = [(p, list(np.asarray(l).shape), str(np.asarray(l).dtype))
                 for p, l in leaves]

        def _write():
            for i, (path, off, blob) in enumerate(blobs):
                key = f"{self.prefix}/{step}/{i:05d}"
                self.store.put(key, blob, tier=int(tiers[i]),
                               codec=codecs[i])
                metas.append(_ShardMeta(key, path, off, len(blob),
                                        codecs[i], int(tiers[i]),
                                        hashlib.sha256(blob).hexdigest()))
            manifest = {
                "step": step,
                "leaves": specs,
                "shards": [dataclasses.asdict(m) for m in metas],
                "written": time.time(),
            }
            # manifest commits LAST -> crash mid-save leaves no valid ckpt
            self.store.put(f"{self.prefix}/{step}/MANIFEST",
                           json.dumps(manifest).encode(), tier=0)
            with self._lock:
                self._manifests[step] = manifest
            self._lifecycle(step)

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------- lifecycle re-optimize
    def _lifecycle(self, current_step: int) -> None:
        """Re-run OPTASSIGN over ALL retained checkpoints with age-decayed
        restore projections; migrate shards whose optimal tier changed."""
        with self._lock:
            steps = sorted(self._manifests)
        # retention
        for s in steps[:-self.keep] if len(steps) > self.keep else []:
            self.delete(s)
            steps.remove(s)
        for age, s in enumerate(reversed(steps)):
            man = self._manifests[s]
            rho = _restore_rate(age)
            spans = np.array([m["nbytes"] / 1e9 for m in man["shards"]])
            stored_tiers = np.array([self.store.tier_of(m["key"])
                                     for m in man["shards"]])
            N = len(spans)
            R = np.ones((N, 1))
            D = np.zeros((N, 1))
            cost = cost_tensor(spans, np.full(N, rho), stored_tiers, R, D,
                               self.table, Weights(), months=1.0)
            feas = latency_feasible(D, np.full(N, self.latency_sla),
                                    self.table)
            allowed = np.zeros(self.table.num_tiers, bool)
            allowed[list(self.tiers)] = True
            feas &= allowed[None, :, None]
            a = greedy_assign(cost, feas)
            for m, t in zip(man["shards"], a.tier):
                if int(t) != self.store.tier_of(m["key"]):
                    self.store.change_tier(m["key"], int(t))
                    m["tier"] = int(t)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        with self._lock:
            cached = sorted(self._manifests)
        if cached:
            return cached[-1]
        # cold start: scan the store for manifests
        steps = []
        for key in self.store.keys():
            if key.startswith(f"{self.prefix}/") and key.endswith("MANIFEST"):
                steps.append(int(key.split("/")[1]))
        return max(steps) if steps else None

    def restore(self, treedef_like, step: Optional[int] = None,
                mesh=None, shardings=None):
        """Rebuild the pytree (and optionally place it on ``mesh`` with
        ``shardings`` — elastic restore onto any topology)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        man = self._manifests.get(step)
        if man is None:
            man = json.loads(
                self.store.get(f"{self.prefix}/{step}/MANIFEST").decode())
            with self._lock:
                self._manifests[step] = man
        buffers: Dict[str, bytearray] = {}
        sizes: Dict[str, Tuple[list, str]] = {
            p: (shape, dt) for p, shape, dt in man["leaves"]}
        for m in man["shards"]:
            blob = self.store.get(m["key"])
            if hashlib.sha256(blob).hexdigest() != m["sha256"]:
                raise IOError(f"corrupt shard {m['key']}")
            buffers.setdefault(m["leaf_path"], bytearray()).extend(blob)
        leaves_by_path = {}
        for path, (shape, dt) in sizes.items():
            arr = np.frombuffer(bytes(buffers[path]), dtype=dt).reshape(shape)
            leaves_by_path[path] = arr
        flat = jax.tree_util.tree_flatten_with_path(treedef_like)[0]
        treedef = jax.tree_util.tree_structure(treedef_like)
        out = []
        for path, ref in flat:
            arr = leaves_by_path[jax.tree_util.keystr(path)]
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if mesh is not None and shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step

    def delete(self, step: int) -> None:
        man = self._manifests.pop(step, None)
        if man is None:
            return
        for m in man["shards"]:
            self.store.delete(m["key"])
        self.store.delete(f"{self.prefix}/{step}/MANIFEST")
