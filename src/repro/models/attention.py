"""Attention blocks: GQA (qk-norm / bias / softcap / sliding window),
MLA (DeepSeek compressed KV), and cross-attention.

Parameter-name conventions consumed by distributed/sharding.py:
  wq/wk/wv/wo (+bq/bk/bv), q_norm/k_norm, MLA: w_dkv/w_uk/w_uv/w_qr, ...
Head counts are padded to a multiple of ``tp`` (Megatron practice) so the
model axis always divides; kv heads are replicated when kv < tp.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, dtype_of, rms_norm


def pad_heads(n: int, tp: int) -> int:
    return ((n + tp - 1) // tp) * tp if tp > 1 else n


def head_counts(cfg: ModelConfig, tp: int) -> Tuple[int, int]:
    """(padded q heads, padded kv heads). MHA pads kv with q; GQA keeps kv."""
    hq = pad_heads(cfg.n_heads, tp)
    if cfg.n_kv_heads == cfg.n_heads:
        return hq, hq
    assert hq % cfg.n_kv_heads == 0, (cfg.name, hq, cfg.n_kv_heads)
    return hq, cfg.n_kv_heads


# ------------------------------------------------------------------ GQA init
def gqa_init(key, cfg: ModelConfig, tp: int = 1, d_in: Optional[int] = None):
    dt = dtype_of(cfg.dtype)
    d = d_in or cfg.d_model
    hq, hkv = head_counts(cfg, tp)
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dt),
        "wk": dense_init(ks[1], d, hkv * hd, dt),
        "wv": dense_init(ks[2], d, hkv * hd, dt),
        "wo": dense_init(ks[3], hq * hd, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, -1, hd)
    k = k.reshape(B, S, -1, hd)
    v = v.reshape(B, S, -1, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, x, cfg: ModelConfig, *, positions, causal=True,
              window=None) -> jnp.ndarray:
    """Full-sequence self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap)
    return o.reshape(B, S, -1) @ p["wo"]


def gqa_decode(p, x, cfg: ModelConfig, *, cache_k, cache_v, pos,
               window=None):
    """Single-token decode. x: (B, 1, d); cache_*: (B, S_max, Hkv, hd);
    pos: (B,) current length (token goes at index pos). Returns
    (y: (B,1,d), new_k, new_v).

    Sliding-window layers use RING-BUFFER caches sized to the window
    (init_cache allocates min(max_seq, window) slots): writes go to
    ``pos % cache_len`` and the whole (small) buffer is attended — softmax
    is permutation-invariant over cached entries and keys are stored
    post-RoPE with absolute positions, so rotation is exact. This cuts both
    cache memory and per-step cache reads by S/window (8x for gemma2 at
    32k) with no cross-shard gather (EXPERIMENTS §Perf iteration 3: a
    windowed dynamic-slice of the seq-sharded cache was tried first and
    REGRESSED — SPMD replicates the cache to serve data-dependent slices).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    bidx = jnp.arange(B)
    cache_len = cache_k.shape[1]
    slot = pos % cache_len                      # ring write (no-op when full)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    kv_len = jnp.minimum(pos + 1, cache_len)
    o = ops.decode_attention(q[:, 0], cache_k, cache_v, kv_len,
                             softcap=cfg.attn_softcap)
    y = o.reshape(B, 1, -1) @ p["wo"]
    return y, cache_k, cache_v


# ------------------------------------------------------------ cross-attention
def cross_init(key, cfg: ModelConfig, tp: int = 1, ctx_dim: Optional[int] = None):
    dt = dtype_of(cfg.dtype)
    hq, hkv = head_counts(cfg, tp)
    hd = cfg.head_dim
    dctx = ctx_dim or cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, hq * hd, dt),
        "wk": dense_init(ks[1], dctx, hkv * hd, dt),
        "wv": dense_init(ks[2], dctx, hkv * hd, dt),
        "wo": dense_init(ks[3], hq * hd, cfg.d_model, dt),
    }


def cross_apply(p, x, context, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B,S,d); context: (B,Sc,dctx). Non-causal attention into context."""
    B, S, _ = x.shape
    Sc = context.shape[1]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, -1, hd)
    k = (context @ p["wk"]).reshape(B, Sc, -1, hd)
    v = (context @ p["wv"]).reshape(B, Sc, -1, hd)
    o = ops.flash_attention(q, k, v, causal=False, softcap=cfg.attn_softcap)
    return o.reshape(B, S, -1) @ p["wo"]


# ----------------------------------------------------------------------- MLA
def mla_init(key, cfg: ModelConfig, tp: int = 1):
    """DeepSeek-V2(-lite) multi-head latent attention. No q-LoRA (lite)."""
    dt = dtype_of(cfg.dtype)
    hq = pad_heads(cfg.n_heads, tp)
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], cfg.d_model,
                         hq * (cfg.qk_nope_dim + cfg.qk_rope_dim), dt),
        "w_dkv": dense_init(ks[1], cfg.d_model, r + cfg.qk_rope_dim, dt),
        "kv_norm": jnp.ones((r,), dt),
        "w_uk": dense_init(ks[2], r, hq * cfg.qk_nope_dim, dt),
        "w_uv": dense_init(ks[3], r, hq * cfg.v_head_dim, dt),
        "wo": dense_init(ks[4], hq * cfg.v_head_dim, cfg.d_model, dt),
    }


def _mla_q(p, x, cfg, positions, hq):
    B, S, _ = x.shape
    dq = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ p["wq"]).reshape(B, S, hq, dq)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p, x, cfg: ModelConfig, *, positions) -> jnp.ndarray:
    """Training/prefill path: expand the latent and run standard attention."""
    B, S, _ = x.shape
    r = cfg.kv_lora_rank
    hq = p["wo"].shape[0] // cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions, hq)
    dkv = x @ p["w_dkv"]
    c_kv = rms_norm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, hq, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, hq, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, hq, cfg.qk_rope_dim))], -1)
    o = ops.flash_attention(q, k, v, causal=True)
    return o.reshape(B, S, -1) @ p["wo"]


def mla_decode(p, x, cfg: ModelConfig, *, cache_ckv, pos):
    """Absorbed decode: the cache holds only (c_kv || k_rope) per token
    (r + rope dims ~ 576 for v2) — MLA's compressed-KV benefit. Attention
    becomes MQA with one latent 'kv head':
      score_h = (q_nope_h @ W_uk_h) . c_kv + q_rope_h . k_rope
      out_h   = (sum_t p_t c_kv_t) @ W_uv_h
    """
    B = x.shape[0]
    r = cfg.kv_lora_rank
    hq = p["wo"].shape[0] // cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, pos[:, None], hq)
    dkv = x @ p["w_dkv"]                                     # (B,1,r+rope)
    c_kv = rms_norm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, r:], pos[:, None], cfg.rope_theta)
    entry = jnp.concatenate([c_kv, k_rope[:, :, 0]], -1)     # (B,1,r+rope)
    bidx = jnp.arange(B)
    cache_ckv = cache_ckv.at[bidx, pos].set(entry[:, 0].astype(cache_ckv.dtype))
    # absorb W_uk into q: (B,1,hq,nope) @ (r,hq*nope) -> (B,hq,r)
    w_uk = p["w_uk"].reshape(r, hq, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    q_full = jnp.concatenate([q_lat, q_rope[:, 0]], -1)      # (B,hq,r+rope)
    kv = cache_ckv[:, :, None, :]                            # (B,S,1,r+rope)
    ctx = ops.decode_attention(q_full, kv, kv[..., :r], pos + 1)  # (B,hq,r)
    w_uv = p["w_uv"].reshape(r, hq, cfg.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv)
    y = o.reshape(B, 1, -1) @ p["wo"]
    return y, cache_ckv
