"""Model configuration schema for the architecture zoo.

A model is a sequence of **stages**; each stage is a repeating **unit** of
layer kinds (scanned over ``repeats`` with stacked parameters, so HLO size is
independent of depth). Layer kinds:

  'attn'         self-attention (GQA; flags select qk_norm/bias/softcap/window)
  'attn_local'   self-attention with sliding window (gemma2 local layers)
  'attn_shared'  weight-tied shared attention block (zamba2)
  'cross'        cross-attention to an encoder/vision context
  'mlp'          dense SwiGLU/GeLU MLP
  'moe'          mixture-of-experts MLP
  'mamba'        Mamba2 SSD mixer

A 'transformer block' in a unit is expressed as ['attn', 'mlp'] etc.; fused
pre-norms are part of each layer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Stage:
    unit: Tuple[str, ...]     # layer kinds executed per repeat
    repeats: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    stages: Tuple[Stage, ...]
    # attention options
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None      # gemma2: 50.0
    final_softcap: Optional[float] = None     # gemma2: 30.0
    sliding_window: Optional[int] = None      # used by 'attn_local'
    # MLA (deepseek) — if kv_lora_rank is set, attention layers use MLA
    kv_lora_rank: Optional[int] = None
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_block_tokens: int = 4096   # dispatch in token blocks (EXPERIMENTS §Perf it.2)
    # Mamba2
    ssm_state: int = 0
    mamba_headdim: int = 64
    mamba_expand: int = 2
    conv_width: int = 4
    # encoder-decoder (whisper): encoder stages; None = decoder-only
    encoder_stages: Optional[Tuple[Stage, ...]] = None
    encoder_context: int = 1500               # cross-attn source length
    # vlm: cross-attn context comes from input_specs (patch embeddings)
    cross_context: int = 0                    # >0 => model takes extra input
    # embedding / head
    tie_embeddings: bool = True
    mlp_act: str = "swiglu"                   # 'swiglu' | 'gelu'
    norm_eps: float = 1e-6
    use_post_norm: bool = False               # gemma2 sandwich norms
    embed_scale: bool = False                 # gemma2 sqrt(d_model) embed scale
    # numerics
    dtype: str = "bfloat16"
    # bookkeeping
    family: str = "dense"                     # dense|moe|ssm|hybrid|vlm|audio
    sub_quadratic: bool = False               # may run long_500k

    @property
    def n_layers(self) -> int:
        return sum(len(s.unit) * s.repeats for s in self.stages)

    @property
    def d_inner(self) -> int:                 # mamba2 inner width
        return self.mamba_expand * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // self.mamba_headdim

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    stages = tuple(Stage(s.unit, min(s.repeats, 2)) for s in cfg.stages)
    enc = None
    if cfg.encoder_stages is not None:
        enc = tuple(Stage(s.unit, min(s.repeats, 2)) for s in cfg.encoder_stages)
    return cfg.scaled(
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        stages=stages,
        encoder_stages=enc,
        encoder_context=32,
        cross_context=16 if cfg.cross_context else 0,
        n_experts=min(cfg.n_experts, 4),
        expert_d_ff=64 if cfg.expert_d_ff else 0,
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        kv_lora_rank=64 if cfg.kv_lora_rank else None,
        qk_rope_dim=16 if cfg.kv_lora_rank else cfg.qk_rope_dim,
        qk_nope_dim=32 if cfg.kv_lora_rank else cfg.qk_nope_dim,
        v_head_dim=32 if cfg.kv_lora_rank else cfg.v_head_dim,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        mamba_headdim=16 if cfg.ssm_state else cfg.mamba_headdim,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        dtype="float32",
    )
