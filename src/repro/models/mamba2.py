"""Mamba2 (SSD) mixer block — arXiv:2405.21060, TPU-adapted.

The selective-state-space layer with state-space duality: inputs project to
(z, x, B, C, dt); (x | B,C) pass through short causal depthwise convs; the
SSD chunked scan (kernels/ops.ssd_scan) computes the sequence mix; a gated
RMSNorm and output projection close the block.

TP adaptation (DESIGN.md §8): the reference implementation fuses one
in_proj; we block-partition it into in_z/in_x (head-channel-sharded over the
model axis), in_bc and in_dt (replicated — tiny) so tensor parallelism never
splits a logical segment. Same math, sharding-clean. The conv is likewise
split into the x part (channel-sharded) and the B/C part (replicated).

Decode carries (conv_x, conv_bc, ssm_state) — O(1) in context length, which
is why mamba2/zamba2 are the long_500k architectures.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, rms_norm

N_GROUPS = 1  # B/C groups (mamba2 default)


def mamba_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.mamba_heads
    bc = 2 * N_GROUPS * n
    ks = jax.random.split(key, 6)
    dt_init = np.log(np.expm1(np.linspace(1e-3, 0.1, h)))  # softplus^-1
    return {
        "in_z": dense_init(ks[0], d, di, dt),
        "in_x": dense_init(ks[1], d, di, dt),
        "in_bc": dense_init(ks[2], d, bc, dt),
        "in_dt": dense_init(ks[3], d, h, dt),
        "conv_x_w": (jax.random.normal(ks[4], (cfg.conv_width, di),
                                       jnp.float32) / cfg.conv_width).astype(dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_w": (jax.random.normal(ks[5], (cfg.conv_width, bc),
                                        jnp.float32) / cfg.conv_width).astype(dt),
        "conv_bc_b": jnp.zeros((bc,), dt),
        "A_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, h)), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(dt_init, jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[0], di, d, dt),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv over seq. u: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + b[None, None, :])


def mamba_apply(p, x, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence SSD. x: (B, S, d) -> (B, S, d)."""
    Bsz, S, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
    hd = cfg.mamba_headdim
    z = x @ p["in_z"]
    xi = _causal_conv(x @ p["in_x"], p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(x @ p["in_bc"], p["conv_bc_w"], p["conv_bc_b"])
    dt_raw = x @ p["in_dt"]
    xs = xi.reshape(Bsz, S, h, hd)
    Bm = bc[..., :N_GROUPS * n].reshape(Bsz, S, N_GROUPS, n)
    Cm = bc[..., N_GROUPS * n:].reshape(Bsz, S, N_GROUPS, n)
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, _ = ops.ssd_scan(xs, dt_v, A, Bm, Cm, p["D"])
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> Tuple:
    bc = 2 * N_GROUPS * cfg.ssm_state
    conv_x = jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype)
    conv_bc = jnp.zeros((batch, cfg.conv_width - 1, bc), dtype)
    ssm_state = jnp.zeros((batch, cfg.mamba_heads, cfg.mamba_headdim,
                           cfg.ssm_state), jnp.float32)
    return conv_x, conv_bc, ssm_state


def _conv_step(state, u_t, w, b):
    """state: (B,W-1,C); u_t: (B,C). Returns (out (B,C), new_state)."""
    window = jnp.concatenate([state, u_t[:, None]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(u_t.dtype), \
        window[:, 1:]


def mamba_decode(p, x, cfg: ModelConfig, *, conv_x, conv_bc, ssm_state):
    """Single-token step. x: (B,1,d). Returns (y, conv_x, conv_bc, ssm)."""
    Bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
    hd = cfg.mamba_headdim
    z = x @ p["in_z"]
    xi_t, conv_x = _conv_step(conv_x, (x @ p["in_x"])[:, 0],
                              p["conv_x_w"], p["conv_x_b"])
    bc_t, conv_bc = _conv_step(conv_bc, (x @ p["in_bc"])[:, 0],
                               p["conv_bc_w"], p["conv_bc_b"])
    dt_raw = (x @ p["in_dt"])[:, 0]
    xs = xi_t.reshape(Bsz, h, hd)
    Bm = bc_t[:, :N_GROUPS * n].reshape(Bsz, N_GROUPS, n)
    Cm = bc_t[:, N_GROUPS * n:].reshape(Bsz, N_GROUPS, n)
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y_t, ssm_state = ops.ssd_step(ssm_state, xs, dt_v, A, Bm, Cm, p["D"])
    y = y_t.reshape(Bsz, 1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], conv_x, conv_bc, ssm_state
