"""Mixture-of-Experts MLP with capacity-based dense dispatch (Shazeer-style).

Expert weights are stacked on a leading expert axis — sharded over the model
axis for expert parallelism (16e -> 1 expert/rank, 64e -> 4/rank on tp=16).
The dispatch/combine einsums surface as all-to-all in the SPMD HLO, which is
what the roofline's collective term measures for MoE cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, mlp_init, mlp_apply


def moe_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg.dtype)
    E, dff = cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "experts_gate": (jax.random.normal(ks[1], (E, d, dff), jnp.float32)
                         * scale).astype(dt),
        "experts_up": (jax.random.normal(ks[2], (E, d, dff), jnp.float32)
                       * scale).astype(dt),
        "experts_down": (jax.random.normal(ks[3], (E, dff, d), jnp.float32)
                         / np.sqrt(dff)).astype(dt),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = mlp_init(ks[4], d, cfg.n_shared_experts * dff,
                               cfg.mlp_act, dt)
    return p


def _moe_block(p, xt, cfg: ModelConfig) -> jnp.ndarray:
    """Gather/scatter capacity dispatch for ONE token block. xt: (T_b, d).

    One-hot einsum dispatch (Mesh-TF style) pays 2*T*E*C*d dense flops that
    XLA cannot see through — 20x the expert matmuls themselves at 4k blocks
    (EXPERIMENTS §Perf iteration 2c). Gathers/scatter-adds move the same
    data at O(T*k*d) cost; take's autodiff transpose is a scatter-add, so
    the backward pass is sparse too."""
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    topv, topi = jax.lax.top_k(logits, k)                    # (T, k)
    gates = jax.nn.softmax(topv, axis=-1)                    # normalize over k
    capacity = int(np.ceil(T * k / E * cfg.capacity_factor))
    capacity = max(min(capacity, T), 1)

    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)      # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                    # (T*k, E)
    pos = (pos.reshape(T, k, E) * onehot).sum(-1)            # (T, k) slot ids
    kept = pos < capacity                                    # (T, k)

    # scatter token ids into (E, C) expert buffers (slots unique by constr.)
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    e_idx = jnp.where(kept, topi, E)                         # overflow -> bin E
    c_idx = jnp.clip(pos, 0, capacity - 1).astype(jnp.int32)
    slot_tok = jnp.zeros((E + 1, capacity), jnp.int32).at[
        e_idx.reshape(-1), c_idx.reshape(-1)].set(tok_ids.reshape(-1))
    slot_valid = jnp.zeros((E + 1, capacity), bool).at[
        e_idx.reshape(-1), c_idx.reshape(-1)].set(True)
    xe = jnp.take(xt, slot_tok[:E].reshape(-1), axis=0
                  ).reshape(E, capacity, d)
    xe = xe * slot_valid[:E, :, None].astype(xe.dtype)        # (E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["experts_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["experts_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["experts_down"])     # (E, C, d)

    # gather back: y_t = sum_k gate * ye[e_tk, c_tk]
    flat_idx = jnp.clip(topi, 0, E - 1) * capacity + c_idx    # (T, k)
    y_k = jnp.take(ye.reshape(E * capacity, d), flat_idx.reshape(-1), axis=0
                   ).reshape(T, k, d)
    w = (gates * kept).astype(y_k.dtype)
    return jnp.einsum("tk,tkd->td", w, y_k).astype(xt.dtype)


def moe_apply(p, x, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). Top-k capacity routing, dispatched in
    token blocks: the (T, E, C) one-hot dispatch tensors are
    O(block^2 * k * cf / E) instead of O(T^2 ...) — at train_4k scale the
    unblocked form needs TB-scale buffers (EXPERIMENTS §Perf iteration 2).

    Blocks are DP-ALIGNED: the scanned leading dim is unsharded and each
    iteration processes one ``block`` of tokens per data shard (middle dim
    carries the batch sharding). Scanning a sharded dim instead triggers
    XLA 'involuntary full rematerialization' (replicates every block —
    EXPERIMENTS §Perf iteration 2b)."""
    from repro.distributed import ctx as dctx
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    mesh = dctx.mesh()
    dp = dctx.dp_axes() or ()
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    blk = cfg.moe_block_tokens
    big = blk * dp_total
    if T % big == 0 and T > blk:
        nb = T // big
        # (nb, dp, blk, d): scan dim unsharded, group dim carries the DP
        # sharding, routing/capacity are PER GROUP (local routing — no
        # cross-shard cumsum, no involuntary resharding: §Perf iter 2b/2c)
        xs = xt.reshape(nb, dp_total, blk, d)
        if mesh is not None and dp:
            from jax.sharding import PartitionSpec as P
            dps = dp if len(dp) > 1 else dp[0]
            xs = jax.lax.with_sharding_constraint(xs, P(None, dps, None, None))
        blk_fn = jax.vmap(_moe_block, in_axes=(None, 0, None))
        if nb > 1:
            y = jax.lax.map(lambda xb: blk_fn(p, xb, cfg), xs)
        else:
            y = blk_fn(p, xs[0], cfg)
    else:
        y = _moe_block(p, xt, cfg)
    y = y.reshape(-1, d)[:T]
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x.reshape(T, d), cfg.mlp_act)
    return y.reshape(B, S, d).astype(x.dtype)


def moe_aux_loss(p, x, cfg: ModelConfig) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(logits, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    return cfg.n_experts * jnp.sum(frac * probs.mean(0))
