"""Stage-based model assembly: init / forward / prefill / decode / loss.

A model is a tuple of stages; each stage scans a repeating unit of blocks
with parameters stacked on the leading (repeats) axis, so HLO size — and
dry-run compile time — is O(#stages), not O(#layers). Weight-tied blocks
('shared_attn', zamba2) keep their parameters at the top level and are
closed over inside the scan body.

Block kinds (see models/config.py): attn, attn_local, shared_attn, cross,
decoder, mla_dense, mla_moe, moe, mamba.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import mamba2, moe as moe_mod
from repro.models.config import ModelConfig, Stage
from repro.models.layers import (dense_init, dtype_of, embed_init, mlp_apply,
                                 mlp_init, rms_norm, softcap)


def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + 127) // 128) * 128


# ------------------------------------------------------------------- blocks
def block_init(key, kind: str, cfg: ModelConfig, tp: int):
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    nw = lambda: (jnp.zeros if cfg.use_post_norm else jnp.ones)((cfg.d_model,), dt)
    if kind in ("attn", "attn_local"):
        p = {"ln1": nw(), "attn": attn.gqa_init(ks[0], cfg, tp),
             "ln2": nw(), "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                          cfg.mlp_act, dt)}
        if cfg.use_post_norm:
            p["post_ln1"] = nw()
            p["post_ln2"] = nw()
        return p
    if kind == "moe":
        return {"ln1": nw(), "attn": attn.gqa_init(ks[0], cfg, tp),
                "ln2": nw(), "moe": moe_mod.moe_init(ks[1], cfg)}
    if kind == "mla_dense":
        return {"ln1": nw(), "attn": attn.mla_init(ks[0], cfg, tp),
                "ln2": nw(), "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                             cfg.mlp_act, dt)}
    if kind == "mla_moe":
        return {"ln1": nw(), "attn": attn.mla_init(ks[0], cfg, tp),
                "ln2": nw(), "moe": moe_mod.moe_init(ks[1], cfg)}
    if kind == "cross":
        return {"ln1": nw(), "cross": attn.cross_init(ks[0], cfg, tp),
                "ln2": nw(), "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                             cfg.mlp_act, dt)}
    if kind == "decoder":
        return {"ln1": nw(), "attn": attn.gqa_init(ks[0], cfg, tp),
                "lnc": nw(), "cross": attn.cross_init(ks[1], cfg, tp),
                "ln2": nw(), "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                             cfg.mlp_act, dt)}
    if kind == "mamba":
        return {"ln1": nw(), "mamba": mamba2.mamba_init(ks[0], cfg)}
    if kind == "shared_attn":
        return {}                      # weights live at params['shared']
    raise ValueError(kind)


def _pre(x, w, cfg):
    return rms_norm(x, w, cfg.norm_eps, plus_one=cfg.use_post_norm)


def block_apply(p, kind: str, x, cfg: ModelConfig, *, positions,
                context=None, shared=None, causal=True):
    post = cfg.use_post_norm
    if kind == "shared_attn":
        p, kind = shared, "attn"
    if kind in ("attn", "attn_local", "moe", "mla_dense", "mla_moe"):
        window = cfg.sliding_window if kind == "attn_local" else None
        if kind.startswith("mla"):
            a = attn.mla_apply(p["attn"], _pre(x, p["ln1"], cfg), cfg,
                               positions=positions)
        else:
            a = attn.gqa_apply(p["attn"], _pre(x, p["ln1"], cfg), cfg,
                               positions=positions, causal=causal,
                               window=window)
        if post and "post_ln1" in p:
            a = _pre(a, p["post_ln1"], cfg)
        x = x + a
        h = _pre(x, p["ln2"], cfg)
        if kind in ("moe", "mla_moe"):
            m = moe_mod.moe_apply(p["moe"], h, cfg)
        else:
            m = mlp_apply(p["mlp"], h, cfg.mlp_act)
        if post and "post_ln2" in p:
            m = _pre(m, p["post_ln2"], cfg)
        return x + m
    if kind == "cross":
        x = x + attn.cross_apply(p["cross"], _pre(x, p["ln1"], cfg),
                                 context, cfg)
        return x + mlp_apply(p["mlp"], _pre(x, p["ln2"], cfg), cfg.mlp_act)
    if kind == "decoder":
        x = x + attn.gqa_apply(p["attn"], _pre(x, p["ln1"], cfg), cfg,
                               positions=positions, causal=True)
        x = x + attn.cross_apply(p["cross"], _pre(x, p["lnc"], cfg),
                                 context, cfg)
        return x + mlp_apply(p["mlp"], _pre(x, p["ln2"], cfg), cfg.mlp_act)
    if kind == "mamba":
        return x + mamba2.mamba_apply(p["mamba"], _pre(x, p["ln1"], cfg), cfg)
    raise ValueError(kind)


# ------------------------------------------------------------------- stages
def stage_init(key, stage: Stage, cfg: ModelConfig, tp: int):
    unit_params = []
    for j, kind in enumerate(stage.unit):
        if kind == "shared_attn":
            unit_params.append({})
            continue
        keys = jax.random.split(jax.random.fold_in(key, j), stage.repeats)
        stacked = jax.vmap(lambda k: block_init(k, kind, cfg, tp))(keys)
        unit_params.append(stacked)
    return tuple(unit_params)


def stage_apply(sp, stage: Stage, x, cfg: ModelConfig, *, positions,
                context=None, shared=None, causal=True, remat=False):
    from repro.distributed import ctx as dctx

    def body(h, xs):
        for j, kind in enumerate(stage.unit):
            h = block_apply(xs[j], kind, h, cfg, positions=positions,
                            context=context, shared=shared, causal=causal)
        # Megatron-SP: residual carry (and remat-saved activations) are
        # sequence-sharded over the model axis between blocks (no-op off-mesh)
        return dctx.constrain_sp(h), None

    if remat:
        # per-block remat: the layer scan saves ONLY the carried residual;
        # attention probabilities / MLP activations are recomputed in the
        # backward pass (EXPERIMENTS.md §Perf iteration 1 — without this the
        # scan AD stacks (L, chunks, B, S, H, K) attention probs: TB/device)
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, sp)
    return x


# -------------------------------------------------------------- model init
def init_params(key, cfg: ModelConfig, tp: int = 1) -> Dict[str, Any]:
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 8)
    V = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], V, cfg.d_model, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "stages": tuple(stage_init(jax.random.fold_in(ks[1], i), s, cfg, tp)
                        for i, s in enumerate(cfg.stages)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, V, dt)
    if any("shared_attn" in s.unit for s in cfg.stages):
        params["shared"] = block_init(ks[3], "attn", cfg, tp)
    if cfg.encoder_stages is not None:
        params["encoder"] = {
            "stages": tuple(stage_init(jax.random.fold_in(ks[4], i), s, cfg, tp)
                            for i, s in enumerate(cfg.encoder_stages)),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
    return params


# ----------------------------------------------------------------- forward
def encode(params, frames, cfg: ModelConfig, *, remat=False):
    """Encoder over precomputed frame/patch embeddings (stubbed frontend)."""
    x = frames.astype(dtype_of(cfg.dtype))
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    for sp, s in zip(params["encoder"]["stages"], cfg.encoder_stages):
        x = stage_apply(sp, s, x, cfg, positions=pos, causal=False,
                        remat=remat)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, *, context=None,
            positions=None, remat=False) -> jnp.ndarray:
    """tokens: (B, S) -> logits (B, S, padded_vocab).

    ``context`` feeds cross-attention ('cross'/'decoder' blocks): encoder
    output (audio), or patch embeddings (vlm) straight from input_specs.
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
    for sp, s in zip(params["stages"], cfg.stages):
        x = stage_apply(sp, s, x, cfg, positions=positions, context=context,
                        shared=params.get("shared"), remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def loss_fn(params, batch, cfg: ModelConfig, *, aux_weight=0.01,
            remat=False):
    """batch: {'tokens': (B,S), 'labels': (B,S), 'context'?: (B,Sc,d)}."""
    logits = forward(params, batch["tokens"], cfg,
                     context=batch.get("context"), remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ------------------------------------------------------------------- decode
@dataclasses.dataclass
class CacheSpec:
    max_seq: int
    batch: int
    dtype: Any


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None, tp: int = 1) -> Tuple:
    """Cache pytree mirroring stage structure. Per unit element:
      attn-like -> (k, v): (repeats, B, S, hkv, hd)
      mla       -> ckv:    (repeats, B, S, r + rope)
      mamba     -> (conv_state, ssm_state) stacked on repeats
      decoder   -> (k, v) self-cache (cross k/v recomputed from context)
      cross     -> None
    """
    dt = dtype or dtype_of(cfg.dtype)
    has_attn = any(k != "mamba" for st in cfg.stages for k in st.unit)
    hkv = attn.head_counts(cfg, tp)[1] if has_attn else 0
    caches = []
    for s in cfg.stages:
        unit_caches = []
        for kind in s.unit:
            if kind in ("attn", "attn_local", "moe", "decoder", "shared_attn"):
                length = max_seq
                if kind == "attn_local" and cfg.sliding_window:
                    length = min(max_seq, cfg.sliding_window)  # ring buffer
                shape = (s.repeats, batch, length, hkv, cfg.head_dim)
                unit_caches.append((jnp.zeros(shape, dt),
                                    jnp.zeros(shape, dt)))
            elif kind in ("mla_dense", "mla_moe"):
                shape = (s.repeats, batch, max_seq,
                         cfg.kv_lora_rank + cfg.qk_rope_dim)
                unit_caches.append(jnp.zeros(shape, dt))
            elif kind == "mamba":
                cx, cbc, ssm = mamba2.mamba_cache_init(cfg, batch, dt)
                unit_caches.append(tuple(
                    jnp.zeros((s.repeats,) + c.shape, c.dtype)
                    for c in (cx, cbc, ssm)))
            else:  # cross
                unit_caches.append(None)
        caches.append(tuple(unit_caches))
    return tuple(caches)


def _block_decode(p, kind, x, cache, cfg, *, pos, context, shared):
    if kind == "shared_attn":
        p, kind = shared, "attn"
    if kind in ("attn", "attn_local", "moe", "decoder"):
        window = cfg.sliding_window if kind == "attn_local" else None
        ck, cv = cache
        a, ck, cv = attn.gqa_decode(p["attn"], _pre(x, p["ln1"], cfg), cfg,
                                    cache_k=ck, cache_v=cv, pos=pos,
                                    window=window)
        if cfg.use_post_norm and "post_ln1" in p:
            a = _pre(a, p["post_ln1"], cfg)
        x = x + a
        if kind == "decoder":
            x = x + attn.cross_apply(p["cross"], _pre(x, p["lnc"], cfg),
                                     context, cfg)
        h = _pre(x, p["ln2"], cfg)
        m = moe_mod.moe_apply(p["moe"], h, cfg) if kind == "moe" else \
            mlp_apply(p["mlp"], h, cfg.mlp_act)
        if cfg.use_post_norm and "post_ln2" in p:
            m = _pre(m, p["post_ln2"], cfg)
        return x + m, (ck, cv)
    if kind in ("mla_dense", "mla_moe"):
        a, ckv = attn.mla_decode(p["attn"], _pre(x, p["ln1"], cfg), cfg,
                                 cache_ckv=cache, pos=pos)
        x = x + a
        h = _pre(x, p["ln2"], cfg)
        m = moe_mod.moe_apply(p["moe"], h, cfg) if kind == "mla_moe" else \
            mlp_apply(p["mlp"], h, cfg.mlp_act)
        return x + m, ckv
    if kind == "cross":
        x = x + attn.cross_apply(p["cross"], _pre(x, p["ln1"], cfg),
                                 context, cfg)
        return x + mlp_apply(p["mlp"], _pre(x, p["ln2"], cfg),
                             cfg.mlp_act), None
    if kind == "mamba":
        cx, cbc, ssm = cache
        y, cx, cbc, ssm = mamba2.mamba_decode(
            p["mamba"], _pre(x, p["ln1"], cfg), cfg,
            conv_x=cx, conv_bc=cbc, ssm_state=ssm)
        return x + y, (cx, cbc, ssm)
    raise ValueError(kind)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, *,
                context=None):
    """One token for every sequence. tokens: (B,1) int; pos: (B,) lengths.
    Returns (logits (B,1,V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    new_caches = []
    for sp, s, sc in zip(params["stages"], cfg.stages, cache):
        def body(h, xs):
            layer_p, layer_c = xs
            new_c = []
            for j, kind in enumerate(s.unit):
                h, c = _block_decode(layer_p[j], kind, h, layer_c[j], cfg,
                                     pos=pos, context=context,
                                     shared=params.get("shared"))
                new_c.append(c)
            return h, tuple(new_c)
        x, new_sc = jax.lax.scan(body, x, (sp, sc))
        new_caches.append(new_sc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap((x @ head).astype(jnp.float32), cfg.final_softcap)
    return logits, tuple(new_caches)


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
