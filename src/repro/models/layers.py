"""Shared building blocks: norms, RoPE, MLPs, initializers.

Functional style: every module is (init(key, cfg) -> params, apply(params, x)).
Params are plain dict pytrees so stages can stack them on a leading axis and
scan (transformer.py) and the sharding rules can pattern-match leaf paths
(distributed/sharding.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ------------------------------------------------------------------- helpers
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    # 1/sqrt(dim) keeps tied-head logits at unit scale (CE ~ ln V at init)
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            / np.sqrt(dim)).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                      # gemma-style (1 + w) scaling
        w = 1.0 + w
    return (y * w).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- MLP
def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, d_model, dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff, dtype)
        p["up"] = dense_init(k3, d_model, d_ff, dtype)
    else:
        p["up"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]
