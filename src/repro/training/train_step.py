"""Distributed train step: loss -> grad -> AdamW, with remat, microbatch
gradient accumulation, and (optionally) int8 error-feedback gradient
compression over the DP axes.

The step is a single jit-compiled function; parameter/optimizer sharding
comes from distributed.sharding.param_specs, batch sharding from
batch_specs. XLA SPMD inserts the DP all-reduce; the compressed variant
replaces it with an explicit shard_map QSGD-style exchange
(training/grad_compression.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    remat: bool = True
    microbatches: int = 1
    compressed_grads: bool = False


class TrainState(dict):
    """{'params': compute-dtype params, 'opt': AdamWState}."""


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig,
                     tp: int = 1) -> Dict[str, Any]:
    params = tr.init_params(key, cfg, tp)
    return {"params": params, "opt": opt.init_state(params, tcfg.adamw)}


def _loss(params, batch, cfg: ModelConfig, remat: bool = False):
    batch = dict(batch)
    if cfg.encoder_stages is not None:
        batch["context"] = tr.encode(params, batch.pop("frames"), cfg,
                                     remat=remat)
    return tr.loss_fn(params, batch, cfg, remat=remat)


def _grads(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    # remat is PER-BLOCK (inside the layer scan), not whole-loss: a whole-
    # loss checkpoint still stacks scan-body residuals across layers.
    loss_f = functools.partial(_loss, remat=tcfg.remat)
    if tcfg.microbatches <= 1:
        return jax.value_and_grad(loss_f)(params, batch, cfg)

    # gradient accumulation over leading-batch microbatch slices
    mb = tcfg.microbatches

    def split(x):
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

    batch_mb = jax.tree.map(split, batch)

    def body(carry, mbatch):
        loss_acc, grad_acc = carry
        loss, g = jax.value_and_grad(loss_f)(params, mbatch, cfg)
        return (loss_acc + loss / mb,
                jax.tree.map(lambda a, b: a + b / mb, grad_acc, g)), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), batch_mb)
    return loss, grads


def train_step(state, batch, cfg: ModelConfig, tcfg: TrainConfig,
               mesh=None):
    """state: {'params', 'opt'}; batch: {'tokens','labels',...}."""
    loss, grads = _grads(state["params"], batch, cfg, tcfg)
    err = state["opt"].err
    if tcfg.compressed_grads and mesh is not None:
        from repro.training.grad_compression import compressed_mean
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        grads, err = compressed_mean(grads, err, mesh, dp)
    new_params, new_opt = opt.apply_updates(
        state["opt"]._replace(err=err), grads, tcfg.adamw,
        compute_dtype=jax.tree.leaves(state["params"])[0].dtype)
    metrics = {"loss": loss, "step": new_opt.step}
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """jit-wrapped train_step with donated state."""
    fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg, mesh=mesh)
    return jax.jit(fn, donate_argnums=(0,))
