"""AdamW optimizer (hand-rolled; no optax offline) with bf16-compute /
fp32-master discipline and optional int8 error-feedback gradient compression.

State layout: master params fp32, first/second moments fp32 — all sharded
like the parameters (optimizer state inherits param PartitionSpecs), i.e.
ZeRO-free Megatron-style replication over DP, sharded over TP. The
compressed all-reduce path (grad_compression.py) reduces DP gradient bytes
4x with error feedback carried in the optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray           # scalar int32
    master: Any                 # fp32 params
    m: Any
    v: Any
    err: Optional[Any]          # error-feedback residual (compression only)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    error_feedback: bool = False


def init_state(params, cfg: AdamWConfig) -> AdamWState:
    # copy=True: params may already be fp32; master must not alias them
    # (jit donation would otherwise see the same buffer twice)
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
    err = zeros() if cfg.error_feedback else None
    return AdamWState(jnp.zeros((), jnp.int32), master, zeros(), zeros(), err)


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(state: AdamWState, grads, cfg: AdamWConfig,
                  compute_dtype=jnp.bfloat16):
    """Returns (new_params_compute, new_state). Grads in fp32."""
    step = state.step + 1
    lr = _schedule(cfg, step)
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.vdot(g.astype(jnp.float32),
                                  g.astype(jnp.float32))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    t = step.astype(jnp.float32)

    def upd(mp, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** t)
        vh = v / (1 - cfg.b2 ** t)
        mp = mp - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * mp)
        return mp, m, v

    mp_leaves, treedef = jax.tree.flatten(state.master)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    trip = [upd(mp, g, m, v) for mp, g, m, v in
            zip(mp_leaves, g_leaves, m_leaves, v_leaves)]
    master = jax.tree.unflatten(treedef, [t[0] for t in trip])
    m = jax.tree.unflatten(treedef, [t[1] for t in trip])
    v = jax.tree.unflatten(treedef, [t[2] for t in trip])
    new_params = jax.tree.map(lambda p: p.astype(compute_dtype), master)
    return new_params, AdamWState(step, master, m, v, state.err)
