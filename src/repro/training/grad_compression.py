"""Int8 error-feedback gradient all-reduce (QSGD/1-bit-Adam style) over the
DP mesh axes, built on shard_map + quant_pack.

Scheme per leaf (flattened to blocks of 256):
  1. g' = g + err                     (error feedback carry-in)
  2. q, s = quant8(g')                (local int8 + per-block fp32 scales)
  3. psum(dequant(q, s)) / n          (wire format int8+scales: 4x fewer
                                       gradient bytes than fp32; here the
                                       exchange is expressed as a psum of
                                       the dequantized tensor so XLA lowers
                                       a single fused all-reduce — the int8
                                       wire encoding is what a DCN-aware
                                       runtime ships, see DESIGN.md)
  4. err' = g' - dequant(q, s)        (carry-out)

The quantization error never accumulates: it is re-injected next step, so
AdamW sees an unbiased gradient stream (standard error-feedback guarantee).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.kernels import ops

_BLOCK = 256


def _quant_leaf(g, e):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    fp = jnp.pad(flat, (0, pad))
    fe = jnp.pad(e.astype(jnp.float32).reshape(-1), (0, pad))
    carried = fp + fe
    q, s = ops.quant_pack(carried, block=_BLOCK)
    deq = ops.quant_unpack(q, s)
    new_err = (carried - deq)[:flat.shape[0]].reshape(g.shape)
    return deq[:flat.shape[0]].reshape(g.shape), new_err


def compressed_mean(grads, err, mesh, dp_axes: Tuple[str, ...]):
    """Mean of grads across DP axes with int8 error feedback.

    grads/err: pytrees (err may be None -> zeros). Returns (grads', err').
    Must be called inside jit with ``mesh`` active; gradients are already
    DP-identical per TP group, so the quantize/psum runs under shard_map
    with fully-replicated specs on the DP axes.
    """
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)

    from jax.sharding import PartitionSpec as P

    def local_fn(*leaves):
        n = len(leaves) // 2
        gs, es = leaves[:n], leaves[n:]
        outs = []
        for g, e in zip(gs, es):
            deq, new_e = _quant_leaf(g, e)
            red = jax.lax.psum(deq, dp_axes) / \
                jnp.prod(jnp.asarray([mesh.shape[a] for a in dp_axes],
                                     jnp.float32))
            outs.append((red, new_e))
        return tuple(x for pair in outs for x in pair)

    # gradients are replicated across DP (per-TP-shard identical after XLA's
    # DP all-reduce was *not* yet inserted — we call this on per-device
    # grads), so specs replicate leaves and psum does the reduction.
    in_specs = tuple(P() for _ in range(2 * len(flat_g)))
    out_specs = tuple(P() for _ in range(2 * len(flat_g)))
    fn = ctx.shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    res = fn(*flat_g, *flat_e)
    new_g = jax.tree.unflatten(treedef, list(res[0::2]))
    new_e = jax.tree.unflatten(treedef, list(res[1::2]))
    return new_g, new_e
