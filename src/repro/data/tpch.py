"""Schema-faithful synthetic TPC-H generator + query workload.

The paper evaluates on TPC-H 1GB / 100GB / 1TB (uniform) and a Zipf-skewed
variant (skew factor 3). The container is offline, so we regenerate the 8
TPC-H tables at a configurable row scale, optionally Zipf-skewing the foreign
keys and value columns, and approximate the 22 query templates with 22
parameterized selection/join patterns over the same schema. A "query family"
(paper §VI-A) is the set of table *files* (row chunks) a query touches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.tables import Table

SEGMENTS = np.array(["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"])
PRIORITIES = np.array(["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"])
SHIPMODES = np.array(["AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB", "REG AIR"])
STATUSES = np.array(["F", "O", "P"])
NATIONS = np.array(["ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA",
                    "FRANCE", "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ", "JAPAN",
                    "JORDAN", "KENYA", "MOROCCO", "MOZAMBIQUE", "PERU", "CHINA",
                    "ROMANIA", "SAUDI ARABIA", "VIETNAM", "RUSSIA", "UNITED KINGDOM",
                    "UNITED STATES"])
REGIONS = np.array(["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"])
TYPES = np.array([f"{a} {b} {c}" for a in ("STANDARD", "SMALL", "MEDIUM", "LARGE",
                                           "ECONOMY", "PROMO")
                  for b in ("ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED")
                  for c in ("TIN", "NICKEL", "BRASS", "STEEL", "COPPER")])


def _zipf_idx(rng, n_values: int, size: int, skew: float) -> np.ndarray:
    if skew <= 0:
        return rng.integers(0, n_values, size)
    ranks = np.arange(1, n_values + 1, dtype=np.float64)
    p = ranks ** (-skew)
    p /= p.sum()
    return rng.choice(n_values, size=size, p=p)


@dataclasses.dataclass
class TPCH:
    tables: Dict[str, Table]
    scale_rows: int
    skew: float


def generate(scale_rows: int = 6000, skew: float = 0.0, seed: int = 0) -> TPCH:
    """Generate the 8 TPC-H tables. ``scale_rows`` = lineitem rows (SF1=6M)."""
    rng = np.random.default_rng(seed)
    n_li = scale_rows
    n_ord = max(scale_rows // 4, 10)
    n_cust = max(scale_rows // 40, 10)
    n_part = max(scale_rows // 30, 10)
    n_supp = max(scale_rows // 600, 5)
    n_ps = n_part * 4

    def dates(n, lo=8035, hi=10591):  # days since epoch ~1992..1998
        return rng.integers(lo, hi, n).astype(np.int64)

    region = Table("region", {
        "r_regionkey": np.arange(len(REGIONS)),
        "r_name": REGIONS.copy(),
    })
    nation = Table("nation", {
        "n_nationkey": np.arange(len(NATIONS)),
        "n_name": NATIONS.copy(),
        "n_regionkey": rng.integers(0, len(REGIONS), len(NATIONS)),
    })
    supplier = Table("supplier", {
        "s_suppkey": np.arange(n_supp),
        "s_name": np.array([f"Supplier#{i:09d}" for i in range(n_supp)]),
        "s_nationkey": rng.integers(0, len(NATIONS), n_supp),
        "s_acctbal": np.round(rng.uniform(-999, 9999, n_supp), 2),
    })
    part = Table("part", {
        "p_partkey": np.arange(n_part),
        "p_name": np.array([f"part {i % 97} brand{i % 13}" for i in range(n_part)]),
        "p_type": TYPES[_zipf_idx(rng, len(TYPES), n_part, skew)],
        "p_size": rng.integers(1, 51, n_part),
        "p_retailprice": np.round(900 + (np.arange(n_part) % 1000) * 1.0, 2),
    })
    partsupp = Table("partsupp", {
        "ps_partkey": np.repeat(np.arange(n_part), 4)[:n_ps],
        "ps_suppkey": rng.integers(0, n_supp, n_ps),
        "ps_availqty": rng.integers(1, 10000, n_ps),
        "ps_supplycost": np.round(rng.uniform(1, 1000, n_ps), 2),
    })
    customer = Table("customer", {
        "c_custkey": np.arange(n_cust),
        "c_name": np.array([f"Customer#{i:09d}" for i in range(n_cust)]),
        "c_nationkey": rng.integers(0, len(NATIONS), n_cust),
        "c_acctbal": np.round(rng.uniform(-999, 9999, n_cust), 2),
        "c_mktsegment": SEGMENTS[_zipf_idx(rng, len(SEGMENTS), n_cust, skew)],
    })
    orders = Table("orders", {
        "o_orderkey": np.arange(n_ord),
        "o_custkey": _zipf_idx(rng, n_cust, n_ord, skew),
        "o_orderstatus": STATUSES[rng.integers(0, 3, n_ord)],
        "o_totalprice": np.round(rng.gamma(2.0, 60000, n_ord), 2),
        "o_orderdate": dates(n_ord),
        "o_orderpriority": PRIORITIES[_zipf_idx(rng, len(PRIORITIES), n_ord, skew)],
    })
    li_order = _zipf_idx(rng, n_ord, n_li, skew)
    shipdate = orders.columns["o_orderdate"][li_order] + rng.integers(1, 121, n_li)
    lineitem = Table("lineitem", {
        "l_orderkey": li_order,
        "l_partkey": _zipf_idx(rng, n_part, n_li, skew),
        "l_suppkey": rng.integers(0, n_supp, n_li),
        "l_quantity": rng.integers(1, 51, n_li).astype(np.int64),
        "l_extendedprice": np.round(rng.uniform(900, 105000, n_li), 2),
        "l_discount": np.round(rng.uniform(0.0, 0.1, n_li), 2),
        "l_tax": np.round(rng.uniform(0.0, 0.08, n_li), 2),
        "l_returnflag": np.array(["A", "N", "R"])[rng.integers(0, 3, n_li)],
        "l_shipdate": shipdate,
        "l_shipmode": SHIPMODES[_zipf_idx(rng, len(SHIPMODES), n_li, skew)],
    })
    # data lakes ingest time-ordered events (paper §VI-B): cluster the fact
    # tables by date so range queries touch contiguous file subsets
    lineitem = lineitem.sort_by("l_shipdate")
    orders = orders.sort_by("o_orderdate")
    return TPCH({t.name: t for t in (region, nation, supplier, part, partsupp,
                                     customer, orders, lineitem)},
                scale_rows, skew)


# --------------------------------------------------------------------- files
def chunk_files(db: TPCH, rows_per_file: int = 500) -> Dict[str, List[Tuple[str, np.ndarray]]]:
    """Split each table into 'files' (contiguous row chunks) — the unit of
    storage and of DATAPART partitioning. Returns table -> [(file_id, row_idx)]."""
    out: Dict[str, List[Tuple[str, np.ndarray]]] = {}
    for name, t in db.tables.items():
        n = t.num_rows
        files = []
        for i, lo in enumerate(range(0, n, rows_per_file)):
            idx = np.arange(lo, min(lo + rows_per_file, n))
            files.append((f"{name}/{i:05d}", idx))
        out[name] = files
    return out


# -------------------------------------------------------------------- queries
# 22 parameterized patterns over the schema: (table, predicate-builder).
def _q_templates():
    def date_range(col, lo, hi):
        return lambda t, rng: (t.columns[col] >= lo + rng.integers(0, 200)) & \
                              (t.columns[col] < hi + rng.integers(0, 200))

    def eq_choice(col, values):
        return lambda t, rng: t.columns[col] == values[rng.integers(0, len(values))]

    def num_range(col, lo, hi, width):
        def f(t, rng):
            a = rng.uniform(lo, hi - width)
            return (t.columns[col] >= a) & (t.columns[col] < a + width)
        return f

    T = []
    # Q1/Q6-style lineitem date-range scans
    for k in range(6):
        T.append(("lineitem", date_range("l_shipdate", 8035 + 360 * k, 8035 + 360 * (k + 1))))
    # shipmode / returnflag selections (Q12-like)
    T.append(("lineitem", eq_choice("l_shipmode", SHIPMODES)))
    T.append(("lineitem", eq_choice("l_returnflag", np.array(["A", "N", "R"]))))
    # quantity / price bands (Q19-like)
    T.append(("lineitem", num_range("l_quantity", 1, 50, 5)))
    T.append(("lineitem", num_range("l_extendedprice", 900, 105000, 9000)))
    # orders patterns (Q3/Q4/Q5-like)
    for k in range(4):
        T.append(("orders", date_range("o_orderdate", 8035 + 500 * k, 8035 + 500 * (k + 1))))
    T.append(("orders", eq_choice("o_orderpriority", PRIORITIES)))
    T.append(("orders", num_range("o_totalprice", 1000, 400000, 40000)))
    # customer segment scans (Q3/Q10-like)
    T.append(("customer", eq_choice("c_mktsegment", SEGMENTS)))
    T.append(("customer", num_range("c_acctbal", -999, 9999, 1500)))
    # part/type scans (Q2/Q8/Q9-like)
    T.append(("part", eq_choice("p_type", TYPES[:30])))
    T.append(("part", num_range("p_size", 1, 50, 8)))
    # partsupp / supplier scans (Q11/Q15/Q16/Q20-like)
    T.append(("partsupp", num_range("ps_supplycost", 1, 1000, 120)))
    T.append(("supplier", num_range("s_acctbal", -999, 9999, 1800)))
    return T


@dataclasses.dataclass
class Query:
    template_id: int
    table: str
    rows: np.ndarray          # matched row indices in the table
    files: Tuple[str, ...]    # file ids touched


def generate_queries(db: TPCH, n_per_template: int = 20, seed: int = 1,
                     rows_per_file: int = 500,
                     template_skew: float = 0.0) -> List[Query]:
    """Instantiate ``n_per_template`` queries per template (paper: 20 each).

    ``template_skew`` > 0 draws template popularity from a Zipf law instead of
    uniform — the 'skewed query workload' configuration.
    """
    rng = np.random.default_rng(seed)
    templates = _q_templates()
    files = chunk_files(db, rows_per_file)
    total = n_per_template * len(templates)
    if template_skew > 0:
        t_idx = _zipf_idx(rng, len(templates), total, template_skew)
    else:
        t_idx = np.repeat(np.arange(len(templates)), n_per_template)
    queries: List[Query] = []
    for qi, ti in enumerate(t_idx):
        table_name, pred = templates[ti]
        t = db.tables[table_name]
        mask = pred(t, rng)
        rows = np.nonzero(mask)[0]
        touched = tuple(fid for fid, idx in files[table_name]
                        if mask[idx].any())
        queries.append(Query(int(ti), table_name, rows, touched))
    return queries


# ------------------------------------------------------- SCOPe pipeline glue
def build_file_rows(db: TPCH, rows_per_file: int = 500):
    """file_id -> (Table, row_idx) map consumed by scope.run_pipeline."""
    out = {}
    for name, files in chunk_files(db, rows_per_file).items():
        for fid, idx in files:
            out[fid] = (db.tables[name], idx)
    return out


def file_sizes_gb(db: TPCH, rows_per_file: int = 500, layout: str = "col"):
    """file_id -> serialized size (bytes) for DATAPART spans."""
    sizes = {}
    for name, files in chunk_files(db, rows_per_file).items():
        t = db.tables[name]
        for fid, idx in files:
            sizes[fid] = float(t.select(idx).nbytes(layout))
    return sizes


def partitions_from_queries(db: TPCH, queries, rows_per_file: int = 500,
                            layout: str = "col", rho_per_query: float = 24.0):
    """Initial partitions (query families) + file_rows for the pipeline.

    ``rho_per_query``: projected executions of each logged query over the
    billing window (the paper runs its 440-query workload repeatedly over
    5.5 months; ~weekly re-execution = 24).
    """
    from repro.core.datapart import make_partitions
    sizes = file_sizes_gb(db, rows_per_file, layout)
    qf = [(q.files, rho_per_query) for q in queries if q.files]
    parts = make_partitions(qf, sizes)
    return parts, build_file_rows(db, rows_per_file)
