"""Enterprise data-lake workload generator (paper §I Figs 1–2, §III).

Generates datasets with log-normal sizes (GB..PB) and monthly access series
drawn from the access-pattern families the paper documents on the Adobe
Experience Platform data lake:

 * ``decreasing``  — read volume decays with dataset age (Fig 2 top-left);
 * ``constant``    — flat read volume (Fig 2 top-right);
 * ``periodic``    — seasonal peaks, e.g. year-on-year analysis (Fig 2 bottom-left);
 * ``spike``       — one-time activation: read+write burst then silence (§I);
 * ``cold``        — zero/near-zero accesses (the skew mass of Fig 1a).

Popularity across datasets is Zipf-like (Fig 1a: few datasets dominate).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

PATTERNS = ("decreasing", "constant", "periodic", "spike", "cold")


@dataclasses.dataclass
class DatasetTrace:
    name: str
    size_gb: float
    created_month: int            # month index when ingested
    pattern: str
    reads: np.ndarray             # (n_months,) read ops per month
    writes: np.ndarray            # (n_months,) write ops per month

    def age_at(self, month: int) -> int:
        return max(month - self.created_month, 0)


@dataclasses.dataclass
class Workload:
    datasets: List[DatasetTrace]
    n_months: int

    def reads_in(self, lo: int, hi: int) -> np.ndarray:
        """Total reads per dataset in months [lo, hi)."""
        return np.array([d.reads[lo:hi].sum() for d in self.datasets])


def generate_workload(n_datasets: int = 200, n_months: int = 24,
                      seed: int = 0,
                      size_lognorm=(4.0, 2.0),
                      pattern_probs: Optional[Dict[str, float]] = None,
                      rng: Optional[np.random.Generator] = None
                      ) -> Workload:
    """``size_lognorm``=(mu, sigma) of ln(size in GB): defaults span
    ~1 GB .. ~1 PB with a heavy right tail, matching Enterprise Data I.

    All randomness flows through ``rng`` (an explicit
    ``np.random.Generator``); ``seed`` only applies when ``rng`` is None,
    so callers sharing one generator get reproducible composed streams.
    """
    rng = np.random.default_rng(seed) if rng is None else rng
    probs = pattern_probs or {"decreasing": 0.3, "constant": 0.15,
                              "periodic": 0.15, "spike": 0.1, "cold": 0.3}
    names = list(probs)
    p = np.array([probs[k] for k in names])
    p = p / p.sum()
    # Zipf base popularity (Fig 1a): a few datasets get most accesses.
    ranks = np.arange(1, n_datasets + 1, dtype=float)
    zipf_w = ranks ** -1.1
    zipf_w = zipf_w / zipf_w.sum() * n_datasets
    rng.shuffle(zipf_w)

    datasets: List[DatasetTrace] = []
    for i in range(n_datasets):
        size_gb = float(np.exp(rng.normal(*size_lognorm)))
        created = int(rng.integers(0, max(n_months - 2, 1)))
        pattern = names[rng.choice(len(names), p=p)]
        base = 40.0 * zipf_w[i]
        months = np.arange(n_months)
        rel = months - created
        active = rel >= 0
        if pattern == "decreasing":
            lam = rng.uniform(0.15, 0.5)
            mean = base * np.exp(-lam * np.maximum(rel, 0))
        elif pattern == "constant":
            mean = base * np.ones(n_months) * 0.6
        elif pattern == "periodic":
            period = rng.choice([6, 12])
            phase = rng.integers(0, period)
            mean = base * (0.15 + 1.7 * ((rel + phase) % period == 0))
        elif pattern == "spike":
            mean = np.where(rel <= 1, base * 3.0, 0.02 * base)
        else:  # cold
            mean = np.full(n_months, 0.02)
        mean = np.where(active, mean, 0.0)
        reads = rng.poisson(np.maximum(mean, 0.0)).astype(float)
        writes = np.zeros(n_months)
        if pattern == "spike":
            writes[created:created + 2] = rng.poisson(base, 2)
        else:
            writes[created] = max(1.0, rng.poisson(3))
            writes += rng.poisson(np.maximum(mean * 0.1, 0.0))
        writes = np.where(active, writes, 0.0)
        datasets.append(DatasetTrace(f"ds{i:04d}", size_gb, created, pattern,
                                     reads, writes))
    return Workload(datasets, n_months)


def feature_matrix(w: Workload, at_month: int, history: int = 4) -> np.ndarray:
    """Paper §IV-C features: (i) size, (ii) age in months, (iii/iv) monthly
    read and write aggregates for the last ``history`` months.

    ``at_month`` is clamped to ``[0, n_months]``: before month 0 there is
    no history (the window is all zeros), and a negative index must never
    reach the slice below — ``reads[0:-1]`` would silently read from the
    *end* of the trace and poison the training features.
    """
    if history < 0:
        raise ValueError(f"history must be >= 0, got {history}")
    at_month = min(max(int(at_month), 0), w.n_months)
    rows = []
    for d in w.datasets:
        lo = max(at_month - history, 0)
        reads = d.reads[lo:at_month]
        writes = d.writes[lo:at_month]
        pad = history - len(reads)
        reads = np.concatenate([np.zeros(pad), reads])
        writes = np.concatenate([np.zeros(pad), writes])
        rows.append(np.concatenate([[np.log1p(d.size_gb), d.age_at(at_month)],
                                    reads, writes]))
    return np.stack(rows)


# ---------------------------------------------------- streaming access logs
QueryFamilies = List[Tuple[Tuple[str, ...], float]]


def n_files_of(d: DatasetTrace, max_files: int = 12,
               file_gb: float = 256.0) -> int:
    """Datasets are stored as contiguous 'files' of ~``file_gb`` each,
    capped at ``max_files`` — the unit DATAPART partitions over."""
    return int(np.clip(np.ceil(d.size_gb / file_gb), 1, max_files))


def dataset_file_sizes(w: Workload, max_files: int = 12,
                       file_gb: float = 256.0) -> Dict[str, float]:
    """file_id -> size in GB for every dataset in the workload."""
    sizes: Dict[str, float] = {}
    for d in w.datasets:
        n = n_files_of(d, max_files, file_gb)
        for j in range(n):
            sizes[f"{d.name}/{j:03d}"] = d.size_gb / n
    return sizes


def monthly_query_log(w: Workload, month: int, rng: np.random.Generator,
                      queries_per_active: int = 3, max_files: int = 12,
                      file_gb: float = 256.0) -> QueryFamilies:
    """One month's access log as (files-touched, rho) query families.

    Each dataset active in ``month`` splits its read volume across one
    full-dataset scan plus ``queries_per_active - 1`` contiguous file-range
    scans (data lakes ingest time-ordered events, so range predicates touch
    contiguous file runs — same structure as the TPC-H chunking).

    ``rng`` is required: all emitter randomness flows through the caller's
    generator so streaming tests and benchmarks are reproducible.
    """
    out: QueryFamilies = []
    for d in w.datasets:
        reads = float(d.reads[month]) if month < len(d.reads) else 0.0
        if reads <= 0.0:
            continue
        n = n_files_of(d, max_files, file_gb)
        files = [f"{d.name}/{j:03d}" for j in range(n)]
        q = max(int(queries_per_active), 1)
        shares = rng.dirichlet(np.ones(q)) * reads
        out.append((tuple(files), float(shares[0])))          # full scan
        for s in shares[1:]:
            lo = int(rng.integers(0, n))
            hi = lo + int(rng.integers(1, n - lo + 1))
            out.append((tuple(files[lo:hi]), float(s)))
    return out


def stream_query_log(w: Workload, rng: np.random.Generator,
                     months: Optional[int] = None,
                     queries_per_active: int = 3, max_files: int = 12,
                     file_gb: float = 256.0) -> Iterator[QueryFamilies]:
    """Month-by-month access-log emitter driving ``StreamingEngine``:
    yields one ``monthly_query_log`` batch per month of the trace."""
    for m in range(months if months is not None else w.n_months):
        yield monthly_query_log(w, m, rng, queries_per_active, max_files,
                                file_gb)
