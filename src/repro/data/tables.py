"""Columnar table abstraction + row/column serialization.

Stands in for parquet (column-major) vs CSV (row-major) in COMPREDICT's
layout study (§V "Row vs Column Oriented Storage"). A table is a dict of
named NumPy columns with dtype classes {int, float, str}.

:func:`encode_dtype_classes` additionally provides the device-transfer view
used by the batched COMPREDICT feature backends: per dtype class, every
partition's values rendered once to strings, dictionary-encoded against a
shared vocabulary, and laid out as padded int32 code matrices that
:mod:`repro.kernels.entropy_features` can histogram in one dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

DTYPE_CLASSES = ("int", "float", "str")


def dtype_class(col: np.ndarray) -> str:
    if col.dtype.kind in "iu":
        return "int"
    if col.dtype.kind == "f":
        return "float"
    return "str"


@dataclasses.dataclass
class Table:
    name: str
    columns: Dict[str, np.ndarray]

    @property
    def num_rows(self) -> int:
        return len(next(iter(self.columns.values()))) if self.columns else 0

    def select(self, mask_or_idx) -> "Table":
        return Table(self.name, {k: v[mask_or_idx] for k, v in self.columns.items()})

    def head(self, n: int) -> "Table":
        return self.select(slice(0, n))

    def concat(self, other: "Table") -> "Table":
        return Table(self.name, {k: np.concatenate([v, other.columns[k]])
                                 for k, v in self.columns.items()})

    def sort_by(self, col: str) -> "Table":
        return self.select(np.argsort(self.columns[col], kind="stable"))

    # -------------------------------------------------------- serialization
    def _str_cols(self) -> List[np.ndarray]:
        out = []
        for v in self.columns.values():
            if dtype_class(v) == "float":
                out.append(np.char.mod("%.4f", v))
            elif dtype_class(v) == "int":
                out.append(np.char.mod("%d", v))
            else:
                out.append(v.astype(str))
        return out

    def to_row_bytes(self) -> bytes:
        """CSV-like row-major layout: rows of comma-joined fields."""
        cols = self._str_cols()
        if not cols:
            return b""
        joined = cols[0]
        for c in cols[1:]:
            joined = np.char.add(np.char.add(joined, ","), c)
        return ("\n".join(joined.tolist()) + "\n").encode()

    def to_col_bytes(self) -> bytes:
        """Parquet-like column-major layout: each column contiguous."""
        chunks = []
        for name, v in self.columns.items():
            header = f"#{name}\n".encode()
            body = ("\n".join(np.asarray(self._col_str(v)).tolist()) + "\n").encode()
            chunks.append(header + body)
        return b"".join(chunks)

    def _col_str(self, v: np.ndarray) -> np.ndarray:
        if dtype_class(v) == "float":
            return np.char.mod("%.4f", v)
        if dtype_class(v) == "int":
            return np.char.mod("%d", v)
        return v.astype(str)

    def serialize(self, layout: str) -> bytes:
        if layout == "row":
            return self.to_row_bytes()
        if layout == "col":
            return self.to_col_bytes()
        raise ValueError(layout)

    # ---------------------------------------------------------------- sizes
    def nbytes(self, layout: str = "row") -> int:
        return len(self.serialize(layout))


# --------------------------------------------------- device-transfer views
@dataclasses.dataclass
class ClassCodes:
    """Integer view of one dtype class across N partitions, device-ready.

    Values are the string renderings (``Table._col_str``) of every column of
    the class, dictionary-encoded once against a vocabulary shared by all N
    partitions (``global_codes`` / ``global_lengths`` — histograms over
    these are additive under partition concatenation), then *localized*:
    ``codes`` index each partition's own compact vocabulary so histogram
    width scales with per-partition distinct counts, not the dataset-wide
    cardinality (high-precision float columns would otherwise blow the
    vocabulary into the 1e5 range). Within a partition the layout is
    row-major (position ``r * n_cols + c``), which makes the bucketed
    20%-of-rows entropy a histogram over contiguous code ranges.
    """

    codes: np.ndarray          # (N, M)    int32 local codes, -1 padded
    n_valid: np.ndarray        # (N,)      int32, values per partition
    n_rows: np.ndarray         # (N,)      int32, rows per partition
    n_cols: np.ndarray         # (N,)      int32, columns of this class
    lengths: np.ndarray        # (N, Vmax) float32, len(s) per local slot
    vocab: np.ndarray          # (N, Vmax) int32, global code per local slot
    n_distinct: np.ndarray     # (N,)      int32, live local slots
    global_codes: np.ndarray   # (N, M)    int32 shared-vocab codes, -1 pad
    global_lengths: np.ndarray  # (V,)     float32, len(s) per global entry

    @property
    def vocab_size(self) -> int:
        return int(self.global_lengths.shape[0])


def encode_dtype_classes(tables: Sequence["Table"]) -> Dict[str, ClassCodes]:
    """One-pass dictionary encoding of N partitions for the feature kernels.

    Returns ``{dtype_class: ClassCodes}``. This is COMPREDICT's "one-time
    full scan" (paper §V): strings are rendered and uniqued exactly once
    here (the NumPy feature path re-renders every column per bucket);
    localization and every subsequent feature extraction — including
    per-batch re-prediction on the streaming hot path — are pure integer
    work (see ``repro.core.compredict.extract_features_batch``).
    """
    out: Dict[str, ClassCodes] = {}
    N = len(tables)
    for d in DTYPE_CLASSES:
        flats: List[np.ndarray] = []
        n_rows = np.zeros(N, np.int32)
        n_cols = np.zeros(N, np.int32)
        for i, t in enumerate(tables):
            cols = [t._col_str(v) for v in t.columns.values()
                    if dtype_class(v) == d]
            n_rows[i] = t.num_rows
            n_cols[i] = len(cols)
            flats.append(np.stack(cols, axis=1).reshape(-1) if cols
                         else np.empty(0, "<U1"))
        n_valid = np.array([f.shape[0] for f in flats], np.int32)
        total = int(n_valid.sum())
        if total:
            uniq, inv = np.unique(np.concatenate(flats), return_inverse=True)
            global_lengths = np.char.str_len(
                uniq.astype(str)).astype(np.float32)
        else:
            inv = np.zeros(0, np.int64)
            global_lengths = np.zeros(1, np.float32)
        M = max(int(n_valid.max()) if N else 0, 1)
        global_codes = np.full((N, M), -1, np.int32)
        locals_: List[Tuple[np.ndarray, np.ndarray]] = []
        off = 0
        for i, nv in enumerate(n_valid):
            g = inv[off:off + nv]
            global_codes[i, :nv] = g
            locals_.append(np.unique(g, return_inverse=True))
            off += nv
        n_distinct = np.array([len(lu) for lu, _ in locals_], np.int32)
        Vmax = max(int(n_distinct.max()) if N else 0, 1)
        codes = np.full((N, M), -1, np.int32)
        vocab = np.full((N, Vmax), -1, np.int32)
        lengths = np.zeros((N, Vmax), np.float32)
        for i, (lu, linv) in enumerate(locals_):
            codes[i, :n_valid[i]] = linv
            vocab[i, :len(lu)] = lu
            lengths[i, :len(lu)] = global_lengths[lu]
        out[d] = ClassCodes(codes=codes, n_valid=n_valid, n_rows=n_rows,
                            n_cols=n_cols, lengths=lengths, vocab=vocab,
                            n_distinct=n_distinct, global_codes=global_codes,
                            global_lengths=global_lengths)
    return out
