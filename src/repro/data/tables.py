"""Columnar table abstraction + row/column serialization.

Stands in for parquet (column-major) vs CSV (row-major) in COMPREDICT's
layout study (§V "Row vs Column Oriented Storage"). A table is a dict of
named NumPy columns with dtype classes {int, float, str}.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

DTYPE_CLASSES = ("int", "float", "str")


def dtype_class(col: np.ndarray) -> str:
    if col.dtype.kind in "iu":
        return "int"
    if col.dtype.kind == "f":
        return "float"
    return "str"


@dataclasses.dataclass
class Table:
    name: str
    columns: Dict[str, np.ndarray]

    @property
    def num_rows(self) -> int:
        return len(next(iter(self.columns.values()))) if self.columns else 0

    def select(self, mask_or_idx) -> "Table":
        return Table(self.name, {k: v[mask_or_idx] for k, v in self.columns.items()})

    def head(self, n: int) -> "Table":
        return self.select(slice(0, n))

    def concat(self, other: "Table") -> "Table":
        return Table(self.name, {k: np.concatenate([v, other.columns[k]])
                                 for k, v in self.columns.items()})

    def sort_by(self, col: str) -> "Table":
        return self.select(np.argsort(self.columns[col], kind="stable"))

    # -------------------------------------------------------- serialization
    def _str_cols(self) -> List[np.ndarray]:
        out = []
        for v in self.columns.values():
            if dtype_class(v) == "float":
                out.append(np.char.mod("%.4f", v))
            elif dtype_class(v) == "int":
                out.append(np.char.mod("%d", v))
            else:
                out.append(v.astype(str))
        return out

    def to_row_bytes(self) -> bytes:
        """CSV-like row-major layout: rows of comma-joined fields."""
        cols = self._str_cols()
        if not cols:
            return b""
        joined = cols[0]
        for c in cols[1:]:
            joined = np.char.add(np.char.add(joined, ","), c)
        return ("\n".join(joined.tolist()) + "\n").encode()

    def to_col_bytes(self) -> bytes:
        """Parquet-like column-major layout: each column contiguous."""
        chunks = []
        for name, v in self.columns.items():
            header = f"#{name}\n".encode()
            body = ("\n".join(np.asarray(self._col_str(v)).tolist()) + "\n").encode()
            chunks.append(header + body)
        return b"".join(chunks)

    def _col_str(self, v: np.ndarray) -> np.ndarray:
        if dtype_class(v) == "float":
            return np.char.mod("%.4f", v)
        if dtype_class(v) == "int":
            return np.char.mod("%d", v)
        return v.astype(str)

    def serialize(self, layout: str) -> bytes:
        if layout == "row":
            return self.to_row_bytes()
        if layout == "col":
            return self.to_col_bytes()
        raise ValueError(layout)

    # ---------------------------------------------------------------- sizes
    def nbytes(self, layout: str = "row") -> int:
        return len(self.serialize(layout))
