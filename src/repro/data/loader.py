"""Tiered training-data pipeline with prefetch and straggler mitigation.

Data shards (tokenized sequences) live in the TieredStore — their placement
is SCOPe-optimized like any other partition (G-PART groups shards that
training jobs read together; OPTASSIGN tiers them by epoch access rate).

Fault-tolerance / scale features:
  * deterministic shard ownership: shard -> host by stable hash, so a
    restarted host recomputes exactly its assignment (no coordinator);
  * prefetch thread with a bounded queue (overlaps storage latency with
    compute);
  * straggler mitigation: a fetch slower than ``straggler_factor`` x the
    EWMA fetch time is re-issued against the backup replica owner
    (hash+1); first responder wins (speculative retry — MapReduce-style);
  * resumable: iteration order is a seeded permutation, (epoch, index)
    checkpointable alongside the model.
"""

from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.storage.codecs import available_schemes
from repro.storage.store import TieredStore

# Preferred shard codec, degrading to what this environment has installed.
DEFAULT_SHARD_CODEC = available_schemes(("zstd-3", "zlib-1", "none"))[0]


def stable_hash(key: str, salt: int = 0) -> int:
    return int.from_bytes(hashlib.sha256(f"{salt}:{key}".encode()
                                         ).digest()[:8], "big")


def shard_owner(shard: str, n_hosts: int, replica: int = 0) -> int:
    return (stable_hash(shard) + replica) % max(n_hosts, 1)


@dataclasses.dataclass
class LoaderStats:
    fetches: int = 0
    speculative_retries: int = 0
    ewma_fetch_s: float = 0.0


def write_token_shards(store: TieredStore, n_shards: int, rows: int,
                       seq: int, vocab: int, seed: int = 0,
                       tier: int = 1, codec: Optional[str] = None,
                       prefix: str = "data") -> List[str]:
    """Synthetic Zipf-token corpus, sharded into the store."""
    codec = codec or DEFAULT_SHARD_CODEC
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -1.1
    p /= p.sum()
    keys = []
    for i in range(n_shards):
        toks = rng.choice(vocab, size=(rows, seq + 1), p=p).astype(np.int32)
        key = f"{prefix}/{i:05d}"
        store.put(key, toks.tobytes(), tier=tier, codec=codec)
        keys.append(key)
    return keys


class TieredDataLoader:
    def __init__(self, store: TieredStore, shards: Sequence[str],
                 batch: int, seq: int, host_id: int = 0, n_hosts: int = 1,
                 seed: int = 0, prefetch: int = 2,
                 straggler_factor: float = 3.0,
                 fetch_timeout_s: float = 5.0,
                 fetch_fn=None):
        self.store = store
        self.shards = list(shards)
        self.batch, self.seq = batch, seq
        self.host_id, self.n_hosts = host_id, n_hosts
        self.seed = seed
        self.prefetch = prefetch
        self.straggler_factor = straggler_factor
        self.fetch_timeout_s = fetch_timeout_s
        self.stats = LoaderStats()
        # injectable fetch (tests simulate slow replicas / dead hosts)
        self._fetch = fetch_fn or (lambda key, replica: self.store.get(key))

    # ------------------------------------------------------------ ownership
    def my_shards(self, epoch: int) -> List[str]:
        order = sorted(self.shards,
                       key=lambda s: stable_hash(s, salt=self.seed + epoch))
        return [s for s in order
                if shard_owner(s, self.n_hosts) == self.host_id]

    # ------------------------------------------------------------- fetching
    def _timed_fetch(self, key: str, replica: int) -> bytes:
        t0 = time.perf_counter()
        blob = self._fetch(key, replica)
        dt = time.perf_counter() - t0
        st = self.stats
        st.fetches += 1
        st.ewma_fetch_s = dt if st.fetches == 1 else \
            0.8 * st.ewma_fetch_s + 0.2 * dt
        return blob

    def fetch_with_backup(self, key: str) -> bytes:
        """Speculative retry: if the primary fetch exceeds
        straggler_factor x EWMA (or the hard timeout), race the backup."""
        budget = max(self.straggler_factor * self.stats.ewma_fetch_s, 1e-3)
        budget = min(budget, self.fetch_timeout_s)
        result: queue.Queue = queue.Queue()

        def _try(replica: int):
            try:
                result.put((replica, self._timed_fetch(key, replica)))
            except Exception as e:  # noqa: BLE001 — surfaced via queue
                result.put((replica, e))

        t = threading.Thread(target=_try, args=(0,), daemon=True)
        t.start()
        try:
            replica, blob = None, None
            got = result.get(timeout=budget if self.stats.fetches >= 3
                             else self.fetch_timeout_s)
            if isinstance(got[1], Exception):
                raise got[1]
            return got[1]
        except queue.Empty:
            self.stats.speculative_retries += 1
            t2 = threading.Thread(target=_try, args=(1,), daemon=True)
            t2.start()
            got = result.get(timeout=self.fetch_timeout_s)
            if isinstance(got[1], Exception):
                raise got[1]
            return got[1]

    # ------------------------------------------------------------- batching
    def batches(self, epoch: int = 0,
                start_index: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator over {tokens, labels} batches."""
        my = self.my_shards(epoch)
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            for key in my[start_index:]:
                blob = self.fetch_with_backup(key)
                toks = np.frombuffer(blob, np.int32).reshape(-1, self.seq + 1)
                q.put(toks)
            q.put(stop)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        buf = np.zeros((0, self.seq + 1), np.int32)
        while True:
            item = q.get()
            if item is stop:
                break
            buf = np.concatenate([buf, item]) if buf.size else item
            while len(buf) >= self.batch:
                chunk, buf = buf[:self.batch], buf[self.batch:]
                yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
