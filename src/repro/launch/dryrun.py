import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices; record memory/cost analysis + roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

(The XLA_FLAGS assignment above MUST precede any jax import — device count
locks at first init. Tests/benches import everything else, never this file.)
"""

import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs.registry import arch_names, get_config
from repro.distributed import ctx
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        param_specs, to_named, zero1_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, applicable, input_specs,
                                 param_structs, train_state_structs)
from repro.models import transformer as tr
from repro.training import train_step as ts
from repro.training.optimizer import AdamWState

RESULTS = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"


def _opt_state_specs(p_specs, p_structs, mesh):
    dp_size = mesh.shape["data"]
    z = zero1_specs(p_specs, p_structs, "data", dp_size)
    return AdamWState(step=P(), master=z, m=z, v=z, err=None)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int = 1):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    sc = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name, tp)
    p_structs = param_structs(cfg, tp)
    p_specs = param_specs(p_structs, cfg, tp)
    named = lambda tree: to_named(tree, mesh)

    with ctx.activate(mesh):
        if sc.kind == "train":
            tcfg = ts.TrainConfig(remat=True, microbatches=microbatches)
            state_structs = train_state_structs(cfg, tcfg, tp)
            state_specs = {
                "params": p_specs,
                "opt": _opt_state_specs(p_specs, p_structs, mesh)}
            b_specs = batch_specs(cfg, mesh)
            fn = functools.partial(ts.train_step, cfg=cfg, tcfg=tcfg)
            jitted = jax.jit(fn, in_shardings=(named(state_specs),
                                               named(b_specs)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_structs, specs["batch"])
        elif sc.kind == "prefill":
            def prefill(params, tokens, context=None):
                if cfg.encoder_stages is not None:
                    context = tr.encode(params, context, cfg)
                return tr.forward(params, tokens, cfg, context=context)
            dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            dps = dp if len(dp) > 1 else dp[0]
            args = [p_structs, specs["tokens"]]
            shard = [named(p_specs), NamedSharding(mesh, P(dps, None))]
            if "context" in specs:
                args.append(specs["context"])
                shard.append(NamedSharding(mesh, P(dps, None, None)))
            jitted = jax.jit(prefill, in_shardings=tuple(shard))
            lowered = jitted.lower(*args)
        else:  # decode
            c_specs = cache_specs(cfg, mesh, batch=sc.batch)
            from repro.distributed.sharding import _dp
            dps = _dp(mesh, sc.batch)
            have_ctx = "context" in specs

            if have_ctx:
                def decode(params, cache, tokens, pos, context):
                    return tr.decode_step(params, cache, tokens, pos, cfg,
                                          context=context)
            else:
                def decode(params, cache, tokens, pos):
                    return tr.decode_step(params, cache, tokens, pos, cfg)
            args = [p_structs, specs["cache"], specs["tokens"], specs["pos"]]
            shard = [named(p_specs), named(c_specs),
                     NamedSharding(mesh, P(dps, None)),
                     NamedSharding(mesh, P(dps))]
            if have_ctx:
                args.append(specs["context"])
                shard.append(NamedSharding(mesh, P(dps, None, None)))
            jitted = jax.jit(decode, in_shardings=tuple(shard),
                             out_shardings=(None, named(c_specs)),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)
    return cfg, mesh, lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             microbatches: int = 1):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__mb{microbatches}" if microbatches > 1 else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] SKIP {arch} {shape_name} {mesh_name}: {reason}")
        return rec
    try:
        t0 = time.time()
        cfg, mesh, lowered = lower_cell(arch, shape_name, multi_pod,
                                        microbatches)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        try:
            import zstandard as zstd
            (out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.zst"
             ).write_bytes(zstd.ZstdCompressor(3).compress(hlo.encode()))
        except Exception:
            pass
        coll = rl.collective_bytes(hlo)
        chips = mesh.size
        total = rl.count_params(param_structs(cfg, mesh.shape["model"]))
        active = rl.active_params(cfg, total)
        sc = SHAPES[shape_name]
        mflops = rl.model_flops(cfg, sc.kind, sc.batch, sc.seq, total, active)
        roof = rl.roofline_terms(cost, hlo, chips, mflops)
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "chips": chips,
            "params_total": total,
            "params_active": active,
            "memory_analysis": {
                k: getattr(mem, k) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")
                if hasattr(mem, k)},
            "cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals",
                                        "optimal_seconds")},
            "collective_bytes": coll,
            "roofline": roof.as_dict(),
        })
        print(f"[dryrun] OK  {arch} {shape_name} {mesh_name} "
              f"compile={t2 - t1:.0f}s dominant={roof.dominant} "
              f"(c={roof.compute_s:.4f}s m={roof.memory_s:.4f}s "
              f"x={roof.collective_s:.4f}s)")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] ERR {arch} {shape_name} {mesh_name}: {rec['error']}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                run_cell(arch, shape, mp, out_dir, args.microbatches)


if __name__ == "__main__":
    main()
