"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no JAX device state — dryrun.py must set XLA_FLAGS before first init.

Topology: TPU v5e, 16x16 = 256 chips/pod; multi-pod adds a leading "pod"
axis over DCN. "data" carries DP (batch), "model" carries TP/EP/SP.
"""

from __future__ import annotations

from repro.compat import make_mesh as _mk


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small meshes for CPU tests (requires enough host devices)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes that carry the batch: ('pod','data') on multi-pod meshes."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape["model"]
