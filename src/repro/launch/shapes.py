"""Assigned input-shape sets + ShapeDtypeStruct stand-ins (no allocation).

Per-arch shape grid (assignment):
  train_4k     seq 4096,    global_batch 256   (train_step)
  prefill_32k  seq 32768,   global_batch 32    (prefill forward)
  decode_32k   seq 32768,   global_batch 128   (serve_step, KV cache = seq)
  long_500k    seq 524288,  global_batch 1     (serve_step; SSM/hybrid only)

``long_500k`` is skipped (reported as such) for full-attention archs; whisper
decode uses its fixed 1500-frame encoder context as the cross input.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason). long_500k only for sub-quadratic archs (DESIGN §5)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention architecture: 500k-token cache decode "
                       "is not sub-quadratic-capable; documented skip")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _context_struct(cfg: ModelConfig, batch: int):
    if cfg.cross_context:
        return _sds((batch, cfg.cross_context, cfg.d_model), jnp.bfloat16)
    return None


def input_specs(cfg: ModelConfig, shape_name: str,
                tp: int = 16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train   -> {'batch': {tokens, labels[, context|frames]}}
    prefill -> {'tokens'[, 'context'|'frames']}
    decode  -> {'tokens', 'pos', 'cache'[, 'context']}
    """
    sc = SHAPES[shape_name]
    B, S = sc.batch, sc.seq
    if sc.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        if cfg.cross_context:
            batch["context"] = _context_struct(cfg, B)
        if cfg.encoder_stages is not None:
            batch["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if sc.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.cross_context:
            out["context"] = _context_struct(cfg, B)
        if cfg.encoder_stages is not None:
            out["context"] = _sds((B, cfg.encoder_context, cfg.d_model),
                                  jnp.bfloat16)
        return out
    # decode: cache sized to the context length
    cache = jax.eval_shape(
        lambda: tr.init_cache(cfg, B, max_seq=S, tp=tp))
    out = {"tokens": _sds((B, 1), jnp.int32),
           "pos": _sds((B,), jnp.int32),
           "cache": cache}
    if cfg.cross_context:
        out["context"] = _context_struct(cfg, B)
    if cfg.encoder_stages is not None:
        out["context"] = _sds((B, cfg.encoder_context, cfg.d_model),
                              jnp.bfloat16)
    return out


def param_structs(cfg: ModelConfig, tp: int = 16):
    return jax.eval_shape(
        lambda k: tr.init_params(k, cfg, tp), jax.random.PRNGKey(0))


def train_state_structs(cfg: ModelConfig, tcfg, tp: int = 16):
    from repro.training import train_step as ts
    return jax.eval_shape(
        lambda k: ts.init_train_state(k, cfg, tcfg, tp), jax.random.PRNGKey(0))
