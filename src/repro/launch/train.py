"""Training launcher: mesh + sharded train step + tiered data + SCOPe ckpts.

On real TPU pods this is the production entry point (the mesh maps onto the
physical slice); on CPU it runs the same code path with a test mesh and the
smoke config:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 20 --batch 8 --seq 64 --data-mesh 1 --model-mesh 1
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.loader import TieredDataLoader, write_token_shards
from repro.distributed import ctx
from repro.distributed.sharding import (batch_specs, param_specs, to_named,
                                        zero1_specs)
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.storage.store import TieredStore
from repro.training import train_step as ts
from repro.training.optimizer import AdamWState
from jax.sharding import PartitionSpec as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="0 = production 16x16 mesh")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tp = args.model_mesh or 16
    mesh = (make_production_mesh() if args.data_mesh == 0
            else make_test_mesh(args.data_mesh, args.model_mesh))
    tcfg = ts.TrainConfig(remat=not args.smoke,
                          microbatches=args.microbatches)

    store = TieredStore()
    shards = write_token_shards(store, n_shards=16, rows=32, seq=args.seq,
                                vocab=cfg.vocab_size)
    loader = TieredDataLoader(store, shards, batch=args.batch, seq=args.seq)
    mgr = CheckpointManager(store) if args.ckpt_every else None

    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg,
                                tp=mesh.shape["model"])
    start = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        state, start = mgr.restore(state)

    p_specs = param_specs(state["params"], cfg, mesh.shape["model"])
    z = zero1_specs(p_specs, state["params"], "data", mesh.shape["data"])
    s_specs = {"params": p_specs,
               "opt": AdamWState(step=P(), master=z, m=z, v=z, err=None)}
    with ctx.activate(mesh):
        import functools
        step_fn = jax.jit(
            functools.partial(ts.train_step, cfg=cfg, tcfg=tcfg),
            in_shardings=(to_named(s_specs, mesh),
                          to_named(batch_specs(cfg, mesh,
                                               batch=args.batch), mesh)),
            donate_argnums=(0,))
        i, t0 = start, time.time()
        while i < args.steps:
            for batch in loader.batches(epoch=i):
                if i >= args.steps:
                    break
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, m = step_fn(state, batch)
                i += 1
                if i % 5 == 0:
                    print(f"step {i} loss {float(m['loss']):.4f} "
                          f"({(time.time() - t0) / (i - start):.2f}s/step)")
                if mgr and i % args.ckpt_every == 0:
                    mgr.save(i, state)
    if mgr:
        mgr.wait()
        print("ckpt bill:", {k: round(v, 6) for k, v in
                             store.meter.as_dict().items() if v})
    print("done at step", i)


if __name__ == "__main__":
    main()
