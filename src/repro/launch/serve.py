"""Serving launcher: prefill + batched decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --batch 4 --tokens 16 --data-mesh 1 --model-mesh 1
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed import ctx
from repro.distributed.sharding import cache_specs, param_specs, to_named
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_production_mesh() if args.data_mesh == 0
            else make_test_mesh(args.data_mesh, args.model_mesh))
    tp = mesh.shape["model"]
    B = args.batch
    max_seq = args.prompt_len + args.tokens + 1

    params = tr.init_params(jax.random.PRNGKey(0), cfg, tp=tp)
    cache = tr.init_cache(cfg, B, max_seq=max_seq, tp=tp)
    p_sh = to_named(param_specs(params, cfg, tp), mesh)
    c_sh = to_named(cache_specs(cfg, mesh, batch=B), mesh)
    from repro.distributed.sharding import _dp
    dps = _dp(mesh, B)
    t_sh = NamedSharding(mesh, P(dps, None))
    q_sh = NamedSharding(mesh, P(dps))

    with ctx.activate(mesh):
        step = jax.jit(lambda p, c, t, q: tr.decode_step(p, c, t, q, cfg),
                       in_shardings=(p_sh, c_sh, t_sh, q_sh),
                       out_shardings=(None, c_sh), donate_argnums=(1,))
        params = jax.device_put(params, p_sh)
        cache = jax.device_put(cache, c_sh)
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (B, args.prompt_len), 0, cfg.vocab_size)
        t0 = time.time()
        logits = None
        for i in range(args.prompt_len):
            logits, cache = step(params, cache,
                                 jax.device_put(prompts[:, i:i + 1], t_sh),
                                 jax.device_put(
                                     jnp.full((B,), i, jnp.int32), q_sh))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        for j in range(args.tokens - 1):
            logits, cache = step(params, cache, jax.device_put(tok, t_sh),
                                 jax.device_put(jnp.full(
                                     (B,), args.prompt_len + j, jnp.int32),
                                     q_sh))
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        dt = time.time() - t0
    print(f"{cfg.name}: {B * args.tokens} tokens in {dt:.2f}s "
          f"({B * args.tokens / dt:.1f} tok/s) on mesh {dict(mesh.shape)}")


if __name__ == "__main__":
    main()
