"""Flash-decoding Pallas kernel: one query token vs a long KV cache.

Grid = (B, Hkv, S/block_k) with the cache axis innermost-sequential; all
`rep = Hq/Hkv` query heads of a KV group are processed together as a
(rep, D) tile, so GQA costs one cache pass regardless of rep. kv_len is a
scalar-prefetch operand (SMEM) that masks the valid cache prefix; sliding
windows bound it from below.

The model-parallel version (distributed/sharding.py) shards the cache's
sequence axis and combines per-shard (m, l, acc) with a psum LSE merge —
this kernel computes each shard's partials.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window: Optional[int], softcap: Optional[float],
            block_k: int, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[pl.program_id(0)]
    q = q_ref[0, 0].astype(jnp.float32) * scale       # (rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bk, Dv)
    s = jnp.dot(q, k.T)                               # (rep, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if window is not None:
        mask &= k_pos > kv_len - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, window=None, softcap=None,
                     block_k: int = 512, interpret: bool = False):
    """q: (B,Hq,D); k/v: (B,S,Hkv,D/Dv); kv_len: (B,) -> (B,Hq,Dv)."""
    B, Hq, D = q.shape
    _, S, Hkv, Dv = (*k.shape[:3], v.shape[-1])
    rep = Hq // Hkv
    block_k = min(block_k, S)
    pad_k = (-S) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nk = k.shape[1] // block_k
    qr = q.reshape(B, Hkv, rep, D)
    grid = (B, Hkv, nk)

    kernel = functools.partial(_kernel, window=window, softcap=softcap,
                               block_k=block_k, scale=1.0 / (D ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, D), lambda b, h, ki, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, ki, lens: (b, ki, h, 0)),
                pl.BlockSpec((1, block_k, 1, Dv),
                             lambda b, h, ki, lens: (b, ki, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, Dv),
                                   lambda b, h, ki, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, Dv), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qr, k, v)
    return out.reshape(B, Hq, Dv)
