"""Pure-jnp oracles for every Pallas kernel (and the CPU/dry-run execution
path). The attention references are *chunked* with online softmax — same
algorithm and memory behaviour class as the kernels, so dry-run HLO bytes do
not blow up with materialized (seq x seq) score matrices.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(x, cap: Optional[float]):
    return x if cap is None else cap * jnp.tanh(x / cap)


# ------------------------------------------------------------ attention (ref)
def attention_naive(q, k, v, *, causal=True, window=None, softcap=None,
                    kv_len=None):
    """Materialized-scores oracle for tests. q:(B,Sq,Hq,D) k/v:(B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    rep = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, rep, D)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    s = _softcap(s, softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        offset = Sk - Sq  # queries are the last Sq positions
        mask &= k_pos <= (q_pos + offset)
        if window is not None:
            mask &= k_pos > (q_pos + offset - window)
    if kv_len is not None:
        mask = mask[None] & (k_pos[None] < kv_len[:, None, None])
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        chunk=512):
    """Online-softmax chunked attention (the kernel's algorithm in jnp).

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0.
    Queries occupy the LAST Sq positions of the Sk keys (prefill/train when
    Sq == Sk; decode-append when Sq < Sk).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qr = q.reshape(B, Sq, Hkv, rep, D).astype(jnp.float32) * scale
    offset = Sk - Sq
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(B, n_chunks, chunk, Hkv, D).astype(jnp.float32)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv).astype(jnp.float32)
    q_pos = jnp.arange(Sq) + offset

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c_idx = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhrd,bkhd->bqhrk", qr, kb)
        s = _softcap(s, softcap)
        mask = (k_pos[None, :] < Sk) if pad else jnp.ones((1, chunk), bool)
        mask = jnp.broadcast_to(mask, (Sq, chunk))
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhrk,bkhd->bqhrd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, rep), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, rep, Dv), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc_t, vc_t, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, window=None, softcap=None,
                         chunk=1024):
    """Single-token attention against a (possibly partially-filled) KV cache.

    q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) valid prefix lengths.
    Chunked online softmax — memory O(chunk), so 500k caches are fine.
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    Dv = v.shape[-1]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qr = q.reshape(B, Hkv, rep, D).astype(jnp.float32) * scale
    pad = (-S) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, Hkv, Dv), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c_idx = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhrd,bkhd->bhrk", qr, kb.astype(jnp.float32))
        s = _softcap(s, softcap)
        mask = k_pos[None, :] < kv_len[:, None]
        if window is not None:
            mask &= k_pos[None, :] > kv_len[:, None] - 1 - window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrk,bkhd->bhrd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, Dv).astype(q.dtype)


def decode_attention_partials(q, k, v, local_len, *, offset=0,
                              global_len=None, window=None, softcap=None,
                              chunk=1024):
    """Unnormalized decode attention over a LOCAL cache shard.

    q: (B, Hq, D); k, v: (B, S_loc, Hkv, D/Dv); local_len: (B,) valid length
    within this shard; offset: global position of the shard's first slot;
    global_len: (B,) total valid length (for window masks). Returns
    (acc (B,Hq,Dv) unnormalized, m (B,Hq), l (B,Hq)) for LSE combination
    across shards (flash-decoding).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    Dv = v.shape[-1]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qr = q.reshape(B, Hkv, rep, D).astype(jnp.float32) * scale
    pad = (-S) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, Hkv, Dv), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c_idx = inp
        k_loc = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhrd,bkhd->bhrk", qr, kb.astype(jnp.float32))
        s = _softcap(s, softcap)
        mask = k_loc[None, :] < local_len[:, None]
        if window is not None and global_len is not None:
            k_glob = k_loc[None, :] + offset
            mask &= k_glob > global_len[:, None] - 1 - window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrk,bkhd->bhrd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks)))
    return (acc.reshape(B, Hq, Dv), m.reshape(B, Hq), l.reshape(B, Hq))


# -------------------------------------------------------------- mamba2 (SSD)
def ssd_scan_ref(x, dt, A, B, C, D=None, *, chunk=128,
                 initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 state-space-duality chunked scan (pure jnp oracle).

    x : (b, s, h, p)   per-head inputs
    dt: (b, s, h)      softplus-ed step sizes (>0)
    A : (h,)           negative decay rates
    B : (b, s, g, n)   input maps (g groups; h % g == 0)
    C : (b, s, g, n)   output maps
    D : (h,) optional  skip connection
    Returns (y: (b,s,h,p), final_state: (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = x.shape[1]
    nc = S // chunk
    xq = jnp.moveaxis(x.reshape(b, nc, chunk, h, p), 1, 0).astype(jnp.float32)
    dtq = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0).astype(jnp.float32)
    Bq = jnp.moveaxis(B.reshape(b, nc, chunk, g, n), 1, 0).astype(jnp.float32)
    Cq = jnp.moveaxis(C.reshape(b, nc, chunk, g, n), 1, 0).astype(jnp.float32)
    A32 = A.astype(jnp.float32)

    def per_chunk(state, inp):
        xb, dtb, Bb, Cb = inp             # (b,q,h,p),(b,q,h),(b,q,g,n)x2
        dA = dtb * A32[None, None, :]     # (b,q,h) log-decay per step
        cum = jnp.cumsum(dA, axis=1)      # (b,q,h)
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
        li = cum[:, :, None, :] - cum[:, None, :, :]      # (b,q,q,h)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        Bh = jnp.repeat(Bb, rep, axis=2)  # (b,q,h,n)
        Ch = jnp.repeat(Cb, rep, axis=2)
        cb = jnp.einsum("bihn,bjhn->bijh", Ch, Bh)         # (b,q,q,h)
        w = cb * Lmat * dtb[:, None, :, :]                 # weight on x_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xb)
        # inter-chunk: contribution of incoming state
        y_inter = jnp.einsum("bihn,bhpn->bihp", Ch, state) * \
            jnp.exp(cum)[..., None]
        # state update: S' = exp(sum dA) * S + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)       # (b,q,h)
        contrib = jnp.einsum("bjh,bjhp,bjhn->bhpn",
                             decay_to_end * dtb, xb, Bh)
        state_new = jnp.exp(cum[:, -1, :])[..., None, None] * state + contrib
        return state_new, y_intra + y_inter

    state0 = (initial_state.astype(jnp.float32) if initial_state is not None
              else jnp.zeros((b, h, p, n), jnp.float32))
    final_state, yq = jax.lax.scan(per_chunk, state0, (xq, dtq, Bq, Cq))
    y = jnp.moveaxis(yq, 0, 1).reshape(b, S, h, p)[:, :s]
    if D is not None:
        y = y + x[:, :s].astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_step_ref(state, x_t, dt_t, A, B_t, C_t, D=None):
    """Single decode step. state: (b,h,p,n); x_t: (b,h,p); dt_t: (b,h);
    B_t, C_t: (b,g,n). Returns (y_t: (b,h,p), new_state)."""
    b, h, p, n = state.shape
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)   # (b,h,n)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    state_new = state * dA[..., None, None] + \
        (dt_t.astype(jnp.float32)[..., None, None]
         * x_t.astype(jnp.float32)[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", state_new, Ch)
    if D is not None:
        y = y + x_t.astype(jnp.float32) * D[None, :, None]
    return y.astype(x_t.dtype), state_new


# --------------------------------------------------------- entropy features
def byte_entropy_ref(data: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Byte histogram + Shannon entropy (bits/byte). data: (n,) uint8."""
    hist = jnp.zeros((256,), jnp.int32).at[data.astype(jnp.int32)].add(1)
    p = hist.astype(jnp.float32) / jnp.maximum(data.shape[0], 1)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    return hist, ent


# -------------------------------------------------------------- quant8 pack
def quant_pack_ref(x: jnp.ndarray, block: int = 256):
    """Per-block absmax int8 quantization. x: (..., M) with M % block == 0."""
    shape = x.shape
    xb = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xb).max(axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def quant_unpack_ref(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    block = q.size // scale.size
    xb = q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
    return xb.reshape(q.shape).astype(dtype)
