"""Mamba2 SSD chunked-scan Pallas kernel.

Grid = (B * H, S/chunk) with the chunk axis innermost-sequential: the running
SSM state (headdim x dstate) is carried in VMEM scratch across chunks — the
TPU-native replacement for the CUDA kernel's inter-block shared-memory pass.
Per chunk the kernel computes (all on MXU-sized f32 tiles):

  cum      = cumsum(dt * A)                     (intra-chunk log decay)
  y_intra  = ((C B^T) .* L .* dt_j) x           L[i,j] = exp(cum_i - cum_j)
  y_inter  = (C state_prev) .* exp(cum)
  state    = exp(cum_last) * state_prev + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T

B/C group handling (h -> group h // (H/G)) happens in the index_map, so the
kernel body is group-agnostic.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, st_ref,
            state_scr, *, chunk: int, use_d: bool):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)              # (q, p)
    dt = dt_ref[0].astype(jnp.float32)            # (q, 1)
    A = a_ref[0, 0]                               # scalar (f32)
    Bm = b_ref[0].astype(jnp.float32)             # (q, n)
    Cm = c_ref[0].astype(jnp.float32)             # (q, n)

    dA = dt[:, 0] * A                             # (q,)
    cum = jnp.cumsum(dA)                          # (q,)
    li = cum[:, None] - cum[None, :]              # (q, q)
    iot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(jot <= iot, jnp.exp(li), 0.0)
    cb = jnp.dot(Cm, Bm.T)                        # (q, q)
    w = cb * Lmat * dt[:, 0][None, :]             # weight on x_j
    y = jnp.dot(w, x)                             # intra-chunk
    state = state_scr[...]                        # (p, n)
    y += jnp.dot(Cm, state.T) * jnp.exp(cum)[:, None]     # inter-chunk (q, p)
    if use_d:
        y += x * d_ref[0, 0]
    y_ref[0] = y.astype(y_ref.dtype)

    # state' = exp(cum_last) * state + sum_j decay_j dt_j x_j B_j^T -> (p, n)
    decay_end = jnp.exp(cum[-1] - cum)            # (q,)
    contrib = jnp.dot(x.T, (decay_end * dt[:, 0])[:, None] * Bm)  # (p, n)
    state_scr[...] = jnp.exp(cum[-1]) * state + contrib

    @pl.when(ci == nc - 1)
    def _finalize():
        st_ref[0] = state_scr[...].astype(st_ref.dtype)


def ssd_scan(x, dt, A, B, C, D=None, *, initial_state=None, chunk: int = 128,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x:(b,s,h,p) dt:(b,s,h) A:(h,) B/C:(b,s,g,n) D:(h,)|None.
    Returns (y:(b,s,h,p), final_state:(b,h,p,n)). initial_state must be None
    (training path); decode uses ops.ssd_step."""
    assert initial_state is None
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = x.shape[1]
    nc = S // chunk
    xt = jnp.moveaxis(x, 2, 1).reshape(b * h, S, p)          # (bh, S, p)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(b * h, S, 1)
    Bt = jnp.moveaxis(B, 2, 1).reshape(b * g, S, n)
    Ct = jnp.moveaxis(C, 2, 1).reshape(b * g, S, n)
    A32 = A.astype(jnp.float32).reshape(h, 1)
    use_d = D is not None
    Dm = (D if use_d else jnp.zeros((h,))).astype(jnp.float32).reshape(h, 1)

    def bc_index(bh, ci):
        return ((bh // h) * g + (bh % h) // rep, ci, 0)

    kernel = functools.partial(_kernel, chunk=chunk, use_d=use_d)
    y, st = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % h, 0)),
            pl.BlockSpec((1, chunk, n), bc_index),
            pl.BlockSpec((1, chunk, n), bc_index),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, S, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A32, Bt, Ct, Dm)
    y = jnp.moveaxis(y.reshape(b, h, S, p), 1, 2)[:, :s]
    return y, st.reshape(b, h, p, n)
