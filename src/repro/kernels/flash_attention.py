"""Flash attention Pallas TPU kernel (GQA + sliding window + logit softcap).

Tiling: grid = (B * Hq, Sq/block_q, Sk/block_k); the K dimension is the
innermost (sequential on TPU) grid axis, so the online-softmax state
(m, l, acc) lives in VMEM scratch and is carried across K steps. Blocks are
(block_q, D) / (block_k, D) VMEM tiles — D is the full head dim (MXU-aligned
128/256 for all assigned archs; 80-dim heads are zero-padded by ops).

Validated in interpret mode against kernels/ref.py (tests/test_kernels.py);
compiled path targets TPU (MXU matmuls via jnp.dot on f32 accumulators).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: Optional[int], softcap: Optional[float],
            block_q: int, block_k: int, sq: int, sk: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, Dv)
    s = jnp.dot(q, k.T)                               # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (sk - sq)                                   # queries sit at the end
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D/Dv) -> (B,Sq,Hq,Dv)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = (*k.shape[:3], v.shape[-1])
    rep = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qt = jnp.moveaxis(q, 2, 1).reshape(B * Hq, Sq, D)          # (BH, Sq, D)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, D)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, Dv)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[1] // block_q
    nk = kt.shape[1] // block_k
    grid = (B * Hq, nq, nk)

    def kv_index(bh, qi, ki):
        b = bh // Hq
        h = (bh % Hq) // rep
        return (b * Hkv + h, ki, 0)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, sq=Sq, sk=Sk,
        scale=1.0 / (D ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq + pad_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :Sq].reshape(B, Hq, Sq, Dv)
    return jnp.moveaxis(out, 1, 2)
