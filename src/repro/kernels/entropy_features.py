"""COMPREDICT byte-entropy feature kernel.

The paper's feature pass is a full scan of each partition (its stated
one-time compute cost, §V). On TPU we compute the byte histogram with a
one-hot matmul per VMEM block — (block, 256) f32 one-hot against a ones
vector rides the MXU — accumulating into a (1, 256) scratch across the
sequential grid axis; entropy is reduced on the final step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(d_ref, hist_ref, ent_ref, hist_scr, *, block: int, n: int):
    bi = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(bi == 0)
    def _init():
        hist_scr[...] = jnp.zeros_like(hist_scr)

    data = d_ref[...].astype(jnp.int32)            # (1, block)
    pos = bi * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    valid = pos < n
    onehot = (data[0][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, 256), 1)).astype(jnp.float32)
    onehot *= valid[0][:, None].astype(jnp.float32)
    hist_scr[...] += onehot.sum(axis=0, keepdims=True)

    @pl.when(bi == nb - 1)
    def _finalize():
        h = hist_scr[...]
        hist_ref[...] = h.astype(jnp.int32)
        p = h / jnp.maximum(jnp.float32(n), 1.0)
        ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)),
                                 0.0))
        ent_ref[0, 0] = ent


def byte_entropy(data, *, block: int = 8192, interpret: bool = False):
    """data: (n,) uint8 -> (hist (256,) int32, entropy bits/byte scalar)."""
    n = data.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    d = jnp.pad(data, (0, pad)).reshape(1, -1)
    nb = d.shape[1] // block
    kernel = functools.partial(_kernel, block=block, n=n)
    hist, ent = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda bi: (0, bi))],
        out_specs=[pl.BlockSpec((1, 256), lambda bi: (0, 0)),
                   pl.BlockSpec((1, 1), lambda bi: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 256), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, 256), jnp.float32)],
        interpret=interpret,
    )(d)
    return hist[0], ent[0, 0]
