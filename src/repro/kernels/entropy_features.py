"""COMPREDICT entropy feature kernels.

The paper's feature pass is a full scan of each partition (its stated
one-time compute cost, §V). Two device-resident primitives live here:

* :func:`byte_entropy` — byte histogram + Shannon entropy of one payload.
  On TPU the histogram is a one-hot matmul per VMEM block — (block, 256)
  f32 one-hot against a ones vector rides the MXU — accumulating into a
  (1, 256) scratch across the sequential grid axis.
* :func:`weighted_entropy_features` — the batched COMPREDICT pipeline:
  per-dtype-class weighted entropy H(P,d), plain entropy, distinct
  fraction, and mean value length for N partitions at once, plus the
  bucketed successive-20%-of-rows entropy variant, with ragged-length and
  pad masking. The grid is (partitions × code blocks); per block a
  (n_buckets, block) × (block, vocab) one-hot matmul scatters counts into
  a per-bucket histogram scratch, and features are reduced on the final
  block. :func:`weighted_entropy_features_ref` is the ``jax.vmap``-based
  pure-jnp oracle with identical semantics.

Inputs for the batched form come from
:func:`repro.data.tables.encode_dtype_classes` (shared-vocabulary int32
codes, row-major within a partition); the consumer-facing seam is
``repro.core.compredict.extract_features_batch`` (see ``docs/engine.md``,
"Feature backends"). Weighted entropy uses the natural log to match
``repro.core.compredict.weighted_entropy``; :func:`byte_entropy` reports
bits/byte (log2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(d_ref, hist_ref, ent_ref, hist_scr, *, block: int, n: int):
    bi = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(bi == 0)
    def _init():
        hist_scr[...] = jnp.zeros_like(hist_scr)

    data = d_ref[...].astype(jnp.int32)            # (1, block)
    pos = bi * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    valid = pos < n
    onehot = (data[0][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, 256), 1)).astype(jnp.float32)
    onehot *= valid[0][:, None].astype(jnp.float32)
    hist_scr[...] += onehot.sum(axis=0, keepdims=True)

    @pl.when(bi == nb - 1)
    def _finalize():
        h = hist_scr[...]
        hist_ref[...] = h.astype(jnp.int32)
        p = h / jnp.maximum(jnp.float32(n), 1.0)
        ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)),
                                 0.0))
        ent_ref[0, 0] = ent


def byte_entropy(data, *, block: int = 8192, interpret: bool = False):
    """data: (n,) uint8 -> (hist (256,) int32, entropy bits/byte scalar)."""
    n = data.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    d = jnp.pad(data, (0, pad)).reshape(1, -1)
    nb = d.shape[1] // block
    kernel = functools.partial(_kernel, block=block, n=n)
    hist, ent = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda bi: (0, bi))],
        out_specs=[pl.BlockSpec((1, 256), lambda bi: (0, 0)),
                   pl.BlockSpec((1, 1), lambda bi: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 256), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, 256), jnp.float32)],
        interpret=interpret,
    )(d)
    return hist[0], ent[0, 0]


# ---------------------------------------------- batched weighted entropy
def _wef_kernel(codes_ref, meta_ref, len_ref, sum_ref, buck_ref, hist_scr,
                *, block: int, n_buckets: int, vpad: int):
    """Grid (partition, code block). Scratch is the per-bucket histogram of
    the current partition; features are reduced on its final block."""
    bi = pl.program_id(1)
    nb_blocks = pl.num_programs(1)

    @pl.when(bi == 0)
    def _init():
        hist_scr[...] = jnp.zeros_like(hist_scr)

    nv = meta_ref[0, 0]                            # values in this partition
    nr = meta_ref[0, 1]                            # rows
    nc = meta_ref[0, 2]                            # columns of this class
    code = codes_ref[...].astype(jnp.int32)[0]                     # (block,)
    pos = bi * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)[0]
    valid = pos < nv                               # pad codes are -1 anyway
    code_oh = ((code[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, vpad), 1)) & valid[:, None]).astype(jnp.float32)
    if n_buckets == 1:
        hist_scr[...] += code_oh.sum(axis=0, keepdims=True)
    else:
        # bucket b spans rows [floor(b*nr/nb), floor((b+1)*nr/nb)); the
        # value at flat position p sits in row p // n_cols (row-major view)
        row = pos // jnp.maximum(nc, 1)
        b_iota = jax.lax.broadcasted_iota(
            jnp.int32, (block, n_buckets - 1), 1) + 1
        edges = (b_iota * nr) // n_buckets
        bucket = (row[:, None] >= edges).sum(axis=1)               # (block,)
        bucket_oh = (jax.lax.broadcasted_iota(
            jnp.int32, (n_buckets, block), 0) == bucket[None, :]
        ).astype(jnp.float32)
        hist_scr[...] += jnp.dot(bucket_oh, code_oh,
                                 preferred_element_type=jnp.float32)

    @pl.when(bi == nb_blocks - 1)
    def _finalize():
        lens = len_ref[...]                                      # (1, vpad)
        hist_b = hist_scr[...]                                   # (nb, vpad)
        hist = hist_b.sum(axis=0, keepdims=True)
        total = jnp.maximum(nv.astype(jnp.float32), 1.0)
        p = hist / total
        plogp = jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0)
        sum_ref[0, 0] = -jnp.sum(lens * plogp)                   # H(P,d)
        sum_ref[0, 1] = -jnp.sum(plogp)                          # plain H
        sum_ref[0, 2] = jnp.sum((hist > 0).astype(jnp.float32)) / total
        sum_ref[0, 3] = jnp.sum(lens * p)                        # mean len
        tot_b = jnp.maximum(hist_b.sum(axis=1, keepdims=True), 1.0)
        pb = hist_b / tot_b
        plogpb = jnp.where(pb > 0, pb * jnp.log(jnp.maximum(pb, 1e-30)), 0.0)
        buck_ref[...] = -(lens * plogpb).sum(axis=1)[None, :]


def _as_batched_lengths(lengths, N: int) -> jnp.ndarray:
    """(V,) shared vocab -> (N, V); (N, Vmax) per-partition passes through."""
    lengths = jnp.asarray(lengths, jnp.float32)
    if lengths.ndim == 1:
        lengths = jnp.broadcast_to(lengths[None, :], (N, lengths.shape[0]))
    return lengths


def weighted_entropy_features(codes, n_valid, n_rows, n_cols, lengths, *,
                              n_buckets: int = 1, block: int = 512,
                              interpret: bool = False):
    """Batched per-partition weighted-entropy features, one device dispatch.

    codes: (N, M) int32 value codes, -1 padded; n_valid / n_rows / n_cols:
    (N,) int32 ragged-shape metadata; lengths: per-slot string lengths,
    either (N, Vmax) local vocabularies (what
    :func:`repro.data.tables.encode_dtype_classes` produces — histogram
    width stays at the per-partition cardinality) or a (V,) vocabulary
    shared by every partition.

    Returns ``(summary (N, 4) f32, bucket_H (N, n_buckets) f32)`` where the
    summary columns are [weighted entropy H(P,d), plain entropy, distinct
    fraction, mean value length] — natural-log, matching
    ``repro.core.compredict.weighted_entropy`` / ``_entropy_block`` — and
    ``bucket_H[:, b]`` is the weighted entropy of the b-th 1/n_buckets of
    rows (``repro.core.compredict.bucketed_weighted_entropy``).
    """
    codes = jnp.asarray(codes, jnp.int32)
    N, M = codes.shape
    block = min(block, max(M, 1))
    pad = (-M) % block
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)), constant_values=-1)
    nb_blocks = codes.shape[1] // block
    lengths = _as_batched_lengths(lengths, N)
    V = lengths.shape[1]
    vpad = -(-V // 128) * 128                      # lane-aligned vocabulary
    lens = jnp.pad(lengths, ((0, 0), (0, vpad - V)))
    meta = jnp.stack([jnp.asarray(n_valid), jnp.asarray(n_rows),
                      jnp.asarray(n_cols)], axis=1).astype(jnp.int32)
    kernel = functools.partial(_wef_kernel, block=block,
                               n_buckets=n_buckets, vpad=vpad)
    return pl.pallas_call(
        kernel,
        grid=(N, nb_blocks),
        in_specs=[pl.BlockSpec((1, block), lambda i, bi: (i, bi)),
                  pl.BlockSpec((1, 3), lambda i, bi: (i, 0)),
                  pl.BlockSpec((1, vpad), lambda i, bi: (i, 0))],
        out_specs=[pl.BlockSpec((1, 4), lambda i, bi: (i, 0)),
                   pl.BlockSpec((1, n_buckets), lambda i, bi: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N, 4), jnp.float32),
                   jax.ShapeDtypeStruct((N, n_buckets), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n_buckets, vpad), jnp.float32)],
        interpret=interpret,
    )(codes, meta, lens)


def weighted_entropy_features_ref(codes, n_valid, n_rows, n_cols, lengths, *,
                                  n_buckets: int = 1):
    """Pure-jnp oracle for :func:`weighted_entropy_features`: one partition
    is a (n_buckets, V) scatter-add histogram + entropy reduction, vmapped
    over the batch. Jit-able with ``n_buckets`` static."""
    codes = jnp.asarray(codes, jnp.int32)
    N, M = codes.shape
    lengths = _as_batched_lengths(lengths, N)
    V = lengths.shape[1]
    nb = n_buckets

    def one(code_row, nv, nr, nc, lens):
        pos = jnp.arange(M, dtype=jnp.int32)
        valid = pos < nv
        safe = jnp.where(valid, code_row, 0)
        if nb == 1:
            bucket = jnp.zeros(M, jnp.int32)
        else:
            row = pos // jnp.maximum(nc, 1)
            edges = (jnp.arange(1, nb, dtype=jnp.int32) * nr) // nb
            bucket = (row[:, None] >= edges[None, :]).sum(axis=1)
        hist_b = jnp.zeros((nb, V), jnp.float32).at[bucket, safe].add(
            valid.astype(jnp.float32))
        hist = hist_b.sum(axis=0)
        total = jnp.maximum(nv.astype(jnp.float32), 1.0)
        p = hist / total
        plogp = jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0)
        summary = jnp.stack([
            -jnp.sum(lens * plogp),
            -jnp.sum(plogp),
            jnp.sum((hist > 0).astype(jnp.float32)) / total,
            jnp.sum(lens * p)])
        tot_b = jnp.maximum(hist_b.sum(axis=1, keepdims=True), 1.0)
        pb = hist_b / tot_b
        plogpb = jnp.where(pb > 0, pb * jnp.log(jnp.maximum(pb, 1e-30)), 0.0)
        return summary, -(lens[None, :] * plogpb).sum(axis=1)

    return jax.vmap(one)(codes, jnp.asarray(n_valid, jnp.int32),
                         jnp.asarray(n_rows, jnp.int32),
                         jnp.asarray(n_cols, jnp.int32), lengths)
