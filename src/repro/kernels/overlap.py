"""DATAPART fractional-overlap matrix kernels (paper §VI, G-PART edges).

G-PART's candidate graph needs, for every partition pair (i, j), the span
of their file intersection. On device this is a blocked one-hot matmul:
a (block_i, block_f) slab carrying *file sizes* at partition i's code
columns, against the transpose of a (block_j, block_f) *indicator* slab
for partition j — their product is exactly
``sum(sizes[c] for c in codes_i & codes_j)`` and rides the MXU. The file
axis is the innermost sequential grid dimension, accumulating into a
(block_i, block_j) VMEM scratch (same init/finalize structure as
``kernels/entropy_features.py``); ``-1`` pad codes match no file column,
which is the whole ragged-masking story.

Three implementations, dispatched through
:func:`repro.kernels.ops.fractional_overlap_matrix`:

* :func:`fractional_overlap_matrix` — the Pallas TPU kernel (or interpret
  mode on CPU);
* :func:`fractional_overlap_matrix_ref` — vmapped-jnp oracle (scatter-add
  one-hot rows, one einsum);
* :func:`fractional_overlap_matrix_np` — numpy fallback, also the shape
  oracle for the host-side blocked sweep in
  ``repro.core.datapart.PartitionIndex.overlap_matrix``.

All three accept an optional second operand (``codes_b``/``spans_b``) so a
row block can sweep against the full set — the rectangular form the
sharded path (``repro.core.datapart._overlap_matrix_sharded``) shards over
devices. Weights are finalized outside the kernel:
``w = inter / (span_a + span_b - inter)`` with an exact 0 wherever the
intersection is empty (``inter == 0`` propagates — no fp residue can link
disjoint partitions, the PYTHONHASHSEED bug class from PR 2).

Scale note: the dense (N, N) sweep is for moderate N (device dispatch
instead of N^2 Python). For N >= 1e6 files use
``PartitionIndex.candidate_pairs`` (inverted-index join / MinHash-style
row sampling) — that path never materializes a matrix.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _finalize_weights(inter, spans_a, spans_b):
    """inter -> fractional overlap; exact 0 for empty intersections."""
    den = spans_a[:, None] + spans_b[None, :] - inter
    return jnp.where(inter > 0.0, inter / jnp.maximum(den, 1e-12), 0.0)


# ------------------------------------------------------------ pallas kernel
def _overlap_kernel(ca_ref, sa_ref, cb_ref, out_ref, acc_scr, *,
                    block_f: int, m: int):
    """Grid (i block, j block, file block); file axis sequential."""
    fi = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ca = ca_ref[...]                                   # (bi, m) int32
    sa = sa_ref[...]                                   # (bi, m) f32
    cb = cb_ref[...]                                   # (bj, m) int32
    cols = fi * block_f + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_f), 1)

    def one_hot(codes, weights, rows):
        """Sum over code positions of (code == file column) slabs — each
        code lands in exactly one file block; -1 pads land in none."""
        def body(mm, acc):
            c = jax.lax.dynamic_slice_in_dim(codes, mm, 1, 1)    # (rows, 1)
            eq = (c == cols).astype(jnp.float32)
            if weights is not None:
                eq *= jax.lax.dynamic_slice_in_dim(weights, mm, 1, 1)
            return acc + eq
        return jax.lax.fori_loop(
            0, m, body, jnp.zeros((rows, block_f), jnp.float32))

    oh_a = one_hot(ca, sa, ca.shape[0])                # sizes at i's codes
    oh_b = one_hot(cb, None, cb.shape[0])              # indicator for j
    acc_scr[...] += jnp.dot(oh_a, oh_b.T,
                            preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _finalize():
        out_ref[...] = acc_scr[...]


def _pad_rows(codes, spans, block):
    n = codes.shape[0]
    pad = (-n) % block
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)), constant_values=-1)
        spans = jnp.pad(spans, (0, pad))
    return codes, spans


def fractional_overlap_matrix(codes, sizes, spans, *, codes_b=None,
                              spans_b=None, block_i: int = 128,
                              block_j: int = 128, block_f: int = 512,
                              interpret: bool = False):
    """(NA, NB) f32 fractional-overlap matrix from ``-1``-padded code rows.

    codes: (NA, M) int32 ascending file codes per partition, -1 padded
    (``PartitionIndex.padded_codes`` layout); sizes: (F,) f32 per-code file
    sizes; spans: (NA,) f32 partition spans. ``codes_b``/``spans_b``
    default to the first operand (square, symmetric sweep).
    """
    codes = jnp.asarray(codes, jnp.int32)
    spans = jnp.asarray(spans, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    if codes_b is None:
        codes_b, spans_b = codes, spans
    else:
        codes_b = jnp.asarray(codes_b, jnp.int32)
        spans_b = jnp.asarray(spans_b, jnp.float32)
    na, nb = codes.shape[0], codes_b.shape[0]
    m = max(codes.shape[1], codes_b.shape[1])
    codes = jnp.pad(codes, ((0, 0), (0, m - codes.shape[1])),
                    constant_values=-1)
    codes_b = jnp.pad(codes_b, ((0, 0), (0, m - codes_b.shape[1])),
                      constant_values=-1)
    block_i = min(block_i, max(na, 1))
    block_j = min(block_j, max(nb, 1))
    ca, spa = _pad_rows(codes, spans, block_i)
    cb, spb = _pad_rows(codes_b, spans_b, block_j)
    csizes = jnp.where(ca >= 0, sizes[jnp.clip(ca, 0, None)], 0.0
                       ).astype(jnp.float32)
    n_f = -(-int(sizes.shape[0]) // block_f)
    kernel = functools.partial(_overlap_kernel, block_f=block_f, m=m)
    inter = pl.pallas_call(
        kernel,
        grid=(ca.shape[0] // block_i, cb.shape[0] // block_j, n_f),
        in_specs=[pl.BlockSpec((block_i, m), lambda i, j, fi: (i, 0)),
                  pl.BlockSpec((block_i, m), lambda i, j, fi: (i, 0)),
                  pl.BlockSpec((block_j, m), lambda i, j, fi: (j, 0))],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j, fi: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ca.shape[0], cb.shape[0]),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, block_j), jnp.float32)],
        interpret=interpret,
    )(ca, csizes, cb)
    return _finalize_weights(inter, spa, spb)[:na, :nb]


# ------------------------------------------------------------- jnp oracle
def fractional_overlap_matrix_ref(codes, sizes, spans, *, codes_b=None,
                                  spans_b=None):
    """Vmapped-jnp oracle: scatter-add each code row into a dense (F,)
    one-hot (sizes on the A side, indicator on the B side), one matmul."""
    codes = jnp.asarray(codes, jnp.int32)
    spans = jnp.asarray(spans, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    if codes_b is None:
        codes_b, spans_b = codes, spans
    else:
        codes_b = jnp.asarray(codes_b, jnp.int32)
        spans_b = jnp.asarray(spans_b, jnp.float32)
    F = sizes.shape[0]

    def one_hot_row(row, weights):
        valid = row >= 0
        safe = jnp.where(valid, row, 0)
        w = jnp.where(valid, weights[safe], 0.0)
        return jnp.zeros(F, jnp.float32).at[safe].add(w)

    oh_a = jax.vmap(lambda r: one_hot_row(r, sizes))(codes)
    oh_b = jax.vmap(lambda r: one_hot_row(r, jnp.ones_like(sizes)))(codes_b)
    inter = oh_a @ oh_b.T
    return _finalize_weights(inter, spans, spans_b)


# ------------------------------------------------------------ numpy fallback
def fractional_overlap_matrix_np(codes, sizes, spans, *, codes_b=None,
                                 spans_b=None):
    """Numpy fallback with identical semantics (f64 accumulate, f32 out)."""
    codes = np.asarray(codes, np.int64)
    spans = np.asarray(spans, np.float64)
    sizes = np.asarray(sizes, np.float64)
    if codes_b is None:
        codes_b, spans_b = codes, spans
    else:
        codes_b = np.asarray(codes_b, np.int64)
        spans_b = np.asarray(spans_b, np.float64)
    F = sizes.shape[0]

    def one_hot(cs, weights):
        oh = np.zeros((cs.shape[0], F))
        r, c = np.nonzero(cs >= 0)
        oh[r, cs[r, c]] = weights[cs[r, c]]
        return oh

    inter = one_hot(codes, sizes) @ one_hot(codes_b, np.ones(F)).T
    den = spans[:, None] + spans_b[None, :] - inter
    out = np.where(inter > 0.0, inter / np.maximum(den, 1e-12), 0.0)
    return out.astype(np.float32)
