"""Public jit'd kernel API with implementation dispatch.

impl resolution:
  'auto'      -> Pallas kernel on TPU backends, chunked-jnp reference
                 elsewhere (CPU container, dry-run lowering);
  'pallas'    -> force the Pallas kernel (compiled for TPU);
  'interpret' -> Pallas kernel in interpret mode (CPU correctness tests);
  'ref'       -> pure-jnp oracle.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _backend() -> str:
    return jax.default_backend()


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _backend() == "tpu" else "ref"


# ----------------------------------------------------------------- attention
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    impl: str = "auto"):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap,
                                  interpret=(mode == "interpret"))
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    softcap=softcap)


def decode_attention(q, k, v, kv_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None, impl: str = "auto"):
    if impl == "auto":
        from repro.distributed import ctx
        if ctx.model_axis_size() > 1 and k.shape[1] % ctx.model_axis_size() == 0:
            from repro.serving.decode import sharded_decode_attention
            return sharded_decode_attention(q, k, v, kv_len, window=window,
                                            softcap=softcap)
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import decode_attention as dk
        return dk.decode_attention(q, k, v, kv_len, window=window,
                                   softcap=softcap,
                                   interpret=(mode == "interpret"))
    return _ref.decode_attention_ref(q, k, v, kv_len, window=window,
                                     softcap=softcap)


# ----------------------------------------------------------------- mamba SSD
def ssd_scan(x, dt, A, B, C, D=None, *, initial_state=None, chunk: int = 128,
             impl: str = "auto"):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import ssd_scan as sk
        return sk.ssd_scan(x, dt, A, B, C, D, initial_state=initial_state,
                           chunk=chunk, interpret=(mode == "interpret"))
    return _ref.ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk,
                             initial_state=initial_state)


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D=None):
    return _ref.ssd_step_ref(state, x_t, dt_t, A, B_t, C_t, D)


# ----------------------------------------------------------- entropy features
def byte_entropy(data, *, impl: str = "auto"):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import entropy_features as ek
        return ek.byte_entropy(data, interpret=(mode == "interpret"))
    return _ref.byte_entropy_ref(data)


def weighted_entropy_features(codes, n_valid, n_rows, n_cols, lengths, *,
                              n_buckets: int = 1, block: int = 512,
                              impl: str = "auto"):
    """Batched COMPREDICT feature primitive (see kernels/entropy_features.py).

    'ref' is the vmapped-jnp path; 'pallas'/'interpret' run the batched
    grid kernel. Returns (summary (N,4), bucket_H (N,n_buckets))."""
    from repro.kernels import entropy_features as ek
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return ek.weighted_entropy_features(
            codes, n_valid, n_rows, n_cols, lengths, n_buckets=n_buckets,
            block=block, interpret=(mode == "interpret"))
    return ek.weighted_entropy_features_ref(
        codes, n_valid, n_rows, n_cols, lengths, n_buckets=n_buckets)


# --------------------------------------------------------- overlap (DATAPART)
def fractional_overlap_matrix(codes, sizes, spans, *, codes_b=None,
                              spans_b=None, block_f: int = 512,
                              impl: str = "auto"):
    """Batched G-PART fractional-overlap matrix (see kernels/overlap.py).

    'ref'/'jnp' is the vmapped-jnp oracle, 'numpy' the host fallback;
    'pallas'/'interpret' run the blocked one-hot-matmul grid kernel.
    Returns (NA, NB) f32."""
    from repro.kernels import overlap as ok
    mode = _resolve(impl)
    if mode == "jnp":        # engine backend names alias the jnp oracle
        mode = "ref"
    if mode in ("pallas", "interpret"):
        return ok.fractional_overlap_matrix(
            codes, sizes, spans, codes_b=codes_b, spans_b=spans_b,
            block_f=block_f, interpret=(mode == "interpret"))
    if mode == "numpy":
        return ok.fractional_overlap_matrix_np(
            codes, sizes, spans, codes_b=codes_b, spans_b=spans_b)
    return ok.fractional_overlap_matrix_ref(
        codes, sizes, spans, codes_b=codes_b, spans_b=spans_b)


# ------------------------------------------------------------------- quant8
def quant_pack(x, *, block: int = 256, impl: str = "auto"):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import quant_pack as qk
        return qk.quant_pack(x, block=block, interpret=(mode == "interpret"))
    return _ref.quant_pack_ref(x, block=block)


def quant_unpack(q, scale, dtype=jnp.float32):
    return _ref.quant_unpack_ref(q, scale, dtype)
