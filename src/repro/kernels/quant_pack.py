"""Int8 block-quantization Pallas kernel — the TPU-native 'computational
compression' codec (checkpoint shards, gradient compression).

Each 256-element block shares one absmax scale; the kernel processes
(rows x 256) VMEM tiles, fully parallel grid. Ratio ~3.9x on fp32 payloads,
decompression at HBM speed — COMPREDICT treats it as just another scheme.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)             # (rows, block)
    scale = jnp.maximum(jnp.abs(x).max(axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quant_pack(x, *, block: int = 256, rows: int = 256,
               interpret: bool = False):
    """x: any shape with size % block == 0 -> (q int8 same shape,
    scale (size/block,) f32)."""
    shape = x.shape
    xb = x.reshape(-1, block)
    nblk = xb.shape[0]
    rows = min(rows, nblk)
    pad = (-nblk) % rows
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    grid = (xb.shape[0] // rows,)
    kernel = functools.partial(_kernel, block=block)
    q, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xb.shape, jnp.int8),
                   jax.ShapeDtypeStruct((xb.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q[:nblk].reshape(shape), s[:nblk, 0]
