"""Compression codec registry for the tiered store and COMPREDICT.

The paper evaluates gzip/snappy/lz4 (+bz2/zlib/lzma/...); this container has
zlib (== gzip payload), lzma and zstandard, plus a TPU-native lossy codec
(`quant8`) backed by the quant_pack Pallas kernel (CPU reference here).
Scheme index 0 is always 'none' (R=1, D=0) per the paper's convention.
"""

from __future__ import annotations

import dataclasses
import lzma
import time
import zlib
from typing import Callable, Dict, List

import numpy as np

try:
    import zstandard as zstd
    _HAVE_ZSTD = True
except ImportError:  # pragma: no cover
    _HAVE_ZSTD = False


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]
    lossy: bool = False


def _zstd_codec(level: int) -> Codec:
    c = zstd.ZstdCompressor(level=level)
    d = zstd.ZstdDecompressor()
    return Codec(f"zstd-{level}", c.compress, d.decompress)


def _quant8_compress(raw: bytes) -> bytes:
    """Lossy int8 block quantization (CPU reference of kernels/quant_pack).

    Interprets the payload as float32; 256-element blocks share one scale.
    Ratio ~= 3.9x on float data; decompression is memory-speed.
    """
    arr = np.frombuffer(raw, dtype=np.uint8)
    pad = (-arr.size) % 4
    f = np.frombuffer(np.concatenate([arr, np.zeros(pad, np.uint8)]).tobytes(),
                      dtype=np.float32)
    blocks = f.reshape(-1, 256) if f.size % 256 == 0 else None
    if blocks is None:
        bpad = (-f.size) % 256
        blocks = np.concatenate([f, np.zeros(bpad, np.float32)]).reshape(-1, 256)
    scale = np.maximum(np.abs(blocks).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.round(blocks / scale[:, None]), -127, 127).astype(np.int8)
    header = np.array([f.size], np.int64).tobytes()
    return header + scale.astype(np.float32).tobytes() + q.tobytes()


def _quant8_decompress(payload: bytes) -> bytes:
    n = int(np.frombuffer(payload[:8], np.int64)[0])
    nblk = -(-n // 256)
    scale = np.frombuffer(payload[8:8 + 4 * nblk], np.float32)
    q = np.frombuffer(payload[8 + 4 * nblk:], np.int8).reshape(nblk, 256)
    f = (q.astype(np.float32) * scale[:, None]).reshape(-1)[:n]
    return f.tobytes()


def default_codecs() -> List[Codec]:
    codecs = [
        Codec("none", lambda b: b, lambda b: b),
        Codec("zlib-1", lambda b: zlib.compress(b, 1), zlib.decompress),
        Codec("zlib-6", lambda b: zlib.compress(b, 6), zlib.decompress),
    ]
    if _HAVE_ZSTD:
        codecs += [_zstd_codec(3), _zstd_codec(19)]
    codecs += [
        Codec("lzma-1", lambda b: lzma.compress(b, preset=1), lzma.decompress),
        Codec("quant8", _quant8_compress, _quant8_decompress, lossy=True),
    ]
    return codecs


def codec_by_name(name: str) -> Codec:
    for c in default_codecs():
        if c.name == name:
            return c
    raise KeyError(name)


# Scheme order follows the paper's evaluation set; index 0 must stay 'none'.
DEFAULT_SCHEME_PREFERENCE = ("none", "zlib-1", "zstd-3", "zstd-19", "lzma-1")


def available_schemes(
        preferred: tuple = DEFAULT_SCHEME_PREFERENCE) -> tuple:
    """``preferred`` filtered down to codecs importable in this environment.

    Lets pipeline defaults degrade gracefully when optional compressors
    (zstandard) are absent instead of raising ``KeyError`` at config time.
    """
    names = {c.name for c in default_codecs()}
    return tuple(s for s in preferred if s in names)


@dataclasses.dataclass
class CodecMeasurement:
    ratio: float            # R = raw / compressed  (>= lower is worse)
    compress_sec: float
    decompress_sec_per_gb: float


def measure(codec: Codec, raw: bytes, repeats: int = 1) -> CodecMeasurement:
    """Ground-truth (ratio, decompression speed) for COMPREDICT labels."""
    t0 = time.perf_counter()
    comp = codec.compress(raw)
    t1 = time.perf_counter()
    best = np.inf
    for _ in range(repeats):
        t2 = time.perf_counter()
        codec.decompress(comp)
        best = min(best, time.perf_counter() - t2)
    gb = max(len(raw), 1) / 1e9
    return CodecMeasurement(
        ratio=len(raw) / max(len(comp), 1),
        compress_sec=t1 - t0,
        decompress_sec_per_gb=(0.0 if codec.name == "none" else best / gb),
    )
