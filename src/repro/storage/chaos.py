"""Seeded fault injection for the storage execution plane.

:class:`ChaosStore` wraps a :class:`~repro.storage.store.TieredStore` and
injects failures into the data-path operations the async migrator drives
(``get`` / ``put`` / ``replace`` / ``change_tier`` / ``delete``):

* **transient** errors (429/503-style, :class:`TransientStoreError`) —
  raised *before* the inner op runs, so nothing is billed; the caller
  retries with backoff,
* **permanent** errors (:class:`PermanentStoreError`) — the caller must
  give up on the move and roll back,
* **payload corruption** — bytes returned by ``get`` (or handed to
  ``put``/``replace``) are flipped; caught by the migrator's checksum
  verification (or by the store's ``expect_checksum`` validation) before
  any commit.

Everything is driven by one seeded ``np.random.Generator``, so a given
``(seed, op sequence)`` produces exactly the same fault schedule — every
retry and rollback path is deterministically testable (the CI chaos
seed-matrix job sweeps seeds). ``max_faults_per_op`` caps the injected
faults per ``(op, key)`` pair, guaranteeing *eventual success* for
retried operations when only transient/corruption faults are enabled.

All other attributes (``meter``, ``advance_months``, ``checksum``,
``plan_keys``, ...) delegate to the inner store untouched — metadata and
billing are never faulted, only the data path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.storage.store import StoreError, TieredStore


class TransientStoreError(StoreError):
    """A retryable 429/503-style failure: the request never reached the
    store, so nothing was billed or mutated."""

    def __init__(self, message: str, status: int = 503):
        super().__init__(message)
        self.status = status


class PermanentStoreError(StoreError):
    """A non-retryable failure (permission revoked, object lost, bucket
    gone): the caller must abandon the move and roll back."""


@dataclasses.dataclass
class ChaosStats:
    """Injected-fault counters, for assertions and benchmark reporting."""

    n_ops: int = 0                    # data-path operations intercepted
    n_transient: int = 0
    n_permanent: int = 0
    n_corrupt_get: int = 0
    n_corrupt_put: int = 0

    @property
    def n_faults(self) -> int:
        return (self.n_transient + self.n_permanent
                + self.n_corrupt_get + self.n_corrupt_put)


def _flip(raw: bytes) -> bytes:
    """Corrupt a payload by flipping its first byte (checksum-detectable)."""
    if not raw:
        return raw
    return bytes([raw[0] ^ 0xFF]) + raw[1:]


class ChaosStore:
    """Fault-injection wrapper around a :class:`TieredStore`.

    ``p_transient`` / ``p_permanent`` / ``p_corrupt`` are per-operation
    probabilities (independent draws from the seeded generator; error
    draws happen before the op, the corruption draw applies to the bytes
    crossing the boundary). ``ops`` restricts which operations are
    faulted; ``max_faults_per_op`` bounds the injected faults per
    ``(op, key)`` so a bounded-retry caller is guaranteed to succeed
    eventually when permanent faults are disabled.
    """

    _DATA_OPS = ("get", "put", "replace", "change_tier", "delete")

    def __init__(self, inner: TieredStore, *, seed: int = 0,
                 p_transient: float = 0.0, p_permanent: float = 0.0,
                 p_corrupt: float = 0.0,
                 max_faults_per_op: Optional[int] = None,
                 ops: Sequence[str] = _DATA_OPS):
        unknown = set(ops) - set(self._DATA_OPS)
        if unknown:
            raise ValueError(f"unknown chaos ops {sorted(unknown)}; "
                             f"faultable ops are {self._DATA_OPS}")
        self._inner = inner
        self._rng = np.random.default_rng(seed)
        self.p_transient = float(p_transient)
        self.p_permanent = float(p_permanent)
        self.p_corrupt = float(p_corrupt)
        self.max_faults_per_op = max_faults_per_op
        self.ops = tuple(ops)
        self.stats = ChaosStats()
        self._fault_counts: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------ delegation
    def __getattr__(self, name):
        # metadata, billing, and plan wiring pass through unfaulted
        return getattr(self._inner, name)

    @property
    def inner(self) -> TieredStore:
        return self._inner

    # ------------------------------------------------------------- injection
    def _exhausted(self, op: str, key: str) -> bool:
        if self.max_faults_per_op is None:
            return False
        return self._fault_counts.get((op, key), 0) >= self.max_faults_per_op

    def _count(self, op: str, key: str) -> None:
        self._fault_counts[(op, key)] = \
            self._fault_counts.get((op, key), 0) + 1

    def _roll(self, op: str, key: str) -> bool:
        """Pre-op error draw; returns whether to corrupt the payload.

        Both draws are taken unconditionally so the fault schedule for a
        seed depends only on the op sequence, not on earlier outcomes.
        """
        u_err = float(self._rng.random())
        u_corrupt = float(self._rng.random())
        self.stats.n_ops += 1
        if op not in self.ops or self._exhausted(op, key):
            return False
        if u_err < self.p_transient:
            self.stats.n_transient += 1
            self._count(op, key)
            raise TransientStoreError(f"{op} {key!r}: injected 503", 503)
        if u_err < self.p_transient + self.p_permanent:
            self.stats.n_permanent += 1
            self._count(op, key)
            raise PermanentStoreError(f"{op} {key!r}: injected permanent "
                                      f"failure")
        if u_corrupt < self.p_corrupt:
            self._count(op, key)
            return True
        return False

    # -------------------------------------------------------- faulted ops
    def get(self, key: str) -> bytes:
        corrupt = self._roll("get", key)
        raw = self._inner.get(key)
        if corrupt:
            self.stats.n_corrupt_get += 1
            return _flip(raw)
        return raw

    def put(self, key: str, raw: bytes, tier: int, codec: str = "none",
            expect_checksum: Optional[str] = None) -> int:
        corrupt = self._roll("put", key)
        if corrupt:
            self.stats.n_corrupt_put += 1
            raw = _flip(raw)
        return self._inner.put(key, raw, tier, codec,
                               expect_checksum=expect_checksum)

    def replace(self, key: str, raw: bytes, new_tier: int,
                codec: str = "none",
                expect_checksum: Optional[str] = None) -> int:
        corrupt = self._roll("replace", key)
        if corrupt:
            self.stats.n_corrupt_put += 1
            raw = _flip(raw)
        return self._inner.replace(key, raw, new_tier, codec,
                                   expect_checksum=expect_checksum)

    def change_tier(self, key: str, new_tier: int) -> None:
        self._roll("change_tier", key)
        self._inner.change_tier(key, new_tier)

    def delete(self, key: str) -> None:
        self._roll("delete", key)
        self._inner.delete(key)
