"""Tiered cloud object store simulation with exact paper billing semantics.

Objects live in one of L tiers; every put/get/tier-change is metered with the
:class:`~repro.core.costs.CostTable` parameters (storage-month accrual, read
and write cents/GB, early-deletion penalties, TTFB latency simulation).

This is the storage substrate under the checkpoint manager and the training
data loader; it is also what the SCOPe pipeline optimizes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Dict, Iterable, Optional

import numpy as np

from repro.core.costs import CostTable, azure_table, move_egress_cents_gb
from repro.storage.codecs import Codec, codec_by_name


class StoreError(Exception):
    """Base class for store-level failures the execution plane can handle."""


class ChecksumError(StoreError):
    """A payload's hash did not match its expected checksum — the bytes
    were corrupted in flight. Retryable: nothing was billed or mutated."""


@dataclasses.dataclass
class BillingMeter:
    """Accrues cents, mirrors the paper's cost break-up columns.

    Contract: every ``*_cents`` field is real money metered by store
    operations. Serving-SLA latency penalties are **never** cents — they
    live only in ``PipelineReport.sla_penalty`` (raw rho-weighted
    excess-ms) and in the solver objective as ``sla_lambda * penalty``;
    nothing in this meter ever accrues them (pinned by
    ``tests/test_billing_parity.py``)."""

    storage_cents: float = 0.0
    read_cents: float = 0.0
    write_cents: float = 0.0
    compute_cents: float = 0.0      # decompression compute
    penalty_cents: float = 0.0      # early-deletion charges
    egress_cents: float = 0.0       # cross-provider transfer (multi-cloud)
    ttfb_seconds: float = 0.0       # accumulated simulated read latency
    decomp_seconds: float = 0.0
    n_reads: int = 0
    n_writes: int = 0

    @property
    def total_cents(self) -> float:
        return (self.storage_cents + self.read_cents + self.write_cents
                + self.compute_cents + self.penalty_cents + self.egress_cents)

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self) | {"total_cents": self.total_cents}


@dataclasses.dataclass
class _Obj:
    payload: bytes
    raw_gb: float
    stored_gb: float
    tier: int
    codec: str
    created_month: float
    moved_month: float
    checksum: str = ""                # lazy sha256 of the DECODED payload


class TieredStore:
    """In-memory multi-tier object store with cost metering.

    Time is *logical months* advanced by :meth:`advance_months` — storage cost
    accrues per object-month, exactly like a cloud bill at the end of a
    billing period (paper §III).
    """

    def __init__(self, table: Optional[CostTable] = None,
                 simulate_latency: bool = False):
        self.table = table or azure_table()
        self.meter = BillingMeter()
        self.simulate_latency = simulate_latency
        self._objs: Dict[str, _Obj] = {}
        self._month = 0.0
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ time
    @property
    def month(self) -> float:
        return self._month

    def advance_months(self, months: float) -> None:
        """Advance logical time, accruing storage cost for everything held."""
        with self._lock:
            for o in self._objs.values():
                self.meter.storage_cents += (
                    o.stored_gb * self.table.storage_cents_gb_month[o.tier] * months)
            self._month += months

    # ------------------------------------------------------------------- ops
    def put(self, key: str, raw: bytes, tier: int, codec: str = "none",
            expect_checksum: Optional[str] = None) -> int:
        """Store ``raw`` at ``tier`` under ``codec``, metering the write.

        ``expect_checksum`` (a sha256 hexdigest of ``raw``) lets a caller
        verify the bytes arrived intact: on mismatch a :class:`ChecksumError`
        is raised *before* anything is billed or mutated — the retry path
        of the async migrator.
        """
        c = codec_by_name(codec)
        if expect_checksum is not None:
            got = hashlib.sha256(raw).hexdigest()
            if got != expect_checksum:
                raise ChecksumError(
                    f"put {key!r}: payload checksum {got[:12]} != expected "
                    f"{expect_checksum[:12]} (corrupted in flight)")
        payload = c.compress(raw)
        raw_gb = len(raw) / 1e9
        stored_gb = len(payload) / 1e9
        with self._lock:
            self.meter.write_cents += stored_gb * self.table.write_cents_gb[tier]
            self.meter.n_writes += 1
            self._objs[key] = _Obj(payload, raw_gb, stored_gb, tier, codec,
                                   self._month, self._month)
        return len(payload)

    def get(self, key: str) -> bytes:
        o = self._objs[key]
        with self._lock:
            self.meter.read_cents += o.stored_gb * self.table.read_cents_gb[o.tier]
            self.meter.ttfb_seconds += float(self.table.ttfb_seconds[o.tier])
            self.meter.n_reads += 1
        if self.simulate_latency:
            time.sleep(min(float(self.table.ttfb_seconds[o.tier]), 0.05))
        t0 = time.perf_counter()
        raw = codec_by_name(o.codec).decompress(o.payload)
        dt = time.perf_counter() - t0
        with self._lock:
            self.meter.decomp_seconds += dt
            self.meter.compute_cents += dt * self.table.compute_cents_sec
        return raw

    def checksum(self, key: str) -> str:
        """sha256 hexdigest of the object's DECODED payload (what :meth:`get`
        returns when nothing corrupts it). Computed lazily from the stored
        payload and cached; a metadata operation — nothing is billed. The
        async migrator compares this against the hash of a fetched payload
        to detect in-flight read corruption before committing a move."""
        o = self._objs[key]
        if not o.checksum:
            dec = codec_by_name(o.codec).decompress(o.payload)
            o.checksum = hashlib.sha256(dec).hexdigest()
        return o.checksum

    def has(self, key: str) -> bool:
        return key in self._objs

    def codec_of(self, key: str) -> str:
        return self._objs[key].codec

    def _egress_cents_gb(self, old_tier: int, new_tier: int) -> float:
        """Per-GB cross-provider egress for a move; 0 on single-cloud tables."""
        return float(move_egress_cents_gb(self.table, old_tier, new_tier))

    def _early_delete_cents(self, o: _Obj) -> float:
        """Prorated remainder of the minimum-stay storage charge (0 once the
        stay elapsed). Call with the lock held."""
        held = self._month - o.moved_month
        min_stay = float(self.table.early_delete_months[o.tier])
        if held < min_stay:
            return (o.stored_gb * self.table.storage_cents_gb_month[o.tier]
                    * (min_stay - held))
        return 0.0

    def change_tier(self, key: str, new_tier: int) -> None:
        """Tier change = read from old + write to new (+ early-delete penalty;
        + the source provider's egress when the flat tiers of a multi-cloud
        table belong to different providers)."""
        o = self._objs[key]
        if new_tier == o.tier:
            return
        with self._lock:
            self.meter.penalty_cents += self._early_delete_cents(o)
            self.meter.read_cents += o.stored_gb * self.table.read_cents_gb[o.tier]
            self.meter.write_cents += o.stored_gb * self.table.write_cents_gb[new_tier]
            self.meter.egress_cents += (
                o.stored_gb * self._egress_cents_gb(o.tier, new_tier))
            o.tier = new_tier
            o.moved_month = self._month

    def replace(self, key: str, raw: bytes, new_tier: int,
                codec: str = "none",
                expect_checksum: Optional[str] = None) -> int:
        """Atomic delete + put: re-encode/re-tier an existing object in ONE
        commit under the lock.

        The delete-side early-deletion penalty, the write-in of the new
        payload, and the source provider's egress (old stored bytes crossing
        the provider boundary exactly once) are billed together with the
        object swap — or, when compression or checksum validation fails, not
        at all. A failed or interrupted re-encode therefore never leaves the
        source deleted with its penalty charged and nothing re-put: the
        store-side half of the async migrator's rollback contract.

        ``expect_checksum`` (sha256 of ``raw``) is verified before any
        billing, mirroring :meth:`put`.
        """
        c = codec_by_name(codec)
        if expect_checksum is not None:
            got = hashlib.sha256(raw).hexdigest()
            if got != expect_checksum:
                raise ChecksumError(
                    f"replace {key!r}: payload checksum {got[:12]} != "
                    f"expected {expect_checksum[:12]} (corrupted in flight)")
        payload = c.compress(raw)      # may raise -> nothing billed/mutated
        raw_gb = len(raw) / 1e9
        stored_gb = len(payload) / 1e9
        with self._lock:
            o = self._objs[key]
            self.meter.penalty_cents += self._early_delete_cents(o)
            self.meter.write_cents += (
                stored_gb * self.table.write_cents_gb[new_tier])
            self.meter.n_writes += 1
            self.meter.egress_cents += (
                o.stored_gb * self._egress_cents_gb(o.tier, new_tier))
            self._objs[key] = _Obj(payload, raw_gb, stored_gb, new_tier,
                                   codec, self._month, self._month)
        return len(payload)

    def delete(self, key: str) -> None:
        with self._lock:
            o = self._objs.pop(key)
            self.meter.penalty_cents += self._early_delete_cents(o)

    # ------------------------------------------------------------ plan wiring
    @staticmethod
    def _plan_key(n: int) -> str:
        return f"part-{n:06d}"

    def apply_plan(self, plan, keys: Optional[list] = None) -> list:
        """Materialize a ``PlacementPlan`` into the store.

        Puts every partition's raw bytes at its assigned tier with its
        assigned codec; returns the object keys (``part-NNNNNN`` unless
        ``keys`` is given). Write costs are metered exactly like any put.
        """
        raws = plan.problem.raw_bytes
        if raws is None:
            raise ValueError("plan has no raw_bytes; build it with a "
                             "PartitionStage-backed problem")
        if keys is not None and len(keys) != len(raws):
            # validate BEFORE the loop: a short keys list would raise an
            # IndexError mid-way with some puts already billed
            raise ValueError(f"keys has {len(keys)} entries for "
                             f"{len(raws)} partitions; nothing applied")
        schemes = plan.problem.schemes
        out = []
        for n, raw in enumerate(raws):
            key = keys[n] if keys is not None else self._plan_key(n)
            self.put(key, raw, int(plan.assignment.tier[n]),
                     schemes[int(plan.assignment.scheme[n])])
            out.append(key)
        return out

    def migrate(self, migration, keys: Optional[list] = None) -> int:
        """Apply a ``MigrationPlan`` produced by ``PlacementEngine.reoptimize``.

        Tier-only moves go through :meth:`change_tier` (read-out + write-in +
        early-deletion penalty). Scheme changes re-encode: get (read +
        decompression compute), delete (penalty), put (write). Returns the
        number of objects moved.

        Partial plans (``MigrationPlan.select``) work unchanged: only the
        *selected* moves appear in ``migration.moved``, so deferred
        candidates are left untouched and the metered cents equal the
        partial plan's ``migration_cents + penalty_cents`` exactly.

        Shapes and key existence are validated up front — a ``keys`` list
        shorter than ``migration.moved`` (or pointing at absent objects)
        raises :class:`ValueError` *before* any move is billed, so a bad
        call can never leave the meter half-charged.
        """
        n_total = len(migration.moved)
        if keys is not None and len(keys) != n_total:
            raise ValueError(f"keys has {len(keys)} entries for a "
                             f"{n_total}-partition migration; "
                             f"nothing migrated")
        schemes = migration.plan.problem.schemes
        moved_idx = [int(n) for n in range(n_total) if migration.moved[n]]
        moved_keys = [keys[n] if keys is not None else self._plan_key(n)
                      for n in moved_idx]
        missing = [k for k in moved_keys if k not in self._objs]
        if missing:
            raise ValueError(f"unknown object keys {missing[:4]} "
                             f"({len(missing)} of {len(moved_keys)} moves); "
                             f"nothing migrated")
        for n, key in zip(moved_idx, moved_keys):
            if migration.new_scheme[n] != migration.old_scheme[n]:
                # read + atomic delete/put/egress commit (see replace):
                # the source can never end up deleted without a committed
                # destination, and egress is charged exactly once on the
                # old payload crossing the provider boundary
                raw = self.get(key)
                self.replace(key, raw, int(migration.new_tier[n]),
                             schemes[int(migration.new_scheme[n])])
            else:
                self.change_tier(key, int(migration.new_tier[n]))
        return len(moved_idx)

    # -------------------------------------------------------- streaming sync
    @staticmethod
    def partition_key(files: Iterable[str]) -> str:
        """Stable object key for a partition, derived from its file set —
        the identity the streaming engine carries across re-partitionings.
        Distinct from ``apply_plan``'s positional ``part-NNNNNN`` keys."""
        h = hashlib.sha1("\x00".join(sorted(files)).encode()).hexdigest()[:16]
        return f"gpart-{h}"

    @classmethod
    def plan_keys(cls, plan) -> list:
        """Object key per plan partition — the string form of
        ``stream.occurrence_keys``: duplicated file sets (a family can
        coexist with a merge producing the same union) get an
        occurrence-index suffix in plan order."""
        from repro.core.stream import occurrence_keys
        return [cls.partition_key(files) + ("" if c == 0 else f"#{c}")
                for files, c in occurrence_keys(plan.problem.partitions)]

    def sync_plan(self, plan, payloads: Optional[list] = None) -> Dict[str, int]:
        """Reconcile store contents with a (streaming) ``PlacementPlan``.

        Partitions are keyed by :meth:`partition_key`, so this composes with
        ``StreamingEngine``: partitions new to the store are put at their
        assigned tier/codec, survivors are tier-changed or re-encoded as the
        plan demands, and ``gpart-*`` objects whose file set no longer exists
        (merged away by a fold/compaction, or expired from the rolling
        window) are deleted — every step metered exactly like the manual
        ops. Returns op counts ``{"put", "moved", "reencoded", "deleted"}``.
        """
        parts = plan.problem.partitions
        if parts is None:
            raise ValueError("plan has no partitions; sync_plan needs the "
                             "partition file sets to key objects")
        if payloads is None:
            payloads = plan.problem.raw_bytes
        if payloads is not None and len(payloads) != len(parts):
            # validate BEFORE the loop: a misaligned payloads list would
            # raise an IndexError with earlier ops already billed
            raise ValueError(f"payloads has {len(payloads)} entries for "
                             f"{len(parts)} partitions; nothing synced")
        schemes = plan.problem.schemes
        stats = {"put": 0, "moved": 0, "reencoded": 0, "deleted": 0}
        keys = self.plan_keys(plan)
        desired = set(keys)
        for n, (p, key) in enumerate(zip(parts, keys)):
            tier = int(plan.assignment.tier[n])
            codec = schemes[int(plan.assignment.scheme[n])]
            o = self._objs.get(key)
            if o is None:
                if payloads is None:
                    raise ValueError("new partitions need payloads (pass "
                                     "payloads= or build with raw_bytes)")
                self.put(key, payloads[n], tier, codec)
                stats["put"] += 1
            elif o.codec != codec:
                raw = self.get(key)
                self.replace(key, raw, tier, codec)
                stats["reencoded"] += 1
            elif o.tier != tier:
                self.change_tier(key, tier)
                stats["moved"] += 1
        for key in [k for k in self._objs
                    if k.startswith("gpart-") and k not in desired]:
            self.delete(key)
            stats["deleted"] += 1
        return stats

    # ----------------------------------------------------------------- intro
    def tier_of(self, key: str) -> int:
        return self._objs[key].tier

    def months_held(self, keys: Iterable[str]) -> np.ndarray:
        """Per-object months since the last placement/move — the residency
        clocks ``PlacementEngine.reoptimize(months_held=...)`` expects, so a
        daemon driving a live store can price early-delete penalties from
        the store's own ground truth instead of a shadow clock."""
        return np.array([self._month - self._objs[k].moved_month
                         for k in keys], np.float64)

    def stored_gb(self, key: str) -> float:
        return self._objs[key].stored_gb

    def keys(self):
        return list(self._objs)

    def tier_usage_gb(self) -> Dict[int, float]:
        usage: Dict[int, float] = {t: 0.0 for t in range(self.table.num_tiers)}
        for o in self._objs.values():
            usage[o.tier] += o.stored_gb
        return usage
