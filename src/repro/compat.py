"""jax API-drift shims, consolidated (ROADMAP carry-over).

Every version-gated jax surface the repo touches lives here, so the rest of
the codebase imports one module instead of scattering ``hasattr`` probes:

* :func:`mesh_context` — ``jax.set_mesh`` vs the legacy Mesh-as-context
  manager global-mesh API.
* :func:`shard_map` — ``jax.shard_map`` vs ``jax.experimental.shard_map``,
  with the replication-check kwarg (``check_rep`` -> ``check_vma`` rename)
  picked from the target's signature.
* :func:`make_mesh` — ``jax.make_mesh`` with the ``AxisType`` kwarg gated
  on availability (older jax defaults every axis to Auto anyway).

``repro.distributed.ctx`` and ``repro.launch.mesh`` re-export these for
their existing call sites; new code should import ``repro.compat``
directly.
"""

from __future__ import annotations

import inspect

import jax


def mesh_context(mesh):
    """``jax.set_mesh`` on new jax; on older versions the Mesh object itself
    is the (legacy global-mesh) context manager with the same effect."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` on new jax, the experimental module on older jax.
    The replication-check kwarg is picked from the target's signature
    (``check_rep`` was renamed ``check_vma`` independently of the function's
    promotion out of jax.experimental)."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    kw = {}
    if check_vma is not None:
        params = inspect.signature(sm).parameters
        kw = {"check_vma" if "check_vma" in params else "check_rep":
              check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with every axis explicitly Auto when the
    ``AxisType`` enum exists; older versions default to Auto without it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes))
