#!/usr/bin/env python
"""Markdown link checker — no third-party deps, used by the CI docs job.

Checks every inline link/image target in the given markdown files (or all
``*.md`` under given directories):

 * relative paths must exist on disk (anchors are stripped; a bare
   ``#anchor`` self-link is checked against the file's own headings);
 * ``http(s)``/``mailto`` targets are recorded but not fetched (CI must not
   depend on external availability).

    python tools/check_links.py README.md docs

Exits 1 with a per-link report if anything is broken.
"""

from __future__ import annotations

import pathlib
import re
import sys
from typing import List

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def _anchor_of(heading: str) -> str:
    """GitHub-style slug: lowercase, drop punctuation, spaces to dashes."""
    slug = re.sub(r"[^\w\- ]", "", heading.strip().lower())
    return re.sub(r" +", "-", slug)


def check_file(path: pathlib.Path) -> List[str]:
    text = CODE_FENCE_RE.sub("", path.read_text())
    anchors = {_anchor_of(h) for h in HEADING_RE.findall(text)}
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in anchors:
                errors.append(f"{path}: missing anchor {target}")
            continue
        rel, _, _anchor = target.partition("#")
        if not (path.parent / rel).exists():
            errors.append(f"{path}: broken link {target}")
    return errors


def check(paths: List[str]) -> List[str]:
    files: List[pathlib.Path] = []
    for p in paths:
        pp = pathlib.Path(p)
        files.extend(sorted(pp.rglob("*.md")) if pp.is_dir() else [pp])
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        errors.extend(check_file(f))
    return errors


def main() -> int:
    targets = sys.argv[1:] or ["README.md", "docs"]
    errors = check(targets)
    for e in errors:
        print(e, file=sys.stderr)
    n = len(errors)
    print(f"check_links: {'OK' if not n else f'{n} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
