"""Fleet solver: one batched OPTASSIGN dispatch vs a per-tenant loop.

A fleet of T tenants (ragged sizes drawn around N partitions each) is
solved two ways: T independent ``capacitated_assign`` calls — Python
dispatch + a jit re-trace per distinct N + per-candidate host finish —
and one ``capacitated_assign_batch`` dispatch (pad to ``(T, N_max)``,
one jitted Lagrangian scan and one lockstep host finish over the whole
fleet). Tenant caps are binding (the greedy-hottest tier is clamped to
90% of its greedy usage) so both paths run the full scan+repair+swap
pipeline rather than the greedy shortcut.

Two speedups are emitted per T. ``speedup`` is the cold ratio — caches
cleared, first solve of the process, which is what a fleet daemon pays
on its first cycle or whenever tenant shapes drift (the loop re-traces
the jitted scan once per distinct N; the batch compiles once).
``speedup_warm`` is the steady-state ratio with jit caches hot. The
acceptance floor is >= 5x (cold) at T >= 64 on CPU.

A second section exercises shared-capacity coupling: a fleet-wide cap
on the most-used tier set *below* fleet demand. The fleet solve trades
tenants off against each other and stays feasible; the per-tenant loop
cannot express the coupling at all — carving the pool into T equal
static slices makes many tenants infeasible, which is reported next to
the fleet result.

``FleetEngine.solve`` vs a ``PlacementEngine`` loop is timed end-to-end
(assignment + billing) at the same scale.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import os
import time

import jax
import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.costs import Weights, azure_table, cost_tensor, \
    latency_feasible
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.core.fleet import FleetEngine
from repro.core.optassign import capacitated_assign, capacitated_assign_batch

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

FLEET_T = (8, 64) if SMOKE else (8, 64, 256)
MEAN_N = 8 if SMOKE else 24
ENGINE_T = 32 if SMOKE else 128
REPEATS = 2 if SMOKE else 3


def _fleet(T, mean_n, seed=0, K=3):
    """T ragged tenants: (cost, feas, stored, cap) with a binding cap."""
    rng = np.random.default_rng(seed)
    table = azure_table()
    out = []
    for _ in range(T):
        N = int(rng.integers(max(1, mean_n // 2), 2 * mean_n))
        spans = rng.uniform(0.5, 50.0, N)
        rho = rng.gamma(1.0, 20.0, N)
        cur = rng.integers(-1, table.num_tiers, N)
        R = np.concatenate([np.ones((N, 1)),
                            rng.uniform(1.2, 6.0, (N, K - 1))], 1)
        D = np.concatenate([np.zeros((N, 1)),
                            rng.uniform(0.01, 3.0, (N, K - 1))], 1)
        lat = rng.choice([0.1, 1.0, 5.0, np.inf], N)
        cost = cost_tensor(spans, rho, cur, R, D, table, Weights(), months=6)
        feas = latency_feasible(D, lat, table)
        stored = np.repeat((spans[:, None] / R)[:, None, :],
                           table.num_tiers, 1)
        # clamp the greedy-hottest tier to 90% of its greedy usage so the
        # cap binds and both paths run the full scan + host finish
        flat = np.where(feas, cost, np.inf).reshape(N, -1)
        t = flat.argmin(1) // K
        s = flat.argmin(1) % K
        use = np.zeros(table.num_tiers)
        np.add.at(use, t, stored[np.arange(N), t, s])
        cap = np.full(table.num_tiers, np.inf)
        cap[use.argmax()] = 0.9 * use.max()
        out.append((cost, feas, stored, cap))
    return out


def _loop(fleet):
    return [capacitated_assign(c, f, s, cap) for c, f, s, cap in fleet]


def _batch(fleet):
    return capacitated_assign_batch([x[0] for x in fleet],
                                    [x[1] for x in fleet],
                                    [x[2] for x in fleet],
                                    [x[3] for x in fleet])


def _cold_ms(fn, *a, repeats=2):
    """Best-of-``repeats`` wall time, jit caches cleared before each."""
    best, out = float("inf"), None
    for _ in range(repeats):
        jax.clear_caches()
        t0 = time.perf_counter()
        out = fn(*a)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def _problems(T, mean_n, table, cfg, seed=1, K=2):
    rng = np.random.default_rng(seed)
    probs = []
    for _ in range(T):
        N = int(rng.integers(max(1, mean_n // 2), 2 * mean_n))
        spans = rng.lognormal(0.0, 1.2, N) * 50.0
        rho = rng.gamma(0.7, 25.0, N)
        R = np.concatenate([np.ones((N, 1)),
                            rng.uniform(1.2, 6.0, (N, K - 1))], 1)
        D = np.concatenate([np.zeros((N, 1)),
                            rng.uniform(0.01, 2.0, (N, K - 1))
                            * spans[:, None]], 1)
        probs.append(PlacementProblem(
            spans_gb=spans, rho=rho, current_tier=np.full(N, -1), R=R, D=D,
            schemes=cfg.schemes, table=table, cfg=cfg))
    return probs


def run():
    rows = []

    # ---- raw solver: batched dispatch vs per-tenant loop ---------------
    for T in FLEET_T:
        fleet = _fleet(T, MEAN_N, seed=T)
        singles, loop_cold = _cold_ms(_loop, fleet)
        batch, batch_cold = _cold_ms(_batch, fleet)
        for s, b in zip(singles, batch.assignments):   # parity, every run
            assert np.array_equal(s.tier, b.tier) and s.cost == b.cost
        _, loop_warm = timed(_loop, fleet, repeats=REPEATS)
        _, batch_warm = timed(_batch, fleet, repeats=REPEATS)
        rows.append(row(f"fleet/capacitated/T{T}", batch_cold,
                        tenants=T, mean_n=MEAN_N,
                        loop_us=round(loop_cold, 1),
                        speedup=round(loop_cold / batch_cold, 2),
                        batch_warm_us=round(batch_warm, 1),
                        loop_warm_us=round(loop_warm, 1),
                        speedup_warm=round(loop_warm / batch_warm, 2)))

    # ---- coupled path: fleet-wide shared cap on a premium tier ---------
    # each tenant's partition 0 is pinned (latency) to tier 0 / scheme 0,
    # with heterogeneous demand; the pooled cap covers the fleet's total
    # pinned demand with 15% headroom. The fleet solve trades tenants off
    # against each other and stays feasible; the per-tenant loop cannot
    # express the coupling — carving the pool into T equal static slices
    # strands capacity and leaves the heavy tenants infeasible.
    T = FLEET_T[-1]
    L = azure_table().num_tiers
    fleet, pinned = [], 0.0
    for c, f, s, _ in _fleet(T, MEAN_N, seed=2):
        f = f.copy()
        f[0, :, :] = False
        f[0, 0, 0] = True
        pinned += s[0, 0, 0]
        fleet.append((c, f, s, np.full(L, np.inf)))
    scap = np.full(L, np.inf)
    scap[0] = 1.15 * pinned
    coupled, us = timed(
        capacitated_assign_batch,
        [x[0] for x in fleet], [x[1] for x in fleet],
        [x[2] for x in fleet], [x[3] for x in fleet],
        repeats=REPEATS,
        shared_tier_groups=np.arange(L), shared_capacity_gb=scap)
    slice_cap = np.where(np.arange(L) == 0, scap[0] / T, np.inf)
    slice_feas = sum(int(capacitated_assign(c, f, s, slice_cap).feasible)
                     for c, f, s, _ in fleet)
    rows.append(row(f"fleet/shared_cap/T{T}", us, tenants=T,
                    feasible=bool(coupled.feasible),
                    cap_gb=round(float(scap[0]), 1),
                    use_gb=round(float(coupled.shared_use_gb[0]), 1),
                    per_tenant_slice_feasible=f"{slice_feas}/{T}"))

    # ---- engines end-to-end: FleetEngine.solve vs PlacementEngine loop -
    table = azure_table()
    caps = np.array([150.0, 300.0, 2500.0, np.inf])
    cfg = ScopeConfig(schemes=("none", "lz4"), capacity_gb=caps)
    probs = _problems(ENGINE_T, MEAN_N, table, cfg)
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    _, loop_cold = _cold_ms(lambda: [pe.solve(p) for p in probs])
    fp, fleet_cold = _cold_ms(fe.solve, probs)
    _, loop_us = timed(lambda: [pe.solve(p) for p in probs],
                       repeats=REPEATS)
    _, fleet_us = timed(fe.solve, probs, repeats=REPEATS)
    rows.append(row(f"fleet/engine_solve/T{ENGINE_T}", fleet_cold,
                    tenants=ENGINE_T, loop_us=round(loop_cold, 1),
                    speedup=round(loop_cold / fleet_cold, 2),
                    fleet_warm_us=round(fleet_us, 1),
                    loop_warm_us=round(loop_us, 1),
                    speedup_warm=round(loop_us / fleet_us, 2),
                    total_cents=round(fp.total_cents, 2)))

    emit(rows, "fleet")


if __name__ == "__main__":
    run()
