"""Drift re-optimization — the scenario the batch monolith couldn't express.

A placement is computed for N synthetic partitions; access rates then drift
(a subset goes hot, another goes cold). ``PlacementEngine.reoptimize`` builds
an incremental MigrationPlan whose objective internalizes tier-change
transfer costs and early-deletion penalties, and locks the schemes of
undrifted partitions. We record:

 * reoptimize latency at N in {500, 2000},
 * how many partitions move and what the migration costs,
 * steady-state cost of stale vs re-optimized vs from-scratch placement —
   reoptimize should recover most of the from-scratch saving while paying
   bounded one-off migration cost.
"""

import time

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.costs import azure_table
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig


def _problem(N, table, cfg, seed):
    rng = np.random.default_rng(seed)
    K = len(cfg.schemes)
    spans = rng.lognormal(0.0, 1.2, N) * 2.0
    rho = rng.gamma(0.7, 25.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))], 1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, K - 1)) * spans[:, None]],
                       1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=cfg.schemes, table=table, cfg=cfg)


def run():
    rows = []
    table = azure_table()
    for N in (500, 2000):
        cfg = ScopeConfig(tier_whitelist=(0, 1, 2, 3))
        eng = PlacementEngine(table, cfg)
        problem = _problem(N, table, cfg, seed=N)
        plan = eng.solve(problem)

        rng = np.random.default_rng(N + 1)
        new_rho = problem.rho.copy()
        hot = rng.random(N) < 0.10          # 10% of partitions go hot
        cold = ~hot & (rng.random(N) < 0.10)  # 10% go cold
        new_rho[hot] *= rng.uniform(20.0, 100.0, int(hot.sum()))
        new_rho[cold] /= rng.uniform(20.0, 100.0, int(cold.sum()))

        mig, us = timed(lambda: eng.reoptimize(plan, new_rho,
                                               months_held=0.25), repeats=1)

        # stale placement billed under the drifted access rates
        import dataclasses
        drifted = dataclasses.replace(problem, rho=new_rho)
        stale = eng.billing(drifted, plan.assignment).total_cents
        # from-scratch re-solve (ignores migration friction entirely)
        scratch = eng.solve(drifted).report.total_cents
        reopt = mig.plan.report.total_cents
        recovered = ((stale - reopt) / max(stale - scratch, 1e-12)
                     if stale > scratch else 1.0)
        rows.append(row(f"drift/N={N}", us,
                        n_moved=mig.n_moved,
                        migration_cents=round(mig.migration_cents, 6),
                        penalty_cents=round(mig.penalty_cents, 6),
                        stale_cents=round(stale, 4),
                        reopt_cents=round(reopt, 4),
                        scratch_cents=round(scratch, 4),
                        saving_recovered=round(recovered, 4)))
    return emit(rows, "drift_reoptimize")


if __name__ == "__main__":
    run()
