"""Array-native G-PART scaling sweep (the DATAPART scalability tentpole).

Three sections:

* ``throughput`` — array-backed ``g_part`` (inverted-index candidate join +
  vectorized heap merge) vs the original pair-by-pair ``g_part_ref`` on the
  same instance, with an identical-result check. The acceptance bar is
  >= 10x at N >= 2e4 query families (measured ~2 orders of magnitude —
  ref is quadratic in Python, the array path is near-linear in candidate
  edges).
* ``sampled`` — the MinHash-style row-sampled estimator at N >= 1e6 files:
  the candidate graph never materializes anything dense, and read_cost
  stays within 1.1x of the exact merge on the largest instance where the
  exact sweep is feasible.
* ``matrix`` — one batched fractional-overlap matrix dispatch
  (``kernels/overlap.py`` via the 'ref' jnp oracle on CPU; 'pallas' on
  TPU) at moderate N, the device-resident candidate path.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import os
import time

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core import datapart as dp

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _instance(n_fams, n_files, seed=0):
    """Contiguous-window query families over a shared file universe (the
    same §VI-B structure bench_gpart streams)."""
    rng = np.random.default_rng(seed)
    sizes = {f"s{i}": float(rng.uniform(0.5, 2.0)) for i in range(n_files)}
    w = rng.integers(2, 9, n_fams)
    lo = rng.integers(0, n_files - 9, n_fams)
    qf = [(tuple(f"s{j}" for j in range(lo[k], lo[k] + w[k])),
           float(rng.uniform(0.5, 8.0))) for k in range(n_fams)]
    return dp.make_partitions(qf, sizes)


def _canon(parts):
    return sorted((tuple(sorted(p.files)), round(p.rho, 9)) for p in parts)


def run():
    rows = []

    # ------------------------------------------------ array vs ref throughput
    ladder = ((1_000, 2_000), (2_000, 2_000)) if SMOKE else \
        ((2_000, 2_000), (20_000, 2_000))
    for n_fams, _ in ladder:
        parts = _instance(n_fams, n_fams * 20)
        arr, us_arr = timed(lambda p=parts: dp.g_part(list(p), s_thresh=15.0),
                            repeats=1)
        ref, us_ref = timed(lambda p=parts: dp.g_part_ref(list(p),
                                                          s_thresh=15.0),
                            repeats=1)
        rows.append(row(
            f"gpart_scale/throughput/N{len(parts)}", us_arr,
            ref_us=round(us_ref, 1),
            speedup_vs_ref=round(us_ref / us_arr, 1),
            identical_result=_canon(arr) == _canon(ref),
            n_partitions=len(arr)))

    # -------------------------------------- sampled estimator accuracy + scale
    n_acc = 2_000 if SMOKE else 20_000
    parts = _instance(n_acc, n_acc * 10, seed=1)
    exact, us_exact = timed(lambda: dp.g_part(list(parts), s_thresh=15.0),
                            repeats=1)
    sampled, us_s = timed(lambda: dp.g_part(list(parts), s_thresh=15.0,
                                            sample=0.5, max_degree=8),
                          repeats=1)
    rows.append(row(
        f"gpart_scale/sampled/N{len(parts)}", us_s,
        exact_us=round(us_exact, 1),
        read_cost_ratio=round(dp.read_cost(sampled)
                              / max(dp.read_cost(exact), 1e-12), 4),
        n_partitions=len(sampled), n_partitions_exact=len(exact)))

    n_files = 50_000 if SMOKE else 1_000_000
    big = _instance(n_files * 3 // 20, n_files, seed=2)
    t0 = time.perf_counter()
    out = dp.g_part(list(big), s_thresh=15.0, sample=0.5, max_degree=8)
    us_big = (time.perf_counter() - t0) * 1e6
    rows.append(row(
        f"gpart_scale/sampled/F{n_files}", us_big,
        n_files=n_files, n_families=len(big), n_partitions=len(out),
        read_cost=round(dp.read_cost(out) / 1e6, 4)))

    # ------------------------------------------------- batched matrix dispatch
    n_mat = 256 if SMOKE else 1_024
    parts = _instance(n_mat, n_mat * 8, seed=3)
    idx = dp.PartitionIndex.from_partitions(parts)
    backend = "ref"   # jnp oracle; 'pallas' when a TPU is attached
    w, us_mat = timed(lambda: np.asarray(idx.overlap_matrix(backend)),
                      repeats=1)
    rows.append(row(
        f"gpart_scale/matrix/N{idx.n}", us_mat, backend=backend,
        nnz_frac=round(float((w > 0).mean()), 4)))

    return emit(rows, "gpart_scale")


if __name__ == "__main__":
    run()
