"""Deliverable (g) — roofline table assembled from dry-run artifacts
(benchmarks/results/dryrun/*.json). Run the dry-run sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import json
import pathlib

from benchmarks.common import emit, row

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def run():
    rows = []
    if not DRYRUN.exists():
        print("no dry-run results yet — run repro.launch.dryrun first")
        return emit(rows, "roofline")
    for path in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(path.read_text())
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(row(name, 0, status="skipped",
                            reason=rec.get("reason", "")[:60]))
            continue
        if rec.get("status") != "ok":
            rows.append(row(name, 0, status=rec.get("status"),
                            error=rec.get("error", "")[:80]))
            continue
        r = rec["roofline"]
        rows.append(row(
            name, rec.get("compile_s", 0) * 1e6,
            compute_s=round(r["compute_s"], 5),
            memory_s=round(r["memory_s"], 5),
            collective_s=round(r["collective_s"], 5),
            dominant=r["dominant"],
            useful_ratio=(round(r["useful_ratio"], 3)
                          if r.get("useful_ratio") else None),
            temp_gb_per_dev=round(
                rec["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9, 2),
        ))
    return emit(rows, "roofline")


if __name__ == "__main__":
    run()
