"""Paper Tables V-VIII + Fig 4 — COMPREDICT prediction quality, plus the
feature-backend sweep (:func:`run_features`, registered as ``features`` in
``benchmarks/run.py``).

V    : training-data (random vs queries) x features (size vs weighted
       entropy) ablation, gzip-class codec;
VI   : compression-ratio prediction, models x schemes x layouts (TPC-H 1GB);
VII  : ratio prediction on larger/skewed TPC-H;
VIII : decompression-speed prediction.
"""

import time

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.compredict import (build_dataset, extract_features_batch,
                                   query_samples, random_samples, train_eval)
from repro.data import tpch
from repro.data.tables import Table, encode_dtype_classes
from repro.storage.codecs import codec_by_name

SCHEMES_V1 = [("zlib-6", "row"), ("zstd-3", "row"), ("zlib-6", "col"),
              ("zstd-3", "col"), ("lzma-1", "col")]
MODELS = ["Averaging", "XGBoostless", "NeuralNetwork", "SVR", "RandomForest"]


def _mk_samples(scale_rows, skew, seed, n_per_template=8):
    db = tpch.generate(scale_rows=scale_rows, skew=skew, seed=seed)
    qs = tpch.generate_queries(db, n_per_template=n_per_template,
                               seed=seed + 1)
    return db, qs, query_samples(qs, db.tables, max_rows=1500)


def run():
    rows = []
    db, qs, samples = _mk_samples(5000, 0.0, 0)

    # ---- Table V: sampling x features (gzip ~ zlib-6, row layout)
    codec = codec_by_name("zlib-6")
    rand = random_samples(db.tables["lineitem"], 60, 900, seed=3)
    for train_data, samp in (("random", rand), ("queries", samples)):
        for feats in ("size", "weighted_entropy"):
            if train_data == "random" and feats == "size":
                continue
            for target in ("ratio", "dspeed"):
                ds = build_dataset(samp, codec, "row", feats)
                (_, res), us = timed(
                    lambda d=ds, t=target: train_eval(d, "RandomForest", t),
                    repeats=1)
                rows.append(row(
                    f"tableV/{train_data}/{feats}/{target}", us,
                    mae=round(res.mae, 4), mape=round(res.mape, 3),
                    r2=round(res.r2, 4)))

    # ---- Fig 4: query samples compress better than random rows
    ds_q = build_dataset(query_samples(
        [q for q in qs if q.table == "lineitem"], db.tables, 900),
        codec, "row")
    ds_r = build_dataset(rand, codec, "row")
    rows.append(row("fig4/ratio_mean", 0,
                    queries=round(float(ds_q.ratio.mean()), 3),
                    random=round(float(ds_r.ratio.mean()), 3)))

    # ---- Table VI: models x schemes x layouts, ratio (TPC-H '1GB')
    for scheme, layout in SCHEMES_V1:
        ds = build_dataset(samples, codec_by_name(scheme), layout)
        for model in ("Averaging", "NeuralNetwork", "SVR", "RandomForest"):
            (_, res), us = timed(
                lambda d=ds, m=model: train_eval(d, m, "ratio"), repeats=1)
            rows.append(row(f"tableVI/{scheme}+{layout}/{model}", us,
                            mae=round(res.mae, 4), mape=round(res.mape, 3),
                            r2=round(res.r2, 4)))

    # ---- Table VII: '100GB' (larger scale) + Zipf-skew variants
    for tag, (scale, skew) in (("100GB", (20000, 0.0)),
                               ("Skew", (5000, 1.2))):
        _, _, samp = _mk_samples(scale, skew, seed=11, n_per_template=6)
        for scheme, layout in (("zlib-6", "row"), ("zlib-6", "col")):
            ds = build_dataset(samp, codec_by_name(scheme), layout)
            for model in ("Averaging", "SVR", "RandomForest"):
                (_, res), us = timed(
                    lambda d=ds, m=model: train_eval(d, m, "ratio"),
                    repeats=1)
                rows.append(row(
                    f"tableVII/{tag}/{scheme}+{layout}/{model}", us,
                    mae=round(res.mae, 4), mape=round(res.mape, 3),
                    r2=round(res.r2, 4)))

    # ---- Table VIII: decompression sec/GB prediction
    for scheme, layout in (("zlib-6", "row"), ("zlib-6", "col"),
                           ("lzma-1", "col")):
        ds = build_dataset(samples, codec_by_name(scheme), layout)
        for model in ("Averaging", "SVR", "RandomForest"):
            (_, res), us = timed(
                lambda d=ds, m=model: train_eval(d, m, "dspeed"), repeats=1)
            rows.append(row(f"tableVIII/{scheme}+{layout}/{model}", us,
                            mae=round(res.mae, 4), mape=round(res.mape, 3),
                            r2=round(res.r2, 4)))
    return emit(rows, "tablesV-VIII_compredict")


# ------------------------------------------------- feature-backend sweep
def _synthetic_partitions(n_parts: int, n_rows: int, seed: int = 0):
    """Mixed-dtype partitions sized like query-result samples."""
    rng = np.random.default_rng(seed)
    strs = np.array([f"v{i}" for i in range(40)])
    out = []
    for i in range(n_parts):
        n = n_rows + int(rng.integers(0, n_rows // 2 + 1))
        out.append(Table(f"p{i}", {
            "a": rng.integers(0, 50, n),
            "b": rng.integers(0, 1000, n),
            "x": rng.normal(size=n).round(2),
            "y": rng.normal(size=n),
            "s": rng.choice(strs[:5], n),
            "t": rng.choice(strs, n),
        }))
    return out


def run_features():
    """NumPy loop vs batched device extraction (kind='bucketed', the full
    COMPREDICT feature set). 'jnp_extract' is the per-batch hot-path cost
    once partitions are dictionary-encoded (the paper's one-time pass,
    reported separately as 'encode'); acceptance bar: >= 10x over the NumPy
    loop at N >= 500 on CPU jit alone."""
    rows = []
    for N, n_rows in ((64, 150), (200, 150), (500, 150), (1000, 150)):
        tabs = _synthetic_partitions(N, n_rows, seed=N)
        sizes = [t.nbytes("col") for t in tabs]
        _, us_np = timed(lambda: extract_features_batch(
            tabs, "col", "bucketed", "numpy", sizes=sizes), repeats=1)
        enc, us_enc = timed(lambda: encode_dtype_classes(tabs), repeats=1)
        fn = lambda: extract_features_batch(          # noqa: E731
            tabs, "col", "bucketed", "jnp", sizes=sizes, encoded=enc)
        fn()                                          # warm the jit cache
        _, us_jnp = timed(fn, repeats=3)
        _, us_tot = timed(lambda: extract_features_batch(
            tabs, "col", "bucketed", "jnp", sizes=sizes), repeats=1)
        rows.append(row(f"features/N{N}/numpy_loop", us_np))
        rows.append(row(f"features/N{N}/encode_once", us_enc))
        rows.append(row(f"features/N{N}/jnp_extract", us_jnp,
                        speedup_vs_numpy=round(us_np / us_jnp, 1)))
        rows.append(row(f"features/N{N}/jnp_encode_plus_extract", us_tot,
                        speedup_vs_numpy=round(us_np / us_tot, 1)))
    # Pallas interpret mode is a correctness vehicle, not a CPU fast path:
    # record its overhead at small N so regressions are visible.
    tabs = _synthetic_partitions(32, 100, seed=1)
    enc = encode_dtype_classes(tabs)
    t0 = time.perf_counter()
    extract_features_batch(tabs, "col", "bucketed", "pallas", encoded=enc)
    rows.append(row("features/N32/pallas_interpret",
                    (time.perf_counter() - t0) * 1e6))
    return emit(rows, "feature_backends")


if __name__ == "__main__":
    run()
    run_features()
