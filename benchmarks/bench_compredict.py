"""Paper Tables V-VIII + Fig 4 — COMPREDICT prediction quality.

V    : training-data (random vs queries) x features (size vs weighted
       entropy) ablation, gzip-class codec;
VI   : compression-ratio prediction, models x schemes x layouts (TPC-H 1GB);
VII  : ratio prediction on larger/skewed TPC-H;
VIII : decompression-speed prediction.
"""

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.compredict import (build_dataset, query_samples,
                                   random_samples, train_eval)
from repro.data import tpch
from repro.storage.codecs import codec_by_name

SCHEMES_V1 = [("zlib-6", "row"), ("zstd-3", "row"), ("zlib-6", "col"),
              ("zstd-3", "col"), ("lzma-1", "col")]
MODELS = ["Averaging", "XGBoostless", "NeuralNetwork", "SVR", "RandomForest"]


def _mk_samples(scale_rows, skew, seed, n_per_template=8):
    db = tpch.generate(scale_rows=scale_rows, skew=skew, seed=seed)
    qs = tpch.generate_queries(db, n_per_template=n_per_template,
                               seed=seed + 1)
    return db, qs, query_samples(qs, db.tables, max_rows=1500)


def run():
    rows = []
    db, qs, samples = _mk_samples(5000, 0.0, 0)

    # ---- Table V: sampling x features (gzip ~ zlib-6, row layout)
    codec = codec_by_name("zlib-6")
    rand = random_samples(db.tables["lineitem"], 60, 900, seed=3)
    for train_data, samp in (("random", rand), ("queries", samples)):
        for feats in ("size", "weighted_entropy"):
            if train_data == "random" and feats == "size":
                continue
            for target in ("ratio", "dspeed"):
                ds = build_dataset(samp, codec, "row", feats)
                (_, res), us = timed(
                    lambda d=ds, t=target: train_eval(d, "RandomForest", t),
                    repeats=1)
                rows.append(row(
                    f"tableV/{train_data}/{feats}/{target}", us,
                    mae=round(res.mae, 4), mape=round(res.mape, 3),
                    r2=round(res.r2, 4)))

    # ---- Fig 4: query samples compress better than random rows
    ds_q = build_dataset(query_samples(
        [q for q in qs if q.table == "lineitem"], db.tables, 900),
        codec, "row")
    ds_r = build_dataset(rand, codec, "row")
    rows.append(row("fig4/ratio_mean", 0,
                    queries=round(float(ds_q.ratio.mean()), 3),
                    random=round(float(ds_r.ratio.mean()), 3)))

    # ---- Table VI: models x schemes x layouts, ratio (TPC-H '1GB')
    for scheme, layout in SCHEMES_V1:
        ds = build_dataset(samples, codec_by_name(scheme), layout)
        for model in ("Averaging", "NeuralNetwork", "SVR", "RandomForest"):
            (_, res), us = timed(
                lambda d=ds, m=model: train_eval(d, m, "ratio"), repeats=1)
            rows.append(row(f"tableVI/{scheme}+{layout}/{model}", us,
                            mae=round(res.mae, 4), mape=round(res.mape, 3),
                            r2=round(res.r2, 4)))

    # ---- Table VII: '100GB' (larger scale) + Zipf-skew variants
    for tag, (scale, skew) in (("100GB", (20000, 0.0)),
                               ("Skew", (5000, 1.2))):
        _, _, samp = _mk_samples(scale, skew, seed=11, n_per_template=6)
        for scheme, layout in (("zlib-6", "row"), ("zlib-6", "col")):
            ds = build_dataset(samp, codec_by_name(scheme), layout)
            for model in ("Averaging", "SVR", "RandomForest"):
                (_, res), us = timed(
                    lambda d=ds, m=model: train_eval(d, m, "ratio"),
                    repeats=1)
                rows.append(row(
                    f"tableVII/{tag}/{scheme}+{layout}/{model}", us,
                    mae=round(res.mae, 4), mape=round(res.mape, 3),
                    r2=round(res.r2, 4)))

    # ---- Table VIII: decompression sec/GB prediction
    for scheme, layout in (("zlib-6", "row"), ("zlib-6", "col"),
                           ("lzma-1", "col")):
        ds = build_dataset(samples, codec_by_name(scheme), layout)
        for model in ("Averaging", "SVR", "RandomForest"):
            (_, res), us = timed(
                lambda d=ds, m=model: train_eval(d, m, "dspeed"), repeats=1)
            rows.append(row(f"tableVIII/{scheme}+{layout}/{model}", us,
                            mae=round(res.mae, 4), mape=round(res.mape, 3),
                            r2=round(res.r2, 4)))
    return emit(rows, "tablesV-VIII_compredict")


if __name__ == "__main__":
    run()
