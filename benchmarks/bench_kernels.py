"""Framework micro-benchmarks: kernel ref-path timings on CPU (wall time is
NOT the deliverable metric — TPU roofline comes from the dry-run — but these
catch algorithmic regressions and give the us_per_call CSV column teeth)."""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, row, timed
from repro.kernels import ops


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention ref (train shape slice)
    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="ref"))
    f(q, k, v).block_until_ready()
    _, us = timed(lambda: f(q, k, v).block_until_ready(), repeats=3)
    flops = 4 * 1024 * 1024 * 8 * 64
    rows.append(row("kernel/flash_attention_ref_1k", us,
                    gflops_cpu=round(flops / us / 1e3, 2)))

    # decode attention (32k cache)
    q1 = jax.random.normal(key, (4, 8, 64), jnp.float32)
    kc = jax.random.normal(key, (4, 32768, 2, 64), jnp.float32)
    kv_len = jnp.full((4,), 32768, jnp.int32)
    d = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l,
                                                        impl="ref"))
    d(q1, kc, kc, kv_len).block_until_ready()
    _, us = timed(lambda: d(q1, kc, kc, kv_len).block_until_ready())
    gb = 2 * kc.size * 4 / 1e9
    rows.append(row("kernel/decode_attention_ref_32k", us,
                    cache_gb=round(gb, 3),
                    gbps_cpu=round(gb / (us / 1e6), 2)))

    # SSD scan
    x = jax.random.normal(key, (2, 2048, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (2, 2048, 8))) * 0.5
    A = -jnp.exp(jax.random.normal(key, (8,)) * 0.3)
    B = jax.random.normal(key, (2, 2048, 1, 64)) * 0.5
    C = jax.random.normal(key, (2, 2048, 1, 64)) * 0.5
    s = jax.jit(lambda *a: ops.ssd_scan(*a, impl="ref")[0])
    s(x, dt, A, B, C).block_until_ready()
    _, us = timed(lambda: s(x, dt, A, B, C).block_until_ready())
    rows.append(row("kernel/ssd_scan_ref_2k", us,
                    tokens_per_s=round(2 * 2048 / (us / 1e6), 0)))

    # quant pack/unpack roundtrip
    w = jax.random.normal(key, (1024, 1024), jnp.float32)
    qp = jax.jit(lambda w: ops.quant_pack(w, impl="ref"))
    qp(w)[0].block_until_ready()
    _, us = timed(lambda: qp(w)[0].block_until_ready())
    rows.append(row("kernel/quant_pack_1M", us,
                    gbps_cpu=round(w.size * 4 / 1e9 / (us / 1e6), 2)))

    # byte entropy (COMPREDICT feature hot loop)
    data = jax.random.randint(key, (1 << 20,), 0, 256, jnp.int32
                              ).astype(jnp.uint8)
    be = jax.jit(lambda d: ops.byte_entropy(d, impl="ref")[1])
    be(data).block_until_ready()
    _, us = timed(lambda: be(data).block_until_ready())
    rows.append(row("kernel/byte_entropy_1MB", us,
                    mbps_cpu=round(1.0 / (us / 1e6), 1)))

    # batched weighted-entropy features (COMPREDICT, 512 partitions)
    N, M, V, nb = 512, 1024, 256, 5
    codes = jax.random.randint(key, (N, M), 0, V, jnp.int32)
    n_cols = jnp.full((N,), 4, jnp.int32)
    n_valid = jax.random.randint(jax.random.fold_in(key, 1), (N,),
                                 M // 2, M + 1, jnp.int32) // 4 * 4
    n_rows_ = n_valid // 4
    lens = jax.random.uniform(key, (N, V), jnp.float32, 1.0, 12.0)
    wef = jax.jit(lambda *a: ops.weighted_entropy_features(
        *a, n_buckets=nb, impl="ref")[0])
    wef(codes, n_valid, n_rows_, n_cols, lens).block_until_ready()
    _, us = timed(lambda: wef(codes, n_valid, n_rows_, n_cols,
                              lens).block_until_ready())
    rows.append(row("kernel/weighted_entropy_512x1k", us,
                    mvals_per_s=round(N * M / us, 1)))
    return emit(rows, "kernels_micro")


if __name__ == "__main__":
    run()
