"""Streaming end-to-end: month-by-month enterprise traces through
StreamingEngine (incremental G-PART fold -> threshold-gated compaction ->
migration-aware re-optimization), reporting per-batch latency, partition
growth, migration volume, and the steady-state bill trajectory."""

import time

import numpy as np

from benchmarks.common import emit, row
from repro.core.costs import azure_table
from repro.core.engine import ScopeConfig, StreamingEngine
from repro.data import workloads as wl


def run():
    rows = []
    for tag, n_datasets, n_months in (("small", 200, 12), ("large", 760, 18)):
        w = wl.generate_workload(n_datasets=n_datasets, n_months=n_months,
                                 seed=7)
        rng = np.random.default_rng(7)
        sizes = wl.dataset_file_sizes(w)
        cfg = ScopeConfig(use_compression=False, months=1.0)
        eng = StreamingEngine(azure_table(), cfg, sizes, drift_threshold=0.5)
        total_us = 0.0
        total_moved = total_new = n_batches = 0
        migration_cents = 0.0
        for batch in wl.stream_query_log(w, rng):
            if not batch:
                continue
            t0 = time.perf_counter()
            mig = eng.ingest_and_reoptimize(batch, months=1.0)
            total_us += (time.perf_counter() - t0) * 1e6
            n_batches += 1
            r = eng.history[-1]
            total_moved += r.n_moved
            total_new += r.n_new
            migration_cents += mig.total_move_cents
        last = eng.history[-1]
        rows.append(row(
            f"stream_e2e/{tag}/per_month", total_us / max(n_batches, 1),
            months=n_batches, n_partitions=last.n_partitions,
            n_families=eng.partitioner.n_families,
            compactions=eng.partitioner.stats.n_compactions,
            fold_merges=eng.partitioner.stats.n_fold_merges,
            total_new=total_new, total_moved=total_moved,
            migration_cents=round(migration_cents, 2),
            steady_cents=round(last.steady_cents, 1)))
    return emit(rows, "stream_e2e")


if __name__ == "__main__":
    run()
