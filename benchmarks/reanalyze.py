"""Recompute roofline terms from stored dry-run HLO (analyzer fixes apply
retroactively — lowering/compile need not rerun)."""
import json, pathlib, sys
import zstandard as zstd
from repro.analysis import roofline as rl

d = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else
                 "benchmarks/results/dryrun")
for jp in sorted(d.glob("*.json")):
    rec = json.loads(jp.read_text())
    if rec.get("status") != "ok":
        continue
    hp = jp.with_suffix("").with_suffix("")  # strip .json
    hp = d / (jp.stem + ".hlo.zst")
    if not hp.exists():
        continue
    hlo = zstd.ZstdDecompressor().decompress(hp.read_bytes()).decode()
    roof = rl.roofline_terms({}, hlo, rec["chips"],
                             rec["roofline"].get("model_flops"))
    rec["roofline"] = roof.as_dict()
    rec["collective_bytes"] = rl.collective_bytes(hlo)
    jp.write_text(json.dumps(rec, indent=1))
    print(f"reanalyzed {jp.name}: dominant={roof.dominant} "
          f"c={roof.compute_s:.4f} m={roof.memory_s:.4f} x={roof.collective_s:.4f}")
