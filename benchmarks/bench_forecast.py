"""Forecast-driven vs reactive daemon backtest on enterprise drift traces.

Closes the paper's §IV-C loop end to end: an ``AccessForecaster`` (forest
on feature_matrix rows, OPTASSIGN-optimal-tier labels on the future
window, isotonic reliability layer, clamp/spike-cap sanity layer) drives a
batch-mode ``ReoptimizationDaemon`` as its ``forecast_fn``, against the
same daemon running reactively (``forecast_fn=None``) and on a plain
linear trend.

The billing is **lagged** — the honest test of pre-warming: month m's
*observed* reads are billed against the placement decided before month m
was seen (the daemon has only observed months < m; the forecast arm
projects month m from them). A reactive daemon therefore eats every
periodic spike at the tier chosen for the quiet phase — with archive in
the whitelist, at archive retrieval rates — while a calibrated forecaster
pre-warms the partition one cycle earlier.

Reported per trace and arm: cumulative cents (storage + observed reads at
the placed tier + migration spend), pre-warm hit rate (fraction of spike
onsets whose partition was already sitting in the hot tier), and mis-tier
months (partition-months placed off the per-month cost-optimal tier under
the observed traffic). ``forecast_not_worse`` records the acceptance
criterion: forecast-driven cumulative cost <= reactive.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import os
import time

import numpy as np

from benchmarks.common import emit, row
from repro.core.costs import azure_table
from repro.core.daemon import ReoptimizationDaemon
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.core.forecast import AccessForecaster, linear_trend_forecast
from repro.data import workloads as wl

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

# periodic/spike-heavy mix: the regime where prediction can beat reaction
PATTERNS = {"decreasing": 0.2, "constant": 0.1, "periodic": 0.35,
            "spike": 0.15, "cold": 0.2}
TRACES = ({"small": (40, 16)} if SMOKE
          else {"small": (80, 24), "enterprise": (150, 30)})
TIERS = (1, 2, 3)               # hot / cool / archive
HORIZON, HISTORY = 2, 4
N_TREES = 8 if SMOKE else 24
REFIT_EVERY = 0 if SMOKE else 4


def _trace(n_datasets, n_months, seed=11):
    return wl.generate_workload(n_datasets=n_datasets, n_months=n_months,
                                seed=seed, pattern_probs=PATTERNS)


def _obs(w, m):
    return np.array([float(d.reads[m]) for d in w.datasets])


def _plan0(w, eng, cfg, table, m0):
    spans = np.array([d.size_gb for d in w.datasets])
    N = len(spans)
    prob = PlacementProblem(spans_gb=spans, rho=_obs(w, m0 - 1),
                            current_tier=np.full(N, -1),
                            R=np.ones((N, 1)), D=np.zeros((N, 1)),
                            schemes=("none",), table=table, cfg=cfg)
    return eng.solve(prob)


def _oracle_tier(table, spans, r):
    """Per-month cost-optimal tier under the OBSERVED traffic (the
    mis-tier reference): storage + reads, whitelist only, no move costs."""
    per = (spans[:, None] * table.storage_cents_gb_month[None, list(TIERS)]
           + (r * spans)[:, None] * table.read_cents_gb[None, list(TIERS)])
    return np.array(list(TIERS))[per.argmin(1)]


def _spike_onsets(w, m0):
    """(month, dataset) pairs where traffic jumps well above the recent
    level — the events pre-warming exists for."""
    onsets = []
    for i, d in enumerate(w.datasets):
        for m in range(m0, w.n_months):
            recent = d.reads[max(m - 3, 0):m]
            lvl = float(recent.mean()) if len(recent) else 0.0
            if d.reads[m] > 5.0 * lvl + 10.0 and d.reads[m] > 50.0:
                onsets.append((m, i))
    return onsets


def _backtest(w, m0, forecast_fn, table):
    """Replay months [m0, n_months) through a batch daemon; bill each
    month's observed reads against the placement decided one cycle
    earlier. Returns (cumulative cents, per-month tier matrix, us/cycle)."""
    cfg = ScopeConfig(tier_whitelist=TIERS, use_compression=False,
                      months=1.0)
    eng = PlacementEngine(table, cfg)
    plan0 = _plan0(w, eng, cfg, table, m0)
    daemon = ReoptimizationDaemon(eng, plan=plan0, forecast_fn=forecast_fn,
                                  rho_abs_tol=1.0, forecast_window=12)
    spans = plan0.problem.spans_gb
    storage = table.storage_cents_gb_month
    read = table.read_cents_gb
    cum = 0.0
    tiers_by_month = {}
    t0 = time.perf_counter()
    for m in range(m0, w.n_months):
        rep = daemon.step(_obs(w, m - 1), months=1.0)   # lagged observation
        tier = daemon.plan.assignment.tier.copy()
        tiers_by_month[m] = tier
        r_m = _obs(w, m)
        cum += float((spans * storage[tier]).sum()
                     + (r_m * spans * read[tier]).sum()) + rep.spent_cents
    us = (time.perf_counter() - t0) * 1e6 / max(w.n_months - m0, 1)
    return cum, tiers_by_month, us


def _arm_metrics(w, m0, tiers_by_month, table, onsets):
    spans = np.array([d.size_gb for d in w.datasets])
    mistier = 0
    for m in range(m0, w.n_months):
        mistier += int((tiers_by_month[m]
                        != _oracle_tier(table, spans, _obs(w, m))).sum())
    hits = sum(1 for m, i in onsets if tiers_by_month[m][i] == TIERS[0])
    hit_rate = hits / len(onsets) if onsets else float("nan")
    return mistier, hit_rate


def _rows():
    table = azure_table()
    rows = []
    for tag, (n_datasets, n_months) in TRACES.items():
        w = _trace(n_datasets, n_months)
        m0 = n_months // 2
        onsets = _spike_onsets(w, m0)

        fc = AccessForecaster(table, tiers=(1, 2), horizon=HORIZON,
                              history=HISTORY, n_trees=N_TREES,
                              refit_every=REFIT_EVERY, seed=0)
        fit_rep = fc.fit(w, fit_month=m0)
        fc.bind(month0=m0 - 1)

        arms = {"reactive": None,
                "trend": lambda h: linear_trend_forecast(h),
                "forecast": fc.forecast_rho}
        cums = {}
        for arm, fn in arms.items():
            cum, tiers_by_month, us = _backtest(w, m0, fn, table)
            mistier, hit_rate = _arm_metrics(w, m0, tiers_by_month, table,
                                             onsets)
            cums[arm] = cum
            derived = dict(
                months=n_months - m0, datasets=n_datasets,
                cum_cents=round(cum, 2), mistier_months=mistier,
                spike_onsets=len(onsets),
                prewarm_hit_rate=(round(hit_rate, 3)
                                  if onsets else None))
            if arm == "forecast":
                derived.update(
                    cum_vs_reactive_pct=round(
                        100.0 * (cum / cums["reactive"] - 1.0), 3),
                    forecast_not_worse=bool(cum <= cums["reactive"] + 1e-6),
                    refits=len(fc.refits_),
                    ece_raw=round(fit_rep.ece_raw, 4),
                    ece_cal=round(fit_rep.ece_cal, 4),
                    calibrated=fit_rep.calibrated)
            rows.append(row(f"forecast/{tag}/{arm}", us, **derived))
    return rows


def run():
    return emit(_rows(), "forecast")


if __name__ == "__main__":
    run()
