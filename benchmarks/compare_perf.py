"""§Perf before/after: baseline vs optimized dry-run roofline terms."""
import json, pathlib, sys

BASE = pathlib.Path("benchmarks/results/dryrun_baseline")
OPT = pathlib.Path("benchmarks/results/dryrun")

def main():
    print("| arch | shape | mesh | term | before (s) | after (s) | delta |")
    print("|---|---|---|---|---|---|---|")
    for jp in sorted(OPT.glob("*.json")):
        new = json.loads(jp.read_text())
        bp = BASE / jp.name
        if not bp.exists() or new.get("status") != "ok":
            continue
        old = json.loads(bp.read_text())
        if old.get("status") != "ok":
            continue
        ro, rn = old["roofline"], new["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            a, b = ro[term], rn[term]
            if a < 1e-4 and b < 1e-4:
                continue
            if abs(b - a) / max(a, 1e-9) < 0.02:
                continue
            print(f"| {new['arch']} | {new['shape']} | {new['mesh']} "
                  f"| {term[:-2]} | {a:.4f} | {b:.4f} "
                  f"| {'-' if b<a else '+'}{abs(b-a)/max(a,1e-12)*100:.0f}% |")

if __name__ == "__main__":
    main()
