"""Paper Table III — tier-prediction confusion matrix / F1 (RF, out-of-time).

Paper: ~700TB in 760 datasets, 2-month horizon, F1 > 0.96."""

from benchmarks.common import emit, row, timed
from repro.core.access_predict import train_tier_predictor
from repro.core.costs import azure_table
from repro.data.workloads import generate_workload


def run():
    table = azure_table()
    w = generate_workload(n_datasets=760, n_months=24, seed=7,
                          size_lognorm=(4.5, 2.0))
    (clf, rep), us = timed(
        lambda: train_tier_predictor(w, table, train_month=12, horizon=2),
        repeats=1)
    rows = [row("tableIII/rf_tier_prediction", us,
                f1=round(rep.f1, 4), accuracy=round(rep.accuracy, 4),
                confusion=rep.confusion.tolist(),
                labels=list(rep.label_names),
                paper_f1_band=">0.96")]
    return emit(rows, "tableIII_access_predict")


if __name__ == "__main__":
    run()
