"""Paper Tables IX-XI — the full SCOPe pipeline vs adapted baselines
(Ares / Hermes / HCompress rows) on TPC-H-style data, Fig 5 — effect of
the compression predictor on the cost/latency trade-off, and the
engine-vs-legacy scaling sweep: vectorized AssignStage/BillingStage vs the
original Python-loop solver + billing at N up to 5000 partitions."""

import time

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.compredict import CompressionPredictor, query_samples
from repro.core.costs import Weights, azure_table
from repro.core.engine import BillingStage, PlacementEngine, PlacementProblem
from repro.core.optassign import capacitated_assign, capacitated_assign_ref
from repro.core.scope import ScopeConfig, paper_variants, run_pipeline
from repro.data import tpch


def _synthetic_problem(N, table, cfg, seed=0):
    """Random-but-realistic (spans, rho, R, D) instance — no TPC-H
    materialization, so the sweep reaches N=5000 partitions."""
    rng = np.random.default_rng(seed)
    K = len(cfg.schemes)
    spans = rng.lognormal(0.0, 1.2, N) * 2.0
    rho = rng.gamma(0.7, 25.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))], 1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, K - 1))
                        * spans[:, None]], 1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=cfg.schemes, table=table, cfg=cfg)


def _legacy_bill_loop(problem, assign, table, months):
    storage = read = decomp = 0.0
    for n in range(problem.n):
        l, k = int(assign.tier[n]), int(assign.scheme[n])
        stored_gb = problem.spans_gb[n] / problem.R[n, k]
        storage += stored_gb * table.storage_cents_gb_month[l] * months
        read += problem.rho[n] * stored_gb * table.read_cents_gb[l]
        decomp += problem.rho[n] * problem.D[n, k] * table.compute_cents_sec
    return storage + read + decomp


def scaling_sweep(rows):
    """Vectorized capacitated solver + BillingStage vs the legacy Python
    reference. The vectorized path runs its full default (iters=200); the
    reference is capped at iters=10 per call so the sweep terminates — at
    N=2000 the uncapped reference would take ~20 minutes."""
    table = azure_table()
    ref_cutoff = 2000                       # ref is too slow beyond this
    for N in (200, 1000, 2000, 5000):
        cfg = ScopeConfig()                 # all four tiers; archive uncapped
        problem = _synthetic_problem(N, table, cfg, seed=N)
        eng = PlacementEngine(table, cfg)
        cost, feas = eng.assign.cost_and_feasibility(problem)
        stored = problem.stored_matrix()
        # tight premium/hot/cool budgets so the capacity constraints actually
        # bind — the regime the capacitated solver exists for
        total = float(problem.spans_gb.sum())
        cap = np.array([total * 0.03, total * 0.07, total * 0.12, np.inf])

        capacitated_assign(cost, feas, stored, cap)   # jit warm-up
        t0 = time.perf_counter()
        vec = capacitated_assign(cost, feas, stored, cap)
        vec_s = time.perf_counter() - t0
        _, bill_us = timed(lambda: BillingStage(table, cfg)(problem, vec),
                           repeats=3)
        rows.append(row(f"scaling/engine/N={N}", vec_s * 1e6,
                        objective=round(vec.cost, 4),
                        feasible=vec.feasible,
                        billing_us=round(bill_us, 1)))
        if N > ref_cutoff:
            continue
        t0 = time.perf_counter()
        ref = capacitated_assign_ref(cost, feas, stored, cap, iters=10)
        ref_s = time.perf_counter() - t0
        _, loop_us = timed(lambda: _legacy_bill_loop(problem, ref, table,
                                                     cfg.months), repeats=3)
        rows.append(row(f"scaling/legacy-iters10/N={N}", ref_s * 1e6,
                        objective=round(ref.cost, 4),
                        feasible=ref.feasible,
                        billing_us=round(loop_us, 1),
                        assign_speedup=round(ref_s / max(vec_s, 1e-9), 1),
                        billing_speedup=round(loop_us / max(bill_us, 1e-3),
                                              1)))
    return rows


def run():
    rows = []
    table = azure_table()
    db = tpch.generate(scale_rows=8000, seed=0)
    qs = tpch.generate_queries(db, n_per_template=5, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, qs)
    total_gb = sum(p.span for p in parts) / 1e9
    cap = np.array([0.163, 0.326, 0.4891, np.inf]) * total_gb * 3.0

    for name, cfg in paper_variants(cap).items():
        rep, us = timed(lambda c=cfg: run_pipeline(parts, file_rows, table,
                                                   c), repeats=1)
        rows.append(row(f"tableX/{name}", us,
                        storage=round(rep.storage_cents, 4),
                        decomp=round(rep.decomp_cents, 5),
                        read=round(rep.read_cents, 4),
                        total=round(rep.total_cents, 4),
                        ttfb_s=round(rep.read_latency_ttfb, 4),
                        decomp_ms=round(rep.decomp_latency_ms, 4),
                        tiers=rep.tiering_scheme,
                        n_partitions=rep.n_partitions))

    # ---- Fig 5: predictor-in-the-loop vs ground truth vs naive predictor
    samples = query_samples(qs, db.tables, max_rows=6000)
    pred = CompressionPredictor(model_name="SVR").fit(
        samples[:80], layouts=("col",))
    pred_avg = CompressionPredictor(model_name="Averaging").fit(
        samples[:80], layouts=("col",))
    for tag, predictor in (("truth", "truth"), ("svr", pred),
                           ("averaging", pred_avg)):
        for alpha, beta in ((1.0, 1.0), (1.0, 4.0), (4.0, 1.0)):
            cfg = ScopeConfig(weights=Weights(alpha=alpha, beta=beta),
                              tier_whitelist=(0, 1, 2), predictor=predictor)
            rep, us = timed(lambda c=cfg: run_pipeline(
                parts, file_rows, table, c), repeats=1)
            rows.append(row(f"fig5/{tag}/a{alpha}b{beta}", us,
                            total=round(rep.total_cents, 4),
                            storage=round(rep.storage_cents, 4),
                            latency_s=round(rep.read_latency_ttfb
                                            + rep.decomp_latency_ms / 1e3, 4)))

    # ---- engine-vs-legacy scaling sweep (N up to 5000 partitions)
    scaling_sweep(rows)
    return emit(rows, "tablesIX-XI_scope_pipeline")


if __name__ == "__main__":
    run()
