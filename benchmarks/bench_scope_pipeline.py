"""Paper Tables IX-XI — the full SCOPe pipeline vs adapted baselines
(Ares / Hermes / HCompress rows) on TPC-H-style data, and Fig 5 — effect of
the compression predictor on the cost/latency trade-off."""

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.compredict import CompressionPredictor, query_samples
from repro.core.costs import Weights, azure_table
from repro.core.scope import ScopeConfig, paper_variants, run_pipeline
from repro.data import tpch


def run():
    rows = []
    table = azure_table()
    db = tpch.generate(scale_rows=8000, seed=0)
    qs = tpch.generate_queries(db, n_per_template=5, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, qs)
    total_gb = sum(p.span for p in parts) / 1e9
    cap = np.array([0.163, 0.326, 0.4891, np.inf]) * total_gb * 3.0

    for name, cfg in paper_variants(cap).items():
        rep, us = timed(lambda c=cfg: run_pipeline(parts, file_rows, table,
                                                   c), repeats=1)
        rows.append(row(f"tableX/{name}", us,
                        storage=round(rep.storage_cents, 4),
                        decomp=round(rep.decomp_cents, 5),
                        read=round(rep.read_cents, 4),
                        total=round(rep.total_cents, 4),
                        ttfb_s=round(rep.read_latency_ttfb, 4),
                        decomp_ms=round(rep.decomp_latency_ms, 4),
                        tiers=rep.tiering_scheme,
                        n_partitions=rep.n_partitions))

    # ---- Fig 5: predictor-in-the-loop vs ground truth vs naive predictor
    samples = query_samples(qs, db.tables, max_rows=6000)
    pred = CompressionPredictor(model_name="SVR").fit(
        samples[:80], layouts=("col",))
    pred_avg = CompressionPredictor(model_name="Averaging").fit(
        samples[:80], layouts=("col",))
    for tag, predictor in (("truth", "truth"), ("svr", pred),
                           ("averaging", pred_avg)):
        for alpha, beta in ((1.0, 1.0), (1.0, 4.0), (4.0, 1.0)):
            cfg = ScopeConfig(weights=Weights(alpha=alpha, beta=beta),
                              tier_whitelist=(0, 1, 2), predictor=predictor)
            rep, us = timed(lambda c=cfg: run_pipeline(
                parts, file_rows, table, c), repeats=1)
            rows.append(row(f"fig5/{tag}/a{alpha}b{beta}", us,
                            total=round(rep.total_cents, 4),
                            storage=round(rep.storage_cents, 4),
                            latency_s=round(rep.read_latency_ttfb
                                            + rep.decomp_latency_ms / 1e3, 4)))
    return emit(rows, "tablesIX-XI_scope_pipeline")


if __name__ == "__main__":
    run()
