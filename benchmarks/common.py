"""Shared benchmark plumbing: timing + CSV/JSON row emission."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, best_us)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def row(name: str, us: float, **derived) -> Dict:
    return {"name": name, "us_per_call": round(us, 1), "derived": derived}


def emit(rows: List[Dict], table_name: str) -> List[Dict]:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{table_name}.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},"
              f"{json.dumps(r['derived'], sort_keys=True)}")
    return rows
