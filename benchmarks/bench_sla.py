"""Cost vs p99 retrieval-latency Pareto frontier + serving-cache backtest.

Two experiments on enterprise drift traces:

**Pareto sweep** (``sla/<trace>/pareto/...``): the same placement problem
solved across a ``sla_lambda`` ladder with a finite per-partition SLA.
Each lambda buys latency with money — hot-but-SLA-violating partitions
climb to faster tiers — tracing the (total_cents, p99_ms) frontier. The
lambda=0 endpoint must match the pre-SLA engine's cost *exactly* (the
bit-parity contract), and the sweep must produce >= 3 distinct frontier
points.

**Cache backtest** (``sla/<trace>/cache/...``): month-by-month lagged
replay of a fixed backing placement fronted by a serving cache. The
*forecast* arm re-admits each month via
:func:`repro.core.cache.forecast_admission` on a calibrated
:class:`~repro.core.forecast.AccessForecaster` projection (floored at the
last observed rate), so a spike's partition is already resident when the
spike lands and active readers are never evicted mid-stream. The *lru*
arm is a :class:`~repro.core.cache.ReactiveLRUCache` warmed only by last
month's observed accesses. Both arms pay identical backing costs and the
same cache price for the bytes they hold, so the comparison is p99 at
(near) equal cost; ``beats_lru_p99_at_equal_cost`` records the
acceptance criterion (strict p99 win within a 5% cost band).

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import emit, row
from repro.core.cache import (CacheConfig, ReactiveLRUCache, cache_cents,
                              forecast_admission, served_latency_terms,
                              weighted_p99_ms)
from repro.core.costs import azure_table
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.core.forecast import AccessForecaster
from repro.data import workloads as wl

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))
# 13-month feature window: the workload's periodic partitions peak every
# 6 or 12 months, so anything shorter leaves the previous peak outside
# the window and the forecaster cannot see a spike coming
HISTORY = 13
N_TREES = 8 if SMOKE else 24

# Periodic-dominant mix: the serving-cache question is about traffic a
# forecaster can anticipate. One-off ``spike`` onsets are unforecastable
# by construction — both arms serve them cold, which only adds identical
# p99 tail mass to each — so they get a token share here (bench_forecast
# keeps the spike-heavy mix for the placement question).
PATTERNS = {"decreasing": 0.2, "constant": 0.15, "periodic": 0.45,
            "spike": 0.05, "cold": 0.15}
TRACES = ({"small": (48, 20)} if SMOKE
          else {"small": (80, 24), "enterprise": (150, 30)})
TIERS = (0, 1, 2, 3)
SLA_MS = 30.0                   # hot tier (5.3 ms) meets it; cool/archive miss
LAMBDAS = (0.0, 1e-4, 1e-3, 1e-2, 1.0)


def _trace(n_datasets, n_months, seed=11):
    return wl.generate_workload(n_datasets=n_datasets, n_months=n_months,
                                seed=seed, pattern_probs=PATTERNS)


def _obs(w, m):
    return np.array([float(d.reads[m]) for d in w.datasets])


def _problem(w, cfg, table, rho):
    spans = np.array([d.size_gb for d in w.datasets])
    N = len(spans)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1),
                            R=np.ones((N, 1)), D=np.zeros((N, 1)),
                            schemes=("none",), table=table, cfg=cfg)


# ------------------------------------------------------------- Pareto sweep
def _pareto_rows(tag, w, table):
    rho = np.mean([_obs(w, m) for m in range(w.n_months)], axis=0)
    base_cfg = ScopeConfig(tier_whitelist=TIERS, use_compression=False,
                           months=1.0)
    base = PlacementEngine(table, base_cfg).solve(
        _problem(w, base_cfg, table, rho))
    rows, frontier = [], []
    for lam in LAMBDAS:
        cfg = dataclasses.replace(base_cfg, sla_lambda=lam, sla_ms=SLA_MS)
        t0 = time.perf_counter()
        plan = PlacementEngine(table, cfg).solve(
            _problem(w, cfg, table, rho))
        us = (time.perf_counter() - t0) * 1e6
        pt = (plan.report.total_cents, plan.report.p99_latency_ms)
        frontier.append(pt)
        rows.append(row(
            f"sla/{tag}/pareto/lam{lam:g}", us,
            total_cents=round(pt[0], 4), p99_ms=round(pt[1], 3),
            sla_penalty=round(plan.report.sla_penalty, 2),
            n_hot=int(plan.report.tiering_scheme[0])))
    distinct = len({(round(c, 6), round(p, 6)) for c, p in frontier})
    rows.append(row(
        f"sla/{tag}/pareto/summary", 0.0,
        n_frontier_points=distinct,
        frontier_ok=bool(distinct >= 3),
        lambda0_matches_baseline=bool(
            frontier[0][0] == base.report.total_cents),
        p99_monotone_nonincreasing=bool(all(
            frontier[i + 1][1] <= frontier[i][1] + 1e-9
            for i in range(len(frontier) - 1)))))
    return rows


# ----------------------------------------------------------- cache backtest
def _month_bill(spans, tier, r, resident, cache_cfg, table):
    """One month's real cents: backing storage + (miss) reads + cache."""
    r_b = np.where(resident, cache_cfg.miss_rate * r, r)
    return (float((spans * table.storage_cents_gb_month[tier]).sum()
                  + (r_b * spans * table.read_cents_gb[tier]).sum())
            + cache_cents(spans, resident, cache_cfg, 1.0))


def _month_p99(r, tier, resident, cache_cfg, table):
    lat = table.ttfb_seconds[tier] * 1e3
    pts, wts = served_latency_terms(r, lat, resident, cache_cfg)
    return pts, wts


def _cache_backtest(w, m0, table, cache_cfg, arm, forecaster=None):
    """Lagged replay: month m is served by the residency decided from
    months < m. Returns (cum cents, pooled p99, us/cycle)."""
    spans = np.array([d.size_gb for d in w.datasets])
    N = len(spans)
    # fixed backing placement from the warmup mean — identical across arms
    cfg = ScopeConfig(tier_whitelist=TIERS, use_compression=False,
                      months=1.0)
    rho0 = np.mean([_obs(w, m) for m in range(m0)], axis=0)
    tier = PlacementEngine(table, cfg).solve(
        _problem(w, cfg, table, rho0)).assignment.tier.astype(int)
    lru = ReactiveLRUCache(cache_cfg.capacity_gb)
    order = np.random.default_rng(0).permutation(N)
    hist = [_obs(w, m) for m in range(max(m0 - HISTORY, 0), m0)]
    cum = 0.0
    pool_pts, pool_w = [], []
    t0 = time.perf_counter()
    for m in range(m0, w.n_months):
        if arm == "forecast":
            # calibrated projection of the month ABOUT to be served drives
            # admission — the tentpole's forecast-driven cache path. The
            # projection is floored at the last observed rate (admit what
            # will be hot OR is hot): pre-warms ahead of forecastable
            # spikes without evicting active trickle readers mid-stream.
            proj = forecaster.forecast_rho(list(hist))
            resident = forecast_admission(np.maximum(proj, hist[-1]),
                                          spans, cache_cfg)
        else:
            resident = lru.mask(N)
        r_m = _obs(w, m)
        # month m0 is a ramp month for BOTH arms (the forecaster's clock
        # starts, the LRU warms): state advances, nothing is scored
        if m > m0:
            cum += _month_bill(spans, tier, r_m, resident, cache_cfg, table)
            pts, wts = _month_p99(r_m, tier, resident, cache_cfg, table)
            pool_pts.append(pts)
            pool_w.append(wts)
        hist.append(r_m)
        if len(hist) > HISTORY:
            hist.pop(0)
        if arm == "lru":
            for i in order:                 # this month's accesses warm it
                if r_m[i] > 0.5:
                    lru.access(int(i), float(spans[i]))
    us = (time.perf_counter() - t0) * 1e6 / max(w.n_months - m0, 1)
    p99 = weighted_p99_ms(np.concatenate(pool_pts), np.concatenate(pool_w))
    return cum, p99, us


def _cache_rows(tag, w, table):
    m0 = max(w.n_months // 2, 2)
    spans = np.array([d.size_gb for d in w.datasets])
    # room for the biggest ~third of partitions (spans are heavy-tailed,
    # so that is most of the bytes but not all); min_rho=0 lets density
    # ranking against capacity decide admission — small trickle-read
    # partitions are cheap to hold, only the big cold spans lose out. A
    # low miss rate so the p99 tail is decided by WHAT is resident when a
    # spike lands (the arms' only difference), not by cache-miss noise.
    cache_cfg = CacheConfig(capacity_gb=float(np.sort(spans)[-max(
        len(spans) // 3, 1):].sum()), hit_latency_ms=1.0, min_rho=0.0,
        storage_cents_gb_month=10.0, miss_rate=0.005)
    out = {}
    rows = []
    for arm in ("lru", "forecast"):
        fc = None
        if arm == "forecast":
            fc = AccessForecaster(table, tiers=(1, 2), horizon=1,
                                  history=HISTORY, n_trees=N_TREES,
                                  refit_every=0, seed=0)
            fc.fit(w, fit_month=m0)
            fc.bind(month0=m0 - 1)
        cum, p99, us = _cache_backtest(w, m0, table, cache_cfg, arm, fc)
        out[arm] = (cum, p99)
        derived = dict(months=w.n_months - m0 - 1, datasets=len(spans),
                       cum_cents=round(cum, 2), p99_ms=round(p99, 3),
                       capacity_gb=round(cache_cfg.capacity_gb, 2))
        if arm == "forecast":
            lru_cum, lru_p99 = out["lru"]
            derived.update(
                p99_vs_lru_pct=round(100.0 * (p99 / max(lru_p99, 1e-9)
                                              - 1.0), 2),
                cost_vs_lru_pct=round(100.0 * (cum / max(lru_cum, 1e-9)
                                               - 1.0), 2),
                beats_lru_p99_at_equal_cost=bool(
                    p99 < lru_p99 and cum <= lru_cum * 1.05))
        rows.append(row(f"sla/{tag}/cache/{arm}", us, **derived))
    return rows


def _rows():
    table = azure_table()
    rows = []
    for tag, (n_datasets, n_months) in TRACES.items():
        w = _trace(n_datasets, n_months)
        rows.extend(_pareto_rows(tag, w, table))
        rows.extend(_cache_rows(tag, w, table))
    return rows


def run():
    return emit(_rows(), "sla")


if __name__ == "__main__":
    run()
