"""Paper Table II — % cost benefit of OPTASSIGN tiering for 4 enterprise
'customer accounts' (PB-scale synthetic workloads, 2 vs 6 month horizons)."""

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.access_predict import optimal_tiers
from repro.core.costs import azure_table
from repro.data.workloads import generate_workload

CUSTOMERS = {
    # (n_datasets, size mu/sigma, seed) — calibrated to Table II volumes
    "A": (520, (5.8, 2.2), 0),
    "B": (463, (5.7, 2.2), 1),
    "C": (160, (5.2, 2.0), 2),
    "D": (210, (5.3, 2.0), 3),
}


def run():
    table = azure_table()
    rows = []
    for cust, (n, lognorm, seed) in CUSTOMERS.items():
        w = generate_workload(n_datasets=n, n_months=24, seed=seed,
                              size_lognorm=lognorm)
        spans = np.array([d.size_gb for d in w.datasets])
        total_pb = spans.sum() / 1e6
        for months in (2, 6):
            lo, hi = 12, 12 + months
            rho = w.reads_in(lo, hi)
            # tiers gated by early-deletion minimums: archive (180d) only
            # unlocks at horizons >= 6 months — the driver of the paper's
            # horizon-growth in benefit (Table II: ~10% @2mo -> 50-84% @6mo)
            allowed = tuple(t for t in (1, 2, 3)
                            if table.early_delete_months[t] <= months)

            def benefit():
                tiers = optimal_tiers(w, table, lo, hi, tiers=allowed)
                all_hot = (spans * table.storage_cents_gb_month[1] * months
                           + rho * spans * table.read_cents_gb[1]).sum()
                opt = (spans * table.storage_cents_gb_month[tiers] * months
                       + rho * spans * table.read_cents_gb[tiers]
                       + spans * table.write_cents_gb[tiers]).sum()
                return 100.0 * (1 - opt / all_hot)

            pct, us = timed(benefit, repeats=1)
            rows.append(row(f"tableII/customer{cust}/{months}mo", us,
                            total_size_pb=round(total_pb, 3),
                            pct_cost_benefit=round(pct, 2),
                            n_datasets=n))
    return emit(rows, "tableII_optassign_enterprise")


if __name__ == "__main__":
    run()
