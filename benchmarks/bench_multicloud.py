"""Multi-cloud placement — cross-provider vs best single-provider plans.

Enterprise-trace workloads (the Table II customer generator) are placed in
the flattened AWS+GCP+Azure ``(provider, tier)`` space (`costs.big3_table`)
and compared against the best plan restricted to any one provider
(`ScopeConfig.provider_whitelist`). Because the flattened space is a strict
superset of every single-provider space, the cross-provider plan can never
be costlier; the recorded `cross_vs_best_single_pct` shows how much of the
bill provider arbitrage actually removes. Also recorded:

 * a capped sweep — finite per-provider capacities exercising the group
   constraint rows in the vectorized capacitated solver,
 * a drift step — `PlacementEngine.reoptimize` across providers, with the
   one-off egress bill the optimizer internalized.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import dataclasses
import os

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.costs import big3_table
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.data.workloads import generate_workload

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

CUSTOMERS = {
    # (n_datasets, size mu/sigma, seed) — Table II calibration
    "C": (160, (5.2, 2.0), 2),
    "D": (210, (5.3, 2.0), 3),
} if not SMOKE else {"S": (24, (4.0, 1.5), 0)}

SCHEMES = ("none", "lz4", "zstd")


def _problem(table, cfg, n, lognorm, seed):
    w = generate_workload(n_datasets=n, n_months=24, seed=seed,
                          size_lognorm=lognorm)
    spans = np.array([d.size_gb for d in w.datasets])
    rho = w.reads_in(12, 18).astype(np.float64)
    rng = np.random.default_rng(seed)
    K = len(SCHEMES)
    R = np.concatenate([np.ones((n, 1)), rng.uniform(1.2, 6.0, (n, K - 1))],
                       1)
    D = np.concatenate([np.zeros((n, 1)),
                        rng.uniform(0.01, 2.0, (n, K - 1)) * spans[:, None]],
                       1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(n, -1), R=R, D=D,
                            schemes=SCHEMES, table=table, cfg=cfg)


def run():
    rows = []
    table = big3_table()
    months = 6.0
    for cust, (n, lognorm, seed) in CUSTOMERS.items():
        cfg = ScopeConfig(schemes=SCHEMES, months=months)
        problem = _problem(table, cfg, n, lognorm, seed)
        eng = PlacementEngine(table, cfg)
        plan, us = timed(lambda: eng.solve(problem), repeats=1)
        cross = plan.report.total_cents

        singles = {}
        for p in table.provider_names:
            c1 = ScopeConfig(schemes=SCHEMES, months=months,
                             provider_whitelist=(p,))
            prob1 = _problem(table, c1, n, lognorm, seed)
            singles[p] = PlacementEngine(table, c1).solve(
                prob1).report.total_cents
        best_single = min(singles.values())
        rows.append(row(
            f"multicloud/customer{cust}/cross_vs_single", us,
            n_datasets=n,
            cross_cents=round(cross, 2),
            **{f"single_{p}_cents": round(v, 2) for p, v in singles.items()},
            best_single_cents=round(best_single, 2),
            cross_vs_best_single_pct=round(100.0 * (1 - cross / best_single),
                                           3),
            never_costlier=bool(cross <= best_single + 1e-9),
            provider_mix=plan.report.provider_scheme))

        # finite per-provider capacities: group rows in the capacitated
        # solver. Azure (the cheapest archive) is capped below its uncapped
        # footprint so the constraint actually binds and mass spills over.
        pa = table.provider_names.index("azure")
        az_cap = 0.5 * float(
            plan.stored_gb[table.provider_of_tier[plan.assignment.tier]
                           == pa].sum())
        capped = big3_table(azure_capacity_gb=az_cap)
        prob_c = _problem(capped, cfg, n, lognorm, seed)
        eng_c = PlacementEngine(capped, cfg)
        plan_c, us_c = timed(lambda: eng_c.solve(prob_c), repeats=1)
        stored = plan_c.stored_gb
        pp = capped.provider_of_tier[plan_c.assignment.tier]
        pa_c = capped.provider_names.index("azure")
        rows.append(row(
            f"multicloud/customer{cust}/provider_caps", us_c,
            feasible=bool(plan_c.assignment.feasible),
            capped_cents=round(plan_c.report.total_cents, 2),
            uncapped_cents=round(cross, 2),
            azure_used_gb=round(float(stored[pp == pa_c].sum()), 2),
            azure_cap_gb=round(az_cap, 2),
            total_stored_gb=round(float(stored.sum()), 2),
            provider_mix=plan_c.report.provider_scheme))

        # drift: re-optimization prices cross-provider egress; against a
        # zero-egress counterfactual, count how many provider moves the
        # egress wall suppresses and what the taken moves actually paid.
        rng = np.random.default_rng(seed + 1)
        new_rho = problem.rho.copy()
        hot = rng.random(n) < 0.10
        cold = ~hot & (rng.random(n) < 0.10)
        new_rho[hot] *= rng.uniform(20.0, 100.0, int(hot.sum()))
        new_rho[cold] /= rng.uniform(20.0, 100.0, int(cold.sum()))
        mig, us_m = timed(lambda: eng.reoptimize(plan, new_rho,
                                                 months_held=0.5), repeats=1)
        crossed = int(((table.provider_of_tier[mig.new_tier]
                        != table.provider_of_tier[mig.old_tier])
                       & mig.moved).sum())
        free = big3_table()
        free = dataclasses.replace(
            free, egress_cents_gb=np.zeros_like(free.egress_cents_gb))
        prob_f = _problem(free, cfg, n, lognorm, seed)
        eng_f = PlacementEngine(free, cfg)
        mig_f = eng_f.reoptimize(eng_f.solve(prob_f), new_rho,
                                 months_held=0.5)
        crossed_f = int(((free.provider_of_tier[mig_f.new_tier]
                          != free.provider_of_tier[mig_f.old_tier])
                         & mig_f.moved).sum())
        rows.append(row(
            f"multicloud/customer{cust}/drift_reopt", us_m,
            n_moved=mig.n_moved,
            n_provider_moves=crossed,
            n_provider_moves_if_egress_free=crossed_f,
            migration_cents=round(mig.migration_cents, 4),
            egress_cents=round(mig.egress_cents, 4),
            penalty_cents=round(mig.penalty_cents, 4),
            steady_cents=round(mig.plan.report.total_cents, 2)))
    return emit(rows, "multicloud")


if __name__ == "__main__":
    run()
