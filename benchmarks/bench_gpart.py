"""Paper Fig 7 — G-PART space/cost trade-off vs no-merge and merge-all,
plus the ordered-partition DP (Thms 5/6) vs G-PART on time-series data,
plus the streaming sweep: amortized incremental ingest vs full rebuild.

``g_part`` here is the array-native implementation; the throughput ladder
against the original ``g_part_ref`` pair loop (and the sampled 1e6-file
sweep) lives in ``bench_gpart_scale.py`` (tag ``gpart_scale``)."""

import time

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core import datapart as dp
from repro.core.stream import StreamingPartitioner
from repro.data import tpch


def _partitions(scale_rows, seed):
    db = tpch.generate(scale_rows=scale_rows, seed=seed)
    qs = tpch.generate_queries(db, n_per_template=6, seed=seed + 1)
    parts, _ = tpch.partitions_from_queries(db, qs)
    return parts


def run():
    rows = []
    for tag, scale in (("1GB", 4000), ("100GB", 16000)):
        parts = _partitions(scale, 0)
        total_span = parts[0].sizes.span(
            frozenset().union(*[p.files for p in parts]))
        merged, us = timed(lambda p=parts, t=total_span: dp.g_part(
            list(p), s_thresh=0.25 * t), repeats=1)
        allm = dp.merge_all(parts)
        for name, ps in (("no_merge", parts), ("g_part", merged),
                         ("merge_all", allm)):
            rows.append(row(
                f"fig7/{tag}/{name}", us if name == "g_part" else 0,
                n_partitions=len(ps),
                duplication=round(dp.duplication(ps), 4),
                read_cost=round(dp.read_cost(ps) / 1e9, 4)))

    # ordered/time-series case: DP optimal vs G-PART heuristic
    rng = np.random.default_rng(5)
    files = {f"t{i}": float(rng.uniform(0.5, 2.0)) for i in range(40)}
    sizes = dp.FileSizes(files)
    parts = []
    for i in range(30):
        w = int(rng.integers(2, 6))
        parts.append(dp.Partition(
            frozenset(f"t{j}" for j in range(i, min(i + w, 40))),
            float(rng.uniform(0.5, 4.0)), sizes))
    c_budget = dp.read_cost(parts) * 1.3
    sol, us_dp = timed(lambda: dp.ordered_dp(parts, c_budget, n_buckets=400),
                       repeats=1)
    gp, us_gp = timed(lambda: dp.g_part(list(parts), s_thresh=20.0),
                      repeats=1)
    rows.append(row("thm5/ordered_dp", us_dp,
                    space=round(sol.space, 3), cost=round(sol.cost, 3),
                    budget=round(c_budget, 3), groups=len(sol.groups)))
    rows.append(row("thm5/g_part_on_ordered", us_gp,
                    space=round(sum(p.span for p in gp), 3),
                    cost=round(dp.read_cost(gp), 3), groups=len(gp)))
    approx, us_a = timed(lambda: dp.ordered_approx(parts, c_budget,
                                                   eps=1.0 / len(parts)),
                         repeats=1)
    rows.append(row("thm6/bicriteria_approx", us_a,
                    space=round(approx.space, 3),
                    cost=round(approx.cost, 3),
                    cost_bound=round(2 * c_budget, 3)))

    rows.extend(_streaming_sweep())
    return emit(rows, "fig7_gpart")


# ------------------------------------------------------- streaming vs rebuild
def _family_stream(rng, n_files, n_batches, per_batch):
    """Contiguous-window query families over a shared file universe —
    the time-ordered ingestion structure of §VI-B, batched by arrival."""
    sizes = {f"s{i}": float(rng.uniform(0.5, 2.0)) for i in range(n_files)}
    batches = []
    for _ in range(n_batches):
        b = []
        for _ in range(per_batch):
            w = int(rng.integers(2, 9))
            lo = int(rng.integers(0, n_files - w))
            b.append((tuple(f"s{j}" for j in range(lo, lo + w)),
                      float(rng.uniform(0.5, 8.0))))
        batches.append(b)
    return sizes, batches


def _streaming_sweep():
    """Amortized per-batch incremental ingest (fold + threshold-gated
    compaction) vs a full G-PART rebuild of the whole log — the acceptance
    bar is >= 5x at N >= 2000 query families."""
    out = []
    rng = np.random.default_rng(11)
    for n_batches, per_batch in ((10, 60), (20, 120)):
        sizes, batches = _family_stream(rng, n_files=per_batch * 20,
                                        n_batches=n_batches,
                                        per_batch=per_batch)
        concat = [qf for b in batches for qf in b]
        n_fams = len(dp.make_partitions(concat, sizes))
        sp = StreamingPartitioner(sizes, s_thresh=15.0, drift_threshold=0.5)
        t0 = time.perf_counter()
        for b in batches:
            sp.ingest(b)
            sp.compact()
        stream_us = (time.perf_counter() - t0) * 1e6
        amortized_us = stream_us / n_batches
        # rebuild timing includes make_partitions: that's the full per-batch
        # cost a non-streaming pipeline pays
        ref, rebuild_us = timed(
            lambda: dp.g_part(dp.make_partitions(concat, sizes),
                              s_thresh=15.0), repeats=1)
        out.append(row(
            f"stream/N{n_fams}/ingest_amortized", amortized_us,
            n_families=n_fams, n_batches=n_batches,
            rebuild_us=round(rebuild_us, 1),
            speedup_vs_rebuild=round(rebuild_us / amortized_us, 2),
            compactions=sp.stats.n_compactions,
            n_partitions=sp.n_partitions,
            read_cost_ratio=round(dp.read_cost(sp.partitions)
                                  / max(dp.read_cost(ref), 1e-12), 4)))
    return out


if __name__ == "__main__":
    run()
