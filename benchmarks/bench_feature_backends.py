"""Feature-extraction backend sweep (thin entry point for run.py's
``features`` tag): NumPy per-partition loop vs the batched jnp/Pallas
COMPREDICT pipeline. Implementation lives in bench_compredict.run_features
so the COMPREDICT benches stay in one module."""

from benchmarks.bench_compredict import run_features


def run():
    return run_features()


if __name__ == "__main__":
    run()
