"""Continuous re-optimization daemon under migration budgets.

Enterprise drift traces (the Table II workload generator) are streamed
month by month through a ``ReoptimizationDaemon`` wrapping a
``StreamingEngine``. For each budget level we record the cumulative cost
(steady-state bill accrued per cycle + one-off migration spend) and
compare against the unbudgeted daemon: budget selection only postpones
spend (deferral keeps charge-once semantics), so cumulative cost should
converge to within a few percent of unbudgeted re-optimization while the
per-cycle spend never exceeds the cap.

A batch-mode section replays ``bench_reoptimize``'s synthetic drift with
a cap, exercising the knapsack + deferral loop on the
``PlacementEngine.reoptimize`` path.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import os
import time

import numpy as np

from benchmarks.common import emit, row
from repro.core.costs import azure_table
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import (PlacementEngine, PlacementProblem,
                               ScopeConfig, StreamingEngine)
from repro.data import workloads as wl

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

TRACES = ({"small": (40, 8)} if SMOKE
          else {"small": (200, 12), "large": (760, 18)})
BATCH_N = 60 if SMOKE else 500


def _per_move_charges(mig) -> np.ndarray:
    return (mig.move_transfer_cents + mig.move_egress_cents
            + mig.move_penalty_cents)


def _stream_run(n_datasets, n_months, budget, collect_moves=False):
    w = wl.generate_workload(n_datasets=n_datasets, n_months=n_months,
                             seed=7)
    rng = np.random.default_rng(7)
    cfg = ScopeConfig(use_compression=False, months=1.0)
    eng = StreamingEngine(azure_table(), cfg, wl.dataset_file_sizes(w),
                          drift_threshold=0.5, rho_abs_tol=1.0)
    daemon = ReoptimizationDaemon(eng, budget=budget)
    per_move_max = 0.0
    t0 = time.perf_counter()
    for batch in wl.stream_query_log(w, rng):
        if not batch:
            continue
        if collect_moves:
            # peek at the candidate charges through the engine directly
            # (bit-identical to the daemon's unbudgeted path — pinned by
            # the parity tests); _report records the cycle in history
            mig = eng.ingest_and_reoptimize(batch, months=1.0)
            daemon._report(mig, mig.deferred, 0)
            if mig.n_candidates:
                per_move_max = max(per_move_max,
                                   float(_per_move_charges(mig).max()))
        else:
            daemon.step(batch, months=1.0)
    us = (time.perf_counter() - t0) * 1e6 / max(len(daemon.history), 1)
    cum = sum(r.steady_cents + r.spent_cents for r in daemon.history)
    return daemon, cum, us, per_move_max


def _stream_rows():
    rows = []
    for tag, (n_datasets, n_months) in TRACES.items():
        unb, cum_unb, us, per_move_max = _stream_run(
            n_datasets, n_months, MigrationBudget(), collect_moves=True)
        max_spend = max(r.spent_cents for r in unb.history)
        rows.append(row(
            f"daemon/{tag}/unbudgeted", us,
            cycles=len(unb.history), cum_cents=round(cum_unb, 2),
            moves=sum(r.n_selected for r in unb.history),
            max_cycle_spent=round(max_spend, 4),
            max_move_cents=round(per_move_max, 4)))
        # "tight": the tightest generally-feasible per-cycle budget — just
        # above the single most expensive move. "below_max_move":
        # deliberately under it; the dominant move stays deferred until its
        # early-delete penalty prorates the charge under the cap (if ever),
        # quantifying what a structurally-too-small budget costs.
        for name, cap in (("tight", min(1.05 * per_move_max,
                                        0.999 * max_spend)),
                          ("below_max_move", 0.5 * per_move_max)):
            d, cum, us, _ = _stream_run(n_datasets, n_months,
                                        MigrationBudget(cents_per_cycle=cap))
            worst = max(r.spent_cents for r in d.history)
            rows.append(row(
                f"daemon/{tag}/cap_{name}", us,
                cycles=len(d.history), cum_cents=round(cum, 2),
                cum_vs_unbudgeted_pct=round(100 * (cum / cum_unb - 1), 3),
                moves=sum(r.n_selected for r in d.history),
                deferrals=sum(r.n_deferred for r in d.history),
                max_deferral_age=max(r.max_deferral_age
                                     for r in d.history),
                cap_cents=round(cap, 4),
                max_cycle_spent=round(worst, 4),
                cap_respected=bool(worst <= cap + 1e-9)))
    return rows


def _batch_problem(N, table, cfg, seed):
    rng = np.random.default_rng(seed)
    K = len(cfg.schemes)
    spans = rng.lognormal(0.0, 1.2, N) * 2.0
    rho = rng.gamma(0.7, 25.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, K - 1)) * spans[:, None]],
                       1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=cfg.schemes, table=table, cfg=cfg)


def _batch_rows():
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2, 3), schemes=("none", "lz4"))
    eng = PlacementEngine(table, cfg)
    plan0 = eng.solve(_batch_problem(BATCH_N, table, cfg, seed=BATCH_N))
    rng = np.random.default_rng(BATCH_N + 1)
    cycles = []
    r = plan0.problem.rho.copy()
    for _ in range(6):
        r = r.copy()
        hot = rng.random(BATCH_N) < 0.05
        cold = ~hot & (rng.random(BATCH_N) < 0.05)
        r[hot] *= rng.uniform(20.0, 100.0, int(hot.sum()))
        r[cold] /= rng.uniform(20.0, 100.0, int(cold.sum()))
        cycles.append(r.copy())
    cycles += [cycles[-1]] * 4          # quiet tail: deferred moves drain

    rows = []
    results = {}
    for name, budget in (("unbudgeted", MigrationBudget()), ("capped", None)):
        if budget is None:
            # cap: must admit the single most expensive move (or it could
            # never drain) but sit below the busiest cycle so it binds
            cur, held = plan0, np.zeros(BATCH_N)
            per_move, per_cycle = [0.0], [0.0]
            for rho in cycles:
                mig = eng.reoptimize(cur, rho, months_held=held + 1.0)
                held = np.where(mig.moved, 0.0, held + 1.0)
                cur = mig.plan
                per_move.append(float(_per_move_charges(mig).max()))
                per_cycle.append(mig.total_move_cents)
            budget = MigrationBudget(cents_per_cycle=max(
                1.05 * max(per_move), 0.35 * max(per_cycle)))
        d = ReoptimizationDaemon(eng, plan=plan0, budget=budget)
        t0 = time.perf_counter()
        d.run(cycles, months=1.0)
        us = (time.perf_counter() - t0) * 1e6 / len(cycles)
        cum = sum(rep.steady_cents + rep.spent_cents for rep in d.history)
        results[name] = cum
        derived = dict(
            cycles=len(cycles), cum_cents=round(cum, 2),
            moves=sum(rep.n_selected for rep in d.history),
            deferrals=sum(rep.n_deferred for rep in d.history),
            max_cycle_spent=round(max(rep.spent_cents
                                      for rep in d.history), 4))
        if name == "capped":
            derived["cap_cents"] = round(budget.cents_per_cycle, 4)
            derived["cum_vs_unbudgeted_pct"] = round(
                100 * (cum / results["unbudgeted"] - 1), 3)
        rows.append(row(f"daemon/batch_N={BATCH_N}/{name}", us, **derived))
    return rows


def run():
    return emit(_stream_rows() + _batch_rows(), "daemon")


if __name__ == "__main__":
    run()
