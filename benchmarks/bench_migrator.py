"""Async migrator execution plane: overhead, chaos retry amplification.

Three sections:

1. ``migrator/sync/*`` — zero-fault ``AsyncMigrator.execute`` timed against
   the synchronous ``TieredStore.migrate`` on the same drifted plan. The
   execution plane is pinned bit-identical to the sync path by the parity
   tests; the benchmark records what the task queue + checksum verification
   costs on top (us per move, overhead ratio).
2. ``migrator/chaos/*`` — the same plan executed through a ``ChaosStore``
   at increasing transient-fault rates: attempts per move, the retry-cents
   share of attempted spend, and the committed-move fraction. Backoff
   sleeps are stubbed out so the numbers isolate the retry machinery.
3. ``migrator/replan`` — a batch ``ReoptimizationDaemon`` re-planning
   permanently failed moves across cycles until the fleet converges:
   cycles to convergence and the failed-cents write-off per cycle.

Set ``BENCH_SMOKE=1`` to shrink to a seconds-long CI smoke run.
"""

import os
import time

import numpy as np

from benchmarks.common import emit, row
from repro.core.costs import azure_table
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import (CompressStage, PartitionedData,
                               PlacementEngine, ScopeConfig)
from repro.core.migrator import AsyncMigrator
from repro.storage.chaos import ChaosStore
from repro.storage.store import TieredStore

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

N_PARTS = 16 if SMOKE else 96
CHAOS_P = (0.2,) if SMOKE else (0.05, 0.2, 0.4)
REPLAN_CYCLES = 4 if SMOKE else 8

_NOSLEEP = lambda s: None  # noqa: E731 — isolate retry cost from backoff


def _drifted():
    rng = np.random.default_rng(11)
    raws = [bytes([65 + i % 26]) * int(60_000 + 40_000 * rng.random())
            for i in range(N_PARTS)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), months=2.0)
    eng = PlacementEngine(azure_table(), cfg)
    data = PartitionedData(
        partitions=[None] * N_PARTS, tables=[None] * N_PARTS,
        raw_bytes=raws, spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=10.0 ** rng.uniform(-2, 3, N_PARTS))
    plan = eng.solve(CompressStage(cfg)(data, azure_table()))
    rho2 = plan.problem.rho * 10.0 ** rng.uniform(-3, 3, N_PARTS)
    mig = eng.reoptimize(plan, rho2, months_held=2.0)
    return eng, plan, mig


def _fresh(eng, plan):
    s = TieredStore(eng.table)
    keys = s.apply_plan(plan)
    s.advance_months(2.0)
    return s, keys


def _sync_rows(eng, plan, mig):
    s1, k1 = _fresh(eng, plan)
    t0 = time.perf_counter()
    s1.migrate(mig, k1)
    us_sync = (time.perf_counter() - t0) * 1e6 / max(mig.n_moved, 1)
    rows = [row("migrator/sync/store.migrate", us_sync, moves=mig.n_moved)]
    for workers in (1, 4):
        s2, k2 = _fresh(eng, plan)
        m = AsyncMigrator(s2, workers=workers, sleep_fn=_NOSLEEP)
        t0 = time.perf_counter()
        rep = m.execute(mig, k2)
        us = (time.perf_counter() - t0) * 1e6 / max(mig.n_moved, 1)
        rows.append(row(
            f"migrator/sync/async_w{workers}", us, moves=rep.n_committed,
            overhead_x=round(us / max(us_sync, 1e-9), 2),
            bill_drift_cents=round(abs(
                (s2.meter.read_cents + s2.meter.write_cents)
                - (s1.meter.read_cents + s1.meter.write_cents)), 9)))
    return rows


def _chaos_rows(eng, plan, mig):
    rows = []
    for p in CHAOS_P:
        s, keys = _fresh(eng, plan)
        ch = ChaosStore(s, seed=3, p_transient=p, max_faults_per_op=3)
        m = AsyncMigrator(ch, max_attempts=5, sleep_fn=_NOSLEEP)
        t0 = time.perf_counter()
        rep = m.execute(mig, keys)
        us = (time.perf_counter() - t0) * 1e6 / max(mig.n_moved, 1)
        att = rep.attempted_cents
        rows.append(row(
            f"migrator/chaos/p{p}", us,
            attempts_per_move=round(rep.n_attempts / max(mig.n_moved, 1), 2),
            retry_cents_share=round(rep.retry_cents / att if att else 0.0, 4),
            committed_frac=round(rep.n_committed / max(mig.n_moved, 1), 3),
            faults=ch.stats.n_faults))
    return rows


def _replan_rows(eng, plan, mig):
    s, keys = _fresh(eng, plan)
    ch = ChaosStore(s, seed=5, p_permanent=1.0, max_faults_per_op=1)
    m = AsyncMigrator(ch, sleep_fn=_NOSLEEP)
    d = ReoptimizationDaemon(eng, plan=plan, migrator=m, store_keys=keys,
                             budget=MigrationBudget(cents_per_cycle=np.inf))
    rho2 = mig.plan.problem.rho
    t0 = time.perf_counter()
    cycles = 0
    for _ in range(REPLAN_CYCLES):
        rep = d.step(rho2, months=1.0)
        cycles += 1
        if rep.n_failed == 0 and rep.n_selected == 0:
            break
    us = (time.perf_counter() - t0) * 1e6 / max(cycles, 1)
    # micro-cents: the bench payloads are ~100 KB, so per-move charges sit
    # far below one cent
    return [row(
        "migrator/replan", us, cycles_to_converge=cycles,
        failed_moves=sum(r.n_failed for r in d.history),
        attempted_ucents=round(
            1e6 * sum(r.attempted_cents for r in d.history), 2))]


def run():
    eng, plan, mig = _drifted()
    return emit(_sync_rows(eng, plan, mig) + _chaos_rows(eng, plan, mig)
                + _replan_rows(eng, plan, mig), "migrator")


if __name__ == "__main__":
    run()
