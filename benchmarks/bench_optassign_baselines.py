"""Paper Table IV — OptAssign (predicted/known access) vs caching-style
baselines on one storage account. Benefit = % vs all-hot."""

import numpy as np

from benchmarks.common import emit, row, timed
from repro.core.access_predict import (optimal_tiers, predicted_tiers,
                                       train_tier_predictor)
from repro.core.costs import azure_table
from repro.data.workloads import generate_workload


def _cost(w, table, tiers, lo, hi):
    months = hi - lo
    spans = np.array([d.size_gb for d in w.datasets])
    rho = w.reads_in(lo, hi)
    return (spans * table.storage_cents_gb_month[tiers] * months
            + rho * spans * table.read_cents_gb[tiers]).sum()


def run():
    table = azure_table()
    w = generate_workload(n_datasets=760, n_months=24, seed=7,
                          size_lognorm=(4.5, 2.0))
    rows = []
    N = len(w.datasets)
    all_hot = np.ones(N, int)

    def pct(tiers, lo, hi):
        return 100 * (1 - _cost(w, table, tiers, lo, hi)
                      / _cost(w, table, all_hot, lo, hi))

    # caching-style rules: hot iff accessed in the last m months
    for m, horizon in ((2, 4), (1, 4)):
        lo, hi = 12, 12 + horizon
        recent = w.reads_in(12 - m, 12) > 0
        tiers = np.where(recent, 1, 2)
        p, us = timed(lambda t=tiers, a=lo, b=hi: pct(t, a, b), repeats=1)
        rows.append(row(f"tableIV/hot_if_accessed_last_{m}mo", us,
                        duration_mo=horizon, benefit_pct=round(p, 2)))

    # use optimal tier of previous month
    prev = optimal_tiers(w, table, 11, 12, tiers=(1, 2))
    p, us = timed(lambda: pct(prev, 12, 14), repeats=1)
    rows.append(row("tableIV/prev_month_optimal", us, duration_mo=2,
                    benefit_pct=round(p, 2)))

    # OptAssign with predicted + known access, 2/4/6 month horizons
    clf, _ = train_tier_predictor(w, table, train_month=12, horizon=2)
    for horizon in (2, 4):
        predt = predicted_tiers(clf, w, 12, tiers=(1, 2))
        p, us = timed(lambda t=predt, h=horizon: pct(t, 12, 12 + h),
                      repeats=1)
        rows.append(row(f"tableIV/optassign_predicted_{horizon}mo", us,
                        benefit_pct=round(p, 2)))
    for horizon in (2, 4, 6):
        known = optimal_tiers(w, table, 12, 12 + horizon, tiers=(1, 2))
        p, us = timed(lambda t=known, h=horizon: pct(t, 12, 12 + h),
                      repeats=1)
        rows.append(row(f"tableIV/optassign_known_{horizon}mo", us,
                        benefit_pct=round(p, 2)))
    # with archive (paper: 43.8% at 6mo)
    known3 = optimal_tiers(w, table, 12, 18, tiers=(1, 2, 3))
    p, us = timed(lambda: pct(known3, 12, 18), repeats=1)
    rows.append(row("tableIV/optassign_known_6mo_with_archive", us,
                    benefit_pct=round(p, 2)))
    return emit(rows, "tableIV_optassign_baselines")


if __name__ == "__main__":
    run()
