"""Render the §Dry-run / §Roofline markdown tables from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.render_roofline [--mesh pod16x16]
"""

import argparse
import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
import os
if os.environ.get("DRYRUN_DIR"):
    DRYRUN = pathlib.Path(os.environ["DRYRUN_DIR"])


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.2f}M"
    return f"{b/1e3:.1f}K"


def load(mesh):
    recs = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def render(mesh: str, full: bool = True) -> str:
    rows = []
    head = ("| arch | shape | status | compute_s | memory_s | collective_s | "
            "dominant | useful 6ND/HLO | HLO flops/dev | HBM/dev | coll/dev | "
            "temp GB/dev | compile_s |")
    sep = "|" + "---|" * 13
    rows.append(head)
    rows.append(sep)
    for r in load(mesh):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                        + "| — " * 10 + "|")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR "
                        + "| — " * 10 + "|")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
            f"| {ro['collective_s']:.4f} | **{ro['dominant']}** "
            f"| {ro['useful_ratio']:.2f} "
            f"| {fmt_bytes(ro['flops'])} | {fmt_bytes(ro['hbm_bytes'])}B "
            f"| {fmt_bytes(ro['coll_bytes'])}B "
            f"| {r['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.1f} "
            f"| {r.get('compile_s', 0):.0f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=["pod16x16", "pod2x16x16", "both"])
    args = ap.parse_args()
    meshes = ["pod16x16", "pod2x16x16"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        print(f"\n### mesh {m}\n")
        print(render(m))


if __name__ == "__main__":
    main()
