"""Benchmark entry point — one module per paper table/figure + framework
micro/roofline benches. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only tableII,fig7,...]
"""

import argparse
import sys
import time

MODULES = [
    ("tableII", "benchmarks.bench_optassign_enterprise"),
    ("tableIII", "benchmarks.bench_access_predict"),
    ("tableIV", "benchmarks.bench_optassign_baselines"),
    ("tablesV-VIII", "benchmarks.bench_compredict"),
    ("features", "benchmarks.bench_feature_backends"),
    ("fig7", "benchmarks.bench_gpart"),
    ("gpart_scale", "benchmarks.bench_gpart_scale"),
    ("tablesIX-XI", "benchmarks.bench_scope_pipeline"),
    ("reopt", "benchmarks.bench_reoptimize"),
    ("stream", "benchmarks.bench_stream"),
    ("daemon", "benchmarks.bench_daemon"),
    ("multicloud", "benchmarks.bench_multicloud"),
    ("fleet", "benchmarks.bench_fleet"),
    ("migrator", "benchmarks.bench_migrator"),
    ("forecast", "benchmarks.bench_forecast"),
    ("sla", "benchmarks.bench_sla"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated tags (e.g. tableII,fig7)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if only:
        valid = [tag for tag, _ in MODULES]
        unknown = sorted(only - set(valid))
        if unknown:
            print(f"unknown benchmark tag(s) {unknown}; "
                  f"valid tags: {', '.join(valid)}", file=sys.stderr)
            sys.exit(2)
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append((tag, repr(e)))
            print(f"{tag}/FAILED,0,{{\"error\": \"{e}\"}}")
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
