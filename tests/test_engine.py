"""Staged PlacementEngine: legacy parity, stage decomposition, reoptimize."""

import numpy as np
import pytest

from repro.core.costs import azure_table
from repro.core.engine import (BillingStage, PartitionedData, PlacementEngine,
                               PlacementProblem, ScopeConfig)
from repro.core.optassign import capacitated_assign_ref, greedy_assign
from repro.core.scope import paper_variants, run_pipeline
from repro.data import tpch
from repro.storage.codecs import available_schemes
from repro.storage.store import TieredStore


@pytest.fixture(scope="module")
def sample():
    db = tpch.generate(scale_rows=1500, seed=0)
    qs = tpch.generate_queries(db, n_per_template=3, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, qs)
    table = azure_table()
    total = sum(p.span for p in parts) / 1e9
    cap = np.array([total * 0.2, total * 0.4, total * 0.6, np.inf])
    return parts, file_rows, table, cap


def _legacy_bill_total(problem, assign, table, cfg) -> float:
    """The seed monolith's per-partition Python billing loop, verbatim."""
    storage = read = decomp = 0.0
    for n in range(problem.n):
        l, k = int(assign.tier[n]), int(assign.scheme[n])
        stored_gb = problem.spans_gb[n] / problem.R[n, k]
        storage += stored_gb * table.storage_cents_gb_month[l] * cfg.months
        read += problem.rho[n] * stored_gb * table.read_cents_gb[l]
        decomp += problem.rho[n] * problem.D[n, k] * table.compute_cents_sec
    return storage + read + decomp


def test_engine_parity_all_paper_variants(sample):
    """On a shared problem, the staged engine reproduces the legacy solver +
    billing loop for every Tables IX-XI variant — except where the vectorized
    solver strictly improves on the legacy heuristic's objective."""
    parts, file_rows, table, cap = sample
    for name, cfg in paper_variants(cap).items():
        eng = PlacementEngine(table, cfg)
        problem = eng.build_problem(parts, file_rows)
        plan = eng.solve(problem)

        cost, feas = eng.assign.cost_and_feasibility(problem)
        if cfg.capacity_gb is None:
            legacy = greedy_assign(cost, feas)
        else:
            legacy = capacitated_assign_ref(cost, feas,
                                            problem.stored_matrix(),
                                            cfg.capacity_gb)
        assert plan.assignment.feasible and legacy.feasible, name
        # never worse than the legacy solver on the shared objective
        assert plan.assignment.cost <= legacy.cost * (1 + 1e-9) + 1e-15, name
        # billing parity: array-math BillingStage == legacy Python loop
        legacy_total = _legacy_bill_total(problem, plan.assignment, table, cfg)
        assert plan.report.total_cents == pytest.approx(legacy_total,
                                                        rel=1e-6), name
        if plan.assignment.cost == pytest.approx(legacy.cost, rel=1e-9):
            legacy_total2 = _legacy_bill_total(problem, legacy, table, cfg)
            assert plan.report.total_cents == pytest.approx(legacy_total2,
                                                            rel=1e-6), name


def test_run_pipeline_is_engine(sample):
    """The compatibility wrapper and the engine agree end-to-end (checked on
    deterministic variants — measured-D variants differ run-to-run)."""
    parts, file_rows, table, cap = sample
    for name, cfg in paper_variants(cap).items():
        if cfg.use_compression:
            continue  # CompressStage re-measures timings each call
        rep = run_pipeline(parts, file_rows, table, cfg)
        plan = PlacementEngine(table, cfg).run(parts, file_rows)
        assert rep.total_cents == pytest.approx(plan.report.total_cents,
                                                rel=1e-6), name
        assert np.array_equal(rep.assignment.tier, plan.assignment.tier), name
        assert np.array_equal(rep.assignment.scheme,
                              plan.assignment.scheme), name


def test_stage_decomposition(sample):
    parts, file_rows, table, cap = sample
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), capacity_gb=cap)
    eng = PlacementEngine(table, cfg)
    data = eng.partition(parts, file_rows)
    assert isinstance(data, PartitionedData)
    assert len(data.partitions) == len(data.raw_bytes) == data.spans_gb.shape[0]
    problem = eng.compress(data, table)
    assert problem.R.shape == (problem.n, len(problem.schemes))
    assert (problem.current_tier == -1).all()
    plan = eng.solve(problem)
    assert plan.report.n_partitions == problem.n
    # staged run == composed stages
    plan2 = eng.run(parts, file_rows)
    assert plan2.report.tiering_scheme == plan.report.tiering_scheme


def _synthetic_plan(capacity=None):
    """Small hand-built problem with real payloads (truth-measured R/D)."""
    table = azure_table()
    rng = np.random.default_rng(0)
    raws = [(bytes([65 + i % 8]) * (200_000 + 50_000 * i))  # compressible
            for i in range(6)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), capacity_gb=capacity,
                      months=2.0)
    eng = PlacementEngine(table, cfg)
    from repro.core.engine import CompressStage, PartitionedData
    data = PartitionedData(
        partitions=[None] * len(raws), tables=[None] * len(raws),
        raw_bytes=raws,
        spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 0.1, 40.0, 0.02, 800.0, 5.0]))
    problem = CompressStage(cfg)(data, table)
    return eng, eng.solve(problem)


def test_reoptimize_locks_unchanged_and_charges_once():
    eng, plan = _synthetic_plan()
    rho = plan.problem.rho
    new_rho = rho.copy()
    new_rho[0] *= 5000.0          # cold -> hot: must migrate up
    new_rho[4] /= 5000.0          # hot -> cold: should migrate down
    mig = eng.reoptimize(plan, new_rho, months_held=0.2)
    assert mig.n_moved >= 1
    # undrifted partitions keep their compression scheme (locked)
    for n in (1, 2, 3, 5):
        assert mig.new_scheme[n] == mig.old_scheme[n]
    # migration cost charged once: read-out + write-in per moved partition
    table = eng.table
    old_stored = plan.stored_gb
    new_stored = mig.plan.stored_gb
    expect = 0.0
    for n in np.where(mig.moved)[0]:
        write_gb = old_stored[n] if mig.new_scheme[n] == mig.old_scheme[n] \
            else new_stored[n]
        expect += (old_stored[n] * table.read_cents_gb[mig.old_tier[n]]
                   + write_gb * table.write_cents_gb[mig.new_tier[n]])
    assert mig.migration_cents == pytest.approx(expect, rel=1e-12)
    # the steady-state report excludes one-off migration charges
    rep = mig.plan.report
    assert rep.total_cents == pytest.approx(
        rep.storage_cents + rep.read_cents + rep.decomp_cents, rel=1e-12)


def test_reoptimize_migration_matches_store_metering():
    """Applying the MigrationPlan to a live TieredStore bills exactly the
    plan's transfer + penalty cents (compute/TTFB metering aside)."""
    eng, plan = _synthetic_plan()
    new_rho = plan.problem.rho.copy()
    new_rho[0] *= 5000.0
    new_rho[4] /= 5000.0

    store = TieredStore(eng.table)
    keys = store.apply_plan(plan)
    assert len(keys) == plan.problem.n
    # stored sizes in the store match the plan's truth-measured estimates
    for n, key in enumerate(keys):
        assert store.stored_gb(key) == pytest.approx(plan.stored_gb[n],
                                                     rel=1e-9)
    store.advance_months(0.2)
    mig = eng.reoptimize(plan, new_rho, months_held=0.2)
    r0, w0 = store.meter.read_cents, store.meter.write_cents
    p0 = store.meter.penalty_cents
    moved = store.migrate(mig)
    assert moved == mig.n_moved >= 1
    transfer = (store.meter.read_cents - r0) + (store.meter.write_cents - w0)
    assert transfer == pytest.approx(mig.migration_cents, rel=1e-9)
    assert store.meter.penalty_cents - p0 == pytest.approx(mig.penalty_cents,
                                                           rel=1e-9, abs=1e-15)
    # objects actually sit on their new tiers
    for n in np.where(mig.moved)[0]:
        assert store.tier_of(keys[n]) == mig.new_tier[n]


def test_reoptimize_early_delete_penalty_charged():
    """Moving out of Cool before its 1-month minimum stay costs the prorated
    remainder — and reoptimize only moves when savings beat that penalty."""
    eng, plan = _synthetic_plan()
    in_cool = plan.assignment.tier == 2
    if not in_cool.any():
        pytest.skip("no partition landed on Cool in this instance")
    n = int(np.where(in_cool)[0][0])
    new_rho = plan.problem.rho.copy()
    new_rho[n] = 1e6              # overwhelming read traffic: must move up
    mig = eng.reoptimize(plan, new_rho, months_held=0.25)
    assert mig.moved[n] and mig.new_tier[n] < 2
    expect = (plan.stored_gb[n]
              * eng.table.storage_cents_gb_month[2] * (1.0 - 0.25))
    assert mig.penalty_cents >= expect - 1e-15


def test_reoptimize_no_drift_is_idempotent():
    """Hysteresis: a no-drift stream of reoptimize calls never migrates —
    the cost tensor internalizes transfer from current_tier, so staying put
    is optimal, and repeated calls are stable fixed points."""
    eng, plan = _synthetic_plan()
    cur = plan
    for months in (0.25, 1.0, 3.0):
        mig = eng.reoptimize(cur, cur.problem.rho.copy(), months_held=months)
        assert mig.n_moved == 0
        assert mig.migration_cents == 0.0 and mig.penalty_cents == 0.0
        assert np.array_equal(mig.new_tier, mig.old_tier)
        assert np.array_equal(mig.new_scheme, mig.old_scheme)
        cur = mig.plan


def test_reoptimize_charges_each_tier_change_at_most_once():
    """A drift step pays its migration once; re-running reoptimize at the
    already-migrated state with the same rates charges nothing further."""
    eng, plan = _synthetic_plan()
    new_rho = plan.problem.rho.copy()
    new_rho[0] *= 5000.0
    new_rho[4] /= 5000.0
    mig1 = eng.reoptimize(plan, new_rho, months_held=0.2)
    assert mig1.n_moved >= 1 and mig1.migration_cents > 0.0
    total_paid = mig1.total_move_cents
    for _ in range(2):
        mig = eng.reoptimize(mig1.plan, new_rho, months_held=0.5)
        assert mig.n_moved == 0
        assert mig.total_move_cents == 0.0
        total_paid += mig.total_move_cents
    assert total_paid == pytest.approx(mig1.total_move_cents)


def test_reoptimize_accepts_per_partition_months_held():
    """Heterogeneous residency clocks: each partition's early-delete penalty
    is prorated by its own hold — the scalar path would mis-price both."""
    import dataclasses as dc
    from repro.core.engine import PlacementEngine, PlacementProblem
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(1, 2), schemes=("none",))
    eng = PlacementEngine(table, cfg)
    prob = PlacementProblem(
        spans_gb=np.array([1.0, 1.0]), rho=np.array([0.1, 0.1]),
        current_tier=np.full(2, -1), R=np.ones((2, 1)), D=np.zeros((2, 1)),
        schemes=("none",), table=table, cfg=cfg)
    plan = eng.solve(prob)
    assert (plan.assignment.tier == 2).all()      # both land on Cool
    hot = np.array([500.0, 500.0])
    held = np.array([0.25, 0.9])                  # placed at different times
    mig = eng.reoptimize(plan, hot, months_held=held)
    assert mig.moved.all() and (mig.new_tier == 1).all()
    expect = sum(1.0 * table.storage_cents_gb_month[2] * (1.0 - h)
                 for h in held)
    assert mig.penalty_cents == pytest.approx(expect, rel=1e-12)
    # the scalar path prices BOTH partitions at the youngest clock
    mig_scalar = eng.reoptimize(plan, hot, months_held=0.25)
    assert mig_scalar.penalty_cents == pytest.approx(
        2 * 1.0 * table.storage_cents_gb_month[2] * 0.75, rel=1e-12)
    assert mig_scalar.penalty_cents > mig.penalty_cents
    with pytest.raises(ValueError):
        eng.reoptimize(plan, hot, months_held=np.array([0.25, 0.5, 0.75]))


def test_drift_gate_absolute_floor():
    from repro.core.engine import drift_gate
    rho_ref = np.array([0.0, 10.0, 10.0])
    rho = np.array([1e-6, 10.1, 20.0])
    # without the floor, a cold partition drifts on an epsilon access
    np.testing.assert_array_equal(
        drift_gate(rho, rho_ref, 0.25), [True, False, True])
    np.testing.assert_array_equal(
        drift_gate(rho, rho_ref, 0.25, rho_abs_tol=0.5),
        [False, False, True])
    # the floor composes with (never weakens) the relative band
    np.testing.assert_array_equal(
        drift_gate(rho, rho_ref, 0.25, rho_abs_tol=20.0),
        [False, False, False])


def test_rho_abs_tol_keeps_cold_partitions_scheme_locked():
    """A cold partition (rho_ref == 0) receiving an epsilon access must not
    lose its scheme lock when rho_abs_tol is set; with a zero floor the
    relative gate alone lets it churn."""
    import dataclasses as dc
    from repro.core.engine import PlacementEngine, PlacementProblem, \
        PlacementPlan
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(1,), schemes=("none", "lz4"),
                      months=2.0)
    eng = PlacementEngine(table, cfg)
    prob = PlacementProblem(
        spans_gb=np.array([1.0, 1.0]), rho=np.array([0.0, 50.0]),
        current_tier=np.full(2, -1), R=np.ones((2, 2)), D=np.zeros((2, 2)),
        schemes=("none", "lz4"), table=table, cfg=cfg)
    plan = eng.solve(prob)
    assert (plan.assignment.scheme == 0).all()    # tie -> first scheme
    # the predictor later learns lz4 compresses 5x: re-encoding now pays,
    # but only unlocked partitions may take it
    better = dc.replace(prob, R=np.array([[1.0, 5.0], [1.0, 5.0]]))
    plan2 = PlacementPlan(better, plan.assignment, plan.report)
    eps = np.array([1e-6, 50.0])
    unlocked = eng.reoptimize(plan2, eps, rho_rel_tol=0.25, rho_abs_tol=0.0)
    assert unlocked.moved[0] and unlocked.new_scheme[0] == 1
    assert not unlocked.moved[1]                  # undrifted stays locked
    locked = eng.reoptimize(plan2, eps, rho_rel_tol=0.25, rho_abs_tol=1e-3)
    assert locked.n_moved == 0 and locked.migration_cents == 0.0
    assert (locked.new_scheme == 0).all()


def test_billing_stage_matches_legacy_loop_random_assignments():
    eng, plan = _synthetic_plan()
    problem = plan.problem
    rng = np.random.default_rng(3)
    stage = BillingStage(eng.table, eng.cfg)
    for _ in range(5):
        import dataclasses as dc
        a = dc.replace(plan.assignment,
                       tier=rng.integers(0, 3, problem.n),
                       scheme=rng.integers(0, len(problem.schemes), problem.n))
        rep = stage(problem, a)
        assert rep.total_cents == pytest.approx(
            _legacy_bill_total(problem, a, eng.table, eng.cfg), rel=1e-9)
