"""Multi-cloud placement: flattened (provider, tier) cost tables with
cross-provider egress, per-provider capacity groups in the capacitated
solver, egress-exactly-once migration accounting (engine + store), and
streaming state carry across a provider switch.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.costs import (CostTable, ProviderCostTable, Weights,
                              aws_s3_provider, azure_blob_provider,
                              azure_table, big3_table, cost_tensor,
                              gcp_gcs_provider, latency_feasible,
                              move_egress_cents_gb, multi_cloud_table)
from repro.core.engine import (PlacementEngine, PlacementProblem, ScopeConfig,
                               StreamingEngine)
from repro.core.optassign import brute_force, capacitated_assign
from repro.storage.store import TieredStore


# ------------------------------------------------------------------ fixtures
def _alpha_beta(egress_alpha=5.0, egress_beta=7.0, alpha_cap=np.inf,
                beta_cap=np.inf):
    """Two hand-built providers: alpha is fast/expensive storage with cheap
    reads, beta is cheap storage with expensive reads — so hot data prefers
    alpha and cold data prefers beta, and rho drift forces provider moves."""
    alpha = CostTable(
        storage_cents_gb_month=np.array([10.0, 8.0]),
        read_cents_gb=np.array([0.1, 0.5]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.01, 0.05]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 0.0]),
        names=("hot", "warm"))
    beta = CostTable(
        storage_cents_gb_month=np.array([2.0, 0.2]),
        read_cents_gb=np.array([1.0, 4.0]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.05, 0.2]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 1.0]),
        names=("std", "cold"))
    return multi_cloud_table([
        ProviderCostTable("alpha", alpha, egress_alpha, alpha_cap),
        ProviderCostTable("beta", beta, egress_beta, beta_cap)])


def _synthetic_problem(table, cfg, N=60, seed=3, K=3):
    rng = np.random.default_rng(seed)
    spans = rng.lognormal(0.0, 1.2, N) * 2.0
    rho = rng.gamma(0.7, 25.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, K - 1)) * spans[:, None]],
                       1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=list(cfg.schemes), table=table, cfg=cfg)


SCHEMES = ("none", "a", "b")


# ------------------------------------------------------- flattened cost table
def test_flat_table_concatenates_provider_vectors():
    t = big3_table()
    assert t.num_tiers == 12 and t.num_providers == 3
    assert t.provider_names == ("aws", "gcp", "azure")
    aws = aws_s3_provider().table
    np.testing.assert_array_equal(t.storage_cents_gb_month[:4],
                                  aws.storage_cents_gb_month)
    np.testing.assert_array_equal(t.read_cents_gb[4:8],
                                  gcp_gcs_provider().table.read_cents_gb)
    np.testing.assert_array_equal(t.early_delete_months[8:],
                                  azure_blob_provider().table
                                  .early_delete_months)
    assert t.names[0] == "aws:standard" and t.names[8] == "azure:hot"
    np.testing.assert_array_equal(t.provider_of_tier,
                                  np.repeat([0, 1, 2], 4))
    np.testing.assert_array_equal(t.provider_tiers(1), [4, 5, 6, 7])


def test_tier_change_block_structure():
    """Delta is block-structured: within-provider blocks are read+write with
    a zero diagonal; cross-provider blocks add the source's egress; the
    ingestion row (-1) pays write only, never egress."""
    t = _alpha_beta()
    delta = t.tier_change_cents_gb()
    L = t.num_tiers
    assert delta.shape == (L + 1, L)
    assert np.allclose(np.diag(delta[:L]), 0.0)
    base = t.read_cents_gb[:, None] + t.write_cents_gb[None, :]
    p = t.provider_of_tier
    for u in range(L):
        for v in range(L):
            if u == v:
                continue
            eg = 0.0 if p[u] == p[v] else t.egress_cents_gb[p[u], p[v]]
            assert delta[u, v] == pytest.approx(base[u, v] + eg)
    np.testing.assert_array_equal(delta[-1], t.write_cents_gb)


def test_egress_matrix_defaults_and_overrides():
    t = _alpha_beta(egress_alpha=5.0, egress_beta=7.0)
    np.testing.assert_array_equal(t.egress_cents_gb,
                                  [[0.0, 5.0], [7.0, 0.0]])
    explicit = multi_cloud_table(
        [ProviderCostTable("a", azure_table()),
         ProviderCostTable("b", azure_table())],
        egress_cents_gb=np.array([[99.0, 3.0], [4.0, 99.0]]))
    # the diagonal is always forced to zero
    np.testing.assert_array_equal(explicit.egress_cents_gb,
                                  [[0.0, 3.0], [4.0, 0.0]])
    with pytest.raises(ValueError):
        multi_cloud_table([ProviderCostTable("a", azure_table())],
                          egress_cents_gb=np.zeros((2, 2)))
    with pytest.raises(ValueError):
        multi_cloud_table([])


def test_move_egress_helper():
    t = _alpha_beta()
    assert float(move_egress_cents_gb(t, 0, 2)) == 5.0       # alpha -> beta
    assert float(move_egress_cents_gb(t, 3, 1)) == 7.0       # beta -> alpha
    assert float(move_egress_cents_gb(t, 0, 1)) == 0.0       # within alpha
    assert float(move_egress_cents_gb(t, -1, 2)) == 0.0      # ingestion
    # single-cloud tables never pay egress
    assert float(move_egress_cents_gb(azure_table(), 0, 3)) == 0.0
    np.testing.assert_array_equal(
        move_egress_cents_gb(t, np.array([0, -1, 3]), np.array([3, 2, 0])),
        [5.0, 0.0, 7.0])


# --------------------------------------------------------------- exact parity
def test_single_provider_zero_egress_plan_identical_greedy():
    """Acceptance bar: one provider + zero egress collapses bit-for-bit to
    today's single-cloud solver on the unbounded (greedy) path."""
    az = azure_table()
    flat = multi_cloud_table([ProviderCostTable("azure", az, 0.0)])
    np.testing.assert_array_equal(flat.tier_change_cents_gb(),
                                  az.tier_change_cents_gb())
    cfg = ScopeConfig(schemes=SCHEMES)
    p1 = PlacementEngine(az, cfg).solve(_synthetic_problem(az, cfg))
    p2 = PlacementEngine(flat, cfg).solve(_synthetic_problem(flat, cfg))
    np.testing.assert_array_equal(p1.assignment.tier, p2.assignment.tier)
    np.testing.assert_array_equal(p1.assignment.scheme, p2.assignment.scheme)
    assert p1.assignment.cost == p2.assignment.cost
    assert p1.report.total_cents == p2.report.total_cents
    assert p2.report.provider_scheme == [p2.problem.n]


def test_single_provider_zero_egress_plan_identical_capacitated():
    az = azure_table()
    flat = multi_cloud_table([ProviderCostTable("azure", az, 0.0)])
    cap = np.array([50.0, 100.0, 200.0, np.inf])
    cfg = ScopeConfig(schemes=SCHEMES, capacity_gb=cap)
    p1 = PlacementEngine(az, cfg).solve(_synthetic_problem(az, cfg))
    p2 = PlacementEngine(flat, cfg).solve(_synthetic_problem(flat, cfg))
    assert p1.assignment.feasible and p2.assignment.feasible
    np.testing.assert_array_equal(p1.assignment.tier, p2.assignment.tier)
    np.testing.assert_array_equal(p1.assignment.scheme, p2.assignment.scheme)
    assert p1.assignment.cost == p2.assignment.cost


def test_single_provider_zero_egress_reoptimize_identical():
    az = azure_table()
    flat = multi_cloud_table([ProviderCostTable("azure", az, 0.0)])
    cfg = ScopeConfig(schemes=SCHEMES)
    migs = []
    for t in (az, flat):
        eng = PlacementEngine(t, cfg)
        plan = eng.solve(_synthetic_problem(t, cfg))
        new_rho = plan.problem.rho.copy()
        new_rho[::5] *= 1000.0
        new_rho[1::5] /= 1000.0
        migs.append(eng.reoptimize(plan, new_rho, months_held=0.25))
    a, b = migs
    np.testing.assert_array_equal(a.new_tier, b.new_tier)
    np.testing.assert_array_equal(a.new_scheme, b.new_scheme)
    assert a.migration_cents == b.migration_cents
    assert a.penalty_cents == b.penalty_cents
    assert b.egress_cents == 0.0


# ------------------------------------------------------- cross-provider plans
def test_cross_provider_never_costlier_than_best_single():
    """The flattened space is a superset of every single-provider space, and
    the unbounded solver is exact — so the cross-provider plan can never
    cost more than the best single-provider plan."""
    t = big3_table()
    cfg = ScopeConfig(schemes=SCHEMES)
    cross = PlacementEngine(t, cfg).solve(
        _synthetic_problem(t, cfg)).report.total_cents
    singles = {}
    for p in t.provider_names:
        c = ScopeConfig(schemes=SCHEMES, provider_whitelist=(p,))
        singles[p] = PlacementEngine(t, c).solve(
            _synthetic_problem(t, c)).report.total_cents
    assert cross <= min(singles.values()) + 1e-9


def test_provider_whitelist_masks_tiers():
    t = big3_table()
    cfg = ScopeConfig(schemes=SCHEMES, provider_whitelist=("gcp",))
    plan = PlacementEngine(t, cfg).solve(_synthetic_problem(t, cfg))
    assert set(np.unique(t.provider_of_tier[plan.assignment.tier])) == {1}
    assert plan.report.provider_scheme == [0, plan.problem.n, 0]
    with pytest.raises(ValueError):
        bad = ScopeConfig(schemes=SCHEMES, provider_whitelist=("nope",))
        PlacementEngine(t, bad).solve(_synthetic_problem(t, bad))
    with pytest.raises(ValueError):
        bad = ScopeConfig(schemes=SCHEMES, provider_whitelist=("gcp",))
        PlacementEngine(azure_table(), bad).solve(
            _synthetic_problem(azure_table(), bad))


# ----------------------------------------------- provider capacity constraints
def _tiny_instance(table, seed, N=5, K=2):
    rng = np.random.default_rng(seed)
    spans = rng.uniform(0.5, 50.0, N)
    rho = rng.gamma(1.0, 20.0, N)
    cur = rng.integers(-1, table.num_tiers, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)), rng.uniform(0.01, 3.0, (N, K - 1))],
                       1)
    cost = cost_tensor(spans, rho, cur, R, D, table, Weights(), months=6)
    feas = latency_feasible(D, np.full(N, np.inf), table)
    stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
    return cost, feas, stored, spans


def test_provider_caps_match_bruteforce_tiny():
    """Per-provider group rows in the capacitated solver find the optimum on
    tiny structured instances (validated against exact enumeration)."""
    table = _alpha_beta()
    groups = table.provider_of_tier
    checked = 0
    for seed in range(12):
        cost, feas, stored, spans = _tiny_instance(table, seed)
        gcap = np.array([spans.sum() * 0.5, spans.sum() * 0.8])
        cap = np.full(table.num_tiers, np.inf)
        bf = brute_force(cost, feas, stored, cap, tier_groups=groups,
                         group_capacity_gb=gcap)
        if not bf.feasible:
            continue
        ca = capacitated_assign(cost, feas, stored, cap, tier_groups=groups,
                                group_capacity_gb=gcap)
        assert ca.feasible
        assert ca.cost == pytest.approx(bf.cost, rel=1e-9)
        checked += 1
    assert checked >= 6


def test_provider_caps_respected_at_scale():
    t = _alpha_beta(alpha_cap=5.0, beta_cap=np.inf)
    cfg = ScopeConfig(schemes=SCHEMES)
    plan = PlacementEngine(t, cfg).solve(_synthetic_problem(t, cfg, N=200))
    assert plan.assignment.feasible
    stored = plan.stored_gb
    p = t.provider_of_tier[plan.assignment.tier]
    assert stored[p == 0].sum() <= 5.0 + 1e-6
    # uncapped run would overflow alpha (the constraint actually binds)
    t_inf = _alpha_beta()
    plan_inf = PlacementEngine(t_inf, cfg).solve(
        _synthetic_problem(t_inf, cfg, N=200))
    p_inf = t_inf.provider_of_tier[plan_inf.assignment.tier]
    assert plan_inf.stored_gb[p_inf == 0].sum() > 5.0


def test_combined_tier_and_provider_caps_match_bruteforce():
    table = _alpha_beta()
    groups = table.provider_of_tier
    checked = 0
    for seed in range(10):
        cost, feas, stored, spans = _tiny_instance(table, seed + 100, N=4)
        total = spans.sum()
        cap = np.array([total * 0.4, np.inf, total * 0.4, np.inf])
        gcap = np.array([total * 0.6, total * 0.9])
        bf = brute_force(cost, feas, stored, cap, tier_groups=groups,
                         group_capacity_gb=gcap)
        if not bf.feasible:
            continue
        ca = capacitated_assign(cost, feas, stored, cap, tier_groups=groups,
                                group_capacity_gb=gcap)
        assert ca.feasible
        assert ca.cost == pytest.approx(bf.cost, rel=1e-9)
        checked += 1
    assert checked >= 5


# ------------------------------------------------------- migration accounting
def _placed_hot_plan(table=None, months=6.0):
    """4 uncompressed partitions, all hot -> everything lands on alpha.

    Spans are tiny (tens of KB) so real payloads can back the plan for
    store tests; per-partition placement is scale-invariant in span, so the
    economics match the GB-scale story exactly."""
    table = table if table is not None else _alpha_beta()
    cfg = ScopeConfig(schemes=("none",), months=months)
    N = 4
    spans = np.array([1.0, 2.0, 3.0, 4.0]) * 1e-5
    raws = [b"\xab" * int(s * 1e9) for s in spans]
    prob = PlacementProblem(
        spans_gb=spans,
        rho=np.array([100.0, 90.0, 80.0, 60.0]),
        current_tier=np.full(N, -1), R=np.ones((N, 1)), D=np.zeros((N, 1)),
        schemes=["none"], table=table, cfg=cfg, raw_bytes=raws)
    eng = PlacementEngine(table, cfg)
    plan = eng.solve(prob)
    assert (table.provider_of_tier[plan.assignment.tier] == 0).all()
    return eng, plan


def test_reoptimize_charges_egress_exactly_once():
    t = _alpha_beta()
    eng, plan = _placed_hot_plan(t)
    mig = eng.reoptimize(plan, plan.problem.rho * 1e-4)
    assert mig.moved.all()
    assert (t.provider_of_tier[mig.new_tier] == 1).all()
    expect_egress = float((plan.stored_gb * 5.0).sum())
    assert mig.egress_cents == pytest.approx(expect_egress, rel=1e-12)
    # migration = read-out + egress + write-in, each exactly once
    expect = float((plan.stored_gb
                    * (t.read_cents_gb[mig.old_tier] + 5.0)
                    + plan.stored_gb
                    * t.write_cents_gb[mig.new_tier]).sum())
    assert mig.migration_cents == pytest.approx(expect, rel=1e-12)
    # repeating at the migrated state charges nothing further
    mig2 = eng.reoptimize(mig.plan, plan.problem.rho * 1e-4)
    assert mig2.n_moved == 0 and mig2.egress_cents == 0.0


def test_reoptimize_internalizes_egress_hysteresis():
    """A drift that would justify a provider move at zero egress is absorbed
    when egress makes the move uneconomical — the optimizer prices the
    off-diagonal block, not just steady state."""
    drift = 0.05
    free = _alpha_beta(egress_alpha=0.0)
    eng_f, plan_f = _placed_hot_plan(free, months=1.0)
    mig_f = eng_f.reoptimize(plan_f, plan_f.problem.rho * drift)
    # cheap to leave alpha: some partition crosses to beta
    assert (free.provider_of_tier[mig_f.new_tier] == 1).any()
    costly = _alpha_beta(egress_alpha=500.0)
    eng_c, plan_c = _placed_hot_plan(costly, months=1.0)
    np.testing.assert_array_equal(plan_c.assignment.tier,
                                  plan_f.assignment.tier)
    mig_c = eng_c.reoptimize(plan_c, plan_c.problem.rho * drift)
    # egress wall: nothing leaves alpha (moves within it are still allowed)
    assert (costly.provider_of_tier[mig_c.new_tier] == 0).all()
    assert mig_c.egress_cents == 0.0


def test_constraint_args_must_come_together():
    cost = np.ones((2, 4, 1))
    feas = np.ones((2, 4, 1), bool)
    stored = np.ones((2, 4, 1))
    cap = np.full(4, np.inf)
    gcap = np.array([1.0, 1.0])
    with pytest.raises(ValueError):
        capacitated_assign(cost, feas, stored, cap, group_capacity_gb=gcap)
    with pytest.raises(ValueError):
        capacitated_assign(cost, feas, stored, cap,
                           tier_groups=np.array([0, 0, 1, 1]))
    with pytest.raises(ValueError):
        brute_force(cost, feas, stored, cap, group_capacity_gb=gcap)


def test_egress_objective_priced_on_old_stored_bytes():
    """The bill charges egress on the bytes that actually leave the source
    provider (the old stored payload); the objective must price it the same
    way, or a scheme change riding a provider move mis-weighs the egress
    wall by the compression-ratio factor.

    Here a partition sits compressed 8x on alpha:hot with high decompression
    cost and must move to beta:std. Decompressing on the way (scheme ->
    none) is truly cheaper; a Delta-basis objective would over-price the
    none cell's egress 8x (on the decompressed bytes) and wrongly keep the
    expensive scheme."""
    alpha = CostTable(
        storage_cents_gb_month=np.array([10.0, 8.0]),
        read_cents_gb=np.array([0.1, 0.5]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.01, 0.05]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 0.0]),
        compute_cents_sec=1.0, names=("hot", "warm"))
    beta = CostTable(
        storage_cents_gb_month=np.array([2.0, 0.2]),
        read_cents_gb=np.array([1.0, 4.0]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.05, 0.2]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 1.0]),
        compute_cents_sec=1.0, names=("std", "cold"))
    t = multi_cloud_table([ProviderCostTable("alpha", alpha, 5.0),
                           ProviderCostTable("beta", beta, 7.0)])
    cfg = ScopeConfig(schemes=("none", "b"), months=1.0,
                      tier_whitelist=(2,))           # beta:std only
    eng = PlacementEngine(t, cfg)
    prob = PlacementProblem(
        spans_gb=np.array([1.0]), rho=np.array([2.0]),
        current_tier=np.array([0]),
        R=np.array([[1.0, 8.0]]), D=np.array([[0.0, 3.0]]),
        schemes=["none", "b"], table=t, cfg=cfg)
    mig = eng._solve_migration(prob, cur_l=np.array([0]),
                               cur_k=np.array([1]),
                               old_stored=np.array([1.0 / 8.0]),
                               months_held=0.0, lock_unchanged=False,
                               rho_rel_tol=0.25, rho_ref=np.array([2.0]))
    # true totals: none = steady 4.0 + move ~0.69 < b = steady 6.75 + ~0.64
    assert mig.new_tier[0] == 2
    assert mig.new_scheme[0] == 0                    # decompress on the move
    # egress billed once, on the old (compressed) stored bytes
    assert mig.egress_cents == pytest.approx(1.0 / 8.0 * 5.0)


def test_egress_composes_with_early_delete_penalty():
    """Leaving beta:cold (1-month minimum stay) early for alpha pays the
    prorated stay remainder AND beta's egress, composed in one plan."""
    t = _alpha_beta()
    cfg = ScopeConfig(schemes=("none",), months=6.0)
    eng = PlacementEngine(t, cfg)
    prob = PlacementProblem(
        spans_gb=np.array([2.0]), rho=np.array([0.001]),
        current_tier=np.array([-1]), R=np.ones((1, 1)), D=np.zeros((1, 1)),
        schemes=["none"], table=t, cfg=cfg)
    plan = eng.solve(prob)
    assert plan.assignment.tier[0] == 3          # beta:cold
    mig = eng.reoptimize(plan, np.array([1e5]), months_held=0.25)
    assert mig.moved[0] and t.provider_of_tier[mig.new_tier[0]] == 0
    stored = plan.stored_gb[0]
    assert mig.egress_cents == pytest.approx(stored * 7.0)
    assert mig.penalty_cents == pytest.approx(
        stored * t.storage_cents_gb_month[3] * (1.0 - 0.25))
    assert mig.total_move_cents == pytest.approx(
        mig.migration_cents + mig.penalty_cents)


# ----------------------------------------------------------- store metering
def test_store_change_tier_meters_egress_once():
    t = _alpha_beta()
    store = TieredStore(t)
    store.put("k", b"x" * 1000, tier=0)
    stored = store.stored_gb("k")
    store.change_tier("k", 1)                     # within alpha
    assert store.meter.egress_cents == 0.0
    store.change_tier("k", 3)                     # alpha -> beta
    assert store.meter.egress_cents == pytest.approx(stored * 5.0)
    store.change_tier("k", 0)                     # beta -> alpha
    assert store.meter.egress_cents == pytest.approx(stored * (5.0 + 7.0))
    assert store.meter.total_cents >= store.meter.egress_cents


def test_store_migrate_bills_exactly_like_the_plan():
    """read+write+egress+penalty deltas from TieredStore.migrate equal the
    MigrationPlan's migration_cents/egress_cents/penalty_cents."""
    t = _alpha_beta()
    eng, plan = _placed_hot_plan(t)
    store = TieredStore(t)
    keys = store.apply_plan(plan)
    store.advance_months(0.5)
    mig = eng.reoptimize(plan, plan.problem.rho * 1e-4, months_held=0.5)
    assert mig.n_moved > 0 and mig.egress_cents > 0.0
    r0, w0 = store.meter.read_cents, store.meter.write_cents
    e0, p0 = store.meter.egress_cents, store.meter.penalty_cents
    store.migrate(mig, keys)
    transfer = (store.meter.read_cents - r0 + store.meter.write_cents - w0
                + store.meter.egress_cents - e0)
    assert transfer == pytest.approx(mig.migration_cents, rel=1e-9)
    assert store.meter.egress_cents - e0 == pytest.approx(mig.egress_cents,
                                                          rel=1e-9)
    assert store.meter.penalty_cents - p0 == pytest.approx(
        mig.penalty_cents, rel=1e-9, abs=1e-15)
    for n in np.where(mig.moved)[0]:
        assert store.tier_of(keys[n]) == mig.new_tier[n]


def test_store_reencode_across_providers_meters_egress_once():
    """The get/delete/put re-encode path charges egress on the old payload
    exactly once when the destination is another provider."""
    t = _alpha_beta()
    store = TieredStore(t)
    raw = bytes(bytearray(range(256))) * 64
    store.put("k", raw, tier=0, codec="none")
    old_stored = store.stored_gb("k")
    mig = type("M", (), {})()                    # minimal MigrationPlan stub
    mig.plan = type("P", (), {})()
    mig.plan.problem = type("Q", (), {})()
    mig.plan.problem.schemes = ["none", "zlib-6"]
    mig.moved = np.array([True])
    mig.old_scheme = np.array([0]); mig.new_scheme = np.array([1])
    mig.old_tier = np.array([0]); mig.new_tier = np.array([2])
    store.migrate(mig, keys=["k"])
    assert store.meter.egress_cents == pytest.approx(old_stored * 5.0)
    assert store.tier_of("k") == 2


def test_sync_plan_meters_egress_on_provider_moves():
    t = _alpha_beta()
    eng, plan = _placed_hot_plan(t)
    # fake file-set partitions so sync_plan can key objects
    class _P:
        def __init__(self, i):
            self.files = frozenset({f"f{i}"})
    prob = dataclasses.replace(plan.problem,
                               partitions=[_P(i) for i in range(4)])
    plan = dataclasses.replace(plan, problem=prob)
    store = TieredStore(t)
    payloads = [b"y" * 5000 for _ in range(4)]
    store.sync_plan(plan, payloads=payloads)
    assert store.meter.egress_cents == 0.0       # initial puts: no egress
    mig = eng.reoptimize(plan, plan.problem.rho * 1e-4)
    prob2 = dataclasses.replace(mig.plan.problem,
                                partitions=[_P(i) for i in range(4)])
    plan2 = dataclasses.replace(mig.plan, problem=prob2)
    stats = store.sync_plan(plan2, payloads=payloads)
    assert stats["moved"] == 4
    expect = sum(store.stored_gb(k) * 5.0 for k in store.keys())
    assert store.meter.egress_cents == pytest.approx(expect)


# ------------------------------------------------------------------ streaming
def _stream_engine(table, **kw):
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(4) for j in range(3)}
    return StreamingEngine(table, cfg, sizes, s_thresh=5.0, **kw)


def _batch(hot=400.0, cold=0.01):
    return [(("d0/0", "d0/1"), hot), (("d1/0", "d1/1"), cold)]


def test_streaming_state_carries_across_provider_switch():
    """A drifted partition migrates to the other provider, pays egress once
    in that step's report, and its held state (tier, minimum-stay clock)
    follows it; steady re-ingestion afterwards charges nothing."""
    t = _alpha_beta()
    eng = _stream_engine(t, window=1, drift_threshold=np.inf)
    mig0 = eng.ingest_and_reoptimize(_batch(), months=1.0)
    prov0 = {tuple(sorted(p.files)): int(t.provider_of_tier[l])
             for p, l in zip(mig0.plan.problem.partitions,
                             mig0.plan.assignment.tier)}
    assert prov0[("d0/0", "d0/1")] == 0          # hot on alpha
    assert prov0[("d1/0", "d1/1")] == 1          # cold on beta
    # the cold family goes hot: it must cross beta -> alpha, paying egress
    mig1 = eng.ingest_and_reoptimize(_batch(cold=500.0), months=1.0)
    i = [j for j, p in enumerate(mig1.plan.problem.partitions)
         if p.files == frozenset({"d1/0", "d1/1"})][0]
    assert mig1.old_tier[i] >= 0                  # state carried, not new
    assert mig1.moved[i]
    assert t.provider_of_tier[mig1.new_tier[i]] == 0
    assert mig1.egress_cents > 0.0
    assert eng.history[-1].egress_cents == mig1.egress_cents
    held = eng._held[frozenset({"d1/0", "d1/1"})][0]
    assert t.provider_of_tier[held.tier] == 0
    assert held.months_held == 0.0                # stay clock reset on move
    # steady stream after the switch: no further egress
    mig2 = eng.ingest_and_reoptimize(_batch(cold=500.0), months=1.0)
    assert mig2.n_moved == 0 and mig2.egress_cents == 0.0


def test_streaming_single_provider_flat_table_matches_plain():
    """StreamingEngine on a flattened single-provider table reproduces the
    plain-table stream exactly (state carry, moves, and charges)."""
    az = azure_table()
    flat = multi_cloud_table([ProviderCostTable("azure", az, 0.0)])
    hist = []
    for table in (az, flat):
        eng = _stream_engine(table, window=1, drift_threshold=np.inf)
        migs = [eng.ingest_and_reoptimize(_batch(), months=1.0),
                eng.ingest_and_reoptimize(_batch(cold=500.0), months=1.0)]
        hist.append(migs)
    for a, b in zip(*hist):
        np.testing.assert_array_equal(a.plan.assignment.tier,
                                      b.plan.assignment.tier)
        assert a.migration_cents == b.migration_cents
        assert a.penalty_cents == b.penalty_cents
        assert b.egress_cents == 0.0


# ------------------------------------------------------------- region egress
def test_region_egress_intra_provider_cross_region_rates():
    """Two regions of one provider pay the reduced inter-region rate in
    both directions; cross-provider lanes still pay full internet egress;
    same-region moves stay free."""
    az = azure_table()
    t = multi_cloud_table([
        ProviderCostTable("aws", az, egress_out_cents_gb=9.0,
                          region="us-east-1", region_egress_out_cents_gb=2.0),
        ProviderCostTable("aws", az, egress_out_cents_gb=9.0,
                          region="us-west-2", region_egress_out_cents_gb=1.0),
        ProviderCostTable("gcp", az, egress_out_cents_gb=12.0)])
    np.testing.assert_array_equal(
        t.egress_cents_gb,
        [[0.0, 2.0, 9.0],     # east -> west uses east's region rate
         [1.0, 0.0, 9.0],     # west -> east uses west's region rate
         [12.0, 12.0, 0.0]])  # gcp out is full internet egress both ways
    assert t.provider_regions == ("us-east-1", "us-west-2", None)
    L = az.num_tiers
    # tier-level helper: cross-region intra-provider move pays 2.0/GB
    assert float(move_egress_cents_gb(t, 0, L)) == 2.0
    assert float(move_egress_cents_gb(t, L, 0)) == 1.0
    # within one region: free, as before
    assert float(move_egress_cents_gb(t, 0, L - 1)) == 0.0
    # region shows up in flattened tier names
    assert t.names[0].startswith("aws@us-east-1:")
    assert t.names[2 * L].startswith("gcp:")


def test_region_same_region_and_missing_region_stay_zero():
    az = azure_table()
    # same provider, same region: duplicate deployment, no egress between
    t = multi_cloud_table([
        ProviderCostTable("aws", az, region="eu",
                          region_egress_out_cents_gb=2.0),
        ProviderCostTable("aws", az, region="eu",
                          region_egress_out_cents_gb=2.0)])
    np.testing.assert_array_equal(t.egress_cents_gb, np.zeros((2, 2)))
    # same provider, no regions declared: legacy behavior, zero egress
    t2 = multi_cloud_table([ProviderCostTable("aws", az),
                            ProviderCostTable("aws", az)])
    np.testing.assert_array_equal(t2.egress_cents_gb, np.zeros((2, 2)))


def test_regionless_tables_bit_identical_to_before():
    """The region fields default off: a table built without regions is
    bit-identical to the historic construction, field by field."""
    t = _alpha_beta(egress_alpha=5.0, egress_beta=7.0)
    assert t.provider_regions == (None, None)
    np.testing.assert_array_equal(t.egress_cents_gb,
                                  [[0.0, 5.0], [7.0, 0.0]])
    assert t.names[0] == "alpha:hot"
    # plans on regioned vs plain duplicates of one provider agree when the
    # region rate is zero (regions only relabel, never re-price)
    az = azure_table()
    plain = multi_cloud_table([ProviderCostTable("a", az),
                               ProviderCostTable("b", az)])
    regioned = multi_cloud_table([
        ProviderCostTable("a", az, region="r1"),
        ProviderCostTable("b", az, region="r2")])
    np.testing.assert_array_equal(plain.egress_cents_gb,
                                  regioned.egress_cents_gb)
    np.testing.assert_array_equal(plain.storage_cents_gb_month,
                                  regioned.storage_cents_gb_month)


def test_region_egress_steers_reoptimize_toward_near_region():
    """When data must leave a full region, the cheap intra-provider lane
    beats the expensive cross-provider one in migration accounting."""
    az = azure_table()
    t = multi_cloud_table([
        ProviderCostTable("aws", az, egress_out_cents_gb=9.0,
                          region="east", region_egress_out_cents_gb=1.0),
        ProviderCostTable("aws", az, egress_out_cents_gb=9.0,
                          region="west", region_egress_out_cents_gb=1.0),
        ProviderCostTable("gcp", az, egress_out_cents_gb=9.0)])
    L = az.num_tiers
    src = 0                       # aws@east tier 0
    to_sibling = float(move_egress_cents_gb(t, src, L))      # aws@west
    to_rival = float(move_egress_cents_gb(t, src, 2 * L))    # gcp
    assert to_sibling == 1.0 and to_rival == 9.0
    assert to_sibling < to_rival
