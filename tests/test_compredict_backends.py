"""Differential + property suite for the COMPREDICT feature backends.

The batched device pipeline (jnp / Pallas-interpret) is a rewrite of a
numeric hot path, so it is pinned three ways against the NumPy loop:

* differential — all three backends agree to 1e-5 across dtype mixes,
  ragged partition lengths, n < block, pad boundaries, empty dtype
  classes, and single-value (zero-entropy) payloads;
* properties — row-permutation invariance, histogram additivity under
  partition concatenation, the log(k) entropy upper bound, and
  backend-choice invariance of ``predict_matrix``;
* regression — integer bucket edges cover every row exactly once.
"""

import functools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.compredict import (CompressionPredictor, _bucket_edges,
                                   bucketed_weighted_entropy,
                                   extract_features, extract_features_batch,
                                   query_samples, weighted_entropy)
from repro.data import tpch
from repro.data.tables import DTYPE_CLASSES, Table, encode_dtype_classes
from repro.kernels import ops
from repro.kernels.entropy_features import (weighted_entropy_features,
                                            weighted_entropy_features_ref)

TOL = dict(rtol=1e-5, atol=1e-5)
STRS = np.array(["alpha", "beta", "gamma", "delta", "epsilon", "zz"])


def _mk_table(n_rows: int, seed: int, *, n_int=1, n_float=1, n_str=1,
              vocab: int = 6, constant: bool = False) -> Table:
    rng = np.random.default_rng(seed)
    cols = {}
    for c in range(n_int):
        cols[f"i{c}"] = (np.full(n_rows, 7) if constant
                         else rng.integers(0, vocab * 37, n_rows))
    for c in range(n_float):
        cols[f"f{c}"] = (np.full(n_rows, 1.5) if constant
                         else rng.normal(size=n_rows).round(2))
    for c in range(n_str):
        cols[f"s{c}"] = (np.full(n_rows, "aaa") if constant
                         else rng.choice(STRS[:vocab], n_rows))
    return Table(f"t{seed}", cols)


# ------------------------------------------------------------- differential
@pytest.mark.parametrize("kind", ["weighted_entropy", "bucketed"])
@pytest.mark.parametrize("mix", [
    dict(n_int=2, n_float=1, n_str=1),      # full dtype mix
    dict(n_int=0, n_float=0, n_str=3),      # int/float classes empty
    dict(n_int=3, n_float=0, n_str=0),      # only ints
    dict(n_int=0, n_float=2, n_str=0),      # only floats
])
def test_backends_agree_across_dtype_mixes(kind, mix):
    """numpy vs jnp vs Pallas(interpret) on ragged batches, to 1e-5."""
    tabs = [_mk_table(n, 10 + n, **mix) for n in (7, 64, 129, 200, 1)]
    X_np = extract_features_batch(tabs, "col", kind, "numpy")
    X_jnp = extract_features_batch(tabs, "col", kind, "jnp")
    X_pal = extract_features_batch(tabs, "col", kind, "pallas")
    np.testing.assert_allclose(X_jnp, X_np, **TOL)
    np.testing.assert_allclose(X_pal, X_np, **TOL)


def test_backends_agree_on_tpch_query_samples():
    """Real mixed-schema partitions (query results over TPC-H tables)."""
    db = tpch.generate(scale_rows=600, seed=3)
    qs = tpch.generate_queries(db, n_per_template=2, seed=4)
    tabs = query_samples(qs, db.tables, max_rows=300)[:6]
    for kind in ("weighted_entropy", "bucketed"):
        X_np = extract_features_batch(tabs, "row", kind, "numpy")
        X_jnp = extract_features_batch(tabs, "row", kind, "jnp")
        X_pal = extract_features_batch(tabs, "row", kind, "pallas")
        np.testing.assert_allclose(X_jnp, X_np, **TOL)
        np.testing.assert_allclose(X_pal, X_np, **TOL)


@pytest.mark.parametrize("n,block", [
    (37, 64),       # n < block: block clamps, no pad
    (128, 64),      # n % block == 0: empty-pad boundary
    (130, 64),      # 2 bytes spill into a heavily padded final block
    (1, 8),         # single value
])
def test_kernel_vs_ref_pad_boundaries(n, block):
    """Pallas grid kernel (interpret) vs the vmapped-jnp oracle at ragged
    lengths straddling block boundaries; pads must never leak."""
    rng = np.random.default_rng(n)
    N, V, nb = 3, 23, 5
    n_cols = np.array([2, 1, 3], np.int32)
    n_valid = np.minimum(n, np.array([n, max(n - 5, 1), n], np.int32))
    n_valid = (n_valid // n_cols) * n_cols          # whole rows
    n_valid = np.maximum(n_valid, n_cols)
    n_rows = n_valid // n_cols
    M = int(n_valid.max())
    codes = np.full((N, M), -1, np.int32)
    for i in range(N):
        codes[i, :n_valid[i]] = rng.integers(0, V, n_valid[i])
    lengths = rng.integers(1, 9, V).astype(np.float32)
    s_ref, b_ref = weighted_entropy_features_ref(
        codes, n_valid, n_rows, n_cols, lengths, n_buckets=nb)
    s_pal, b_pal = weighted_entropy_features(
        codes, n_valid, n_rows, n_cols, lengths, n_buckets=nb, block=block,
        interpret=True)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), **TOL)
    np.testing.assert_allclose(np.asarray(b_pal), np.asarray(b_ref), **TOL)


def test_ops_dispatch_ref_equals_interpret():
    codes = np.array([[0, 1, 1, 2, -1, -1]], np.int32)
    args = (codes, np.array([4]), np.array([2]), np.array([2]),
            np.array([3.0, 1.0, 2.0], np.float32))
    s_a, b_a = ops.weighted_entropy_features(*args, n_buckets=2, impl="ref")
    s_b, b_b = ops.weighted_entropy_features(*args, n_buckets=2,
                                             impl="interpret")
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), **TOL)
    np.testing.assert_allclose(np.asarray(b_a), np.asarray(b_b), **TOL)


def test_single_value_payload_is_zero_entropy():
    """Constant columns carry exactly 0 nats in every backend and bucket."""
    tabs = [_mk_table(50, 1, constant=True), _mk_table(3, 2, constant=True)]
    for backend in ("numpy", "jnp", "pallas"):
        X = extract_features_batch(tabs, "col", "bucketed", backend)
        base, blk = 3, 5
        for ci in range(len(DTYPE_CLASSES)):
            assert np.allclose(X[:, base + ci * blk], 0.0, atol=1e-6), backend
            assert np.allclose(X[:, base + ci * blk + 1], 0.0, atol=1e-6)
        np.testing.assert_allclose(X[:, 18:], 0.0, atol=1e-6)


def test_all_backends_handle_zero_rows():
    """0-row partitions (windows can empty out mid-stream) come back as
    all-zero entropy features in EVERY backend — the NumPy loop used to
    divide by zero here, breaking backend invariance."""
    tabs = [_mk_table(0, 5), _mk_table(10, 6)]
    outs = {}
    for backend in ("numpy", "jnp", "pallas"):
        X = extract_features_batch(tabs, "col", "weighted_entropy", backend)
        assert np.isfinite(X).all(), backend
        np.testing.assert_allclose(X[0, 3:], [0, 0, 0, 0, 1] * 3, atol=1e-6)
        outs[backend] = X
    np.testing.assert_allclose(outs["jnp"], outs["numpy"], **TOL)
    np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)


def test_n_buckets_is_honored_by_every_backend():
    """Width and values must not depend on the backend when n_buckets != 5,
    and the empty-batch width formula must match the non-empty one."""
    tabs = [_mk_table(17, 8), _mk_table(40, 9)]
    outs = {b: extract_features_batch(tabs, "col", "bucketed", b, n_buckets=3)
            for b in ("numpy", "jnp", "pallas")}
    for b, X in outs.items():
        assert X.shape == (2, 18 + 3 * 3), b
    np.testing.assert_allclose(outs["jnp"], outs["numpy"], **TOL)
    np.testing.assert_allclose(outs["pallas"], outs["numpy"], **TOL)
    empty = extract_features_batch([], "col", "bucketed", "numpy",
                                   n_buckets=3)
    assert empty.shape == (0, outs["numpy"].shape[1])


# --------------------------------------------------------------- properties
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_row_permutation_invariance(seed):
    """Weighted entropy is a bag statistic: shuffling rows changes nothing
    (numpy dict and the batched jnp backend alike)."""
    t = _mk_table(40 + seed % 60, seed, n_int=2)
    perm = np.random.default_rng(seed).permutation(t.num_rows)
    tp = t.select(perm)
    assert weighted_entropy(t) == pytest.approx(weighted_entropy(tp))
    X = extract_features_batch([t, tp], "col", "weighted_entropy", "jnp")
    np.testing.assert_allclose(X[0], X[1], **TOL)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_histogram_additivity_under_concat(seed):
    """Shared-vocabulary histograms add under partition concatenation —
    the invariant that makes incremental/merged feature maintenance sound."""
    t1 = _mk_table(30 + seed % 20, seed)
    t2 = _mk_table(45 + seed % 11, seed + 1)
    enc = encode_dtype_classes([t1, t2, t1.concat(t2)])
    for d in DTYPE_CLASSES:
        cc = enc[d]
        V = cc.vocab_size
        h = [np.bincount(cc.global_codes[i, :cc.n_valid[i]], minlength=V)
             for i in range(3)]
        np.testing.assert_array_equal(h[0] + h[1], h[2])


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 200))
def test_entropy_upper_bound_log_k(k):
    """A k-symbol payload has plain entropy <= log(k) and weighted entropy
    <= maxlen * log(k), with equality for the uniform payload."""
    vals = np.array([f"s{i:03d}" for i in range(k)])
    t = Table("k", {"s": np.tile(vals, 4)})
    enc = encode_dtype_classes([t])["str"]
    summary, _ = ops.weighted_entropy_features(
        enc.codes, enc.n_valid, enc.n_rows, enc.n_cols, enc.lengths,
        impl="ref")
    H_w, H_plain = float(summary[0, 0]), float(summary[0, 1])
    assert H_plain <= np.log(k) * (1 + 1e-5) + 1e-6
    assert H_plain == pytest.approx(np.log(k), rel=1e-4)   # uniform payload
    assert H_w <= 4 * np.log(k) * (1 + 1e-5) + 1e-6        # len("sNNN") = 4


@functools.lru_cache(maxsize=1)
def _fitted_predictor():
    from repro.storage.codecs import available_schemes, codec_by_name
    db = tpch.generate(scale_rows=500, seed=7)
    qs = tpch.generate_queries(db, n_per_template=3, seed=8)
    samples = query_samples(qs, db.tables, max_rows=250)[:40]
    scheme = available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0]
    pred = CompressionPredictor(model_name="SVR").fit(
        samples, layouts=("col",), codecs=[codec_by_name(scheme)])
    tabs = [db.tables["orders"].head(n) for n in (33, 90, 150)]
    return pred, scheme, tabs


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 3))
def test_predict_matrix_backend_invariance(seed):
    """The backend is an implementation detail: predictions through the
    same fitted models must not depend on it."""
    pred, scheme, tabs = _fitted_predictor()
    subset = tabs[seed % len(tabs):]
    out = {b: pred.predict_matrix(subset, ["none", scheme], "col",
                                  feature_backend=b)
           for b in ("numpy", "jnp", "pallas")}
    for b in ("jnp", "pallas"):
        np.testing.assert_allclose(out[b][0], out["numpy"][0], rtol=1e-4,
                                   atol=1e-6)
        np.testing.assert_allclose(out[b][1], out["numpy"][1], rtol=1e-4,
                                   atol=1e-8)
    assert (out["numpy"][0][:, 0] == 1.0).all()     # scheme 'none' pinned
    assert (out["numpy"][1][:, 0] == 0.0).all()


# --------------------------------------------------------------- regression
@pytest.mark.parametrize("n", [0, 1, 2, 4, 5, 7, 9, 10, 101, 9998])
@pytest.mark.parametrize("nb", [1, 3, 5])
def test_bucket_edges_cover_every_row_exactly_once(n, nb):
    """The final row must never fall off the last bucket when
    n % n_buckets != 0: integer edges partition range(n) exactly."""
    edges = _bucket_edges(n, nb)
    assert edges[0] == 0 and edges[-1] == n
    assert (np.diff(edges) >= 0).all()
    covered = np.concatenate([np.arange(lo, hi)
                              for lo, hi in zip(edges[:-1], edges[1:])])
    np.testing.assert_array_equal(covered, np.arange(n))


def test_bucketed_entropy_sees_the_final_row():
    """n=7, nb=5: a distinctive final row must land in the last bucket —
    a truncated last edge would report 0 entropy there."""
    vals = np.array(["a"] * 6 + ["unique-tail"])
    t = Table("tail", {"s": vals})
    feats = bucketed_weighted_entropy(t, n_buckets=5)
    str_idx = DTYPE_CLASSES.index("str")
    last_bucket = feats[4 * len(DTYPE_CLASSES) + str_idx]
    assert last_bucket > 0.0                      # {'a', 'unique-tail'} mix
    # and the device backends agree on the same tail bucket
    X_np = extract_features_batch([t], "col", "bucketed", "numpy")
    X_jnp = extract_features_batch([t], "col", "bucketed", "jnp")
    np.testing.assert_allclose(X_jnp, X_np, **TOL)


def test_batch_matches_single_extract_and_sizes_passthrough():
    tabs = [_mk_table(n, n) for n in (12, 33)]
    sizes = [t.nbytes("row") for t in tabs]
    X = extract_features_batch(tabs, "row", "bucketed", "numpy", sizes=sizes)
    for i, t in enumerate(tabs):
        np.testing.assert_array_equal(
            X[i], extract_features(t, "row", "bucketed", size=sizes[i]))
    with pytest.raises(ValueError):
        extract_features_batch(tabs, "row", "bucketed", "tpu")
