"""OPTASSIGN solver correctness: greedy/matching/capacitated vs brute force."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.costs import (Weights, azure_table, cost_tensor,
                              latency_feasible, tpch_capacity_table)
from repro.core.optassign import (brute_force, capacitated_assign,
                                  capacitated_assign_ref, greedy_assign,
                                  lock_schemes, matching_assign)


def _random_instance(rng, N=6, K=3):
    table = azure_table()
    spans = rng.uniform(0.5, 50.0, N)
    rho = rng.gamma(1.0, 20.0, N)
    cur = rng.integers(-1, table.num_tiers, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))], 1)
    D = np.concatenate([np.zeros((N, 1)), rng.uniform(0.01, 3.0, (N, K - 1))], 1)
    T = rng.choice([0.1, 1.0, 5.0, np.inf], N)
    cost = cost_tensor(spans, rho, cur, R, D, table, Weights(), months=6)
    feas = latency_feasible(D, T, table)
    return cost, feas, spans, R, table


def test_greedy_matches_bruteforce_unbounded():
    rng = np.random.default_rng(0)
    for trial in range(10):
        cost, feas, *_ = _random_instance(rng)
        if not feas.any(axis=(1, 2)).all():
            continue
        g = greedy_assign(cost, feas)
        b = brute_force(cost, feas)
        assert g.feasible and b.feasible
        assert g.cost == pytest.approx(b.cost, rel=1e-6)


def test_greedy_respects_latency_mask():
    rng = np.random.default_rng(1)
    cost, feas, *_ = _random_instance(rng)
    g = greedy_assign(cost, feas)
    for n in range(cost.shape[0]):
        assert feas[n, g.tier[n], g.scheme[n]]


def test_greedy_infeasible_reported():
    cost = np.ones((2, 4, 2))
    feas = np.zeros((2, 4, 2), bool)
    g = greedy_assign(cost, feas)
    assert not g.feasible and g.cost == float("inf")


def test_scheme_locking():
    rng = np.random.default_rng(2)
    cost, feas, *_ = _random_instance(rng, N=5, K=3)
    locked = np.array([1, -1, 2, -1, 0])
    feas2 = lock_schemes(feas, locked)
    g = greedy_assign(cost, feas2)
    if g.feasible:
        for n, k in enumerate(locked):
            if k >= 0:
                assert g.scheme[n] == k


def test_matching_vs_bruteforce_capacitated_equal_sizes():
    """Thm 2 case: unit partitions, capacities in units, no compression."""
    rng = np.random.default_rng(3)
    for _ in range(8):
        N, L = 6, 3
        cost_nl = rng.uniform(1.0, 100.0, (N, L))
        feas_nl = rng.random((N, L)) > 0.15
        cap = np.array([2, 2, 6])
        m = matching_assign(cost_nl, feas_nl, cap)
        # brute force over tier choices with unit capacities
        cost3 = cost_nl[:, :, None]
        feas3 = feas_nl[:, :, None]
        stored = np.ones((N, L, 1))
        b = brute_force(cost3, feas3, stored, cap.astype(float))
        assert m.feasible == b.feasible
        if m.feasible:
            assert m.cost == pytest.approx(b.cost, rel=1e-9)
            used = np.bincount(m.tier, minlength=L)
            assert (used <= cap).all()


def test_capacitated_ref_close_to_bruteforce():
    rng = np.random.default_rng(4)
    gaps = []
    for _ in range(6):
        cost, feas, spans, R, table = _random_instance(rng, N=5, K=2)
        stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
        cap = np.array([spans.sum() / 3, spans.sum() / 2, spans.sum(), np.inf])
        c = capacitated_assign_ref(cost, feas, stored, cap)
        b = brute_force(cost, feas, stored, cap)
        if not b.feasible:
            continue
        assert c.feasible
        gaps.append(c.cost / b.cost - 1.0)
    assert gaps and max(gaps) < 0.02, f"capacitated gap too large: {gaps}"


def test_capacitated_vectorized_matches_bruteforce():
    """The jitted-Lagrangian + repair + 1-swap solver finds the optimum on
    tiny instances (f64 rescoring makes this exact, not approximate)."""
    rng = np.random.default_rng(4)
    checked = 0
    for _ in range(12):
        cost, feas, spans, R, table = _random_instance(rng, N=5, K=2)
        stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
        cap = np.array([spans.sum() / 3, spans.sum() / 2, spans.sum(), np.inf])
        b = brute_force(cost, feas, stored, cap)
        if not b.feasible:
            continue
        v = capacitated_assign(cost, feas, stored, cap)
        assert v.feasible
        assert v.cost == pytest.approx(b.cost, rel=1e-9)
        used = np.zeros(table.num_tiers)
        np.add.at(used, v.tier, stored[np.arange(len(v.tier)), v.tier, v.scheme])
        assert (used <= cap + 1e-9).all()
        checked += 1
    assert checked >= 6


def test_capacitated_vectorized_not_worse_than_ref():
    rng = np.random.default_rng(5)
    for _ in range(6):
        cost, feas, spans, R, table = _random_instance(rng, N=8, K=3)
        stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
        cap = np.array([spans.sum() / 4, spans.sum() / 3, spans.sum(), np.inf])
        v = capacitated_assign(cost, feas, stored, cap)
        r = capacitated_assign_ref(cost, feas, stored, cap)
        if r.feasible:
            assert v.feasible
            assert v.cost <= r.cost * (1 + 1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_capacitated_vectorized_optimality_property(seed):
    """Hypothesis: vectorized == brute force on tiny capacitated instances."""
    rng = np.random.default_rng(seed)
    cost, feas, spans, R, table = _random_instance(rng, N=4, K=2)
    stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
    cap = np.array([spans.sum() / 3, spans.sum() / 2, spans.sum(), np.inf])
    b = brute_force(cost, feas, stored, cap)
    if not b.feasible:
        return
    v = capacitated_assign(cost, feas, stored, cap)
    assert v.feasible
    assert v.cost == pytest.approx(b.cost, rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_greedy_optimality_property(seed):
    """Hypothesis: greedy == brute force whenever capacities are unbounded."""
    rng = np.random.default_rng(seed)
    cost, feas, *_ = _random_instance(rng, N=4, K=2)
    g = greedy_assign(cost, feas)
    b = brute_force(cost, feas)
    assert g.feasible == b.feasible
    if g.feasible:
        assert g.cost == pytest.approx(b.cost, rel=1e-6)


def test_tier_change_cost_matrix():
    t = azure_table()
    delta = t.tier_change_cents_gb()
    assert delta.shape == (5, 4)
    assert np.allclose(np.diag(delta[:4]), 0.0)       # stay-put is free
    assert (delta[-1] == t.write_cents_gb).all()      # ingestion row
    # moving out of archive is expensive (rehydration read)
    assert delta[3, 1] > delta[1, 3]
