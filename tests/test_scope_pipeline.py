"""End-to-end SCOPe pipeline + access prediction (paper §IV-C, §VII)."""

import dataclasses

import numpy as np
import pytest

from repro.core.access_predict import (optimal_tiers, predicted_tiers,
                                       train_tier_predictor)
from repro.core.compredict import CompressionPredictor, query_samples
from repro.core.costs import azure_table
from repro.core.engine import PlacementEngine
from repro.core.scope import ScopeConfig, paper_variants, run_pipeline
from repro.data import tpch
from repro.data.workloads import generate_workload
from repro.storage.codecs import available_schemes, codec_by_name


@pytest.fixture(scope="module")
def pipeline_inputs():
    db = tpch.generate(scale_rows=3000, seed=0)
    queries = tpch.generate_queries(db, n_per_template=3, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, queries)
    return parts, file_rows


def test_scope_beats_default(pipeline_inputs):
    parts, file_rows = pipeline_inputs
    table = azure_table()
    default = run_pipeline(parts, file_rows, table, ScopeConfig(
        use_partitioning=False, use_tiering=False, use_compression=False,
        fixed_tier=0, tier_whitelist=(0, 1, 2)))
    scope = run_pipeline(parts, file_rows, table, ScopeConfig(
        tier_whitelist=(0, 1, 2)))
    assert scope.total_cents < default.total_cents
    assert scope.n_partitions >= default.n_partitions  # G-PART splits datasets


def test_partitioning_reduces_read_cost(pipeline_inputs):
    parts, file_rows = pipeline_inputs
    table = azure_table()
    whole = run_pipeline(parts, file_rows, table, ScopeConfig(
        use_partitioning=False, use_tiering=False, use_compression=False,
        fixed_tier=0))
    parted = run_pipeline(parts, file_rows, table, ScopeConfig(
        use_partitioning=True, use_tiering=False, use_compression=False,
        fixed_tier=0))
    # paper Tables IX-XI rows 1 vs 5: partitioning slashes read cost
    assert parted.read_cents < whole.read_cents


def test_latency_sla_respected(pipeline_inputs):
    parts, file_rows = pipeline_inputs
    table = azure_table()
    rep = run_pipeline(parts, file_rows, table, ScopeConfig(
        latency_sla_sec=0.03, tier_whitelist=(0, 1, 2, 3)))
    # premium TTFB=0.0053 is the only tier under a 30ms SLA with decomp time
    assert rep.assignment.feasible
    assert rep.read_latency_ttfb <= 0.03


def test_paper_variant_grid(pipeline_inputs):
    parts, file_rows = pipeline_inputs
    table = azure_table()
    # small synthetic capacity: forces tiering decisions like Table XII
    total = sum(p.span for p in parts) / 1e9
    cap = np.array([total * 0.2, total * 0.4, total * 0.6, np.inf])
    variants = paper_variants(cap)
    results = {}
    for name in ["Default (store on premium)",
                 "Multi-Tiering [Hermes]",
                 "SCOPe (Total cost focused)"]:
        results[name] = run_pipeline(parts, file_rows, table, variants[name])
    assert results["SCOPe (Total cost focused)"].total_cents <= \
        results["Default (store on premium)"].total_cents
    # default premium latency is the floor
    assert results["Default (store on premium)"].read_latency_ttfb == \
        pytest.approx(0.0053)


def test_feature_backend_parity_end_to_end():
    """CompressStage with feature_backend='pallas' (interpret on CPU) and
    'jnp' must produce the *identical* PlacementPlan — same tiers, same
    schemes — as the NumPy feature loop on a seeded TPC-H-style workload."""
    db = tpch.generate(scale_rows=900, seed=2)
    queries = tpch.generate_queries(db, n_per_template=2, seed=3)
    parts, file_rows = tpch.partitions_from_queries(db, queries)
    schemes = available_schemes(("none", "zstd-3", "zlib-6", "zlib-1"))
    pred = CompressionPredictor(model_name="SVR").fit(
        query_samples(queries, db.tables, max_rows=300)[:40],
        layouts=("col",),
        codecs=[codec_by_name(s) for s in schemes if s != "none"])
    table = azure_table()
    base_cfg = ScopeConfig(schemes=schemes, predictor=pred,
                           tier_whitelist=(0, 1, 2))
    plans = {}
    for backend in ("numpy", "jnp", "pallas"):
        cfg = dataclasses.replace(base_cfg, feature_backend=backend)
        plans[backend] = PlacementEngine(table, cfg).run(parts, file_rows)
    for backend in ("jnp", "pallas"):
        np.testing.assert_array_equal(plans[backend].assignment.tier,
                                      plans["numpy"].assignment.tier)
        np.testing.assert_array_equal(plans[backend].assignment.scheme,
                                      plans["numpy"].assignment.scheme)
        assert plans[backend].report.total_cents == pytest.approx(
            plans["numpy"].report.total_cents, rel=1e-4)


def test_access_prediction_f1():
    w = generate_workload(n_datasets=150, n_months=24, seed=0)
    table = azure_table()
    clf, rep = train_tier_predictor(w, table, train_month=12, horizon=4)
    assert rep.f1 > 0.8, f"F1 too low: {rep.f1}, confusion={rep.confusion}"
    assert rep.confusion.sum() == 150


def test_predicted_vs_known_cost_gap():
    """Paper Table IV: predicted-access benefit ~= known-access benefit."""
    w = generate_workload(n_datasets=120, n_months=24, seed=1)
    table = azure_table()
    clf, _ = train_tier_predictor(w, table, train_month=12, horizon=4)
    known = optimal_tiers(w, table, 16, 20, tiers=(1, 2))
    pred = predicted_tiers(clf, w, 16, tiers=(1, 2))
    spans = np.array([d.size_gb for d in w.datasets])
    rho = w.reads_in(16, 20)

    def cost_of(tiers):
        sc = spans * table.storage_cents_gb_month[tiers] * 4
        rc = rho * spans * table.read_cents_gb[tiers]
        return (sc + rc).sum()

    c_known, c_pred = cost_of(known), cost_of(pred)
    all_hot = cost_of(np.ones(len(spans), int))
    benefit_known = 1 - c_known / all_hot
    benefit_pred = 1 - c_pred / all_hot
    assert benefit_known >= benefit_pred - 1e-9
    assert benefit_pred > 0.5 * benefit_known
