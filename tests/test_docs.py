"""Documentation invariants: links resolve, every benchmark tag is
documented, and the docs' worked billing example matches the code."""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_readme_and_docs_links_resolve():
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_links.py"),
         str(ROOT / "README.md"), str(ROOT / "docs")],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_required_docs_exist():
    for f in ("README.md", "docs/costs.md", "docs/engine.md",
              "docs/paper_map.md"):
        assert (ROOT / f).is_file(), f


def test_every_benchmark_tag_documented_in_readme():
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.run import MODULES
    finally:
        sys.path.pop(0)
    readme = (ROOT / "README.md").read_text()
    for tag, _ in MODULES:
        assert f"`{tag}`" in readme, f"benchmark tag {tag} not in README.md"


def test_every_readme_listed_tag_is_registered():
    """Reverse direction: each tag the README's benchmark table lists must
    be registered in benchmarks/run.py (a renamed/removed tag can't keep
    haunting the docs)."""
    import re

    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.run import MODULES
    finally:
        sys.path.pop(0)
    registered = {tag for tag, _ in MODULES}
    readme = (ROOT / "README.md").read_text()
    listed = set()
    for line in readme.splitlines():
        m = re.match(r"\|\s*`([^`]+)`\s*\|\s*`bench_\w+\.py`", line)
        if m:
            listed.add(m.group(1))
    assert listed, "README benchmark table not found"
    missing = sorted(listed - registered)
    assert not missing, f"README lists unregistered benchmark tags {missing}"


def test_unknown_benchmark_tag_exits_nonzero():
    """--only with a bogus tag must fail loudly, not silently run nothing."""
    env = os.environ | {"PYTHONPATH": os.pathsep.join(
        ["src", str(ROOT), os.environ.get("PYTHONPATH", "")])}
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nosuchtag"],
        capture_output=True, text=True, cwd=ROOT, env=env)
    assert r.returncode != 0
    assert "nosuchtag" in r.stderr
    assert "valid tags" in r.stderr and "tableII" in r.stderr


def test_costs_doc_worked_example_matches_code():
    """The cross-provider migration bill in docs/costs.md is computed by
    the real code paths: Delta, migration, egress, and penalty cents."""
    from repro.core.costs import big3_table
    from repro.core.engine import PlacementEngine, PlacementProblem, \
        ScopeConfig

    t = big3_table()
    src = t.names.index("gcp:nearline")
    dst = t.names.index("aws:standard_ia")
    delta = t.tier_change_cents_gb()
    assert delta[src, dst] == pytest.approx(13.0512)
    cfg = ScopeConfig(schemes=("none",), months=1.0,
                      tier_whitelist=(dst, src))
    prob = PlacementProblem(
        spans_gb=np.array([10.0]), rho=np.array([10000.0]),
        current_tier=np.array([src]), R=np.full((1, 1), 2.5),
        D=np.zeros((1, 1)), schemes=["none"], table=t, cfg=cfg)
    eng = PlacementEngine(t, cfg)
    mig = eng._solve_migration(prob, np.array([src]), np.array([0]),
                               np.array([4.0]), 0.4, False, 0.25,
                               np.array([10000.0]))
    assert mig.new_tier[0] == dst
    assert mig.migration_cents == pytest.approx(52.2048)
    assert mig.egress_cents == pytest.approx(48.0)
    assert mig.penalty_cents == pytest.approx(2.4)
    assert mig.total_move_cents == pytest.approx(54.6048)

    doc = (ROOT / "docs" / "costs.md").read_text()
    for figure in ("52.2048", "48.0", "2.4", "54.6048", "13.0512"):
        assert figure in doc
