"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU; output shapes and finiteness asserted (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import arch_names, get_config
from repro.models import transformer as tr

B, S = 2, 16


def _batch(cfg, key):
    kt, kc = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.cross_context:
        batch["context"] = jax.random.normal(
            kc, (B, cfg.cross_context, cfg.d_model), jnp.float32)
    if cfg.encoder_stages is not None:
        batch["frames"] = jax.random.normal(
            kc, (B, cfg.encoder_context, cfg.d_model), jnp.float32)
    return batch


def _context(params, batch, cfg):
    if cfg.encoder_stages is not None:
        return tr.encode(params, batch["frames"], cfg)
    return batch.get("context")


@pytest.mark.parametrize("arch", arch_names())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: tr.forward(
        p, b["tokens"], cfg, context=_context(p, b, cfg)))(params, batch)
    assert logits.shape == (B, S, tr.padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", arch_names())
def test_train_step_gradients(arch):
    cfg = get_config(arch, smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        lb = dict(batch)
        lb["context"] = _context(p, batch, cfg)
        return tr.loss_fn(p, lb, cfg)

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(val)), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), \
        f"{arch}: non-finite grads"
    # embedding gradient must be non-zero (signal flows end to end)
    gnorm = sum(float(jnp.abs(g).sum()) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", arch_names())
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    context = _context(params, batch, cfg)
    cache = tr.init_cache(cfg, B, max_seq=32)
    pos = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, c, t, q, ctx: tr.decode_step(
        p, c, t, q, cfg, context=ctx))
    logits = None
    tok = batch["tokens"][:, :1]
    for i in range(3):
        logits, cache = step(params, cache, tok, pos + i, context)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert logits.shape == (B, 1, tr.padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode"


def test_decode_matches_forward_gqa():
    """Greedy decode logits == teacher-forced forward logits (yi-9b smoke)."""
    cfg = get_config("yi-9b", smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                cfg.vocab_size)
    full = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, 1, max_seq=8)
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = tr.decode_step(params, cache, tokens[:, i:i + 1],
                                       jnp.array([i]), cfg)
        outs.append(logits[:, 0])
    stacked = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_mamba():
    cfg = get_config("mamba2-780m", smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                cfg.vocab_size)
    full = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, 1, max_seq=8)
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = tr.decode_step(params, cache, tokens[:, i:i + 1],
                                       jnp.array([i]), cfg)
        outs.append(logits[:, 0])
    stacked = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact published shapes."""
    expect = {
        "gemma2-9b": dict(d_model=3584, n_heads=16, n_kv_heads=8,
                          d_ff=14336, vocab_size=256000, n_layers=42),
        "qwen3-4b": dict(d_model=2560, n_heads=32, n_kv_heads=8,
                         d_ff=9728, vocab_size=151936, n_layers=36),
        "qwen2-7b": dict(d_model=3584, n_heads=28, n_kv_heads=4,
                         d_ff=18944, vocab_size=152064, n_layers=28),
        "yi-9b": dict(d_model=4096, n_heads=32, n_kv_heads=4,
                      d_ff=11008, vocab_size=64000, n_layers=48),
        "zamba2-2.7b": dict(d_model=2560, n_heads=32, n_kv_heads=32,
                            vocab_size=32000, ssm_state=64),
        "llama4-scout-17b-a16e": dict(d_model=5120, n_heads=40, n_kv_heads=8,
                                      vocab_size=202048, n_experts=16,
                                      top_k=1, n_layers=48),
        "deepseek-v2-lite-16b": dict(d_model=2048, n_heads=16,
                                     vocab_size=102400, n_experts=64,
                                     top_k=6, kv_lora_rank=512, n_layers=27),
        "llama-3.2-vision-90b": dict(d_model=8192, n_heads=64, n_kv_heads=8,
                                     d_ff=28672, vocab_size=128256,
                                     n_layers=100),
        "whisper-small": dict(d_model=768, n_heads=12, n_kv_heads=12,
                              d_ff=3072, vocab_size=51865),
        "mamba2-780m": dict(d_model=1536, vocab_size=50280, ssm_state=128),
    }
    for arch, want in expect.items():
        cfg = get_config(arch)
        for k, v in want.items():
            got = getattr(cfg, k)
            assert got == v, f"{arch}.{k}: {got} != {v}"
    # zamba2: 54 mamba layers + 9 shared-attn applications
    z = get_config("zamba2-2.7b")
    kinds = [k for s in z.stages for k in s.unit for _ in range(1)]
    n_mamba = sum(s.unit.count("mamba") * s.repeats for s in z.stages)
    assert n_mamba == 54


def test_decode_ring_buffer_matches_forward_windowed():
    """gemma2-family: ring-buffer window cache decode == teacher-forced
    forward with sliding-window masks, beyond the wrap-around point."""
    cfg = get_config("gemma2-9b", smoke=True)   # window=16 in smoke
    assert cfg.sliding_window == 16
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    S_test = 24                                 # > window -> ring wraps
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S_test), 0,
                                cfg.vocab_size)
    full = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, 1, max_seq=S_test + 2)
    outs = []
    for i in range(S_test):
        logits, cache = tr.decode_step(params, cache, tokens[:, i:i + 1],
                                       jnp.array([i]), cfg)
        outs.append(logits[:, 0])
    stacked = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(full),
                               rtol=3e-2, atol=3e-2)
