"""Property-based invariants for the capacitated OPTASSIGN solvers.

Runs under real ``hypothesis`` when installed (the CI ``properties`` job)
and under the deterministic ``tests/_hypothesis_compat.py`` enumeration
otherwise. Strategies draw a SEED, not arrays: every example uses the same
(N, L, K) shapes so the jitted Lagrangian scan compiles once, and the
seeded ``default_rng`` varies the values.

Invariants:

* a feasible solution never violates per-tier, per-group, or fleet-shared
  capacities;
* batch padding cells are inert — the batched solve is bit-identical to
  independent per-tenant solves;
* the returned assignment is 1-swap optimal: no single partition can move
  to another feasible, capacity-respecting cell and lower the objective;
* ``sla_lambda=0`` reduces exactly to the pre-SLA solver, and
  ``sla_lambda=lam`` is identical to folding ``cost + lam * penalty``
  by hand.
"""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.core.costs import (Weights, azure_table, cost_tensor,
                              sla_penalty_tensor)
from repro.core.optassign import (capacitated_assign,
                                  capacitated_assign_batch)

N, K = 8, 2
TABLE = azure_table()
L = TABLE.num_tiers
EPS = 1e-9


def _instance(seed: int, tight: float = 0.6):
    """One random capacitated instance with caps that usually bind."""
    rng = np.random.default_rng(seed)
    spans = rng.uniform(0.5, 30.0, N)
    rho = rng.gamma(1.0, 25.0, N)
    cur = rng.integers(-1, L, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, K - 1))], 1)
    cost = cost_tensor(spans, rho, cur, R, D, TABLE, Weights(), months=4.0)
    feas = rng.random((N, L, K)) > 0.15
    feas[:, rng.integers(0, L), :] = True      # at least one open tier
    stored = np.repeat((spans[:, None] / R)[:, None, :], L, 1)
    tot = spans.sum()
    cap = np.array([tight * tot * rng.uniform(0.2, 0.6),
                    tight * tot * rng.uniform(0.3, 0.8), tot, np.inf])
    return cost, feas, stored, cap, D, rho


def _usage(stored, tier, scheme):
    use = np.zeros(L)
    np.add.at(use, tier, stored[np.arange(N), tier, scheme])
    return use


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_capacities_never_violated(seed):
    cost, feas, stored, cap, _, _ = _instance(seed)
    groups = np.array([0, 0, 1, 1])
    gcap = np.array([cap[0] + cap[1], np.inf])
    a = capacitated_assign(cost, feas, stored, cap, tier_groups=groups,
                           group_capacity_gb=gcap)
    if not a.feasible:
        return
    use = _usage(stored, a.tier, a.scheme)
    assert (use <= cap + EPS).all(), (use, cap)
    for g in range(gcap.shape[0]):
        assert use[groups == g].sum() <= gcap[g] + EPS
    # every chosen cell was actually feasible
    assert feas[np.arange(N), a.tier, a.scheme].all()


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_batch_padding_inert(seed):
    """Ragged tenants through the padded batch == independent solves."""
    insts = [_instance(seed * 3 + t) for t in range(3)]
    # ragged: drop rows from two tenants so padding cells exist
    keep = (N, N - 3, N - 5)
    costs = [i[0][:k] for i, k in zip(insts, keep)]
    feats = [i[1][:k] for i, k in zip(insts, keep)]
    stores = [i[2][:k] for i, k in zip(insts, keep)]
    caps = [i[3] for i in insts]
    singles = [capacitated_assign(c, f, s, cap)
               for c, f, s, cap in zip(costs, feats, stores, caps)]
    batch = capacitated_assign_batch(costs, feats, stores, caps)
    for one, got in zip(singles, batch.assignments):
        assert np.array_equal(one.tier, got.tier)
        assert np.array_equal(one.scheme, got.scheme)
        assert one.cost == got.cost and one.feasible == got.feasible
    assert batch.cost == float(sum(s.cost for s in singles))


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_one_swap_optimality(seed):
    """No single-partition move to a feasible, capacity-respecting cell
    may lower the objective of the returned assignment."""
    cost, feas, stored, cap, _, _ = _instance(seed)
    a = capacitated_assign(cost, feas, stored, cap)
    if not a.feasible:
        return
    use = _usage(stored, a.tier, a.scheme)
    total = cost[np.arange(N), a.tier, a.scheme].sum()
    for n in range(N):
        l0, k0 = int(a.tier[n]), int(a.scheme[n])
        for l in range(L):
            for k in range(K):
                if (l, k) == (l0, k0) or not feas[n, l, k]:
                    continue
                u = use.copy()
                u[l0] -= stored[n, l0, k0]
                u[l] += stored[n, l, k]
                if not (u <= cap + EPS).all():
                    continue
                swapped = total - cost[n, l0, k0] + cost[n, l, k]
                assert swapped >= total - 1e-6, (n, l, k)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_sla_lambda_zero_reduces_to_base_solver(seed):
    cost, feas, stored, cap, D, rho = _instance(seed)
    rng = np.random.default_rng(seed + 1)
    sla = rng.choice([10.0, 75.0, np.inf], N)
    pen = sla_penalty_tensor(rho, sla, D, TABLE)
    base = capacitated_assign(cost, feas, stored, cap)
    zero = capacitated_assign(cost, feas, stored, cap, sla_penalty=pen,
                              sla_lambda=0.0)
    assert np.array_equal(base.tier, zero.tier)
    assert np.array_equal(base.scheme, zero.scheme)
    assert base.cost == zero.cost and base.feasible == zero.feasible

    lam = float(rng.uniform(0.01, 3.0))
    with_sla = capacitated_assign(cost, feas, stored, cap, sla_penalty=pen,
                                  sla_lambda=lam)
    by_hand = capacitated_assign(cost + lam * pen, feas, stored, cap)
    assert np.array_equal(with_sla.tier, by_hand.tier)
    assert np.array_equal(with_sla.scheme, by_hand.scheme)
    assert with_sla.cost == by_hand.cost
