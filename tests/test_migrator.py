"""Resilient async execution plane: AsyncMigrator retries/rollback/budget,
ChaosStore fault injection, zero-fault bit-parity with the synchronous
``store.migrate``/``sync_plan`` paths, and daemon integration in batch,
streaming, and fleet modes.

``CHAOS_SEED`` (env, default 0) offsets every injected-fault seed — the CI
chaos matrix sweeps it so retry/rollback paths stay deterministic across
schedules, not just for one lucky seed.
"""

import os

import numpy as np
import pytest

from repro.core.costs import azure_table
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import (PlacementEngine, ScopeConfig, StreamingEngine)
from repro.core.fleet import FleetEngine
from repro.core.migrator import (AsyncMigrator, MigratorReport, MoveState,
                                 _meter_cents)
from repro.storage.chaos import (ChaosStore, PermanentStoreError,
                                 TransientStoreError)
from repro.storage.store import ChecksumError, TieredStore

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))

#: deterministic meter fields — compute/decomp are wall-clock measured and
#: excluded from every parity comparison (see migrator module docstring)
_FIELDS = ("storage_cents", "read_cents", "write_cents", "penalty_cents",
           "egress_cents", "n_reads", "n_writes")


def _meter_sig(store):
    return tuple(getattr(store.meter, f) for f in _FIELDS)


def _state_sig(store):
    return {k: (o.payload, o.tier, o.codec, o.stored_gb, o.moved_month)
            for k, o in store._objs.items()}


# ------------------------------------------------------------------ fixtures
def _payload_plan():
    """Real-payload plan (truth-measured R/D) so a store can execute it;
    rho spread forces both tier moves and re-encodes under drift."""
    from repro.core.engine import CompressStage, PartitionedData
    table = azure_table()
    raws = [(bytes([65 + i % 8]) * (200_000 + 50_000 * i)) for i in range(6)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), months=2.0)
    eng = PlacementEngine(table, cfg)
    data = PartitionedData(
        partitions=[None] * len(raws), tables=[None] * len(raws),
        raw_bytes=raws, spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 0.1, 40.0, 0.02, 800.0, 5.0]))
    return eng, eng.solve(CompressStage(cfg)(data, table))


def _drift(plan):
    r = plan.problem.rho.copy()
    r[0] *= 5000.0
    r[4] /= 5000.0
    return r


def _drifted_mig():
    eng, plan = _payload_plan()
    mig = eng.reoptimize(plan, _drift(plan), months_held=2.0)
    assert mig.n_moved >= 2
    assert (mig.moved & (mig.new_scheme != mig.old_scheme)).any()
    return eng, plan, mig


def _fresh_store(eng, plan, months=2.0):
    s = TieredStore(eng.table)
    keys = s.apply_plan(plan)
    s.advance_months(months)
    return s, keys


def _stream_engine():
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(6) for j in range(4)}
    return StreamingEngine(azure_table(), cfg, sizes, s_thresh=5.0,
                           window=1, drift_threshold=np.inf)


def _stream_cycles():
    quiet = [(("d0/0", "d0/1"), 400.0),
             (("d1/0", "d1/1", "d1/2"), 0.01),
             (("d2/0", "d2/1"), 0.01)]
    hot = [(f, 500.0 if f[0][0] in "d1d2" else h) for f, h in quiet]
    return [quiet, quiet, hot, hot, hot, hot]


def _payload_fn(p):
    return b"Z" * (1000 * sum(ord(f[-1]) for f in sorted(p.files)))


# ------------------------------------------------------------- chaos store
def test_chaos_store_schedule_is_deterministic():
    def run():
        s = TieredStore(azure_table())
        ch = ChaosStore(s, seed=CHAOS_SEED + 7, p_transient=0.3,
                        p_permanent=0.1, p_corrupt=0.3)
        log = []
        for i in range(40):
            try:
                ch.put(f"k{i % 5}", b"x" * 1000, tier=0)
                log.append("ok")
            except TransientStoreError:
                log.append("t")
            except PermanentStoreError:
                log.append("p")
        return log, (ch.stats.n_transient, ch.stats.n_permanent,
                     ch.stats.n_corrupt_put)

    a, b = run(), run()
    assert a == b
    assert sum(b[1]) > 0


def test_chaos_store_validates_ops_and_delegates_metadata():
    s = TieredStore(azure_table())
    with pytest.raises(ValueError, match="unknown chaos ops"):
        ChaosStore(s, ops=("get", "frobnicate"))
    ch = ChaosStore(s, seed=0, p_transient=1.0, ops=("get",))
    ch.put("a", b"x" * 100, tier=0)        # put not faulted
    assert ch.has("a") and ch.tier_of("a") == 0
    assert ch.meter is s.meter and ch.inner is s
    with pytest.raises(TransientStoreError):
        ch.get("a")


def test_chaos_max_faults_per_op_guarantees_eventual_success():
    s = TieredStore(azure_table())
    s.put("a", b"x" * 1000, tier=0)
    ch = ChaosStore(s, seed=CHAOS_SEED, p_transient=1.0, max_faults_per_op=3)
    outcomes = []
    for _ in range(5):
        try:
            ch.get("a")
            outcomes.append("ok")
        except TransientStoreError:
            outcomes.append("t")
    assert outcomes == ["t", "t", "t", "ok", "ok"]


# --------------------------------------------------- zero-fault parity pins
def test_zero_fault_execute_is_bit_identical_to_store_migrate():
    eng, plan, mig = _drifted_mig()
    s1, k1 = _fresh_store(eng, plan)
    s1.migrate(mig, k1)
    s2, k2 = _fresh_store(eng, plan)
    rep = AsyncMigrator(s2, sleep_fn=None).execute(mig, k2)
    assert rep.n_committed == mig.n_moved and rep.n_failed == 0
    assert rep.n_attempts == mig.n_moved and rep.retry_cents == 0.0
    assert _meter_sig(s1) == _meter_sig(s2)
    assert _state_sig(s1) == _state_sig(s2)


def test_zero_fault_execute_sync_is_bit_identical_to_sync_plan():
    e1, e2 = _stream_engine(), _stream_engine()
    s1, s2 = TieredStore(e1.table), TieredStore(e2.table)
    m = AsyncMigrator(s2, sleep_fn=None)
    for batch in _stream_cycles():
        mig1 = e1.ingest_and_reoptimize(batch, months=1.0)
        parts = mig1.plan.problem.partitions
        s1.advance_months(1.0)
        s1.sync_plan(mig1.plan, payloads=[_payload_fn(p) for p in parts])
        mig2 = e2.ingest_and_reoptimize(batch, months=1.0)
        s2.advance_months(1.0)
        rep = m.execute_sync(mig2, [_payload_fn(p)
                                    for p in mig2.plan.problem.partitions])
        assert rep.n_failed == 0 and rep.retry_cents == 0.0
    assert _meter_sig(s1) == _meter_sig(s2)
    assert _state_sig(s1) == _state_sig(s2)


# -------------------------------------------------------- failure handling
@pytest.mark.parametrize("seed", [CHAOS_SEED, CHAOS_SEED + 1, CHAOS_SEED + 2])
def test_transient_faults_retry_to_exact_fault_free_bill_plus_retry(seed):
    """The acceptance identity: under 429/503s with eventual success the
    cumulative billed cents equal the fault-free bill plus the explicitly
    metered retry cents — no move double-billed, end state identical."""
    eng, plan, mig = _drifted_mig()
    ref, kr = _fresh_store(eng, plan)
    ref.migrate(mig, kr)
    s, k = _fresh_store(eng, plan)
    ch = ChaosStore(s, seed=seed, p_transient=0.4, p_corrupt=0.2,
                    max_faults_per_op=2)
    rep = AsyncMigrator(ch, sleep_fn=None, max_attempts=6).execute(mig, k)
    assert rep.n_failed == 0 and rep.n_committed == mig.n_moved
    assert _meter_cents(s.meter) == pytest.approx(
        _meter_cents(ref.meter) + rep.retry_cents, abs=1e-12)
    assert {k: v[:3] for k, v in _state_sig(s).items()} == \
           {k: v[:3] for k, v in _state_sig(ref).items()}
    assert rep.attempted_cents == pytest.approx(
        rep.committed_cents + rep.retry_cents, abs=1e-12)


def test_corruption_is_caught_by_checksums_never_committed():
    """Corrupted get/put payloads raise ChecksumError before any commit;
    retried reads land the true bytes, so the final store content matches
    the fault-free reference byte-for-byte."""
    eng, plan, mig = _drifted_mig()
    ref, kr = _fresh_store(eng, plan)
    ref.migrate(mig, kr)
    s, k = _fresh_store(eng, plan)
    ch = ChaosStore(s, seed=CHAOS_SEED + 11, p_corrupt=0.6,
                    max_faults_per_op=2, ops=("get", "replace"))
    rep = AsyncMigrator(ch, sleep_fn=None, max_attempts=8).execute(mig, k)
    assert ch.stats.n_corrupt_get + ch.stats.n_corrupt_put > 0
    assert rep.n_failed == 0
    assert {k: v[0] for k, v in _state_sig(s).items()} == \
           {k: v[0] for k, v in _state_sig(ref).items()}


def test_corrupted_put_rejected_by_store_checksum_validation():
    import hashlib
    s = TieredStore(azure_table())
    ch = ChaosStore(s, seed=CHAOS_SEED, p_corrupt=1.0, ops=("put",))
    raw = b"payload" * 100
    with pytest.raises(ChecksumError):
        ch.put("a", raw, tier=0,
               expect_checksum=hashlib.sha256(raw).hexdigest())
    assert not s.has("a") and s.meter.write_cents == 0.0


def test_permanent_failure_rolls_back_with_source_intact():
    eng, plan, mig = _drifted_mig()
    s, k = _fresh_store(eng, plan)
    before = _state_sig(s)
    ch = ChaosStore(s, seed=CHAOS_SEED + 3, p_permanent=1.0)
    rep = AsyncMigrator(ch, sleep_fn=None).execute(mig, k)
    assert rep.n_committed == 0 and rep.n_rolled_back == mig.n_moved
    for t in rep.tasks:
        assert t.state is MoveState.ROLLED_BACK and t.attempts == 1
    # no source deleted, nothing moved — the store is exactly as it was
    assert _state_sig(s) == before
    assert np.array_equal(rep.failed_mask(), mig.moved)
    landed = mig.land(rep.unapplied_mask())
    assert landed.n_moved == 0
    assert np.array_equal(landed.deferred, mig.moved)


def test_retries_exhausted_mark_failed_without_partial_commit():
    eng, plan, mig = _drifted_mig()
    s, k = _fresh_store(eng, plan)
    before = _state_sig(s)
    ch = ChaosStore(s, seed=CHAOS_SEED, p_transient=1.0)
    rep = AsyncMigrator(ch, sleep_fn=None, max_attempts=3).execute(mig, k)
    assert rep.n_committed == 0 and rep.n_failed == mig.n_moved
    assert all(t.attempts == 3 for t in rep.tasks)
    assert _state_sig(s) == before
    # transients raise before the op runs: nothing was ever billed
    assert rep.failed_cents == 0.0 and rep.attempted_cents == 0.0


def test_failed_reencode_meters_exactly_its_wasted_reads():
    """A re-encode whose every get comes back corrupted burns exactly
    max_attempts read charges — metered as failed_cents, nothing else."""
    eng, plan, mig = _drifted_mig()
    re_rows = np.flatnonzero(mig.moved
                             & (mig.new_scheme != mig.old_scheme))
    s, k = _fresh_store(eng, plan)
    base = _meter_cents(s.meter)
    ch = ChaosStore(s, seed=CHAOS_SEED, p_corrupt=1.0, ops=("get",))
    rep = AsyncMigrator(ch, sleep_fn=None, max_attempts=4).execute(
        mig.select(np.isin(np.arange(len(mig.moved)), re_rows[:1])), k)
    assert rep.n_failed == 1 and rep.n_committed == 0
    n = int(re_rows[0])
    o = s._objs[k[n]]
    expect = 4 * o.stored_gb * s.table.read_cents_gb[o.tier]
    assert rep.failed_cents == pytest.approx(expect, rel=1e-12)
    assert _meter_cents(s.meter) - base == pytest.approx(expect, rel=1e-12)


def test_budget_cap_holds_over_attempted_spend():
    """With a cents cap below the plan's total, the migrator stops
    launching (and retrying) once another full-cost attempt could
    overrun — cumulative attempted cents never exceed the cap."""
    eng, plan, mig = _drifted_mig()
    charges = (mig.move_transfer_cents + mig.move_egress_cents
               + mig.move_penalty_cents)[mig.moved]
    cap = float(np.sort(charges)[0] * 1.5)     # fits ~one move, not all
    s, k = _fresh_store(eng, plan)
    ch = ChaosStore(s, seed=CHAOS_SEED, p_transient=0.5, max_faults_per_op=1)
    rep = AsyncMigrator(ch, sleep_fn=None, max_attempts=5).execute(
        mig, k, budget_cents=cap)
    assert rep.attempted_cents <= cap + 1e-9
    assert rep.n_skipped > 0
    for t in rep.tasks:
        if t.state is MoveState.SKIPPED:
            assert t.attempts == 0 and t.spent_cents == 0.0
    # skipped rows surface in unapplied (re-planned), not in failed
    assert rep.unapplied_mask().sum() == rep.n_failed + rep.n_skipped


def test_backoff_is_exponential_jittered_and_seeded():
    delays = []
    eng, plan, mig = _drifted_mig()
    s, k = _fresh_store(eng, plan)
    ch = ChaosStore(s, seed=CHAOS_SEED, p_transient=1.0, max_faults_per_op=3,
                    ops=("get",))
    m = AsyncMigrator(ch, seed=42, max_attempts=5, base_delay_s=0.01,
                      backoff_mult=2.0, jitter=0.5, sleep_fn=delays.append)
    one = np.flatnonzero(mig.moved)[:1]
    rep = m.execute(mig.select(np.isin(np.arange(len(mig.moved)), one)), k)
    assert rep.n_committed == 1 and len(delays) == 3
    for i, d in enumerate(delays):
        lo = 0.01 * 2.0 ** i
        assert lo <= d <= lo * 1.5
    assert rep.backoff_s == pytest.approx(sum(delays))
    # same chaos + jitter seeds -> identical schedule
    s2, k2 = _fresh_store(eng, plan)
    ch2 = ChaosStore(s2, seed=CHAOS_SEED, p_transient=1.0,
                     max_faults_per_op=3, ops=("get",))
    delays2 = []
    AsyncMigrator(ch2, seed=42, max_attempts=5, base_delay_s=0.01,
                  backoff_mult=2.0, jitter=0.5,
                  sleep_fn=delays2.append).execute(
        mig.select(np.isin(np.arange(len(mig.moved)), one)), k2)
    assert delays == delays2


def test_execute_validates_keys_length_before_any_op():
    eng, plan, mig = _drifted_mig()
    s, k = _fresh_store(eng, plan)
    sig = _meter_sig(s)
    with pytest.raises(ValueError, match="nothing executed"):
        AsyncMigrator(s, sleep_fn=None).execute(mig, k[:-1])
    assert _meter_sig(s) == sig


def test_execute_sync_validates_payloads_length_before_any_op():
    e = _stream_engine()
    mig = e.ingest_and_reoptimize(_stream_cycles()[0], months=1.0)
    s = TieredStore(e.table)
    with pytest.raises(ValueError, match="nothing executed"):
        AsyncMigrator(s, sleep_fn=None).execute_sync(mig, [b"x"])
    assert len(s.keys()) == 0 and s.meter.total_cents == 0.0


def test_workers_overlap_lands_everything_with_equal_cents():
    eng, plan, mig = _drifted_mig()
    ref, kr = _fresh_store(eng, plan)
    ref.migrate(mig, kr)
    s, k = _fresh_store(eng, plan)
    rep = AsyncMigrator(s, workers=4, sleep_fn=None).execute(mig, k)
    assert rep.n_committed == mig.n_moved and rep.n_failed == 0
    # float accumulation order depends on scheduling: approx, not bitwise
    for f in _FIELDS:
        assert getattr(s.meter, f) == pytest.approx(getattr(ref.meter, f),
                                                    rel=1e-9)
    assert {k: v[:3] for k, v in _state_sig(s).items()} == \
           {k: v[:3] for k, v in _state_sig(ref).items()}


# ------------------------------------------------------- daemon integration
def test_batch_daemon_migrator_zero_fault_parity():
    eng, plan0 = _payload_plan()
    s1, k1 = TieredStore(eng.table), None
    k1 = s1.apply_plan(plan0)
    d1 = ReoptimizationDaemon(eng, plan=plan0, store=s1, store_keys=k1)
    s2 = TieredStore(eng.table)
    k2 = s2.apply_plan(plan0)
    d2 = ReoptimizationDaemon(eng, plan=plan0, store_keys=k2,
                              migrator=AsyncMigrator(s2, sleep_fn=None))
    for _ in range(3):
        r1 = d1.step(_drift(plan0), months=1.0)
        r2 = d2.step(_drift(plan0), months=1.0)
        assert r1.spent_cents == r2.spent_cents
        assert r2.n_failed == 0 and r2.retry_cents == 0.0
        assert r2.attempted_cents == pytest.approx(r2.spent_cents, abs=1e-12)
    assert _meter_sig(s1) == _meter_sig(s2)
    assert _state_sig(s1) == _state_sig(s2)
    assert np.array_equal(d1.plan.assignment.tier, d2.plan.assignment.tier)


def test_stream_daemon_migrator_zero_fault_parity():
    e1, e2 = _stream_engine(), _stream_engine()
    s1, s2 = TieredStore(e1.table), TieredStore(e2.table)
    d1 = ReoptimizationDaemon(e1, store=s1, payload_fn=_payload_fn)
    d2 = ReoptimizationDaemon(e2, payload_fn=_payload_fn,
                              migrator=AsyncMigrator(s2, sleep_fn=None))
    for b in _stream_cycles():
        r1 = d1.step(b, months=1.0)
        r2 = d2.step(b, months=1.0)
        assert r1.spent_cents == r2.spent_cents and r2.n_failed == 0
    assert _meter_sig(s1) == _meter_sig(s2)
    assert _state_sig(s1) == _state_sig(s2)
    for h1, h2 in zip(e1.history, e2.history):
        assert h1 == h2


def test_fleet_daemon_migrators_zero_fault_parity():
    import dataclasses
    eng, p1 = _payload_plan()
    p2 = eng.solve(dataclasses.replace(p1.problem,
                                       rho=p1.problem.rho[::-1].copy()))
    fe = FleetEngine(eng.table, eng.cfg)
    drifts = [_drift(p1), _drift(p2)]
    dref = ReoptimizationDaemon(fe, plans=[p1, p2])
    stores, keys, migrs = [], [], []
    for p in (p1, p2):
        s = TieredStore(eng.table)
        keys.append(s.apply_plan(p))
        stores.append(s)
        migrs.append(AsyncMigrator(s, sleep_fn=None))
    dm = ReoptimizationDaemon(fe, plans=[p1, p2], migrators=migrs,
                              store_keys=keys)
    for _ in range(3):
        rr = dref.step(drifts, months=1.0)
        rm = dm.step(drifts, months=1.0)
        assert rr.spent_cents == rm.spent_cents and rm.n_failed == 0
    for t in range(2):
        assert np.array_equal(dref.plans[t].assignment.tier,
                              dm.plans[t].assignment.tier)
        # each tenant's store matches its own batch-mode store= daemon
        s = TieredStore(eng.table)
        k = s.apply_plan((p1, p2)[t])
        db = ReoptimizationDaemon(eng, plan=(p1, p2)[t], store=s,
                                  store_keys=k)
        for _ in range(3):
            db.step(drifts[t], months=1.0)
        assert _meter_sig(s) == _meter_sig(stores[t])
        assert _state_sig(s) == _state_sig(stores[t])


@pytest.mark.parametrize("seed", [CHAOS_SEED, CHAOS_SEED + 5])
def test_batch_daemon_replans_failed_moves_until_converged(seed):
    """A permanently-failed move is reverted (MigrationPlan.land), re-enters
    the candidate set, and lands on a later cycle; every metered non-storage
    cent is accounted as landed, retry, or failed spend — no double-billing.
    (The fault-free-bill + retry identity is strictly per-cycle — a move
    delayed across cycles legitimately shifts storage accrual and prorated
    penalties — and is pinned by the transient-fault test above.)"""
    eng, plan0 = _payload_plan()
    s1 = TieredStore(eng.table)
    k1 = s1.apply_plan(plan0)
    d1 = ReoptimizationDaemon(eng, plan=plan0, store=s1, store_keys=k1)
    for _ in range(5):
        d1.step(_drift(plan0), months=1.0)

    s2 = TieredStore(eng.table)
    k2 = s2.apply_plan(plan0)
    base_ops = _meter_cents(s2.meter) - s2.meter.storage_cents
    # every op's FIRST touch fails permanently (then its fault budget is
    # exhausted): guaranteed rollbacks in early cycles, guaranteed landing
    # on re-plan — deterministic for any seed in the CI chaos matrix
    ch = ChaosStore(s2, seed=seed, p_permanent=1.0, max_faults_per_op=1)
    d2 = ReoptimizationDaemon(eng, plan=plan0, store_keys=k2,
                              migrator=AsyncMigrator(ch, sleep_fn=None))
    for _ in range(5):
        r = d2.step(_drift(plan0), months=1.0)
        assert r.attempted_cents == pytest.approx(
            r.spent_cents + r.retry_cents + r.failed_cents, abs=1e-12)
    assert any(r.n_failed > 0 for r in d2.history)
    # converged to the same placement despite the injected failures
    assert np.array_equal(d1.plan.assignment.tier, d2.plan.assignment.tier)
    assert {k: v[:3] for k, v in _state_sig(s2).items()} == \
           {k: v[:3] for k, v in _state_sig(s1).items()}
    # no-double-billing: every non-storage cent the store metered is a
    # landed, retry, or failed cent some cycle report owns
    ops_cents = _meter_cents(s2.meter) - s2.meter.storage_cents - base_ops
    assert ops_cents == pytest.approx(
        sum(r.attempted_cents for r in d2.history), abs=1e-12)


def test_stream_daemon_chaos_accounting_identity():
    e1 = _stream_engine()
    s1 = TieredStore(e1.table)
    d1 = ReoptimizationDaemon(e1, store=s1, payload_fn=_payload_fn)
    d1.run(_stream_cycles(), months=1.0)

    e2 = _stream_engine()
    s2 = TieredStore(e2.table)
    ch = ChaosStore(s2, seed=CHAOS_SEED + 1, p_transient=0.35,
                    max_faults_per_op=2)
    d2 = ReoptimizationDaemon(e2, payload_fn=_payload_fn,
                              migrator=AsyncMigrator(ch, sleep_fn=None,
                                                     max_attempts=6))
    reps = d2.run(_stream_cycles(), months=1.0)
    extra = sum(r.retry_cents + r.failed_cents for r in reps)
    assert _meter_cents(s2.meter) == pytest.approx(
        _meter_cents(s1.meter) + extra, abs=1e-12)
    assert s1._objs.keys() == s2._objs.keys()


def test_fleet_daemon_shared_budget_caps_attempted_spend():
    import dataclasses
    eng, p1 = _payload_plan()
    p2 = eng.solve(dataclasses.replace(p1.problem,
                                       rho=p1.problem.rho[::-1].copy()))
    fe = FleetEngine(eng.table, eng.cfg)
    drifts = [_drift(p1), _drift(p2)]
    ref = ReoptimizationDaemon(fe, plans=[p1, p2])
    cap = 0.6 * ref.step(drifts, months=1.0).spent_cents
    stores, keys, migrs = [], [], []
    for i, p in enumerate((p1, p2)):
        s = TieredStore(eng.table)
        keys.append(s.apply_plan(p))
        stores.append(s)
        migrs.append(AsyncMigrator(
            ChaosStore(s, seed=CHAOS_SEED + i, p_transient=0.4,
                       max_faults_per_op=2), sleep_fn=None, max_attempts=6))
    d = ReoptimizationDaemon(fe, plans=[p1, p2], migrators=migrs,
                             store_keys=keys,
                             budget=MigrationBudget(cents_per_cycle=cap))
    for _ in range(6):
        r = d.step(drifts, months=1.0)
        assert r.attempted_cents <= cap + 1e-9
    assert sum(r.n_selected for r in d.history) > 0


def test_daemon_migrator_argument_validation():
    eng, plan0 = _payload_plan()
    s = TieredStore(eng.table)
    m = AsyncMigrator(s, sleep_fn=None)
    with pytest.raises(ValueError, match="not both"):
        ReoptimizationDaemon(eng, plan=plan0, store=s, migrator=m)
    with pytest.raises(ValueError, match="incompatible"):
        ReoptimizationDaemon(eng, plan=plan0, migrator=m,
                             amortize_oversized=True)
    with pytest.raises(ValueError, match="migrators="):
        ReoptimizationDaemon(eng, plan=plan0, migrators=[m])
    fe = FleetEngine(eng.table, eng.cfg)
    with pytest.raises(ValueError, match="migrators="):
        ReoptimizationDaemon(fe, plans=[plan0, plan0], migrator=m)
    with pytest.raises(ValueError, match="one migrator per tenant"):
        ReoptimizationDaemon(fe, plans=[plan0, plan0], migrators=[m])


def test_report_attempted_defaults_to_spent_without_migrator():
    eng, plan0 = _payload_plan()
    d = ReoptimizationDaemon(eng, plan=plan0)
    r = d.step(_drift(plan0), months=1.0)
    assert r.attempted_cents == r.spent_cents
    assert r.n_failed == 0 and r.retry_cents == 0.0 and r.failed_cents == 0.0
