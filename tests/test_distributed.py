"""Distributed machinery tests — run in subprocesses with 8 host devices
(device count locks at first jax init, so the main pytest process must stay
single-device for the smoke/bench paths)."""

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code))
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_train_step_shards_and_matches_single_device():
    """Sharded (2x4 mesh) train step == single-device train step."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_config
        from repro.distributed import ctx
        from repro.distributed.sharding import batch_specs, param_specs, to_named, zero1_specs
        from repro.launch.mesh import make_test_mesh
        from repro.training import train_step as ts
        from repro.training.optimizer import AdamWState

        cfg = get_config("qwen3-4b", smoke=True)
        tcfg = ts.TrainConfig(remat=True, microbatches=1)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg, tp=4)
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab_size)
        batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

        ref_state, ref_m = jax.jit(functools.partial(ts.train_step, cfg=cfg, tcfg=tcfg))(state, batch)

        mesh = make_test_mesh(data=2, model=4)
        p_specs = param_specs(state["params"], cfg, 4)
        z = zero1_specs(p_specs, state["params"], "data", 2)
        s_specs = {"params": p_specs,
                   "opt": AdamWState(step=P(), master=z, m=z, v=z, err=None)}
        with ctx.activate(mesh):
            fn = functools.partial(ts.train_step, cfg=cfg, tcfg=tcfg)
            jitted = jax.jit(fn, in_shardings=(to_named(s_specs, mesh),
                                               to_named(batch_specs(cfg, mesh), mesh)))
            new_state, m = jitted(state, batch)
        np.testing.assert_allclose(float(m["loss"]), float(ref_m["loss"]), rtol=2e-4)
        d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
                         new_state["params"], ref_state["params"])
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, f"param divergence {worst}"
        print("OK loss", float(m["loss"]), "worst", worst)
    """)
    assert "OK loss" in out


def test_decode_sharded_matches_single_device():
    """Seq-sharded flash-decoding == unsharded decode (GQA + MLA archs)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_config
        from repro.distributed import ctx
        from repro.distributed.sharding import cache_specs, param_specs, to_named
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tr

        for arch in ["yi-9b", "deepseek-v2-lite-16b"]:
            cfg = get_config(arch, smoke=True)
            params = tr.init_params(jax.random.PRNGKey(0), cfg, tp=4)
            B, S = 2, 32
            cache = tr.init_cache(cfg, B, max_seq=S, tp=4)
            toks = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 0, cfg.vocab_size)

            # reference: unsharded
            c = cache
            outs = []
            for i in range(4):
                lg, c = jax.jit(lambda p, c, t, q: tr.decode_step(p, c, t, q, cfg))(
                    params, c, toks[:, i:i+1], jnp.full((B,), i, jnp.int32))
                outs.append(np.asarray(lg))

            mesh = make_test_mesh(data=2, model=4)
            p_sh = to_named(param_specs(params, cfg, 4), mesh)
            c_sh = to_named(cache_specs(cfg, mesh), mesh)
            t_sh = NamedSharding(mesh, P("data", None))
            q_sh = NamedSharding(mesh, P("data"))
            params_d = jax.device_put(params, p_sh)
            with ctx.activate(mesh):
                step = jax.jit(lambda p, c, t, q: tr.decode_step(p, c, t, q, cfg),
                               in_shardings=(p_sh, c_sh, t_sh, q_sh),
                               out_shardings=(None, c_sh))
                c2 = jax.device_put(cache, c_sh)
                outs2 = []
                for i in range(4):
                    lg2, c2 = step(params_d, c2,
                                   jax.device_put(toks[:, i:i+1], t_sh),
                                   jax.device_put(jnp.full((B,), i, jnp.int32), q_sh))
                    outs2.append(np.asarray(lg2))
            for a, b in zip(outs, outs2):
                np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
            print("OK", arch)
    """)
    assert out.count("OK") == 2


def test_compressed_grad_mean():
    """Int8 error-feedback mean: quantization error carried, not lost."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import ctx
        from repro.launch.mesh import make_test_mesh
        from repro.training.grad_compression import compressed_mean
        mesh = make_test_mesh(data=4, model=2)
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 3.0}
        with ctx.mesh_context(mesh):
            red, err = compressed_mean(g, None, mesh, ("data",))
        # reduction of replicated grads is mean-preserving up to quant error
        q_err = float(jnp.abs(red["w"] - g["w"]).max())
        bound = float(jnp.abs(g["w"]).max()) / 127.0 + 1e-6
        assert q_err <= bound, (q_err, bound)
        # error feedback holds the residual exactly
        np.testing.assert_allclose(np.asarray(err["w"] + red["w"]),
                                   np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
        print("OK", q_err)
    """)
    assert "OK" in out


def test_mini_dryrun_multipod_mesh():
    """lower+compile a smoke config on a (2,2,2) pod mesh; memory/cost/HLO
    collectives all extracted — the 512-device dry-run in miniature."""
    out = run_sub("""
        import jax, jax.numpy as jnp, functools, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis import roofline as rl
        from repro.configs.registry import get_config
        from repro.distributed import ctx
        from repro.distributed.sharding import batch_specs, param_specs, to_named
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tr
        from repro.training import train_step as ts

        cfg = get_config("qwen3-4b", smoke=True)
        mesh = make_test_mesh(data=2, model=2, pod=2)
        tcfg = ts.TrainConfig(remat=True)
        state = jax.eval_shape(lambda k: ts.init_train_state(k, cfg, tcfg, 2),
                               jax.random.PRNGKey(0))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        p_specs = param_specs(state["params"], cfg, 2)
        from repro.training.optimizer import AdamWState
        s_specs = {"params": p_specs,
                   "opt": AdamWState(step=P(), master=p_specs, m=p_specs,
                                     v=p_specs, err=None)}
        with ctx.activate(mesh):
            fn = functools.partial(ts.train_step, cfg=cfg, tcfg=tcfg)
            lowered = jax.jit(fn, in_shardings=(to_named(s_specs, mesh),
                                                to_named(batch_specs(cfg, mesh), mesh))
                              ).lower(state, batch)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = rl.collective_bytes(compiled.as_text())
        assert coll["total"] > 0, "no collectives found in multi-pod HLO"
        roof = rl.roofline_terms(cost, compiled.as_text(), mesh.size, 1e9)
        print("OK", json.dumps({"coll": coll["total"],
                                "flops": roof.flops,
                                "dominant": roof.dominant}))
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    """GPipe wrapper == sequential stage application (4 stages, 8 mb)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_test_mesh

        S, d = 4, 16
        mesh = make_test_mesh(data=2, model=1, pod=S)  # 'pod' = pipe axis
        ks = jax.random.split(jax.random.PRNGKey(0), S)
        params = {"w": jnp.stack([jax.random.normal(k, (d, d)) * 0.3
                                  for k in ks]),
                  "b": jnp.stack([jnp.zeros((d,)) for _ in ks])}

        def stage(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        x = jax.random.normal(jax.random.PRNGKey(1), (16, d))
        ref = x
        for s in range(S):
            ref = stage(jax.tree.map(lambda a: a[s], params), ref)
        out = pipeline_apply(stage, params, x, mesh=mesh, axis="pod",
                             microbatches=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("OK pipeline")
    """)
    assert "OK pipeline" in out


def test_compat_shims_and_sharded_overlap_matrix():
    """repro.compat consolidates the jax API-drift gates, and the G-PART
    overlap matrix sharded over a device mesh equals the unsharded sweep."""
    out = run_sub("""
        import jax, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import datapart as dp
        from repro.launch.mesh import make_test_mesh

        mesh = compat.make_mesh((4,), ("data",))
        assert tuple(mesh.axis_names) == ("data",)

        def f(x):
            return jax.lax.psum(x, "data")
        fn = compat.shard_map(f, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False)
        y = fn(np.ones(4, np.float32))
        np.testing.assert_allclose(np.asarray(y), 4.0)
        with compat.mesh_context(mesh):
            pass
        print("OK compat")

        rng = np.random.default_rng(0)
        files = [f"t/{i}" for i in range(50)]
        sizes = {f: float(rng.random() * 3 + 0.2) for f in files}
        qf = [(tuple(rng.choice(files, size=int(rng.integers(2, 7)),
                                replace=False)),
               float(rng.random() * 5 + 0.5)) for _ in range(30)]
        idx = dp.PartitionIndex.from_partitions(dp.make_partitions(qf, sizes))
        w0 = np.asarray(idx.overlap_matrix("ref"))
        w4 = np.asarray(idx.overlap_matrix("ref", mesh=make_test_mesh(data=4)))
        np.testing.assert_allclose(w4, w0, rtol=1e-6, atol=1e-6)
        print("OK sharded overlap")
    """)
    assert "OK compat" in out and "OK sharded overlap" in out
