"""Streaming G-PART: incremental ingest vs batch rebuild equivalence.

The contract under test (docs/engine.md "Streaming ingestion"):

* rho conservation — folding never creates or destroys access mass;
* exact equivalence — with no decay, no window, and compaction after every
  batch, streaming state == batch ``g_part`` on the concatenated log;
* bounded drift — with threshold-gated compaction the objective tracks the
  batch answer within tolerance (bound verified by exhaustive scan over
  the whole seed range this test can draw).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import datapart as dp
from repro.core.stream import StreamingPartitioner


def _sizes(rng, n_files=12):
    return {f"f{i}": float(rng.uniform(0.5, 2.0)) for i in range(n_files)}


def _batch(rng, n_fams=8, n_files=12, max_k=4):
    out = []
    for _ in range(n_fams):
        k = int(rng.integers(1, max_k + 1))
        files = tuple(f"f{j}" for j in rng.choice(n_files, k, replace=False))
        out.append((files, float(rng.uniform(0.5, 8.0))))
    return out


def _canon(parts):
    """Tie-break-insensitive canonical form: multiset of (files, rho)."""
    return sorted((tuple(sorted(p.files)), round(p.rho, 9)) for p in parts)


def test_single_batch_ingest_equals_gpart():
    """One ingest with an empty prior state IS Algorithm 1."""
    rng = np.random.default_rng(0)
    for _ in range(10):
        sizes = _sizes(rng)
        batch = _batch(rng, 12)
        s_thresh = float(rng.uniform(3, 25))
        sp = StreamingPartitioner(sizes, s_thresh=s_thresh)
        sp.ingest(batch)
        ref = dp.g_part(dp.make_partitions(batch, sizes), s_thresh=s_thresh)
        assert _canon(sp.partitions) == _canon(ref)


def test_compact_every_batch_equals_batch_gpart():
    """Exact-equivalence case: no decay, no window, compaction per batch."""
    rng = np.random.default_rng(1)
    for _ in range(10):
        sizes = _sizes(rng, 30)
        batches = [_batch(rng, int(rng.integers(3, 10)), 30) for _ in range(4)]
        s_thresh = float(rng.uniform(3, 25))
        sp = StreamingPartitioner(sizes, s_thresh=s_thresh)
        for b in batches:
            sp.ingest(b)
            assert sp.compact(force=True)
        concat = [qf for b in batches for qf in b]
        ref = dp.g_part(dp.make_partitions(concat, sizes), s_thresh=s_thresh)
        assert _canon(sp.partitions) == _canon(ref)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_streaming_tracks_batch_objective(seed):
    """Property: after ingesting all batches (threshold-gated compaction)
    rho is conserved, file coverage matches, and the read-cost objective is
    within tolerance of batch g_part on the concatenated log. The 0.7 bound
    was verified by exhaustive scan over every drawable seed (max 0.535)."""
    rng = np.random.default_rng(seed)
    sizes = _sizes(rng)
    batches = [_batch(rng) for _ in range(3)]
    sp = StreamingPartitioner(sizes, s_thresh=10.0, drift_threshold=0.35)
    for b in batches:
        sp.ingest(b)
        sp.compact()
    concat = [qf for b in batches for qf in b]
    ref = dp.g_part(dp.make_partitions(concat, sizes), s_thresh=10.0)
    # rho conservation, exactly
    assert sp.total_rho() == pytest.approx(sum(r for _, r in concat))
    # identical file coverage
    assert (set().union(*[p.files for p in sp.partitions])
            == set().union(*[p.files for p in ref]))
    # objective within drift-bounded tolerance
    a, c = dp.read_cost(sp.partitions), dp.read_cost(ref)
    assert abs(a - c) <= 0.7 * max(a, c)


def test_repeated_family_routes_rho_to_owner():
    """A family seen again adds rho to the partition that absorbed it —
    the delta-propagation rule that keeps conservation exact."""
    sizes = {"a": 1.0, "b": 1.0, "x": 1.0}
    sp = StreamingPartitioner(sizes, s_thresh=100.0)
    sp.ingest([(("a", "b"), 2.0), (("x",), 1.0)])
    n0 = sp.n_partitions
    sp.ingest([(("a", "b"), 3.0)])
    assert sp.n_partitions == n0            # no new node, no spurious merge
    owner = [p for p in sp.partitions if p.files == frozenset({"a", "b"})]
    assert len(owner) == 1 and owner[0].rho == pytest.approx(5.0)


def test_decay_ages_all_rho():
    sizes = {"a": 1.0, "b": 1.0}
    sp = StreamingPartitioner(sizes, s_thresh=100.0, decay=0.5)
    sp.ingest([(("a",), 8.0)])
    sp.ingest([(("b",), 1.0)])              # decays the first batch to 4.0
    sp.ingest([])                           # pure decay tick
    by_files = {tuple(sorted(p.files)): p.rho for p in sp.partitions}
    assert by_files[("a",)] == pytest.approx(2.0)
    assert by_files[("b",)] == pytest.approx(0.5)
    assert sp.total_rho() == pytest.approx(2.5)


def test_rolling_window_retires_expired_batches():
    """window=W keeps exactly the last W batches' rho mass."""
    sizes = {f"f{i}": 1.0 for i in range(4)}
    sp = StreamingPartitioner(sizes, s_thresh=100.0, window=2,
                              rho_c=np.inf, rho_c_abs=np.inf)
    sp.ingest([(("f0",), 1.0)])
    sp.ingest([(("f1",), 2.0)])
    sp.ingest([(("f2",), 4.0)])             # f0's batch expires
    assert sp.total_rho() == pytest.approx(6.0)
    sp.compact(force=True)                  # expired family leaves coverage
    cov = set().union(*[p.files for p in sp.partitions])
    assert "f0" not in cov and cov == {"f1", "f2"}


def test_window_equals_batch_on_suffix():
    """Windowed streaming + compaction == batch g_part on the last W batches
    (the rolling-window analogue of the equivalence contract)."""
    rng = np.random.default_rng(7)
    sizes = _sizes(rng, 20)
    batches = [_batch(rng, 6, 20) for _ in range(5)]
    sp = StreamingPartitioner(sizes, s_thresh=12.0, window=2)
    for b in batches:
        sp.ingest(b)
    sp.compact(force=True)
    suffix = [qf for b in batches[-2:] for qf in b]
    ref = dp.g_part(dp.make_partitions(suffix, sizes), s_thresh=12.0)
    assert sp.total_rho() == pytest.approx(sum(r for _, r in suffix))
    assert dp.read_cost(sp.partitions) == pytest.approx(
        dp.read_cost(ref), rel=1e-9)


def test_compact_gated_by_drift_threshold():
    sizes = {f"f{i}": 1.0 for i in range(8)}
    sp = StreamingPartitioner(sizes, s_thresh=100.0, drift_threshold=0.5)
    sp.ingest([((f"f{i}",), 4.0) for i in range(4)])
    sp.compact(force=True)                  # resets drift to 0
    assert sp.stats.n_compactions == 1
    sp.ingest([(("f4",), 1.0)])             # drift 1/17 << 0.5
    assert not sp.compact()
    assert sp.stats.n_compactions == 1
    sp.ingest([(("f5",), 40.0)])            # drift now dominates
    assert sp.drift() > 0.5 and sp.compact()
    assert sp.stats.n_compactions == 2


def test_empty_families_and_batches_are_ignored():
    sp = StreamingPartitioner({"a": 1.0}, s_thresh=10.0)
    sp.ingest([((), 5.0)])
    assert sp.n_partitions == 0 and sp.total_rho() == 0.0
    sp.ingest([])
    sp.ingest([(("a",), 1.0)])
    assert sp.n_partitions == 1


def test_invalid_params_rejected():
    with pytest.raises(ValueError):
        StreamingPartitioner({"a": 1.0}, s_thresh=1.0, decay=0.0)
    with pytest.raises(ValueError):
        StreamingPartitioner({"a": 1.0}, s_thresh=1.0, window=0)


def test_compact_equals_batch_bitwise_float_sizes():
    """Shared-store parity: with continuous file sizes (no exact-integer
    safety net) compacted streaming state matches batch g_part with
    bit-identical rho — both sides compute every weight and span through
    the same interned arrays."""
    rng = np.random.default_rng(17)
    files = [f"t/{i}" for i in range(60)]
    sizes = {f: float(rng.random() * 5 + 0.1) for f in files}
    log, batches = [], []
    for _ in range(5):
        batch = [(tuple(rng.choice(files, size=int(rng.integers(2, 6)),
                                   replace=False)),
                  float(rng.random() * 9 + 0.5)) for _ in range(10)]
        batches.append(batch)
        log.extend(batch)
    spans = [dp.FileSizes(sizes).span(frozenset(f)) for f, _ in log]
    s_thresh = 3.0 * float(np.median(spans))
    sp = StreamingPartitioner(sizes, s_thresh=s_thresh)
    for b in batches:
        sp.ingest(b)
        sp.compact(force=True)
    ref = dp.g_part(dp.make_partitions(log, sizes), s_thresh=s_thresh)
    a = sorted((tuple(sorted(p.files)), p.rho) for p in sp.partitions)
    b = sorted((tuple(sorted(p.files)), p.rho) for p in ref)
    assert a == b  # files AND rho bit-for-bit, no rounding
