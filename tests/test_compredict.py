"""COMPREDICT: features, sampling, prediction quality (paper §V bands)."""

import numpy as np
import pytest

from repro.core import ml
from repro.core.compredict import (CompressionPredictor, build_dataset,
                                   extract_features, query_samples,
                                   random_samples, train_eval,
                                   weighted_entropy)
from repro.data import tpch
from repro.storage.codecs import available_schemes, codec_by_name


@pytest.fixture(scope="module")
def db():
    return tpch.generate(scale_rows=4000, seed=0)


@pytest.fixture(scope="module")
def queries(db):
    return tpch.generate_queries(db, n_per_template=8, seed=1)


@pytest.fixture(scope="module")
def samples(db, queries):
    return query_samples(queries, db.tables, max_rows=1200)


def test_weighted_entropy_repetition_lowers_entropy(db):
    t = db.tables["lineitem"].head(1000)
    h_orig = weighted_entropy(t)
    # constant column set -> much lower string-dtype entropy
    rep = t.select(np.zeros(1000, int))
    h_rep = weighted_entropy(rep)
    assert h_rep["str"] < h_orig["str"]
    assert h_rep["float"] < h_orig["float"]


def test_feature_shapes(db):
    t = db.tables["orders"].head(500)
    assert extract_features(t, "row", "size").shape == (3,)
    f = extract_features(t, "col", "weighted_entropy")
    assert f.shape == (18,) and np.isfinite(f).all()
    fb = extract_features(t, "col", "bucketed")
    assert fb.shape == (18 + 15,)


def test_entropy_predicts_ratio_better_than_size(samples):
    """Paper Table V: queries+weighted_entropy >> random+*; also beats size
    features on MAPE for gzip-class codecs."""
    codec = codec_by_name("zlib-6")
    ds_ent = build_dataset(samples, codec, "row", "weighted_entropy")
    _, res_rf = train_eval(ds_ent, "RandomForest", "ratio", seed=0)
    _, res_svr = train_eval(ds_ent, "SVR", "ratio", seed=0)
    best = max(res_rf.r2, res_svr.r2)
    assert best > 0.9, f"entropy features R2 too low: {res_rf} {res_svr}"
    assert min(res_rf.mape, res_svr.mape) < 5.0
    ds_size = build_dataset(samples, codec, "row", "size")
    _, res_size = train_eval(ds_size, "SVR", "ratio", seed=0)
    assert best >= res_size.r2 - 0.02


def test_random_samples_worse_than_query_samples(db, queries):
    codec = codec_by_name("zlib-6")
    rand = random_samples(db.tables["lineitem"], 40, 800, seed=2)
    li_queries = [q for q in queries if q.table == "lineitem"]
    qsamp = query_samples(li_queries, db.tables, max_rows=800)
    ds_r = build_dataset(rand, codec, "row", "weighted_entropy")
    ds_q = build_dataset(qsamp, codec, "row", "weighted_entropy")
    # paper Fig 4: query results (same table) compress better than random
    # row samples, because selections concentrate repeated values
    assert ds_q.ratio.mean() > ds_r.ratio.mean()


def test_predictor_interface(db, queries, samples):
    scheme = available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0]
    pred = CompressionPredictor().fit(samples[:60], layouts=("col",),
                                      codecs=[codec_by_name(scheme)])
    t = db.tables["customer"].head(400)
    r, d = pred.predict(t, scheme, "col")
    assert r >= 1.0 and d >= 0.0
    R, D = pred.predict_matrix([t], ["none", scheme], "col")
    assert R.shape == (1, 2) and R[0, 0] == 1.0 and D[0, 0] == 0.0


def test_layouts_differ(db):
    t = db.tables["lineitem"].head(2000)
    row_b = t.serialize("row")
    col_b = t.serialize("col")
    assert row_b != col_b
    from repro.storage.codecs import measure
    m_row = measure(codec_by_name("zlib-6"), row_b)
    m_col = measure(codec_by_name("zlib-6"), col_b)
    # columnar layout groups similar values -> compresses at least as well
    assert m_col.ratio > 0.8 * m_row.ratio
