"""AccessForecaster + forecasting-path bug sweep: window validation,
early-month feature clamps, trend clamps, isotonic calibration, out-of-time
(no-leakage) fitting, seeded determinism, forecast_fn=None daemon parity in
all three modes, and the streaming context protocol."""

import numpy as np
import pytest

from repro.core import ml
from repro.core.access_predict import optimal_tiers, train_tier_predictor
from repro.core.costs import azure_table
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import (PlacementEngine, PlacementProblem, ScopeConfig,
                               StreamingEngine)
from repro.core.fleet import FleetEngine
from repro.core.forecast import (AccessForecaster, clamp_rho,
                                 linear_trend_forecast)
from repro.data.workloads import feature_matrix, generate_workload

TAB = azure_table()
SPIKY = {"decreasing": 0.2, "constant": 0.1, "periodic": 0.35,
         "spike": 0.15, "cold": 0.2}


def _workload(n=60, months=18, seed=7):
    return generate_workload(n_datasets=n, n_months=months, seed=seed,
                             pattern_probs=SPIKY)


def _fitted(w, **kw):
    kw.setdefault("n_trees", 10)
    fc = AccessForecaster(TAB, tiers=(1, 2), horizon=2, history=4, **kw)
    fc.fit(w, fit_month=12)
    return fc


# ------------------------------------------------------------- sanity layer
def test_clamp_rho_bounds_and_nonfinite():
    assert clamp_rho(-3.0) == 0.0
    assert clamp_rho(np.nan) == 0.0
    assert clamp_rho(np.inf, hi=5.0) == 0.0   # non-finite collapses to lo
    assert clamp_rho(2.0) == 2.0
    out = clamp_rho(np.array([2.0, -1.0, np.nan]), hi=1.5)
    assert out.tolist() == [1.5, 0.0, 0.0]
    # per-element upper bounds (the spike cap is a vector)
    out = clamp_rho(np.array([5.0, 5.0]), hi=np.array([3.0, 10.0]))
    assert out.tolist() == [3.0, 5.0]


def test_linear_trend_clamps_degenerate_histories():
    # length-1 history: last value, clamped (was returned unclamped)
    assert linear_trend_forecast([3.0]) == 3.0
    assert linear_trend_forecast([-5.0]) == 0.0
    # all-constant: no slope, the constant survives
    assert linear_trend_forecast([2.0, 2.0, 2.0]) == pytest.approx(2.0)
    # steep negative trend extrapolates below zero -> clamped
    assert linear_trend_forecast([9.0, 3.0, 0.1]) == pytest.approx(0.0)
    # vector histories clamp element-wise
    out = linear_trend_forecast([np.array([4.0, 1.0]), np.array([1.0, 2.0])])
    np.testing.assert_allclose(out, [0.0, 3.0])
    with pytest.raises(ValueError):
        linear_trend_forecast([])
    # a NaN observation cannot escape the sanity layer
    assert np.isfinite(linear_trend_forecast([1.0, np.nan]))


# ----------------------------------------------------- window validation bugs
def test_optimal_tiers_rejects_degenerate_windows():
    w = _workload(n=10, months=8)
    with pytest.raises(ValueError, match="non-empty"):
        optimal_tiers(w, TAB, 5, 5, (1, 2))
    with pytest.raises(ValueError, match="non-empty"):
        optimal_tiers(w, TAB, 6, 4, (1, 2))
    with pytest.raises(ValueError, match="outside"):
        optimal_tiers(w, TAB, 6, 9, (1, 2))
    with pytest.raises(ValueError, match="outside"):
        optimal_tiers(w, TAB, -1, 3, (1, 2))
    assert len(optimal_tiers(w, TAB, 4, 8, (1, 2))) == 10


def test_train_tier_predictor_validates_out_of_time_window():
    w = _workload(n=12, months=10)
    # t + h == n_months: the test window [t+h, min(t+2h, n)) is empty
    with pytest.raises(ValueError, match="train_month \\+ horizon"):
        train_tier_predictor(w, TAB, train_month=8, horizon=2)
    # t + h > n_months: previously an *inverted* slice
    with pytest.raises(ValueError, match="train_month \\+ horizon"):
        train_tier_predictor(w, TAB, train_month=9, horizon=2)
    with pytest.raises(ValueError, match="horizon"):
        train_tier_predictor(w, TAB, train_month=4, horizon=0)
    with pytest.raises(ValueError, match="train_month"):
        train_tier_predictor(w, TAB, train_month=-1, horizon=2)
    clf, rep = train_tier_predictor(w, TAB, train_month=6, horizon=2)
    assert rep.confusion.sum() == 12


def test_feature_matrix_clamps_early_months():
    w = _workload(n=8, months=12)
    H = 4
    X0 = feature_matrix(w, 0, H)
    # month 0: no history exists — read/write aggregates are all zero
    np.testing.assert_array_equal(X0[:, 2:], 0.0)
    np.testing.assert_allclose(
        X0[:, 0], [np.log1p(d.size_gb) for d in w.datasets])
    np.testing.assert_array_equal(X0[:, 1], [d.age_at(0) for d in w.datasets])
    # month 1: the window is [0,0,0, month-0 traffic]
    X1 = feature_matrix(w, 1, H)
    np.testing.assert_array_equal(X1[:, 2:5], 0.0)
    np.testing.assert_array_equal(X1[:, 5], [d.reads[0] for d in w.datasets])
    np.testing.assert_array_equal(X1[:, 6:9], 0.0)
    np.testing.assert_array_equal(X1[:, 9], [d.writes[0] for d in w.datasets])
    # a negative month clamps to month 0 instead of slicing from the END
    # of the trace (reads[0:-1] — the silent feature-poisoning bug)
    np.testing.assert_array_equal(feature_matrix(w, -1, H), X0)
    np.testing.assert_array_equal(feature_matrix(w, -3, H), X0)
    with pytest.raises(ValueError):
        feature_matrix(w, 3, -1)


# ----------------------------------------------------------- reliability layer
def test_random_forest_predict_proba():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(80, 3))
    y = (X[:, 0] + 0.1 * rng.normal(size=80) > 0).astype(int)
    clf = ml.RandomForest(n_trees=8, max_depth=4, task="clf", n_classes=2)
    clf.fit(X, y)
    p = clf.predict_proba(X)
    assert p.shape == (80, 2)
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-12)
    np.testing.assert_array_equal(p.argmax(1), clf.predict(X))
    reg = ml.RandomForest(n_trees=2, task="reg")
    reg.fit(X, X[:, 0])
    with pytest.raises(ValueError):
        reg.predict_proba(X)


def test_isotonic_calibrator_pava():
    # known instance: the (0.2 -> 1, 0.3 -> 0) violator pair pools to 0.5
    c = ml.IsotonicCalibrator().fit([0.1, 0.2, 0.3, 0.4], [0, 1, 0, 1])
    np.testing.assert_allclose(c.predict([0.1, 0.25, 0.4]), [0.0, 0.5, 1.0])
    # output is monotone non-decreasing over the whole unit interval
    grid = c.predict(np.linspace(0.0, 1.0, 101))
    assert (np.diff(grid) >= -1e-12).all()
    assert grid.min() >= 0.0 and grid.max() <= 1.0
    # perfectly separable scores reproduce the outcomes
    c2 = ml.IsotonicCalibrator().fit([0.1, 0.2, 0.8, 0.9], [0, 0, 1, 1])
    np.testing.assert_allclose(c2.predict([0.15, 0.85]), [0.0, 1.0])
    with pytest.raises(ValueError):
        ml.IsotonicCalibrator().fit([], [])
    with pytest.raises(ValueError):
        ml.IsotonicCalibrator().predict([0.5])


def test_forecaster_calibration_reliability():
    """The reliability layer may never make calibration worse than the raw
    forest votes (ECE on the held-out out-of-time slice), and the
    calibrated error stays inside a loose absolute tolerance."""
    w = _workload(n=120, months=20, seed=5)
    fc = _fitted(w, n_trees=16, seed=1)
    rep = fc.fit_report
    assert rep.calibrated
    assert rep.ece_cal <= rep.ece_raw + 0.05
    assert rep.ece_cal < 0.25
    # calibrated probabilities are probabilities
    p = fc.predict_p_hot(feature_matrix(w, 13, 4))
    assert p.min() >= 0.0 and p.max() <= 1.0


# ------------------------------------------------------- out-of-time fitting
def test_forecaster_fit_is_out_of_time():
    w = _workload()
    fc = _fitted(w)
    rep = fc.fit_report
    # no label window may peek at or beyond fit_month
    assert all(hi <= rep.fit_month for _, hi in rep.label_windows)
    # the calibration slice is strictly LATER than every training month
    assert min(rep.cal_months) > max(rep.train_months)
    with pytest.raises(ValueError, match="beyond the trace"):
        fc.fit(w, fit_month=99)
    with pytest.raises(ValueError, match="usable train months"):
        fc.fit(w, fit_month=3)        # only month 1 usable with horizon 2


def test_forecaster_refits_stay_out_of_time():
    w = _workload()
    fc = _fitted(w, refit_every=3)
    fc.bind(month0=11)
    hist = [np.array([d.reads[m] for d in w.datasets]) for m in range(11, 17)]
    for t in range(1, len(hist) + 1):
        fc.forecast_rho(hist[:t])
    assert fc.refits_, "refit cadence never fired"
    # after the last refit the report covers the refit month, and every
    # label window still ends at or before it (daemon never trains on
    # months it has not observed)
    assert fc.fit_report.fit_month == fc.refits_[-1]
    assert all(hi <= fc.fit_report.fit_month
               for _, hi in fc.fit_report.label_windows)
    assert fc.refits_ == sorted(set(fc.refits_))


# ------------------------------------------------------------- determinism
def _batch_problem(w, rho0, cfg):
    spans = np.array([d.size_gb for d in w.datasets])
    N = len(spans)
    return PlacementProblem(spans_gb=spans, rho=rho0,
                            current_tier=np.full(N, -1),
                            R=np.ones((N, 1)), D=np.zeros((N, 1)),
                            schemes=("none",), table=TAB, cfg=cfg)


def _run_forecast_daemon(seed):
    w = generate_workload(n_datasets=40, n_months=18, seed=seed,
                          pattern_probs=SPIKY)
    cfg = ScopeConfig(tier_whitelist=(1, 2), use_compression=False,
                      months=1.0)
    eng = PlacementEngine(TAB, cfg)
    fc = _fitted(w, seed=0)
    fc.bind(month0=11)
    rho0 = np.array([float(d.reads[11]) for d in w.datasets])
    d = ReoptimizationDaemon(eng, plan=eng.solve(_batch_problem(w, rho0, cfg)),
                             forecast_fn=fc.forecast_rho, rho_abs_tol=1.0)
    tiers, rhos = [], []
    for m in range(12, 17):
        obs = np.array([float(d_.reads[m - 1]) for d_ in w.datasets])
        d.step(obs, months=1.0)
        tiers.append(d.plan.assignment.tier.copy())
        rhos.append(np.asarray(d.plan.problem.rho, float).copy())
    return tiers, rhos


def test_forecast_driven_daemon_is_deterministic():
    """Same workload seed => bit-identical forecasts and plans."""
    t1, r1 = _run_forecast_daemon(21)
    t2, r2 = _run_forecast_daemon(21)
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a, b)
    # and every projected rho passed the sanity layer
    for r in r1:
        assert np.isfinite(r).all() and (r >= 0.0).all()


# ------------------------------------------------- forecast_fn=None parity
def test_forecast_none_batch_parity():
    """With forecast_fn=None the daemon IS the reactive engine chain."""
    w = _workload(n=30, months=16, seed=3)
    cfg = ScopeConfig(tier_whitelist=(1, 2), use_compression=False,
                      months=1.0)
    eng = PlacementEngine(TAB, cfg)
    rho0 = np.array([float(d.reads[10]) for d in w.datasets])
    plan = eng.solve(_batch_problem(w, rho0, cfg))
    daemon = ReoptimizationDaemon(eng, plan=plan, forecast_fn=None,
                                  rho_abs_tol=0.0, rho_rel_tol=0.25)
    ref_plan, held, ref = plan, np.zeros(plan.problem.n), \
        np.asarray(plan.problem.rho, float).copy()
    from repro.core.engine import drift_gate
    for m in range(11, 15):
        obs = np.array([float(d.reads[m]) for d in w.datasets])
        daemon.step(obs, months=1.0)
        held = held + 1.0
        mig = eng.reoptimize(ref_plan, obs, months_held=held,
                             rho_rel_tol=0.25, rho_abs_tol=0.0, rho_ref=ref)
        held = np.where(mig.moved, 0.0, held)
        drifted = drift_gate(obs, ref, 0.25, 0.0)
        ref = np.where(~mig.moved & ~drifted, ref, obs)
        ref_plan = mig.plan
        np.testing.assert_array_equal(daemon.plan.assignment.tier,
                                      ref_plan.assignment.tier)
        assert daemon.plan.report.total_cents == ref_plan.report.total_cents


def test_forecast_none_stream_parity():
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(5) for j in range(3)}
    batches = [[(("d0/0", "d0/1"), 300.0), (("d1/0",), 0.01)],
               [(("d0/0", "d0/1"), 0.5), (("d1/0",), 250.0)],
               [(("d0/0", "d0/1"), 0.5), (("d1/0",), 260.0)]]
    e1 = StreamingEngine(TAB, cfg, sizes, s_thresh=5.0, window=1,
                         drift_threshold=np.inf)
    migs = [e1.ingest_and_reoptimize(b, months=1.0) for b in batches]
    e2 = StreamingEngine(TAB, cfg, sizes, s_thresh=5.0, window=1,
                         drift_threshold=np.inf)
    d = ReoptimizationDaemon(e2, forecast_fn=None)
    reps = d.run(batches, months=1.0)
    for m, r in zip(migs, reps):
        assert r.spent_cents == m.total_move_cents
        assert r.steady_cents == m.plan.report.total_cents
    assert np.array_equal(e2.plan.assignment.tier, e1.plan.assignment.tier)


def test_forecast_none_fleet_parity():
    cfg = ScopeConfig(schemes=("none",), use_compression=False)
    rng = np.random.default_rng(4)
    pe, fe = PlacementEngine(TAB, cfg), FleetEngine(TAB, cfg)
    probs = []
    for n in (5, 8, 3):
        spans = rng.uniform(0.5, 40.0, n)
        probs.append(PlacementProblem(
            spans_gb=spans, rho=rng.gamma(1.0, 20.0, n),
            current_tier=np.full(n, -1), R=np.ones((n, 1)),
            D=np.zeros((n, 1)), schemes=("none",), table=TAB, cfg=cfg))
    fleet = ReoptimizationDaemon(fe, plans=[pe.solve(p) for p in probs],
                                 forecast_fn=None)
    singles = [ReoptimizationDaemon(pe, plan=pe.solve(p), forecast_fn=None)
               for p in probs]
    for cycle in range(3):
        rhos = [p.rho * rng.uniform(0.2, 4.0, p.n) for p in probs]
        fleet.step(rhos)
        for d, r in zip(singles, rhos):
            d.step(r)
        for t, d in enumerate(singles):
            np.testing.assert_array_equal(fleet.plans[t].assignment.tier,
                                          d.plan.assignment.tier)


# -------------------------------------------------------- projection algebra
def test_projection_interpolates_between_trend_and_hot_level(monkeypatch):
    w = _workload()
    fc = _fitted(w)
    fc.bind(month0=11)
    hist = [np.full(3, 10.0), np.full(3, 10.0), np.full(3, 10.0)]

    monkeypatch.setattr(fc, "predict_p_hot", lambda X: np.zeros(len(X)))
    base_only = AccessForecaster.forecast_rho(fc, hist)
    np.testing.assert_allclose(base_only, 10.0)   # p=0 -> pure trend

    fc.bind(month0=11)
    monkeypatch.setattr(fc, "predict_p_hot", lambda X: np.ones(len(X)))
    hot = AccessForecaster.forecast_rho(fc, hist)
    np.testing.assert_allclose(hot, np.maximum(10.0, fc.hot_rho_))

    fc.bind(month0=11)
    monkeypatch.setattr(fc, "predict_p_hot", lambda X: np.full(len(X), 0.5))
    mid = AccessForecaster.forecast_rho(fc, hist)
    np.testing.assert_allclose(mid, (base_only + hot) / 2.0)

    # the spike cap binds: even p=1 cannot exceed spike_mult * max(peak, hot)
    fc.bind(month0=11)
    monkeypatch.setattr(fc, "predict_p_hot", lambda X: np.ones(len(X)))
    out = AccessForecaster.forecast_rho(fc, hist)
    assert (out <= fc.spike_mult * np.maximum(10.0, fc.hot_rho_) + 1e-9).all()


def test_forecaster_untrained_falls_back_to_trend():
    fc = AccessForecaster(TAB, horizon=2, history=4)
    out = fc.forecast_rho([np.array([5.0, 1.0]), np.array([7.0, 0.5])])
    np.testing.assert_allclose(out, [9.0, 0.0])   # trend, clamped at 0


# ------------------------------------------------------- streaming protocol
class _RecordingFn:
    stream_context = True

    def __init__(self):
        self.calls = []

    def __call__(self, history, key=None, span_gb=None):
        self.calls.append((tuple(history), key, span_gb))
        return float(history[-1])


def test_stream_daemon_passes_context_to_opted_in_forecast_fn():
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(4) for j in range(3)}
    eng = StreamingEngine(TAB, cfg, sizes, s_thresh=5.0, window=1,
                          drift_threshold=np.inf)
    fn = _RecordingFn()
    d = ReoptimizationDaemon(eng, forecast_fn=fn)
    d.step([(("d0/0", "d0/1"), 100.0), (("d1/0",), 2.0)], months=1.0)
    assert fn.calls, "context forecast_fn never invoked"
    for hist, key, span in fn.calls:
        assert key is not None and span is not None and span > 0.0
        assert len(hist) >= 1
    # a plain callable (no stream_context) still gets history only
    eng2 = StreamingEngine(TAB, cfg, sizes, s_thresh=5.0, window=1,
                           drift_threshold=np.inf)
    d2 = ReoptimizationDaemon(eng2, forecast_fn=lambda h: float(h[-1]))
    rep = d2.step([(("d0/0", "d0/1"), 100.0)], months=1.0)
    assert rep.n_partitions >= 1


def test_stream_forecast_fn_drives_streaming_daemon():
    w = _workload(n=20, months=16, seed=9)
    fc = _fitted(w)
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 1.0 for i in range(4) for j in range(2)}
    eng = StreamingEngine(TAB, cfg, sizes, s_thresh=5.0, window=1,
                          drift_threshold=np.inf)
    d = ReoptimizationDaemon(eng, forecast_fn=fc.stream_forecast_fn())
    for rho in (200.0, 150.0, 0.5):
        rep = d.step([(("d0/0", "d0/1"), rho), (("d1/0",), 1.0)], months=1.0)
        assert np.isfinite(rep.steady_cents)
    rho_now = np.asarray(eng.plan.problem.rho, float)
    assert np.isfinite(rho_now).all() and (rho_now >= 0.0).all()


# ------------------------------------------------------------ fleet wiring
def test_forecast_fn_sequence_is_fleet_only():
    cfg = ScopeConfig(use_compression=False, schemes=("none",))
    eng = PlacementEngine(TAB, cfg)
    prob = PlacementProblem(spans_gb=np.array([1.0]), rho=np.array([1.0]),
                            current_tier=np.array([-1]), R=np.ones((1, 1)),
                            D=np.zeros((1, 1)), schemes=("none",),
                            table=TAB, cfg=cfg)
    plan = eng.solve(prob)
    with pytest.raises(ValueError, match="fleet"):
        ReoptimizationDaemon(eng, plan=plan,
                             forecast_fn=[lambda h: h[-1]])
    fe = FleetEngine(TAB, cfg)
    with pytest.raises(ValueError, match="one callable per"):
        ReoptimizationDaemon(fe, plans=[plan, plan],
                             forecast_fn=[lambda h: h[-1]])


def test_fleet_daemon_per_tenant_forecasters():
    """A forecast_fn list applies each tenant's own forecaster; with
    identity forecasters the trajectory matches forecast_fn=None."""
    cfg = ScopeConfig(schemes=("none",), use_compression=False)
    rng = np.random.default_rng(6)
    pe, fe = PlacementEngine(TAB, cfg), FleetEngine(TAB, cfg)
    probs = []
    for n in (4, 6):
        probs.append(PlacementProblem(
            spans_gb=rng.uniform(0.5, 30.0, n), rho=rng.gamma(1.0, 20.0, n),
            current_tier=np.full(n, -1), R=np.ones((n, 1)),
            D=np.zeros((n, 1)), schemes=("none",), table=TAB, cfg=cfg))
    ident = [lambda h: np.asarray(h[-1], float) for _ in probs]
    d1 = ReoptimizationDaemon(fe, plans=[pe.solve(p) for p in probs],
                              forecast_fn=ident)
    d2 = ReoptimizationDaemon(fe, plans=[pe.solve(p) for p in probs],
                              forecast_fn=None)
    for cycle in range(3):
        rhos = [p.rho * rng.uniform(0.3, 3.0, p.n) for p in probs]
        d1.step(rhos)
        d2.step(rhos)
        for t in range(len(probs)):
            np.testing.assert_array_equal(d1.plans[t].assignment.tier,
                                          d2.plans[t].assignment.tier)
