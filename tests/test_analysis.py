"""Unit tests for the HLO analyzer + cost-model invariants (hypothesis)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.analysis import hlo_stats
from repro.core.costs import Weights, azure_table, cost_tensor, latency_feasible

MINI_HLO = """\
HloModule test

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%y), channel_id=1, replica_groups=[2,4]<=[8]
  %one = s32[] constant(1)
  %j = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%j, %ar)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


def test_hlo_stats_while_trip_multiplication():
    st_ = hlo_stats.analyze(MINI_HLO)
    # dot: 2 * 8*16 * 16 flops, executed 12 times
    assert st_.flops == pytest.approx(12 * 2 * 8 * 16 * 16)
    # all-reduce operand bytes: 8*16*4 per trip, 12 trips
    assert st_.coll_bytes == pytest.approx(12 * 8 * 16 * 4)
    assert st_.coll_by_kind["all-reduce"] == st_.coll_bytes
    assert st_.n_collectives == 12


def test_hlo_stats_group_size_parsing():
    assert hlo_stats._group_size("replica_groups=[2,4]<=[8]") == 4
    assert hlo_stats._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert hlo_stats._group_size("no groups here") == 1


def test_hlo_stats_trip_count_fusion_wrapped():
    text = MINI_HLO.replace(
        "ROOT %lt = pred[] compare(%i, %c), direction=LT",
        "ROOT %lt = pred[] fusion(%i, %c), kind=kLoop, calls=%wc")
    st_ = hlo_stats.analyze(text)
    assert st_.flops == pytest.approx(12 * 2 * 8 * 16 * 16)


# ------------------------------------------------------ cost-model invariants
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_better_compression_never_costs_more(seed):
    """For a fixed tier, raising R (same D) weakly decreases cost."""
    rng = np.random.default_rng(seed)
    table = azure_table()
    N = 4
    spans = rng.uniform(0.1, 100, N)
    rho = rng.gamma(1.0, 10.0, N)
    cur = np.full(N, -1)
    R1 = rng.uniform(1.0, 4.0, (N, 1))
    R2 = R1 * rng.uniform(1.0, 2.0, (N, 1))      # strictly better ratios
    D = rng.uniform(0.0, 1.0, (N, 1))
    c1 = cost_tensor(spans, rho, cur, R1, D, table, Weights())
    c2 = cost_tensor(spans, rho, cur, R2, D, table, Weights())
    assert (c2 <= c1 + 1e-9).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_latency_feasibility_monotone_in_threshold(seed):
    rng = np.random.default_rng(seed)
    table = azure_table()
    D = rng.uniform(0, 5, (3, 2))
    t_lo = rng.uniform(0, 2, 3)
    f_lo = latency_feasible(D, t_lo, table)
    f_hi = latency_feasible(D, t_lo + rng.uniform(0, 5, 3), table)
    assert (f_lo <= f_hi).all()                  # relaxing T never removes


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_pushdown_fraction_reduces_access_cost(seed):
    """Paper §IV-A: pushdown-amenable queries drop read+decomp terms."""
    rng = np.random.default_rng(seed)
    table = azure_table()
    N = 3
    spans = rng.uniform(0.1, 50, N)
    rho = rng.gamma(1.0, 10.0, N) + 1.0
    cur = np.full(N, -1)
    R = rng.uniform(1.0, 4.0, (N, 2))
    D = rng.uniform(0.01, 2.0, (N, 2))
    c0 = cost_tensor(spans, rho, cur, R, D, table, pushdown_fraction=0.0)
    c5 = cost_tensor(spans, rho, cur, R, D, table, pushdown_fraction=0.5)
    c1 = cost_tensor(spans, rho, cur, R, D, table, pushdown_fraction=1.0)
    assert (c5 <= c0 + 1e-9).all() and (c1 <= c5 + 1e-9).all()
