"""Fleet solver: batched vs per-tenant bit-parity, shared fleet-wide caps,
ragged padding invariance, fleet daemon parity, N=0 corner regressions."""

import dataclasses

import numpy as np
import pytest

from repro.core.costs import (ProviderCostTable, Weights, azure_table,
                              cost_tensor, latency_feasible,
                              multi_cloud_table)
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.core.fleet import FleetEngine
from repro.core.optassign import (capacitated_assign, capacitated_assign_batch,
                                  greedy_assign, greedy_assign_batch)


# ----------------------------------------------------------------- fixtures
def _tenant_instance(rng, N, K=3):
    """One tenant's (cost, feas, stored, cap) with tier caps that bind."""
    table = azure_table()
    spans = rng.uniform(0.5, 50.0, max(N, 1))[:N]
    rho = rng.gamma(1.0, 20.0, max(N, 1))[:N]
    cur = rng.integers(-1, table.num_tiers, max(N, 1))[:N]
    R = np.concatenate([np.ones((max(N, 1), 1)),
                        rng.uniform(1.2, 6.0, (max(N, 1), K - 1))], 1)[:N]
    D = np.concatenate([np.zeros((max(N, 1), 1)),
                        rng.uniform(0.01, 3.0, (max(N, 1), K - 1))], 1)[:N]
    T = rng.choice([0.1, 1.0, 5.0, np.inf], max(N, 1))[:N]
    cost = cost_tensor(spans, rho, cur, R, D, table, Weights(), months=6)
    feas = latency_feasible(D, T, table)
    stored = np.repeat((spans[:, None] / R)[:, None, :], table.num_tiers, 1)
    tot = spans.sum() if N else 1.0
    cap = np.array([tot / 3, tot / 2, tot, np.inf])
    return cost, feas, stored, cap


def _ragged_fleet(seed=0, Ns=(5, 9, 3, 9, 1, 8, 6)):
    rng = np.random.default_rng(seed)
    return [_tenant_instance(rng, n) for n in Ns]


def _make_problem(rng, N, table, cfg, K=3):
    spans = rng.uniform(0.5, 50.0, N)
    rho = rng.gamma(1.0, 20.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 3.0, (N, K - 1))], 1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=list(cfg.schemes)[:K], table=table,
                            cfg=cfg)


def _identical(a, b):
    return (np.array_equal(a.tier, b.tier)
            and np.array_equal(a.scheme, b.scheme)
            and a.cost == b.cost and a.feasible == b.feasible)


# -------------------------------------------------------------- core parity
def test_batch_bit_identical_to_per_tenant_solves():
    """THE fleet parity pin: no shared rows => every tenant's assignment,
    cost, and feasibility bit-identical to its own capacitated_assign."""
    fleet = _ragged_fleet()
    singles = [capacitated_assign(c, f, s, cap) for c, f, s, cap in fleet]
    batch = capacitated_assign_batch([x[0] for x in fleet],
                                     [x[1] for x in fleet],
                                     [x[2] for x in fleet],
                                     [x[3] for x in fleet])
    assert batch.feasible
    for single, got in zip(singles, batch.assignments):
        assert _identical(single, got)
    assert batch.cost == float(sum(s.cost for s in singles))


def test_greedy_batch_bit_identical():
    fleet = _ragged_fleet(seed=7)
    singles = [greedy_assign(c, f) for c, f, _, _ in fleet]
    batch = greedy_assign_batch([x[0] for x in fleet], [x[1] for x in fleet])
    for single, got in zip(singles, batch):
        assert _identical(single, got)


def test_ragged_padding_invariance_empty_tenant_changes_nothing():
    """Adding an N=0 tenant anywhere in the batch is a no-op for everyone
    else — padded rows carry zero cost and zero usage."""
    fleet = _ragged_fleet(seed=1)
    base = capacitated_assign_batch([x[0] for x in fleet],
                                    [x[1] for x in fleet],
                                    [x[2] for x in fleet],
                                    [x[3] for x in fleet])
    rng = np.random.default_rng(9)
    empty = _tenant_instance(rng, 0)
    for pos in (0, len(fleet) // 2, len(fleet)):
        fleet2 = fleet[:pos] + [empty] + fleet[pos:]
        got = capacitated_assign_batch([x[0] for x in fleet2],
                                       [x[1] for x in fleet2],
                                       [x[2] for x in fleet2],
                                       [x[3] for x in fleet2])
        others = got.assignments[:pos] + got.assignments[pos + 1:]
        for a, b in zip(base.assignments, others):
            assert _identical(a, b)
        inserted = got.assignments[pos]
        assert inserted.feasible and inserted.cost == 0.0
        assert inserted.tier.shape == (0,)


def test_shared_inf_caps_preserve_bit_parity():
    """Shared rows with infinite caps never couple anything: still
    bit-identical to per-tenant solves (the zero-multiplier pin)."""
    fleet = _ragged_fleet(seed=2)
    L = fleet[0][0].shape[1]
    singles = [capacitated_assign(c, f, s, cap) for c, f, s, cap in fleet]
    batch = capacitated_assign_batch(
        [x[0] for x in fleet], [x[1] for x in fleet],
        [x[2] for x in fleet], [x[3] for x in fleet],
        shared_tier_groups=np.zeros(L, int),
        shared_capacity_gb=np.array([np.inf]))
    for single, got in zip(singles, batch.assignments):
        assert _identical(single, got)
    assert batch.shared_use_gb is not None


def test_shared_cap_binds_fleet_wide_where_per_tenant_solves_violate():
    """A global cap on one tier that every per-tenant solve (which cannot
    see the other tenants) collectively violates: the fleet solve respects
    it, stays feasible, and pays at least the uncoupled cost."""
    fleet = _ragged_fleet(seed=3)
    L = fleet[0][0].shape[1]
    uncoupled = capacitated_assign_batch([x[0] for x in fleet],
                                         [x[1] for x in fleet],
                                         [x[2] for x in fleet],
                                         [x[3] for x in fleet])
    # fleet-wide usage per tier under the uncoupled optimum
    use = np.zeros(L)
    for (c, f, s, cap), a in zip(fleet, uncoupled.assignments):
        np.add.at(use, a.tier.astype(int),
                  s[np.arange(len(a.tier)), a.tier.astype(int),
                    a.scheme.astype(int)])
    tgt = int(use.argmax())
    scap = np.full(L, np.inf)
    scap[tgt] = 0.5 * use[tgt]          # binds: fleet must shed half
    coupled = capacitated_assign_batch(
        [x[0] for x in fleet], [x[1] for x in fleet],
        [x[2] for x in fleet], [x[3] for x in fleet],
        shared_tier_groups=np.arange(L),
        shared_capacity_gb=scap)
    assert coupled.feasible
    assert coupled.shared_use_gb[tgt] <= scap[tgt] + 1e-9
    assert coupled.cost >= uncoupled.cost - 1e-9
    # per-tenant solves cannot coordinate: summed usage violates the cap
    assert use[tgt] > scap[tgt]


def test_shared_cap_infeasible_when_below_minimum_footprint():
    fleet = _ragged_fleet(seed=4, Ns=(4, 6))
    L = fleet[0][0].shape[1]
    batch = capacitated_assign_batch(
        [x[0] for x in fleet], [x[1] for x in fleet],
        [x[2] for x in fleet], [x[3] for x in fleet],
        shared_tier_groups=np.zeros(L, int),
        shared_capacity_gb=np.array([1e-6]))   # below any possible footprint
    assert not batch.feasible and batch.cost == float("inf")


# ----------------------------------------------------------- corner cases
def test_zero_partition_tenant_and_empty_fleet():
    """step0 / argmin padding hazards: N=0 tenants, empty fleets, and
    all-infinite capacities must not divide by empty means or reshape
    zero-size arrays."""
    rng = np.random.default_rng(5)
    empty = _tenant_instance(rng, 0)
    # single-tenant N=0 (direct and batched)
    single = capacitated_assign(*empty)
    assert single.feasible and single.cost == 0.0
    assert greedy_assign(empty[0], empty[1]).feasible
    got = capacitated_assign_batch([empty[0]], [empty[1]], [empty[2]],
                                   [empty[3]])
    assert got.feasible and got.cost == 0.0
    # fleet of zero tenants
    out = capacitated_assign_batch([], [], [], np.ones(4))
    assert out.feasible and out.cost == 0.0 and out.assignments == []


def test_all_infeasible_tenant_reported_not_crashed():
    L, K = 4, 2
    cost = np.ones((3, L, K))
    feas = np.zeros((3, L, K), bool)
    stored = np.ones((3, L, K))
    cap = np.full(L, np.inf)
    got = capacitated_assign_batch([cost], [feas], [stored], [cap])
    assert not got.feasible and got.cost == float("inf")
    # all-infinite caps + all-infeasible cells is the step0 0/0 corner
    single = capacitated_assign(cost, feas, stored, cap)
    assert not single.feasible


# ------------------------------------------------------------ FleetEngine
def test_fleet_engine_solve_matches_placement_engine():
    table = azure_table()
    cfg = ScopeConfig(schemes=("none", "lz4", "zstd3"))
    rng = np.random.default_rng(6)
    probs = [_make_problem(rng, n, table, cfg) for n in (6, 9, 4, 7)]
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    fp = fe.solve(probs)
    for p, plan in zip(probs, fp.plans):
        single = pe.solve(p)
        assert _identical(single.assignment, plan.assignment)
        assert single.report.total_cents == plan.report.total_cents
    assert fp.total_cents == pytest.approx(
        sum(pe.solve(p).report.total_cents for p in probs))


def test_fleet_engine_capacitated_solve_and_reoptimize_parity():
    table = azure_table()
    caps = np.array([25.0, 50.0, 300.0, np.inf])
    cfg = ScopeConfig(schemes=("none", "lz4", "zstd3"), capacity_gb=caps)
    rng = np.random.default_rng(7)
    probs = [_make_problem(rng, n, table, cfg) for n in (6, 9, 4)]
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    fp = fe.solve(probs)
    singles = [pe.solve(p) for p in probs]
    for single, plan in zip(singles, fp.plans):
        assert _identical(single.assignment, plan.assignment)
    new_rhos = [p.rho * rng.uniform(0.2, 4.0, p.n) for p in probs]
    migs, fleet = fe.reoptimize(fp.plans, new_rhos, months_held=2.0)
    for single, mig, rho in zip(singles, migs, new_rhos):
        ref = pe.reoptimize(single, rho, months_held=2.0)
        assert np.array_equal(ref.moved, mig.moved)
        assert ref.migration_cents == mig.migration_cents
        assert ref.penalty_cents == mig.penalty_cents
        assert ref.plan.report.total_cents == mig.plan.report.total_cents


def test_fleet_engine_shared_provider_cap_couples_tenants():
    """fleet_provider_capacity_gb: a provider's global capacity binds the
    fleet total, not each tenant separately."""
    az = azure_table()
    table = multi_cloud_table([ProviderCostTable("alpha", az),
                               ProviderCostTable("beta", az)])
    cfg = ScopeConfig(schemes=("none", "lz4"))
    rng = np.random.default_rng(8)
    probs = [_make_problem(rng, n, table, cfg, K=2) for n in (5, 8, 6)]
    fe0 = FleetEngine(table, cfg)
    base = fe0.solve(probs)
    prov = np.asarray(table.provider_of_tier, int)
    use_p = np.zeros(2)
    for p, plan in zip(probs, base.plans):
        tier = plan.assignment.tier.astype(int)
        np.add.at(use_p, prov[tier], plan.stored_gb)
    big = int(use_p.argmax())
    name = table.provider_names[big]
    fe = FleetEngine(table, cfg,
                     fleet_provider_capacity_gb={name: 0.6 * use_p[big]})
    assert fe.coupled
    fp = fe.solve(probs)
    assert fp.fleet.feasible
    got = np.zeros(2)
    for p, plan in zip(probs, fp.plans):
        tier = plan.assignment.tier.astype(int)
        np.add.at(got, prov[tier], plan.stored_gb)
    assert got[big] <= 0.6 * use_p[big] + 1e-9
    assert fp.total_cents >= base.total_cents - 1e-9


def test_fleet_engine_validates_provider_names():
    table = azure_table()
    cfg = ScopeConfig()
    with pytest.raises(ValueError, match="MultiCloudCostTable"):
        FleetEngine(table, cfg, fleet_provider_capacity_gb={"x": 1.0})


def test_fleet_engine_mesh_single_device_matches_unsharded():
    """mesh= with one device takes the plain jitted path — same results."""
    import jax
    from jax.sharding import Mesh
    fleet = _ragged_fleet(seed=10, Ns=(5, 7, 2))
    base = capacitated_assign_batch([x[0] for x in fleet],
                                    [x[1] for x in fleet],
                                    [x[2] for x in fleet],
                                    [x[3] for x in fleet])
    mesh = Mesh(np.array(jax.devices()[:1]), ("tenants",))
    got = capacitated_assign_batch([x[0] for x in fleet],
                                   [x[1] for x in fleet],
                                   [x[2] for x in fleet],
                                   [x[3] for x in fleet], mesh=mesh)
    for a, b in zip(base.assignments, got.assignments):
        assert _identical(a, b)


# ------------------------------------------------------------ fleet daemon
def test_fleet_daemon_infinite_budget_matches_independent_daemons():
    """Acceptance pin: a fleet daemon cycle with unbounded budget is
    bit-identical to T independent batch-mode daemons."""
    table = azure_table()
    cfg = ScopeConfig(schemes=("none", "lz4"))
    rng = np.random.default_rng(11)
    probs = [_make_problem(rng, n, table, cfg, K=2) for n in (6, 9, 4)]
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    singles = [pe.solve(p) for p in probs]
    fleet_daemon = ReoptimizationDaemon(fe, plans=[pe.solve(p)
                                                   for p in probs])
    daemons = [ReoptimizationDaemon(pe, plan=s) for s in singles]
    for cycle in range(4):
        rhos = [p.rho * rng.uniform(0.2, 4.0, p.n) for p in probs]
        rep = fleet_daemon.step(rhos)
        reps = [d.step(r) for d, r in zip(daemons, rhos)]
        assert rep.n_tenants == len(probs)
        assert rep.n_selected == sum(r.n_selected for r in reps)
        assert rep.spent_cents == pytest.approx(
            sum(r.spent_cents for r in reps), abs=1e-9)
        assert rep.steady_cents == pytest.approx(
            sum(r.steady_cents for r in reps), abs=1e-9)
        for t, d in enumerate(daemons):
            assert np.array_equal(fleet_daemon.plans[t].assignment.tier,
                                  d.plan.assignment.tier)
            assert np.array_equal(fleet_daemon.plans[t].assignment.scheme,
                                  d.plan.assignment.scheme)


def test_fleet_daemon_shared_budget_caps_whole_fleet():
    table = azure_table()
    cfg = ScopeConfig(schemes=("none", "lz4"))
    rng = np.random.default_rng(12)
    probs = [_make_problem(rng, n, table, cfg, K=2) for n in (8, 8, 8)]
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    cap = 0.5
    d = ReoptimizationDaemon(fe, plans=[pe.solve(p) for p in probs],
                             budget=MigrationBudget(cents_per_cycle=cap))
    for cycle in range(3):
        rhos = [p.rho * rng.uniform(0.1, 8.0, p.n) for p in probs]
        rep = d.step(rhos)
        assert rep.spent_cents <= cap + 1e-9
        assert rep.n_tenants == 3


def test_fleet_daemon_rejects_wrong_arguments():
    table = azure_table()
    cfg = ScopeConfig(schemes=("none", "lz4"))
    rng = np.random.default_rng(13)
    prob = _make_problem(rng, 4, table, cfg, K=2)
    pe = PlacementEngine(table, cfg)
    fe = FleetEngine(table, cfg)
    plan = pe.solve(prob)
    with pytest.raises(ValueError, match="plans="):
        ReoptimizationDaemon(fe)
    with pytest.raises(ValueError, match="plans="):
        ReoptimizationDaemon(fe, plan=plan)
    with pytest.raises(ValueError, match="fleet mode"):
        ReoptimizationDaemon(pe, plan=plan, plans=[plan])
    with pytest.raises(ValueError, match="batch-mode only"):
        ReoptimizationDaemon(fe, plans=[plan], amortize_oversized=True)


def test_chunked_scan_dispatch_preserves_bit_parity(monkeypatch):
    """Fleets larger than _FLEET_CHUNK run the lean scan in fixed-size
    chunks (one compiled shape for any T); chunk boundaries and the dummy
    pad tenants in the last chunk must not perturb a single bit."""
    from repro.core import optassign as oa
    monkeypatch.setattr(oa, "_FLEET_CHUNK", 4)   # 11 tenants -> 3 chunks
    fleet = _ragged_fleet(seed=21, Ns=(5, 9, 3, 9, 1, 8, 6, 4, 7, 2, 5))
    singles = [capacitated_assign(c, f, s, cap) for c, f, s, cap in fleet]
    batch = capacitated_assign_batch([x[0] for x in fleet],
                                     [x[1] for x in fleet],
                                     [x[2] for x in fleet],
                                     [x[3] for x in fleet])
    for single, got in zip(singles, batch.assignments):
        assert _identical(single, got)
