"""StreamingEngine: rolling-window ingest → compact → reoptimize lifecycle,
tier-state carry-over by file-set identity, and TieredStore.sync_plan.
"""

import numpy as np
import pytest

from repro.core.costs import azure_table
from repro.core.engine import ScopeConfig, StreamingEngine, compredict_rd_fn
from repro.data import workloads as wl
from repro.storage.store import TieredStore


def _engine(**kw):
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(6) for j in range(4)}
    return StreamingEngine(azure_table(), cfg, sizes, s_thresh=5.0, **kw), sizes


def _hot_cold_batch(hot=400.0, cold=0.01):
    """Two datasets with wildly different traffic — forces distinct tiers."""
    return [
        (("d0/0", "d0/1"), hot),
        (("d1/0", "d1/1", "d1/2"), cold),
    ]


def test_first_batch_places_everything_as_new():
    eng, _ = _engine()
    mig = eng.ingest_and_reoptimize(_hot_cold_batch())
    assert (mig.old_tier == -1).all()
    assert mig.n_moved == 0 and mig.migration_cents == 0.0
    assert mig.penalty_cents == 0.0
    r = eng.history[-1]
    assert r.n_new == r.n_partitions == 2
    # hot data lands on a faster tier than cold data
    tiers = {tuple(sorted(p.files)): int(t) for p, t in
             zip(mig.plan.problem.partitions, mig.plan.assignment.tier)}
    assert tiers[("d0/0", "d0/1")] < tiers[("d1/0", "d1/1", "d1/2")]


def test_steady_stream_is_idempotent():
    """window=1 makes repeated identical batches a no-drift stream: after
    the first placement no partition ever moves and nothing is charged."""
    eng, _ = _engine(window=1, drift_threshold=np.inf)
    eng.ingest_and_reoptimize(_hot_cold_batch())
    for _ in range(3):
        mig = eng.ingest_and_reoptimize(_hot_cold_batch())
        assert mig.n_moved == 0
        assert mig.migration_cents == 0.0 and mig.penalty_cents == 0.0
        assert (mig.new_tier == mig.old_tier).all()


def test_drift_triggers_bounded_migration_and_state_carry():
    """Cold->hot drift moves exactly the drifted partition; its survivor
    keeps tier identity across the fold."""
    eng, _ = _engine(window=1, drift_threshold=np.inf)
    mig0 = eng.ingest_and_reoptimize(_hot_cold_batch())
    cold_files = frozenset({"d1/0", "d1/1", "d1/2"})
    # same structure, cold dataset turns hot
    mig = eng.ingest_and_reoptimize(_hot_cold_batch(hot=400.0, cold=500.0))
    idx = [i for i, p in enumerate(mig.plan.problem.partitions)
           if p.files == cold_files]
    assert len(idx) == 1
    i = idx[0]
    assert mig.old_tier[i] >= 0, "survivor must carry its placement state"
    assert mig.moved[i] and mig.new_tier[i] < mig.old_tier[i]
    assert mig.migration_cents > 0.0
    # the untouched hot partition did not move
    other = [i2 for i2 in range(len(mig.moved)) if i2 != i]
    assert not mig.moved[other].any()
    assert mig0.plan.problem.n == mig.plan.problem.n


def test_migration_charged_once_then_stable():
    """After paying for a drift-induced move, re-ingesting the same rates
    charges nothing further (hysteresis at the stream level)."""
    eng, _ = _engine(window=1, drift_threshold=np.inf)
    eng.ingest_and_reoptimize(_hot_cold_batch())
    drifted = _hot_cold_batch(hot=400.0, cold=500.0)
    mig1 = eng.ingest_and_reoptimize(drifted)
    assert mig1.n_moved >= 1
    for _ in range(2):
        mig = eng.ingest_and_reoptimize(drifted)
        assert mig.n_moved == 0
        assert mig.migration_cents == 0.0 and mig.penalty_cents == 0.0


def test_minimum_stay_clock_carries_across_batches():
    """months accumulate for unmoved partitions, so early-deletion pricing
    sees the true residency, not per-batch resets."""
    eng, _ = _engine(window=1, drift_threshold=np.inf)
    eng.ingest_and_reoptimize(_hot_cold_batch(), months=1.0)
    eng.ingest_and_reoptimize(_hot_cold_batch(), months=1.0)
    held = {tuple(sorted(k)): sts[0].months_held
            for k, sts in eng._held.items()}
    assert held[("d0/0", "d0/1")] == pytest.approx(1.0)
    eng.ingest_and_reoptimize(_hot_cold_batch(), months=2.5)
    held = {tuple(sorted(k)): sts[0].months_held
            for k, sts in eng._held.items()}
    assert held[("d0/0", "d0/1")] == pytest.approx(3.5)


def test_enterprise_trace_end_to_end_with_store_sync():
    """Month-by-month enterprise trace through StreamingEngine, mirrored
    into a metered TieredStore via sync_plan."""
    w = wl.generate_workload(n_datasets=40, n_months=6, seed=5)
    rng = np.random.default_rng(1)
    sizes = wl.dataset_file_sizes(w)
    cfg = ScopeConfig(use_compression=False, months=1.0)
    eng = StreamingEngine(azure_table(), cfg, sizes, drift_threshold=0.5)
    store = TieredStore(azure_table())
    for batch in wl.stream_query_log(w, rng):
        if not batch:
            continue
        mig = eng.ingest_and_reoptimize(batch, months=1.0)
        parts = mig.plan.problem.partitions
        payloads = [b"x" * max(int(p.span * 1e3), 1) for p in parts]
        stats = store.sync_plan(mig.plan, payloads=payloads)
        # store ends the month holding exactly the plan's partitions
        assert len(store.keys()) == len(parts)
        for n, key in enumerate(store.plan_keys(mig.plan)):
            assert store.tier_of(key) == int(mig.plan.assignment.tier[n])
        # sync touches only what the migration plan says moved
        assert stats["moved"] + stats["reencoded"] >= mig.n_moved - \
            stats["deleted"] - stats["put"]
        store.advance_months(1.0)
    assert eng.history and eng.history[-1].n_partitions > 0
    assert store.meter.total_cents > 0.0


def test_empty_batches_are_noop_and_do_not_freeze_s_thresh():
    """An empty first batch must neither crash nor lock in a degenerate
    span cap; the first real batch still sizes s_thresh from its medians."""
    eng, _ = _engine()
    eng._s_thresh = None                    # force batch-derived sizing
    mig = eng.ingest_and_reoptimize([])
    assert mig.plan.problem.n == 0 and mig.n_moved == 0
    assert eng.partitioner is None          # creation deferred
    assert eng.history[-1].n_partitions == 0
    mig = eng.ingest_and_reoptimize(_hot_cold_batch())
    assert mig.plan.problem.n == 2
    assert np.isfinite(eng.partitioner.s_thresh)


# fixed decompression-speed labels (sec/GB) for the fitted predictor:
# the real `measure` times actual decompress calls, so the fit — and the
# scheme choice downstream of it — wobbles with wall-clock noise.  These
# tests assert backend parity and that compression engages, neither of
# which should depend on how loaded the CI host is.  Ratios stay real.
_DET_DSPEED = {"zstd-3": 1.0, "zlib-1": 3.0, "zlib-6": 4.0}


def _compredict_stream_fixture():
    """Small TPC-H stream with a fitted predictor wired in via rd_fn."""
    from repro.core import compredict as cp_mod
    from repro.core.compredict import CompressionPredictor, query_samples
    from repro.data import tpch
    from repro.storage.codecs import (CodecMeasurement, available_schemes,
                                      codec_by_name)

    db = tpch.generate(scale_rows=600, seed=9)
    queries = tpch.generate_queries(db, n_per_template=2, seed=10)
    parts, file_rows = tpch.partitions_from_queries(db, queries)
    schemes = available_schemes(("none", "zstd-3", "zlib-6", "zlib-1"))

    real_measure = cp_mod.measure

    def det_measure(codec, raw, repeats=1):
        m = real_measure(codec, raw, repeats=repeats)
        return CodecMeasurement(
            ratio=m.ratio, compress_sec=0.0,
            decompress_sec_per_gb=_DET_DSPEED.get(codec.name, 0.0))

    cp_mod.measure = det_measure
    try:
        pred = CompressionPredictor(model_name="SVR").fit(
            query_samples(queries, db.tables, max_rows=250)[:30],
            layouts=("col",),
            codecs=[codec_by_name(s) for s in schemes if s != "none"])
    finally:
        cp_mod.measure = real_measure
    sizes = {f: file_rows[f][0].select(file_rows[f][1]).nbytes("col") / 1e9
             for p in parts for f in p.files}
    batches = [[(tuple(sorted(p.files)), p.rho) for p in parts[:4]],
               [(tuple(sorted(p.files)), p.rho * (3.0 if i % 2 else 1.0))
                for i, p in enumerate(parts[:6])]]
    return pred, file_rows, sizes, schemes, batches


def test_streaming_feature_backend_parity():
    """Streaming re-prediction through compredict_rd_fn: the Pallas and
    NumPy feature backends yield the identical per-batch placement."""
    pred, file_rows, sizes, schemes, batches = _compredict_stream_fixture()
    migs = {}
    for backend in ("numpy", "pallas"):
        cfg = ScopeConfig(months=1.0, schemes=schemes)
        eng = StreamingEngine(
            azure_table(), cfg, sizes, s_thresh=5.0,
            rd_fn=compredict_rd_fn(pred, file_rows, layout="col",
                                   feature_backend=backend))
        migs[backend] = [eng.ingest_and_reoptimize(b, months=1.0)
                        for b in batches]
    for m_np, m_pal in zip(migs["numpy"], migs["pallas"]):
        np.testing.assert_array_equal(m_pal.plan.assignment.tier,
                                      m_np.plan.assignment.tier)
        np.testing.assert_array_equal(m_pal.plan.assignment.scheme,
                                      m_np.plan.assignment.scheme)
        assert m_pal.plan.report.total_cents == pytest.approx(
            m_np.plan.report.total_cents, rel=1e-4)
    # compression actually engages on the stream (schemes beyond 'none')
    assert (migs["numpy"][-1].plan.assignment.scheme > 0).any()


def test_compredict_rd_fn_caches_surviving_partitions(monkeypatch):
    """Partitions that survive across batches must not be re-materialized
    or re-serialized by compredict_rd_fn (hot-path cost)."""
    from repro.core import engine as eng_mod
    pred, file_rows, sizes, schemes, batches = _compredict_stream_fixture()
    calls = []
    real = eng_mod.PartitionStage._partition_tables

    def spy(parts, fr):
        calls.append(len(parts))
        return real(parts, fr)

    monkeypatch.setattr(eng_mod.PartitionStage, "_partition_tables",
                        staticmethod(spy))
    cfg = ScopeConfig(months=1.0, schemes=schemes)
    eng = StreamingEngine(azure_table(), cfg, sizes, s_thresh=5.0,
                          window=1, drift_threshold=np.inf,
                          rd_fn=compredict_rd_fn(pred, file_rows))
    eng.ingest_and_reoptimize(batches[0], months=1.0)
    assert len(calls) == 1 and calls[0] > 0  # first batch: all materialized
    eng.ingest_and_reoptimize(batches[0], months=1.0)
    assert len(calls) == 1                   # identical batch: pure cache hit


def _two_provider_table():
    """Hand-built 2-provider space where hot data belongs on provider A
    (cheap reads) and cold data on provider B (cheap storage), with real
    egress — forces a provider move on a hot->cold drift."""
    from repro.core.costs import ProviderCostTable, CostTable, \
        multi_cloud_table

    def one_tier(storage, read, egress):
        return ProviderCostTable(
            provider=f"p{storage}", egress_out_cents_gb=egress,
            table=CostTable(
                storage_cents_gb_month=np.array([storage]),
                read_cents_gb=np.array([read]),
                write_cents_gb=np.array([0.01]),
                ttfb_seconds=np.array([0.02]),
                capacity_gb=np.array([np.inf]),
                early_delete_months=np.array([0.0]),
                names=("only",)))
    return multi_cloud_table([one_tier(10.0, 0.01, 0.5),
                              one_tier(1.0, 5.0, 0.5)])


def test_empty_batch_after_provider_move_reports_zero_egress():
    """Regression (ISSUE 5): the empty-stream step must construct the same
    StreamStepReport / MigrationPlan field set as the live path — in
    particular an explicit ``egress_cents == 0.0`` right after a provider
    move, not a missing/defaulted field."""
    import dataclasses
    table = _two_provider_table()
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {"d0/0": 1.0, "d0/1": 1.0}
    eng = StreamingEngine(table, cfg, sizes, s_thresh=5.0, window=1,
                          drift_threshold=0.5)
    eng.ingest_and_reoptimize([(("d0/0", "d0/1"), 100.0)])
    mig = eng.ingest_and_reoptimize([(("d0/0", "d0/1"), 0.001)])
    assert mig.n_moved == 1 and mig.egress_cents > 0.0  # provider move paid
    live = eng.history[-1]
    # empty batches expire the window; compaction drops the dead partition
    eng.ingest_and_reoptimize([])
    empty_mig = eng.ingest_and_reoptimize([])
    assert empty_mig.plan.problem.n == 0
    rep = eng.history[-1]
    assert rep.n_partitions == 0
    assert rep.egress_cents == 0.0 and rep.migration_cents == 0.0
    # field-set parity with the live path (no defaulted/missing fields)
    assert set(dataclasses.asdict(rep)) == set(dataclasses.asdict(live))
    # the empty MigrationPlan carries the live path's arrays too
    for arr in (empty_mig.candidate, empty_mig.move_transfer_cents,
                empty_mig.move_egress_cents, empty_mig.move_penalty_cents,
                empty_mig.old_stored_gb):
        assert arr is not None and arr.shape == (0,)
    assert empty_mig.select(np.zeros(0, bool)) is empty_mig


def test_select_moves_defers_and_reproposes_next_batch():
    """A partial step keeps deferred candidates at their old placement,
    charges nothing for them, and re-proposes them next batch."""
    eng, _ = _engine(window=1, drift_threshold=np.inf)
    eng.ingest_and_reoptimize(_hot_cold_batch())
    drifted = _hot_cold_batch(hot=400.0, cold=500.0)
    mig = eng.ingest_and_reoptimize(
        drifted, select_moves=lambda m: np.zeros(m.plan.problem.n, bool))
    assert mig.n_candidates >= 1 and mig.n_moved == 0
    assert mig.migration_cents == 0.0 and mig.penalty_cents == 0.0
    assert np.array_equal(mig.new_tier, mig.old_tier)
    assert eng.history[-1].n_deferred == mig.n_candidates
    # deferred moves stay drifted (lock base kept) and execute next batch
    mig2 = eng.ingest_and_reoptimize(drifted)
    assert mig2.n_moved == mig.n_candidates
    assert eng.history[-1].n_deferred == 0


def test_stream_rho_abs_tol_stabilizes_cold_lock():
    """Epsilon accesses on a cold partition must not reset its drift-lock
    base when the absolute floor is set; without the floor every epsilon
    batch re-bases the lock (the scheme lock is defeated)."""
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(6) for j in range(4)}
    cold_files = frozenset({"d1/0", "d1/1", "d1/2"})

    def run(abs_tol):
        eng = StreamingEngine(azure_table(), cfg, sizes, s_thresh=5.0,
                              window=1, drift_threshold=np.inf,
                              rho_abs_tol=abs_tol)
        eng.ingest_and_reoptimize(_hot_cold_batch(cold=0.0))
        refs = []
        for eps in (1e-6, 3e-6, 2e-6):
            eng.ingest_and_reoptimize(_hot_cold_batch(cold=eps))
            refs.append(eng._held[cold_files][0].rho_ref)
        return refs

    # floor on: the lock base never re-bases off the original cold rate
    assert run(0.5) == [0.0, 0.0, 0.0]
    # floor off: every epsilon batch counts as drift and re-bases the lock
    assert all(r > 0.0 for r in run(0.0))


def test_sync_plan_requires_partitions_and_payloads():
    eng, _ = _engine()
    mig = eng.ingest_and_reoptimize(_hot_cold_batch())
    store = TieredStore(azure_table())
    with pytest.raises(ValueError):
        store.sync_plan(mig.plan)           # no raw_bytes, no payloads
    import dataclasses
    bad = dataclasses.replace(mig.plan.problem, partitions=None)
    with pytest.raises(ValueError):
        store.sync_plan(dataclasses.replace(mig.plan, problem=bad))


def test_sync_plan_preserves_foreign_objects():
    """sync_plan only reconciles gpart-* objects; checkpoints and manual
    puts survive."""
    eng, _ = _engine()
    mig = eng.ingest_and_reoptimize(_hot_cold_batch())
    store = TieredStore(azure_table())
    store.put("ckpt-0001", b"model", tier=1)
    parts = mig.plan.problem.partitions
    store.sync_plan(mig.plan,
                    payloads=[b"x" * 100 for _ in parts])
    assert "ckpt-0001" in store.keys()
