"""DATAPART: G-PART invariants + ordered DP vs brute force (Thms 5/6)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import datapart as dp


def _parts_from_spec(spec, rhos):
    """spec: list of file-id tuples; files auto-sized 1.0 unless suffixed."""
    all_files = sorted({f for fs in spec for f in fs})
    sizes = dp.FileSizes({f: 1.0 for f in all_files})
    return [dp.Partition(frozenset(fs), r, sizes) for fs, r in zip(spec, rhos)]


def test_overlap_and_span():
    parts = _parts_from_spec([("a", "b", "c"), ("b", "c", "d")], [1, 1])
    assert parts[0].span == 3.0
    assert dp.overlap(parts[0], parts[1]) == 2.0
    assert dp.fractional_overlap(parts[0], parts[1]) == pytest.approx(0.5)


def test_gpart_merges_full_overlap():
    parts = _parts_from_spec([("a", "b"), ("a", "b"), ("x", "y")], [5, 5, 5])
    out = dp.g_part(parts, s_thresh=100.0)
    spans = sorted(p.span for p in out)
    assert len(out) == 2 and spans == [2.0, 2.0]


def test_gpart_respects_access_feasibility():
    # wildly different access rates must not merge
    parts = _parts_from_spec([("a", "b"), ("a", "b")], [1.0, 1e6])
    out = dp.g_part(parts, s_thresh=100.0, rho_c=4.0, rho_c_abs=10.0)
    assert len(out) == 2


def test_gpart_s_thresh_stops_growth():
    spec = [(f"f{i}", f"f{i+1}") for i in range(10)]
    parts = _parts_from_spec(spec, [1.0] * 10)
    out = dp.g_part(parts, s_thresh=3.0)
    # merged nodes exceeding s_thresh must not have kept merging: every
    # result is below s_thresh + one merge step's worth of files
    assert all(p.span <= 6.0 for p in out)


def test_gpart_covers_all_files():
    rng = np.random.default_rng(0)
    spec = [tuple(f"f{j}" for j in rng.choice(20, rng.integers(1, 6),
                                              replace=False))
            for _ in range(15)]
    parts = _parts_from_spec(spec, rng.uniform(1, 5, 15))
    out = dp.g_part(parts, s_thresh=8.0)
    orig = set().union(*[p.files for p in parts])
    got = set().union(*[p.files for p in out])
    assert got == orig


def test_gpart_reduces_duplication():
    rng = np.random.default_rng(1)
    # heavily overlapping families with comparable access rates
    spec = [tuple(f"f{j}" for j in range(i, i + 6)) for i in range(12)]
    parts = _parts_from_spec(spec, rng.uniform(2, 4, 12))
    merged = dp.g_part(parts, s_thresh=30.0)
    assert dp.duplication(merged) <= dp.duplication(parts)
    assert dp.read_cost(merged) >= 0


def _ordered_parts(rng, n):
    """Time-ordered partitions: window [i, i+w) of unit files."""
    files = {f"t{i}": float(rng.uniform(0.5, 2.0)) for i in range(n + 6)}
    sizes = dp.FileSizes(files)
    parts = []
    for i in range(n):
        w = int(rng.integers(2, 5))
        parts.append(dp.Partition(frozenset(f"t{j}" for j in range(i, i + w)),
                                  float(rng.uniform(0.5, 4.0)), sizes))
    return parts


def test_ordered_dp_matches_bruteforce():
    rng = np.random.default_rng(2)
    for _ in range(6):
        parts = _ordered_parts(rng, 6)
        no_merge_cost = dp.read_cost(parts)
        c_thresh = no_merge_cost * 1.5
        exact = dp.ordered_brute_force(parts, c_thresh)
        sol = dp.ordered_dp(parts, c_thresh, n_buckets=4000)
        assert exact is not None and sol is not None
        assert sol.cost <= c_thresh * 1.01
        # discretization may round cost up; space must match exact optimum
        assert sol.space == pytest.approx(exact.space, rel=2e-2)


def test_ordered_approx_bicriteria():
    """Thm 6: space <= OPT space, cost <= (1 + N*eps) * C."""
    rng = np.random.default_rng(3)
    parts = _ordered_parts(rng, 7)
    c = dp.read_cost(parts) * 1.2
    exact = dp.ordered_brute_force(parts, c)
    approx = dp.ordered_approx(parts, c, eps=1.0 / len(parts))
    assert exact is not None and approx is not None
    assert approx.space <= exact.space + 1e-9
    assert approx.cost <= 2.0 * c * 1.01   # (1,2) bi-criteria for eps=1/N


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_gpart_rho_conservation(seed):
    """Total access mass is conserved by merging."""
    rng = np.random.default_rng(seed)
    spec = [tuple(f"f{j}" for j in rng.choice(12, rng.integers(1, 5),
                                              replace=False))
            for _ in range(8)]
    rhos = rng.uniform(0.5, 8.0, 8)
    parts = _parts_from_spec(spec, rhos)
    out = dp.g_part(parts, s_thresh=rng.uniform(2, 20))
    assert sum(p.rho for p in out) == pytest.approx(sum(rhos))


def test_merge_all_baseline():
    parts = _parts_from_spec([("a", "b"), ("b", "c")], [1, 2])
    allm = dp.merge_all(parts)
    assert len(allm) == 1 and allm[0].span == 3.0 and allm[0].rho == 3.0


# --------------------------------------------- array-native core equivalence
def _random_instance(seed, n_parts=20, n_files=40, unit=False):
    rng = np.random.default_rng(seed)
    files = [f"t/{i}" for i in range(n_files)]
    sizes = {f: 1.0 if unit else float(rng.random() * 4 + 0.25)
             for f in files}
    qf = []
    for _ in range(n_parts):
        k = int(rng.integers(1, 7))
        fs = tuple(rng.choice(files, size=k, replace=False))
        qf.append((fs, float(rng.random() * 9 + 0.5)))
    return dp.make_partitions(qf, sizes)


def _canon(parts):
    return sorted((tuple(sorted(p.files)), round(p.rho, 9)) for p in parts)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_gpart_equals_ref(seed):
    """The exact-equivalence pin: array-native g_part returns the SAME
    partitions and read_cost as the original pair-by-pair g_part_ref."""
    for unit in (True, False):
        parts = _random_instance(seed, unit=unit)
        med = float(np.median([p.span for p in parts]))
        for mult in (1.5, 3.0, 10.0):
            ref = dp.g_part_ref(parts, s_thresh=mult * med)
            arr = dp.g_part(parts, s_thresh=mult * med, backend="numpy")
            assert _canon(ref) == _canon(arr)
            assert dp.read_cost(arr) == pytest.approx(dp.read_cost(ref),
                                                      abs=1e-12)


def test_gpart_equals_ref_device_backends():
    """Candidate graphs from the jnp / pallas-interpret overlap matrix give
    the same merge result (weights are recomputed in f64 either way)."""
    parts = _random_instance(77)
    med = float(np.median([p.span for p in parts]))
    ref = dp.g_part_ref(parts, s_thresh=3.0 * med)
    for backend in ("ref", "interpret"):
        arr = dp.g_part(parts, s_thresh=3.0 * med, backend=backend)
        assert _canon(ref) == _canon(arr)


def test_gpart_sampled_read_cost_close():
    """MinHash-style sampling: fewer candidate edges, read_cost within
    1.1x of the exact merge on a moderate instance."""
    parts = _random_instance(5, n_parts=120, n_files=150)
    med = float(np.median([p.span for p in parts]))
    exact = dp.read_cost(dp.g_part(parts, s_thresh=3.0 * med))
    sampled = dp.read_cost(dp.g_part(parts, s_thresh=3.0 * med,
                                     sample=0.6, sample_seed=0))
    assert sampled <= exact * 1.1
    # rho conservation holds regardless of which edges were sampled
    tot = sum(p.rho for p in parts)
    out = dp.g_part(parts, s_thresh=3.0 * med, sample=0.3, sample_seed=1)
    assert sum(p.rho for p in out) == pytest.approx(tot)


def test_filesizes_span_memoized_and_matches_index():
    """Satellite regression: memoized FileSizes.span agrees with the
    vectorized index path to 1e-9, and repeat lookups hit the cache."""
    parts = _random_instance(11)
    fs = parts[0].sizes
    idx = dp.PartitionIndex.from_partitions(parts)
    spans = idx.span()
    for i, p in enumerate(parts):
        assert fs.span(p.files) == pytest.approx(spans[i], abs=1e-9)
    assert len(fs._span_cache) >= len({p.files for p in parts})
    cached = fs.span(parts[0].files)
    assert fs._span_cache[parts[0].files] == cached  # second hit, same value


def test_index_vectorized_metrics_agree():
    parts = _random_instance(13)
    idx = dp.PartitionIndex.from_partitions(parts)
    assert idx.read_cost() == pytest.approx(dp.read_cost(parts), abs=1e-9)
    assert idx.duplication() == pytest.approx(dp.duplication(parts),
                                              abs=1e-12)
    assert idx.fractional_overlap(0, 1) == pytest.approx(
        dp.fractional_overlap(parts[0], parts[1]), abs=1e-12)
