"""Codecs, tiered store billing, cost-model algebra, ML substrate."""

import numpy as np
import pytest

from repro.core import ml
from repro.core.costs import azure_table
from repro.storage.codecs import (available_schemes, codec_by_name,
                                  default_codecs, measure)
from repro.storage.store import TieredStore


def test_codec_roundtrip_lossless():
    raw = (b"hello world, " * 1000) + bytes(range(256)) * 10
    for c in default_codecs():
        if c.lossy:
            continue
        assert c.decompress(c.compress(raw)) == raw


def test_quant8_roundtrip_approximate():
    rng = np.random.default_rng(0)
    f = rng.normal(0, 3, 4096).astype(np.float32)
    c = codec_by_name("quant8")
    back = np.frombuffer(c.decompress(c.compress(f.tobytes())), np.float32)
    assert back.shape == f.shape
    # per-block int8: relative error bounded by block max / 127
    assert np.abs(back - f).max() < np.abs(f).max() / 100.0
    m = measure(c, f.tobytes())
    assert 3.0 < m.ratio < 4.2


def test_compressible_data_compresses():
    raw = b"abcd" * 50_000
    best = available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0]
    m = measure(codec_by_name(best), raw)
    assert m.ratio > 50


def test_store_billing_accrual():
    s = TieredStore()
    payload = b"x" * 1_000_000  # 1 MB
    s.put("a", payload, tier=1)
    s.advance_months(2.0)
    gb = len(payload) / 1e9
    assert s.meter.storage_cents == pytest.approx(gb * 2.08 * 2.0)
    s.get("a")
    assert s.meter.read_cents == pytest.approx(gb * 0.01331)
    assert s.meter.n_reads == 1


def test_store_tier_change_and_early_delete_penalty():
    s = TieredStore()
    s.put("a", b"y" * 2_000_000, tier=3)   # archive: 6-month min stay
    s.advance_months(1.0)
    before = s.meter.penalty_cents
    s.change_tier("a", 1)
    assert s.meter.penalty_cents > before   # early-deletion charge
    assert s.tier_of("a") == 1


def test_store_compression_reduces_stored_size():
    s = TieredStore()
    raw = b"z" * 500_000
    n = s.put("a", raw, tier=1,
              codec=available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0])
    assert n < len(raw) / 100
    assert s.get("a") == raw
    assert s.meter.compute_cents > 0       # decompression was metered


def test_ml_random_forest_regression():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, (300, 3))
    y = X[:, 0] ** 2 + 0.5 * X[:, 1] + 0.1 * rng.normal(size=300)
    m = ml.RandomForest(n_trees=15, max_depth=8).fit(X[:200], y[:200])
    assert ml.r2(y[200:], m.predict(X[200:])) > 0.85


def test_ml_mlp_regression():
    rng = np.random.default_rng(1)
    X = rng.uniform(-2, 2, (400, 2))
    y = np.sin(X[:, 0]) + X[:, 1]
    m = ml.MLP(hidden=(32, 32), epochs=300).fit(X[:300], y[:300])
    assert ml.r2(y[300:], m.predict(X[300:])) > 0.9


def test_ml_classifier_and_metrics():
    rng = np.random.default_rng(2)
    X = rng.normal(0, 1, (400, 4))
    y = (X[:, 0] + X[:, 1] > 0).astype(int)
    m = ml.RandomForest(n_trees=20, max_depth=6, task="clf", n_classes=2)
    m.fit(X[:300], y[:300])
    pred = m.predict(X[300:])
    assert ml.f1_binary(y[300:], pred) > 0.85
    conf = ml.confusion(y[300:], pred, 2)
    assert conf.sum() == 100


def test_kernel_ridge():
    rng = np.random.default_rng(3)
    X = rng.uniform(-1, 1, (200, 2))
    y = X[:, 0] * X[:, 1]
    m = ml.KernelRidge(alpha=1e-3).fit(X[:150], y[:150])
    assert ml.r2(y[150:], m.predict(X[150:])) > 0.8


# ------------------------------------------------- atomicity / validation
def _meter_sig(s):
    m = s.meter
    return (m.storage_cents, m.read_cents, m.write_cents, m.penalty_cents,
            m.egress_cents, m.n_reads, m.n_writes)


def test_put_checksum_mismatch_bills_and_mutates_nothing():
    s = TieredStore()
    from repro.storage.store import ChecksumError
    with pytest.raises(ChecksumError):
        s.put("a", b"x" * 1000, tier=1, expect_checksum="0" * 64)
    assert not s.has("a") and _meter_sig(s) == _meter_sig(TieredStore())


def test_replace_survives_kill_between_delete_and_put():
    """Regression for the partial-failure billing bug: a re-encode that dies
    after the delete half must NOT leave the early-delete penalty billed
    with the source gone. ``replace`` commits delete+put+egress in one
    locked step, so a checksum failure leaves object and meter untouched."""
    from repro.storage.store import ChecksumError
    s = TieredStore()
    raw = b"y" * 2_000_000
    s.put("a", raw, tier=3)            # archive: 6-month minimum stay
    s.advance_months(1.0)
    sig, tier, pay = _meter_sig(s), s.tier_of("a"), s.get("a")
    sig = _meter_sig(s)                # include the get we just billed
    with pytest.raises(ChecksumError):
        s.replace("a", raw, new_tier=1, codec="zlib-1",
                  expect_checksum="f" * 64)
    assert _meter_sig(s) == sig        # no penalty, no write, no egress
    assert s.tier_of("a") == tier and s.codec_of("a") == "none"
    assert s.get("a") == pay


def test_replace_survives_compress_failure(monkeypatch):
    """Same contract when the put half itself dies (codec blows up):
    nothing billed, source object intact."""
    import repro.storage.store as store_mod
    s = TieredStore()
    s.put("a", b"z" * 500_000, tier=3)
    s.advance_months(0.5)
    sig = _meter_sig(s)

    class _Boom:
        def compress(self, raw):
            raise RuntimeError("codec died mid-flight")

    monkeypatch.setattr(store_mod, "codec_by_name", lambda name: _Boom())
    with pytest.raises(RuntimeError):
        s.replace("a", b"z" * 500_000, new_tier=1, codec="zlib-1")
    monkeypatch.undo()
    assert _meter_sig(s) == sig
    assert s.tier_of("a") == 3 and s.get("a") == b"z" * 500_000


def _store_plan():
    from repro.core.engine import (CompressStage, PartitionedData,
                                   PlacementEngine, ScopeConfig)
    raws = [bytes([65 + i]) * (200_000 + 50_000 * i) for i in range(4)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), months=2.0)
    eng = PlacementEngine(azure_table(), cfg)
    data = PartitionedData(
        partitions=[None] * 4, tables=[None] * 4, raw_bytes=raws,
        spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 40.0, 0.02, 800.0]))
    return eng, eng.solve(CompressStage(cfg)(data, azure_table()))


def test_plan_ops_validate_shapes_before_mutating():
    """Wrong-length keys/payloads and unknown keys raise ValueError with
    the store bit-for-bit untouched — no half-applied plans."""
    eng, plan = _store_plan()
    s = TieredStore(eng.table)
    with pytest.raises(ValueError, match="keys has 1 entries"):
        s.apply_plan(plan, keys=["only-one"])
    assert len(list(s.keys())) == 0 and _meter_sig(s) == _meter_sig(
        TieredStore(eng.table))
    keys = s.apply_plan(plan)
    s.advance_months(2.0)
    rho2 = plan.problem.rho.copy()
    rho2[0] *= 5000.0
    rho2[3] /= 5000.0
    mig = eng.reoptimize(plan, rho2, months_held=2.0)
    assert mig.n_moved >= 1
    sig = _meter_sig(s)
    tiers = {k: s.tier_of(k) for k in keys}
    with pytest.raises(ValueError, match="keys has 2 entries"):
        s.migrate(mig, keys[:2])
    with pytest.raises(ValueError, match="unknown object keys"):
        s.migrate(mig, ["ghost"] + keys[1:])
    with pytest.raises(ValueError, match="payloads has 1 entries"):
        s.sync_plan(mig.plan, payloads=[b"x"])
    assert _meter_sig(s) == sig
    assert {k: s.tier_of(k) for k in keys} == tiers
