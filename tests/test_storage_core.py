"""Codecs, tiered store billing, cost-model algebra, ML substrate."""

import numpy as np
import pytest

from repro.core import ml
from repro.core.costs import azure_table
from repro.storage.codecs import (available_schemes, codec_by_name,
                                  default_codecs, measure)
from repro.storage.store import TieredStore


def test_codec_roundtrip_lossless():
    raw = (b"hello world, " * 1000) + bytes(range(256)) * 10
    for c in default_codecs():
        if c.lossy:
            continue
        assert c.decompress(c.compress(raw)) == raw


def test_quant8_roundtrip_approximate():
    rng = np.random.default_rng(0)
    f = rng.normal(0, 3, 4096).astype(np.float32)
    c = codec_by_name("quant8")
    back = np.frombuffer(c.decompress(c.compress(f.tobytes())), np.float32)
    assert back.shape == f.shape
    # per-block int8: relative error bounded by block max / 127
    assert np.abs(back - f).max() < np.abs(f).max() / 100.0
    m = measure(c, f.tobytes())
    assert 3.0 < m.ratio < 4.2


def test_compressible_data_compresses():
    raw = b"abcd" * 50_000
    best = available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0]
    m = measure(codec_by_name(best), raw)
    assert m.ratio > 50


def test_store_billing_accrual():
    s = TieredStore()
    payload = b"x" * 1_000_000  # 1 MB
    s.put("a", payload, tier=1)
    s.advance_months(2.0)
    gb = len(payload) / 1e9
    assert s.meter.storage_cents == pytest.approx(gb * 2.08 * 2.0)
    s.get("a")
    assert s.meter.read_cents == pytest.approx(gb * 0.01331)
    assert s.meter.n_reads == 1


def test_store_tier_change_and_early_delete_penalty():
    s = TieredStore()
    s.put("a", b"y" * 2_000_000, tier=3)   # archive: 6-month min stay
    s.advance_months(1.0)
    before = s.meter.penalty_cents
    s.change_tier("a", 1)
    assert s.meter.penalty_cents > before   # early-deletion charge
    assert s.tier_of("a") == 1


def test_store_compression_reduces_stored_size():
    s = TieredStore()
    raw = b"z" * 500_000
    n = s.put("a", raw, tier=1,
              codec=available_schemes(("zstd-3", "zlib-6", "zlib-1"))[0])
    assert n < len(raw) / 100
    assert s.get("a") == raw
    assert s.meter.compute_cents > 0       # decompression was metered


def test_ml_random_forest_regression():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, (300, 3))
    y = X[:, 0] ** 2 + 0.5 * X[:, 1] + 0.1 * rng.normal(size=300)
    m = ml.RandomForest(n_trees=15, max_depth=8).fit(X[:200], y[:200])
    assert ml.r2(y[200:], m.predict(X[200:])) > 0.85


def test_ml_mlp_regression():
    rng = np.random.default_rng(1)
    X = rng.uniform(-2, 2, (400, 2))
    y = np.sin(X[:, 0]) + X[:, 1]
    m = ml.MLP(hidden=(32, 32), epochs=300).fit(X[:300], y[:300])
    assert ml.r2(y[300:], m.predict(X[300:])) > 0.9


def test_ml_classifier_and_metrics():
    rng = np.random.default_rng(2)
    X = rng.normal(0, 1, (400, 4))
    y = (X[:, 0] + X[:, 1] > 0).astype(int)
    m = ml.RandomForest(n_trees=20, max_depth=6, task="clf", n_classes=2)
    m.fit(X[:300], y[:300])
    pred = m.predict(X[300:])
    assert ml.f1_binary(y[300:], pred) > 0.85
    conf = ml.confusion(y[300:], pred, 2)
    assert conf.sum() == 100


def test_kernel_ridge():
    rng = np.random.default_rng(3)
    X = rng.uniform(-1, 1, (200, 2))
    y = X[:, 0] * X[:, 1]
    m = ml.KernelRidge(alpha=1e-3).fit(X[:150], y[:150])
    assert ml.r2(y[150:], m.predict(X[150:])) > 0.8
